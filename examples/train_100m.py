"""End-to-end driver: train a ~100M-param Mixtral-family MoE for a few
hundred steps with checkpoint/restart (deliverable b).

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""

import argparse
import dataclasses
import sys

from repro.launch import train as train_cli
from repro.models import registry
from repro.models.config import LayerSpec, ModelConfig
from repro.models.registry import register


@register("mixtral-100m")
def mixtral_100m() -> ModelConfig:
    # ~100M params: 4L, d=512, 8 experts of ff=1792, vocab 32000
    return ModelConfig(
        name="mixtral-100m", family="moe", n_layers=4, d_model=512,
        n_heads=8, n_kv_heads=2, d_ff=1792, d_ff_expert=1792,
        vocab_size=32000, pattern=(LayerSpec(mixer="attn", ffn="moe"),),
        n_experts=8, top_k=2, rope_theta=1e6)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt_100m")
    args = ap.parse_args()
    n = registry.exact_param_count(registry.get_config("mixtral-100m"))
    print(f"mixtral-100m: {n/1e6:.1f}M params")
    return train_cli.main([
        "--arch", "mixtral-100m", "--steps", str(args.steps),
        "--batch", "4", "--seq", "256", "--mesh", "1x1",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    sys.exit(main())
