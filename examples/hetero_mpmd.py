"""Paper-faithful heterogeneous run: disaggregated attention/expert groups
(zebra MPMD engine) with Asym-EA offload, vs the fused baseline.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/hetero_mpmd.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import hardware as HW
from repro.core.planner import plan_zp_group
from repro.core.profiler import ZPGroupShape
from repro.core.zebra_mpmd import ZebraMPMD
from repro.models import registry, stack
from repro.models.modules import Policy, RunConfig
from repro.pytree import split_params


def main():
    cfg = registry.smoke_config(registry.get_config("mixtral-w1"))
    cfg = dataclasses.replace(cfg, n_layers=4, capacity_factor=8.0)
    run = RunConfig(policy=Policy(compute_dtype=jnp.float32),
                    moe_impl="gather")

    # Plan the ZP group as if on A40+V100 (paper's O-testbed classes).
    zp = ZPGroupShape(M=4, N=4, attn_class=HW.A40, exp_class=HW.V100)
    plan = plan_zp_group(registry.get_config("mixtral-w1"), zp,
                         global_batch=16, seq_len=4096)
    print(f"planned R={plan.R} offload={plan.offload} "
          f"iter={plan.predicted.iter_time*1e3:.1f}ms "
          f"(no-asym {plan.predicted_no_asym.iter_time*1e3:.1f}ms)")

    devs = jax.devices()
    eng = ZebraMPMD(cfg, run, attn_devices=devs[:4], exp_devices=devs[4:8],
                    num_microbatches=2,
                    offload=tuple(min(o, cfg.n_experts // 2)
                                  for o in plan.offload[:cfg.n_layers]))
    params, _ = split_params(stack.init_model(jax.random.PRNGKey(0), cfg))
    attn_side, exp_layers = eng.shard_params(params)
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (8, 64), 0, cfg.vocab_size)
    targets = jax.random.randint(jax.random.fold_in(key, 1), (8, 64), 0,
                                 cfg.vocab_size)
    loss, ga, ge = eng.train_step(attn_side, exp_layers, tokens, targets)
    print(f"disaggregated loss: {float(loss):.4f}")
    print("MPMD hetero example OK")


if __name__ == "__main__":
    main()
