"""Quickstart: train a reduced Mixtral-architecture MoE with zebra
parallelism on emulated devices, then greedy-decode from it.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.zebra_spmd import ZebraConfig
from repro.data import DataConfig, DataLoader
from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.models.config import ShapeConfig
from repro.models.modules import Policy, RunConfig
from repro.train.step import make_train_program
from repro.train import optimizer as opt


def main():
    n = jax.device_count()
    dm = {1: (1, 1), 2: (1, 2), 4: (2, 2), 8: (2, 4)}.get(n, (1, n))
    mesh = make_mesh(dm, ("data", "model"))
    cfg = registry.smoke_config(registry.get_config("mixtral-d2"))
    run = RunConfig(policy=Policy(compute_dtype=jnp.float32),
                    attn_impl="ref", moe_impl="gather")
    shape = ShapeConfig("quickstart", "train", seq_len=128, global_batch=8)
    program = make_train_program(
        cfg, mesh, run, shape,
        opt_cfg=opt.OptimizerConfig(peak_lr=1e-3, warmup_steps=10,
                                    total_steps=60),
        zcfg=ZebraConfig(mode="replicated", num_microbatches=2))
    loader = DataLoader(DataConfig(cfg.vocab_size, 128, 8))

    with mesh:
        params = program.init_params()
        opt_state = program.init_opt(params)
    first = last = None
    for step in range(60):
        with mesh:
            params, opt_state, metrics = program.train_step(
                params, opt_state, next(loader))
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
        if (step + 1) % 10 == 0:
            print(f"step {step+1:3d} loss {last:.4f}")
    assert last < first, "loss must decrease"
    print(f"quickstart OK: loss {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
