"""Continuous-batching serving example: Poisson trace, chunked prefill,
slot recycling (deliverable b, serving flavour).

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/serve_batched.py
"""

import jax
import sys

from repro.launch import serve as serve_cli


def main():
    n = jax.device_count()
    mesh = {1: "1x1", 2: "1x2", 4: "2x2", 8: "2x4"}.get(n, f"1x{n}")
    return serve_cli.main([
        "--arch", "qwen3-moe-30b-a3b", "--smoke", "--slots", "4",
        "--requests", "6", "--prompt-len", "32", "--gen", "16",
        "--prefill-chunk", "8", "--mesh", mesh,
    ])


if __name__ == "__main__":
    sys.exit(main())
