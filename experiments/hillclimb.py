"""§Perf hillclimb driver: re-lower selected cells under candidate changes
and diff the roofline terms against the paper-faithful baseline.

    PYTHONPATH=src python experiments/hillclimb.py dbrx-132b train_4k \
        baseline bf16_params zebra_r8 ...

Each variant is one hypothesis from the EXPERIMENTS.md §Perf log.
"""

import json
import sys
import time


VARIANTS = {
    # paper-faithful baseline: EP sharding, alltoall dispatch, full remat,
    # f32 master params
    "baseline": {},
    # store params bf16, f32 master in ZeRO-sharded opt state
    "bf16_params": {"param_dtype": "bfloat16"},
    # TPU-hybrid zebra: TP attention + EP experts, R=8 microbatch pipeline
    "zebra_r8": {"zebra_mode": "replicated", "microbatches": 8},
    "zebra_r8_bf16": {"zebra_mode": "replicated", "microbatches": 8,
                      "param_dtype": "bfloat16"},
    # remat policy: save dot outputs instead of full recompute
    "remat_dots": {"remat": "dots"},
    # reduce-scatter gradients into the param layout (vs full all-reduce)
    "grad_rs": {"constrain_grads": True},
    "grad_rs_bf16": {"constrain_grads": True, "param_dtype": "bfloat16"},
    "zebra_r8_grs_bf16": {"zebra_mode": "replicated", "microbatches": 8,
                          "param_dtype": "bfloat16",
                          "constrain_grads": True},
    "remat_none": {"remat": "none"},
    # replicated-bf16 embedding gather + batch-sharded xent chunk stream
    "embed_repl": {"embed_mode": "replicated"},
    "embed_repl_dots": {"embed_mode": "replicated", "remat": "dots"},
    "best_dbrx": {"embed_mode": "replicated", "remat": "dots",
                  "param_dtype": "bfloat16"},
    # larger attention query chunks (fewer K/V re-reads in chunked attn)
    "chunk2048": {"chunk_q": 2048},
    "chunk1024": {"chunk_q": 1024},
    "chunk2048_bf16": {"chunk_q": 2048, "param_dtype": "bfloat16"},
    "combo": {"zebra_mode": "replicated", "microbatches": 8,
              "param_dtype": "bfloat16", "chunk_q": 1024},
    # dropless-leaning capacity (1.0): -20% expert FLOPs + smaller buffers
    "cap1_dots_bf16": {"capacity_factor": 1.0, "remat": "dots",
                       "param_dtype": "bfloat16"},
    "cap1": {"capacity_factor": 1.0},
}


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    variants = sys.argv[3:] or ["baseline"]
    from repro.launch.dryrun import lower_cell
    rows = []
    for v in variants:
        kw = VARIANTS[v]
        t0 = time.time()
        try:
            rec = lower_cell(arch, shape, multi_pod=False, **kw)
        except Exception as e:
            rec = {"status": f"FAIL {type(e).__name__}: {e}"}
        rec["variant"] = v
        rec["wall_s"] = round(time.time() - t0, 1)
        rows.append(rec)
        print(json.dumps(rec), flush=True)

    print(f"\n== {arch} x {shape} ==")
    print(f"{'variant':18s} {'t_comp':>8s} {'t_mem':>8s} {'t_coll':>8s} "
          f"{'t_ring':>8s} {'bound':>10s} {'mfu_bound':>9s} {'temp_GB':>8s} "
          f"fits")
    for r in rows:
        if r.get("status") != "ok":
            print(f"{r['variant']:18s} {r.get('status', '?')[:50]}")
            continue
        print(f"{r['variant']:18s} {r['t_compute_s']:8.3f} "
              f"{r['t_memory_s']:8.3f} {r['t_collective_s']:8.3f} "
              f"{r.get('t_collective_ring_s', 0):8.3f} "
              f"{r['bound']:>10s} {r['mfu_bound']:9.4f} "
              f"{r['temp_bytes_per_device'] / 1e9:8.1f} "
              f"{'Y' if r['fits_16gb'] else 'N'}")


if __name__ == "__main__":
    main()
