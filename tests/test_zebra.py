"""Zebra parallelism engines: SPMD (sharded EP + microbatch pipeline) and
MPMD (disaggregated two-mesh) vs the fused single-program reference."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import zebra_spmd as Z
from repro.core.zebra_mpmd import ZebraMPMD
from repro.models import modules, registry, stack
from repro.models.modules import Policy, RunConfig
from repro.pytree import split_params

pytestmark = pytest.mark.zebra  # CI job slice (see .github/workflows/ci.yml)

RUN = RunConfig(policy=Policy(compute_dtype=jnp.float32), moe_impl="gather")
KEY = jax.random.PRNGKey(0)


def moe_cfg(arch="qwen3-moe-30b-a3b", cap=99.0, **kw):
    cfg = registry.smoke_config(registry.get_config(arch))
    return dataclasses.replace(cfg, capacity_factor=cap, **kw)


@pytest.mark.parametrize("use_gmm_kernel", [False, True])
@pytest.mark.parametrize("mode", ["replicated", "alltoall"])
def test_ep_moe_matches_oracle(mesh8, mode, use_gmm_kernel):
    cfg = moe_cfg()
    run = dataclasses.replace(RUN, use_gmm_kernel=use_gmm_kernel)
    ffn, _ = split_params(modules.init_moe(KEY, cfg))
    x = jax.random.normal(KEY, (8, 16, cfg.d_model)) * 0.3
    y_ref, _ = modules.apply_moe(ffn, cfg, RUN, x)
    with mesh8:
        zcfg = Z.ZebraConfig(mode=mode, capacity_factor=99.0,
                             batch_axes=("data",) if mode == "replicated"
                             else ("data", "model"))
        moe_fn = Z.make_ep_moe(mesh8, cfg, run, zcfg)
        y, _ = jax.jit(moe_fn)(ffn, x.reshape(-1, cfg.d_model))
    np.testing.assert_allclose(y.reshape(x.shape), y_ref, atol=1e-4)


def test_ep_moe_capacity_drops_tokens(mesh8):
    """With capacity_factor ~ 0, outputs collapse toward zero (all dropped),
    never NaN — the GShard drop semantics."""
    cfg = moe_cfg(cap=0.01)
    ffn, _ = split_params(modules.init_moe(KEY, cfg))
    x = jax.random.normal(KEY, (8, 16, cfg.d_model))
    with mesh8:
        zcfg = Z.ZebraConfig(mode="replicated", capacity_factor=0.01,
                             batch_axes=("data",))
        moe_fn = Z.make_ep_moe(mesh8, cfg, RUN, zcfg)
        y, _ = jax.jit(moe_fn)(ffn, x.reshape(-1, cfg.d_model))
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("R", [1, 2, 4])
def test_zebra_pipeline_matches_fused(mesh8, R):
    cfg = moe_cfg()
    params, _ = split_params(stack.init_model(KEY, cfg))
    tokens = jax.random.randint(KEY, (8, 32), 0, cfg.vocab_size)
    want, _, _ = stack.apply_model(params, cfg, RUN, tokens)
    with mesh8:
        zcfg = Z.ZebraConfig(num_microbatches=R, mode="replicated",
                             capacity_factor=99.0, batch_axes=("data",))
        override = Z.make_layer_override(mesh8, cfg, RUN, zcfg)
        got = jax.jit(lambda p, t: stack.apply_model(
            p, cfg, RUN, t, layer_override=override)[0])(params, tokens)
    np.testing.assert_allclose(got, want, atol=2e-3)


def test_zebra_grads_match_fused(mesh8):
    cfg = moe_cfg()
    params, _ = split_params(stack.init_model(KEY, cfg))
    tokens = jax.random.randint(KEY, (8, 32), 0, cfg.vocab_size)

    def loss(p, override=None):
        lg, _, _ = stack.apply_model(p, cfg, RUN, tokens,
                                     layer_override=override)
        return jnp.mean(lg ** 2)

    g_ref = jax.grad(loss)(params)
    with mesh8:
        zcfg = Z.ZebraConfig(num_microbatches=4, mode="replicated",
                             capacity_factor=99.0, batch_axes=("data",))
        override = Z.make_layer_override(mesh8, cfg, RUN, zcfg)
        g = jax.jit(jax.grad(lambda p: loss(p, override)))(params)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g, g_ref)))
    assert err < 1e-3, err


# ---------------------------------------------------------------------------
# MPMD (disaggregated) engine
# ---------------------------------------------------------------------------

def _fused_loss_and_grads(cfg, params, tokens, targets):
    def loss(p):
        lg, _, _ = stack.apply_model(p, cfg, RUN, tokens)
        logp = jax.nn.log_softmax(lg, axis=-1)
        return jnp.mean(-jnp.take_along_axis(
            logp, targets[..., None], axis=-1)[..., 0])
    return jax.value_and_grad(loss)(params)


@pytest.mark.parametrize("offload,n_chunks",
                         [(None, 1), ((1, 0), 1), (None, 2), ((1, 0), 2)])
def test_mpmd_engine_matches_fused(offload, n_chunks):
    cfg = moe_cfg("mixtral-w1", n_layers=2)
    params, _ = split_params(stack.init_model(KEY, cfg))
    tokens = jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size)
    targets = jax.random.randint(jax.random.fold_in(KEY, 1), (4, 16), 0,
                                 cfg.vocab_size)
    loss_ref, g_ref = _fused_loss_and_grads(cfg, params, tokens, targets)

    devs = jax.devices()
    eng = ZebraMPMD(cfg, RUN, attn_devices=devs[:2], exp_devices=devs[2:6],
                    num_microbatches=2, offload=offload, n_chunks=n_chunks)
    attn_side, exp_layers = eng.shard_params(params)
    loss, ga, ge = eng.train_step(attn_side, exp_layers, tokens, targets)
    assert abs(float(loss) - float(loss_ref)) < 1e-5

    # reassemble expert grads and compare layer 0
    l = 0
    n_att = eng.plan.n_attn_experts(l)
    ref_blk = jax.tree.map(lambda x: x[l], g_ref["blocks"]["pos0"])
    np.testing.assert_allclose(ga["layers"][l]["mixer"]["wq"],
                               ref_blk["mixer"]["wq"], atol=1e-4)
    np.testing.assert_allclose(ga["layers"][l]["ffn"]["router"],
                               ref_blk["ffn"]["router"], atol=1e-4)
    np.testing.assert_allclose(ge[l]["wi_gate"],
                               ref_blk["ffn"]["wi_gate"][n_att:], atol=1e-4)
    if n_att:
        np.testing.assert_allclose(ga["layers"][l]["ffn"]["wi_gate"],
                                   ref_blk["ffn"]["wi_gate"][:n_att],
                                   atol=1e-4)
    np.testing.assert_allclose(ga["embed"]["table"],
                               g_ref["embed"]["table"], atol=1e-4)


def test_mpmd_expert_params_live_on_expert_mesh():
    cfg = moe_cfg("mixtral-w1", n_layers=2)
    params, _ = split_params(stack.init_model(KEY, cfg))
    devs = jax.devices()
    eng = ZebraMPMD(cfg, RUN, attn_devices=devs[:2], exp_devices=devs[2:6],
                    num_microbatches=1)
    attn_side, exp_layers = eng.shard_params(params)
    exp_devices = {d for leaf in jax.tree.leaves(exp_layers)
                   for d in leaf.devices()}
    assert exp_devices <= set(devs[2:6])
    attn_devices = {d for leaf in jax.tree.leaves(attn_side["layers"][0])
                    for d in leaf.devices()}
    assert attn_devices <= set(devs[:2])
