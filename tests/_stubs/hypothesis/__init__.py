"""Deterministic fallback for the `hypothesis` API surface this repo uses.

Activated by tests/conftest.py ONLY when the real hypothesis package is not
installed (this container has no network access for pip). It implements just
the subset the test-suite imports — ``given``, ``settings`` and the
``integers`` / ``floats`` / ``sampled_from`` / ``lists`` / ``booleans`` /
``just`` / ``tuples`` strategies — with a seeded RNG so runs are
reproducible. Example 0 draws every strategy's minimum and example 1 its
maximum, so boundary cases (empty groups, zero offload, ...) are always
exercised; the remaining examples are uniform draws.

If hypothesis is ever installed (see requirements-dev.txt) the real package
shadows this stub automatically.
"""

from __future__ import annotations

import functools
import inspect
import random

__version__ = "0.0-repro-stub"


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng, phase):
        """phase 0 -> minimal example, 1 -> maximal, else random."""
        return self._draw(rng, phase)


class strategies:  # namespace mirroring `hypothesis.strategies`
    @staticmethod
    def integers(min_value, max_value):
        def draw(rng, phase):
            if phase == 0:
                return min_value
            if phase == 1:
                return max_value
            return rng.randint(min_value, max_value)
        return _Strategy(draw)

    @staticmethod
    def floats(min_value, max_value, **_kw):
        def draw(rng, phase):
            if phase == 0:
                return float(min_value)
            if phase == 1:
                return float(max_value)
            return rng.uniform(min_value, max_value)
        return _Strategy(draw)

    @staticmethod
    def booleans():
        def draw(rng, phase):
            if phase in (0, 1):
                return bool(phase)
            return rng.random() < 0.5
        return _Strategy(draw)

    @staticmethod
    def just(value):
        return _Strategy(lambda rng, phase: value)

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)

        def draw(rng, phase):
            if phase == 0:
                return elements[0]
            if phase == 1:
                return elements[-1]
            return rng.choice(elements)
        return _Strategy(draw)

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng, phase):
            if phase == 0:
                size = min_size
            elif phase == 1:
                size = max_size
            else:
                size = rng.randint(min_size, max_size)
            return [elements.draw(rng, phase) for _ in range(size)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*strats):
        return _Strategy(
            lambda rng, phase: tuple(s.draw(rng, phase) for s in strats))


st = strategies


class settings:
    """Decorator factory: records max_examples on the given-wrapped test."""

    def __init__(self, max_examples=10, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_max_examples = self.max_examples
        return fn


def given(*arg_strats, **kw_strats):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", 10)
            rng = random.Random(0xC0FFEE)
            for i in range(n):
                ex_args = [s.draw(rng, i) for s in arg_strats]
                ex_kw = {k: s.draw(rng, i) for k, s in kw_strats.items()}
                try:
                    fn(*args, *ex_args, **kwargs, **ex_kw)
                except _Unsatisfied:
                    continue
        # Hide the test's own parameters from pytest's fixture resolution
        # (they are supplied by the strategies, exactly as real hypothesis
        # does by exposing a parameterless wrapper).
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return decorate


def assume(condition):
    """Weak `assume`: abandons only the enclosing check, like hypothesis."""
    if not condition:
        raise _Unsatisfied()


class _Unsatisfied(Exception):
    pass


class HealthCheck:  # accepted but unused (settings(suppress_health_check=..))
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
