"""Paged KV-cache serving (DESIGN.md §9).

Covers the block allocator invariants (no page shared by two live
requests, all-or-nothing allocation, copy-free recycle), the page-indexed
cache scatter/gather in ``apply_attention``, the Pallas paged decode
kernel vs the XLA gather fallback vs a dense oracle, chunked == whole
prefill THROUGH page tables, greedy parity of the paged engine against
the dense-cache reference engine (token-exact at temperature 0), slot
recycling under paging (the PR-2 no-leak contract, now with zero device
traffic on free), preemption-requeue determinism (sampler keys unchanged
after requeue), and the slot-lift acceptance: at equal simulated HBM the
paged engine sustains >= 1.5x the reservation engine's slot count.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.launch.mesh import make_mesh
from repro.launch.serve import build_trace
from repro.models import modules, registry, stack
from repro.models.config import LayerSpec, ModelConfig
from repro.models.modules import Policy, RunConfig
from repro.pytree import split_params
from repro.serve import (BlockAllocator, ContinuousBatchingEngine, GREEDY,
                         Request, SamplingParams, Scheduler, ServeMetrics,
                         make_continuous_program, pages_for)

pytestmark = pytest.mark.serve  # CI job slice (see .github/workflows/ci.yml)

RUN = RunConfig(policy=Policy(compute_dtype=jnp.float32), attn_impl="ref",
                moe_impl="gather")

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64)


@pytest.fixture(scope="module")
def mesh1():
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def tiny_params():
    return split_params(stack.init_model(jax.random.PRNGKey(0), TINY))[0]


def _prompt(seed, n, vocab=64):
    return np.random.RandomState(seed).randint(0, vocab, size=(n,)).tolist()


def _ref_greedy(params, cfg, run, prompt, n, eos=None):
    seq = jnp.asarray(prompt, jnp.int32)[None]
    out = []
    for _ in range(n):
        logits, _, _ = stack.apply_model(params, cfg, run, seq)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        if eos is not None and nxt == eos:
            break
        seq = jnp.concatenate([seq, jnp.asarray([[nxt]], jnp.int32)], 1)
    return out


def _paged_engine(cfg, mesh, params, *, n_slots, max_len, page_size=8,
                  n_pages=None, prefill_chunk=6, **eng_kw):
    prog = make_continuous_program(cfg, mesh, RUN, n_slots=n_slots,
                                   max_len=max_len, page_size=page_size,
                                   n_pages=n_pages)
    with mesh:
        p = jax.device_put(params, prog.param_shardings)
    alloc = BlockAllocator(prog.n_pages, prog.page_size, prog.max_pages)
    sched = Scheduler(n_slots, max_len, prefill_chunk=prefill_chunk,
                      allocator=alloc)
    return ContinuousBatchingEngine(prog, p, sched, **eng_kw)


# ---------------------------------------------------------------------------
# Block allocator (host-side, no jax)
# ---------------------------------------------------------------------------

def test_allocator_no_sharing_and_all_or_nothing():
    a = BlockAllocator(n_pages=6, page_size=8, max_pages_per_seq=4)
    assert a.pages_for(1) == 1 and a.pages_for(8) == 1 and a.pages_for(9) == 2
    assert a.allocate(0, 17)  # 3 pages
    assert a.allocate(1, 20)  # 3 pages
    a.check()
    assert a.n_free == 0 and a.pages_in_use == 6
    # all-or-nothing: a failing allocate/extend changes nothing
    assert not a.allocate(2, 1)
    assert not a.extend(0)
    a.check()
    assert 2 not in a.tables and a.n_free == 0
    # per-seq table bound binds even with free pages
    a.free(1)
    assert a.n_free == 3
    assert a.extend(0)  # 4th page — at the per-seq cap
    assert not a.extend(0)  # 5th would exceed max_pages_per_seq
    a.check()
    # copy-free recycle: free returns every page exactly once
    a.free(0)
    a.check()
    assert a.n_free == 6 and not a.tables
    # covers/n_lines track the owned frontier
    assert a.allocate(7, 10)
    assert a.covers(7, 15) and not a.covers(7, 16)
    assert a.n_lines(7) == 16
    t = a.table(7, pad_to=4)
    assert t.shape == (4,) and (t[:2] >= 0).all() and (t[2:] == -1).all()


def test_allocator_fits_pool_guard():
    a = BlockAllocator(n_pages=4, page_size=8, max_pages_per_seq=4)
    assert a.fits_pool(32) and not a.fits_pool(33)
    sched = Scheduler(1, max_len=64, prefill_chunk=8, allocator=a)
    with pytest.raises(ValueError):
        sched.submit(Request(rid=0, prompt=_prompt(0, 40),
                             max_new_tokens=8))  # 48 lines > 32-line pool
    assert sched.n_rejected == 1


def test_no_page_shared_across_live_requests_during_trace(mesh1,
                                                          tiny_params):
    """Drive a tight-pool trace tick by tick and assert the allocator's
    exactly-once page ownership invariant at every step."""
    eng = _paged_engine(TINY, mesh1, tiny_params, n_slots=2, max_len=32,
                        n_pages=6)
    reqs = [Request(rid=i, prompt=_prompt(i, 9 + i), max_new_tokens=8)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    alloc = eng.sched.allocator
    while eng.sched.has_work() or eng._active.any():
        eng.tick()
        alloc.check()  # no page owned twice, none leaked
        # live page tables on the device side mirror the allocator
        for slot in np.nonzero(eng._active)[0]:
            rid = int(eng._rid[slot])
            np.testing.assert_array_equal(
                eng._ptab[slot], alloc.table(rid, eng.p.max_pages))
        assert eng.tick_count < 500
    assert alloc.pages_in_use == 0  # everything returned on finish


# ---------------------------------------------------------------------------
# Page-indexed cache scatter (apply_attention paged paths)
# ---------------------------------------------------------------------------

def test_paged_decode_write_matches_table_and_drops_dead():
    p, _ = split_params(modules.init_attention(jax.random.PRNGKey(1), TINY))
    x = jnp.asarray(np.random.RandomState(0).randn(3, 1, TINY.d_model),
                    jnp.float32)
    # slot 0 at position 9 (page 1, line 1), slot 1 dead, slot 2 at
    # position 3 (page 0, line 3); tables point into a 5-page pool.
    pt = jnp.asarray([[4, 2, -1], [-1, -1, -1], [0, -1, -1]], jnp.int32)
    pos = jnp.asarray([[9], [-1], [3]], jnp.int32)
    cache = modules.init_paged_attention_cache(TINY, 5, 8, jnp.float32)
    _, c = modules.apply_attention(p, TINY, RUN, x, pos, causal=True,
                                   cache=cache,
                                   cache_index=jnp.asarray([9, -1, 3],
                                                           jnp.int32),
                                   page_table=pt)
    assert int(c["pos"][2, 1]) == 9   # slot 0: page_table[0][1]=2 -> page 2
    assert int(c["pos"][0, 3]) == 3   # slot 2: page 0, line 3
    written = {(2, 1), (0, 3)}
    expect = np.full((5, 8), -1)
    for pg, ln in written:
        expect[pg, ln] = c["pos"][pg, ln]
    np.testing.assert_array_equal(np.asarray(c["pos"]), expect)


def test_paged_kernel_matches_xla_fallback_and_oracle():
    rng = np.random.RandomState(0)
    B, H, KH, hd, P, ps, MP = 3, 4, 2, 16, 10, 8, 4
    q = jnp.asarray(rng.randn(B, H, hd), jnp.float32)
    kp = jnp.asarray(rng.randn(P, ps, KH, hd), jnp.float32)
    vp = jnp.asarray(rng.randn(P, ps, KH, hd), jnp.float32)
    pt = jnp.asarray([[3, 7, 1, -1], [0, -1, -1, -1], [5, 2, -1, -1]],
                     jnp.int32)
    q_pos = jnp.asarray([19, -1, 9], jnp.int32)

    for kw in ({}, dict(window=6), dict(softcap=5.0),
               dict(window=6, softcap=5.0)):
        ref = kops.paged_decode_attention(q, kp, vp, pt, q_pos,
                                          use_kernel=False, **kw)
        ker = kops.paged_decode_attention(q, kp, vp, pt, q_pos,
                                          use_kernel=True, interpret=True,
                                          **kw)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                                   rtol=2e-5, atol=2e-5)
        assert np.all(np.asarray(ref)[1] == 0)  # dead slot -> zeros

    # dense oracle: pages 0..2 hold positions 0..23 contiguously
    pt3 = jnp.asarray([[0, 1, 2, -1]], jnp.int32)
    qq = jnp.asarray(rng.randn(1, H, hd), jnp.float32)
    qp3 = jnp.asarray([13], jnp.int32)
    out = kops.paged_decode_attention(qq, kp, vp, pt3, qp3,
                                      use_kernel=False)
    k_lin = np.asarray(kp[:3]).reshape(24, KH, hd)[:14]
    v_lin = np.asarray(vp[:3]).reshape(24, KH, hd)[:14]
    qf = np.asarray(qq).reshape(KH, H // KH, hd)
    s = np.einsum("kgh,tkh->kgt", qf, k_lin) * hd ** -0.5
    pr = np.exp(s - s.max(-1, keepdims=True))
    pr /= pr.sum(-1, keepdims=True)
    o = np.einsum("kgt,tkh->kgh", pr, v_lin).reshape(1, H, hd)
    np.testing.assert_allclose(np.asarray(out), o, rtol=1e-5, atol=1e-5)


def test_stale_lines_of_recycled_pages_unreachable():
    """A page carrying a PREVIOUS owner's K/V beyond the new owner's
    frontier contributes nothing: structural positions put stale lines
    past the causal mask (DESIGN.md §9.2)."""
    rng = np.random.RandomState(1)
    KH, hd, ps = 2, 16, 8
    kp = jnp.asarray(rng.randn(4, ps, KH, hd), jnp.float32)
    vp = jnp.asarray(rng.randn(4, ps, KH, hd), jnp.float32)
    q = jnp.asarray(rng.randn(1, 4, hd), jnp.float32)
    pt = jnp.asarray([[2, 3]], jnp.int32)
    q_pos = jnp.asarray([11], jnp.int32)  # lines 0..11 live, 12..15 stale
    base = kops.paged_decode_attention(q, kp, vp, pt, q_pos,
                                       use_kernel=False)
    # scribble over the stale tail of page 3 (lines 4..7 = positions 12..15)
    kp2 = kp.at[3, 4:].set(99.0)
    vp2 = vp.at[3, 4:].set(-99.0)
    got = kops.paged_decode_attention(q, kp2, vp2, pt, q_pos,
                                      use_kernel=False)
    np.testing.assert_allclose(np.asarray(base), np.asarray(got),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Chunked prefill == whole prefill, through page tables
# ---------------------------------------------------------------------------

def test_paged_chunked_prefill_matches_whole(mesh1, tiny_params):
    prompt = jnp.asarray(_prompt(5, 13), jnp.int32)[None]
    # non-contiguous, differently-ordered physical pages for the two runs:
    # logits must not care WHERE the pages live
    pt_w = jnp.asarray([[5, 0, 3, -1]], jnp.int32)
    pt_c = jnp.asarray([[1, 4, 2, -1]], jnp.int32)

    def run_prefill(pt, chunks):
        state = stack.init_paged_decode_state(TINY, 1, 6, 8, jnp.float32)
        off = 0
        for c in chunks:
            logits, state, _ = stack.apply_model(
                tiny_params, TINY, RUN, prompt[:, off:off + c],
                decode_state=state, cache_index=jnp.asarray(off, jnp.int32),
                attend_to_cache=True, page_table=pt)
            off += c
        return logits[:, -1]

    l_w = run_prefill(pt_w, [13])
    l_c = run_prefill(pt_c, [5, 5, 3])
    np.testing.assert_allclose(np.asarray(l_w), np.asarray(l_c),
                               rtol=2e-5, atol=2e-5)
    # and both match the cache-free structural forward
    logits, _, _ = stack.apply_model(tiny_params, TINY, RUN, prompt)
    np.testing.assert_allclose(np.asarray(l_w), np.asarray(logits[:, -1]),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Engine parity vs the dense-cache reference engine
# ---------------------------------------------------------------------------

def test_paged_engine_greedy_parity_with_dense(mesh1, tiny_params):
    """Token-exact greedy parity (temperature 0) between the paged engine
    and the dense reservation engine over a multi-request trace."""
    reqs = [Request(rid=i, prompt=_prompt(40 + i, 9 + i), max_new_tokens=6)
            for i in range(3)]

    dense_prog = make_continuous_program(TINY, mesh1, RUN, n_slots=2,
                                         max_len=32)
    with mesh1:
        dp = jax.device_put(tiny_params, dense_prog.param_shardings)
    dense = ContinuousBatchingEngine(
        dense_prog, dp, Scheduler(2, 32, prefill_chunk=6))
    res_d = dense.run([Request(rid=r.rid, prompt=r.prompt,
                               max_new_tokens=r.max_new_tokens)
                       for r in reqs])

    eng = _paged_engine(TINY, mesh1, tiny_params, n_slots=2, max_len=32)
    res_p = eng.run(reqs)
    assert res_p == res_d


def test_paged_engine_moe_poisson_acceptance(mesh1):
    """Smoke MoE arch through a Poisson trace on the paged engine: every
    request completes and matches the unbatched greedy reference."""
    cfg = registry.smoke_config(registry.get_config("qwen3-moe-30b-a3b"))
    max_len = 30
    params0, _ = split_params(stack.init_model(jax.random.PRNGKey(0), cfg))
    eng = _paged_engine(cfg, mesh1, params0, n_slots=2, max_len=max_len,
                        page_size=8, prefill_chunk=4)
    trace = build_trace(seed=0, n=4, rate=0.6, prompt_len=16, gen=10,
                        vocab=cfg.vocab_size, sampling=GREEDY)
    res = eng.run(trace)
    assert sorted(res) == [r.rid for r in trace]
    for r in trace:
        want = _ref_greedy(params0, cfg, RUN, r.prompt, r.max_new_tokens)
        assert res[r.rid] == want, (r.rid, res[r.rid], want)


def test_paged_windowed_arch_matches_reference(mesh1):
    """Sliding-window layers use the linear paged layout with the window
    enforced by masking: greedy output matches the cache-free reference
    (the paged path never evicts, so chunked prefill stays exact)."""
    cfg = ModelConfig(name="tiny-win", family="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=64,
                      pattern=(LayerSpec(mixer="local_attn"),), window=8)
    params0 = split_params(stack.init_model(jax.random.PRNGKey(2), cfg))[0]
    eng = _paged_engine(cfg, mesh1, params0, n_slots=1, max_len=24,
                        prefill_chunk=5)
    req = Request(rid=0, prompt=_prompt(31, 13), max_new_tokens=6)
    res = eng.run([req])
    assert res[0] == _ref_greedy(params0, cfg, RUN, req.prompt, 6)


# ---------------------------------------------------------------------------
# Recycle-no-leak under paging (PR-2 contract, zero device traffic on free)
# ---------------------------------------------------------------------------

def test_paged_slot_recycle_no_kv_leak(mesh1, tiny_params):
    """Serve A then B through the same slot AND the same physical pages
    (1-slot engine, pool barely fitting one request): B's logits must
    match a fresh run bit-for-bit-close even though its pages still hold
    A's stale K/V beyond B's frontier."""
    req_a = Request(rid=0, prompt=_prompt(21, 10), max_new_tokens=4)
    req_b = Request(rid=1, prompt=_prompt(22, 7), max_new_tokens=6)

    eng = _paged_engine(TINY, mesh1, tiny_params, n_slots=1, max_len=24,
                        n_pages=3, record_logits=True)
    res = eng.run([req_a, req_b])
    # pool of exactly one sequence: B necessarily reused A's pages
    assert eng.sched.allocator.pages_in_use == 0

    fresh = _paged_engine(TINY, mesh1, tiny_params, n_slots=1, max_len=24,
                          n_pages=3, record_logits=True)
    res_f = fresh.run([Request(rid=1, prompt=req_b.prompt,
                               max_new_tokens=6)])

    assert res[1] == res_f[1]
    assert len(eng.logits[1]) == len(fresh.logits[1]) == 6
    for a, b in zip(eng.logits[1], fresh.logits[1]):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
    assert res[1] == _ref_greedy(tiny_params, TINY, RUN, req_b.prompt, 6)


# ---------------------------------------------------------------------------
# Preemption: requeue determinism (sampler keys unchanged)
# ---------------------------------------------------------------------------

def test_preemption_requeue_determinism(mesh1, tiny_params):
    """A pool too small for the trace forces preempt-newest; the resumed
    request replays prompt+generated and continues sampling at key(rid,
    n_done) — results must equal the ample-pool run token for token, under
    REAL sampling (temperature/top-k/top-p), not just greedy."""
    sp = SamplingParams(temperature=0.8, top_k=5, top_p=0.9)
    reqs = [Request(rid=i, prompt=_prompt(60 + i, 9 + i),
                    max_new_tokens=12, sampling=sp) for i in range(3)]

    ample = _paged_engine(TINY, mesh1, tiny_params, n_slots=2, max_len=32)
    res_a = ample.run([Request(rid=r.rid, prompt=r.prompt,
                               max_new_tokens=r.max_new_tokens,
                               sampling=sp) for r in reqs])
    assert ample.sched.n_preempted == 0

    tight = _paged_engine(TINY, mesh1, tiny_params, n_slots=2, max_len=32,
                          n_pages=5)
    res_t = tight.run(reqs)
    assert tight.sched.n_preempted > 0, "pool was not tight enough"
    assert res_t == res_a
    tight.sched.allocator.check()


def test_serve_driver_exits_nonzero_on_dropped_requests(monkeypatch):
    """launch/serve.py must FAIL (non-zero) when any arch drops or leaves
    a request unfinished, so the CI serve-smoke step actually gates."""
    from repro.launch import serve as serve_mod
    monkeypatch.setattr(serve_mod, "serve_arch",
                        lambda arch, args, serve_cfg=None: {"ok": arch == serve_mod.
                                            SMOKE_ARCHS[0]})
    assert serve_mod.main(["--smoke"]) == 1
    monkeypatch.setattr(serve_mod, "serve_arch",
                        lambda arch, args, serve_cfg=None: {"ok": True})
    assert serve_mod.main(["--smoke"]) == 0


# ---------------------------------------------------------------------------
# Acceptance: slot lift at fixed simulated HBM
# ---------------------------------------------------------------------------

def test_paged_slot_lift_at_fixed_hbm(mesh1, tiny_params):
    """With the pool capped at the reservation engine's HBM (slots_ref x
    max_len cache lines), the paged engine sustains >= 1.5x slots_ref
    concurrent requests on a mixed-length trace."""
    slots_ref, max_len, ps = 2, 32, 8
    budget_pages = slots_ref * max_len // ps  # equal simulated HBM
    eng = _paged_engine(TINY, mesh1, tiny_params, n_slots=3 * slots_ref,
                        max_len=max_len, page_size=ps,
                        n_pages=budget_pages, prefill_chunk=8,
                        metrics=ServeMetrics())
    trace = build_trace(seed=3, n=10, rate=2.0, prompt_len=12, gen=8,
                        vocab=TINY.vocab_size, sampling=GREEDY)
    res = eng.run(trace)
    assert sorted(res) == [r.rid for r in trace]
    sustained = eng.metrics.summary()["max_concurrent_active"]
    assert sustained >= 1.5 * slots_ref, \
        f"paged engine sustained {sustained} slots at the HBM budget " \
        f"that backs {slots_ref} reserved slots"
    assert eng.page_peak <= budget_pages
