"""Chaos-hardened serving (DESIGN.md §13).

Covers the deterministic fault-injection layer end to end: the spec
grammar and its parse-time validation, seeded injector replay, the
transactional KV-transfer retry/rollback machine (host-only fakes for
the link, so every fault path is exercised without a mesh), and the REAL
tiny fleet under the full seeded fault-schedule matrix from
:func:`repro.core.simulator.chaos_matrix` — the headline invariant: every
submitted request is finished (token-exact vs the fault-free run) or
explicitly shed, surviving pools hold the exactly-once page invariant
with zero pages in use after drain, and a replay with the same
``(seed, spec)`` produces an identical fault log and identical results.
"""

import numpy as np
import pytest

from repro.core.simulator import chaos_matrix
from repro.ft.chaos import (FaultInjector, FaultPlan, FaultSpec,
                            GroupCrashed)
from repro.serve.fleet import make_fleet
from repro.serve.kv_transfer import KVTransferEngine, TransferAbortedError
from repro.serve.metrics import ServeMetrics

from tests.test_serve_disagg import RUN, TINY  # noqa: F401
from tests.test_serve_fleet import _trace, mesh1, tiny_params  # noqa: F401

pytestmark = pytest.mark.chaos  # CI chaos-smoke job slice


# ---------------------------------------------------------------------------
# Spec grammar (host-only)
# ---------------------------------------------------------------------------

def test_parse_full_grammar():
    plan = FaultPlan.parse("drop%0.5*4; corrupt@3:g2*2 ;hb_loss@6:g3~8")
    assert plan.specs[0] == FaultSpec("drop", None, "*", 0.5, 4, 1)
    assert plan.specs[1] == FaultSpec("corrupt", 3, "g2", 1.0, 2, 1)
    assert plan.specs[2] == FaultSpec("hb_loss", 6, "g3", 1.0, 1, 8)


def test_parse_defaults():
    (s,) = FaultPlan.parse("stall").specs
    assert (s.tick, s.target, s.prob, s.count, s.duration) == \
        (None, "*", 1.0, 1, 1)


@pytest.mark.parametrize("bad,match", [
    ("", "empty"),
    ("  ;  ", "empty"),
    ("frobnicate*2", "unknown chaos site"),
    ("drop%0", "probability"),
    ("drop%1.5", "probability"),
    ("drop*0", "count"),
    ("hb_loss@2:g1~0", "duration"),
    ("drop~4", "DURATION"),                 # windows only
    ("hb_loss:g1", "@TICK"),                # window needs a start
    ("hb_loss@4~2", "TARGET"),              # window needs a group
    ("crash_start@2", "TARGET"),            # crashes need a group
    ("drop@@2", "malformed"),
])
def test_parse_rejects_malformed(bad, match):
    with pytest.raises(ValueError, match=match):
        FaultPlan.parse(bad)


# ---------------------------------------------------------------------------
# Injector semantics (host-only)
# ---------------------------------------------------------------------------

def test_fire_respects_arming_budget_and_target():
    inj = FaultInjector(FaultPlan.parse("drop@3:g2*2"), seed=0)
    inj.begin_tick(2)
    assert not inj.fire("drop", "g2")        # not armed yet
    inj.begin_tick(3)
    assert not inj.fire("drop", "g9")        # wrong target
    assert not inj.fire("corrupt", "g2")     # wrong site
    assert inj.fire("drop", "g2")
    assert inj.fire("drop", "g2")
    assert not inj.fire("drop", "g2")        # budget spent
    assert inj.log() == [(3, "drop", "g2", 0), (3, "drop", "g2", 1)]


def test_window_active_and_logged_once():
    inj = FaultInjector(FaultPlan.parse("hb_loss@5:g3~3"), seed=0)
    for t, want in [(4, False), (5, True), (7, True), (8, False)]:
        inj.begin_tick(t)
        assert inj.active("hb_loss", "g3") is want
        assert not inj.fire("hb_loss", "g3")  # windows never fire point-wise
    assert len(inj.log()) == 1                # opening logged exactly once


def test_seeded_replay_is_bit_identical():
    def drive(seed):
        inj = FaultInjector(FaultPlan.parse("drop%0.4*6"), seed=seed)
        for t in range(30):
            inj.begin_tick(t)
            inj.fire("drop", "g2")
        return inj.log(), inj.log_signature()

    assert drive(11) == drive(11)
    assert drive(11)[1] != drive(12)[1]       # the seed is the plan


# ---------------------------------------------------------------------------
# Transactional transfer: retry / replay / rollback (host-only fakes)
# ---------------------------------------------------------------------------

def _fake_engine(spec=None, seed=0, **kw):
    """A KVTransferEngine whose link is a pair of host fakes: gather
    returns a fixed numpy payload, scatter counts applications by
    incrementing the (integer) destination state."""
    chaos = FaultInjector(FaultPlan.parse(spec), seed=seed) if spec \
        else None
    kw.setdefault("chunk_pages", 2)
    kw.setdefault("max_retries", 2)
    kw.setdefault("timeout_s", 0.5)
    kw.setdefault("backoff_s", 0.1)
    eng = KVTransferEngine(chaos=chaos, **kw)
    eng._gather = lambda state, ids: {"kv": np.ones((2, 4), np.float32)}
    eng._scatter = lambda dst, payload, ids: dst + 1
    return eng


def _ship(eng, n_pages=2):
    ids = list(range(n_pages))
    return eng.transfer("src", 0, ids, ids, dst_n_pages=8,
                        src_name="g0", dst_name="g2")


def test_clean_transfer_applies_each_chunk_once():
    eng = _fake_engine()
    assert _ship(eng, n_pages=4) == 2        # 4 pages / chunk_pages=2
    st = eng.stats
    assert (st.n_retries, st.n_timeouts, st.n_aborts) == (0, 0, 0)
    assert st.n_pages == 4 and st.n_chunks == 2


def test_drop_retries_then_commits_and_charges_the_clock():
    eng = _fake_engine("drop:g2*1")
    assert _ship(eng) == 1
    st = eng.stats
    assert (st.n_retries, st.n_timeouts) == (1, 1)
    assert st.sim_seconds == pytest.approx(0.5 + 0.1)  # timeout + backoff


def test_corrupt_caught_by_checksum_and_retried():
    eng = _fake_engine("corrupt:g2*1")
    assert _ship(eng) == 1
    assert eng.stats.n_checksum_failures == 1
    assert eng.stats.n_retries == 1


def test_corrupt_slips_through_without_checksums():
    eng = _fake_engine("corrupt:g2*1", verify_checksums=False)
    assert _ship(eng) == 1                   # delivered, nobody noticed
    assert eng.stats.n_checksum_failures == 0
    assert eng.stats.n_retries == 0


def test_stall_replays_the_chunk_idempotently():
    eng = _fake_engine("stall:g2*1")
    # delivered + replayed: the scatter applied TWICE — idempotence is
    # the contract the page-granular scatter provides.
    assert _ship(eng) == 2
    st = eng.stats
    assert st.n_replayed_chunks == 1 and st.n_timeouts == 1
    assert st.n_chunks == 1                  # accounted once, not twice


def test_retry_exhaustion_aborts_with_rollback_state():
    eng = _fake_engine("drop:g2*3")          # budget > max_retries=2
    with pytest.raises(TransferAbortedError) as ei:
        _ship(eng)
    assert eng.stats.n_aborts == 1
    # nothing landed: the caller's state rides back on the exception
    assert ei.value.dst_state == 0


def test_abort_after_partial_scatter_hands_back_live_state():
    # Every attempt DELIVERS (scatter lands) but the ack is lost, until
    # the retry budget dies: the donated-state contract — the exception
    # carries the live tree with the landed writes (harmless: those
    # pages are still under lease when the caller aborts the import).
    eng = _fake_engine("stall:g2*3")         # budget > max_retries=2
    with pytest.raises(TransferAbortedError) as ei:
        _ship(eng)
    assert ei.value.dst_state == 3           # one scatter per attempt
    assert eng.stats.n_replayed_chunks == 3


@pytest.mark.parametrize("site,role,victim", [
    ("crash_mid_export:g0", "src", "g0"),
    ("crash_mid_import:g2", "dst", "g2"),
])
def test_mid_transfer_crash_raises_with_role_and_state(site, role, victim):
    eng = _fake_engine(site)
    with pytest.raises(GroupCrashed) as ei:
        _ship(eng)
    assert ei.value.role == role and ei.value.name == victim
    assert ei.value.dst_state == 0


# ---------------------------------------------------------------------------
# Real fleet under the seeded fault matrix (tiny model, CPU)
# ---------------------------------------------------------------------------

def _chaos_fleet(mesh, params, chaos=None, **kw):
    kw.setdefault("prefill_classes", ["a40", "a40"])
    kw.setdefault("decode_classes", ["v100", "v100"])
    kw.setdefault("decode_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 6)
    kw.setdefault("metrics", ServeMetrics())
    return make_fleet(TINY, mesh, RUN, params, chaos=chaos, **kw)


@pytest.fixture(scope="module")
def fault_free(mesh1, tiny_params):
    fleet = _chaos_fleet(mesh1, tiny_params)
    return fleet.run(_trace())


def _check_invariants(fleet, res, want):
    all_rids = set(res) | set(fleet.shed) | set(fleet.rejected)
    assert all_rids == set(want)             # submitted ⊆ finished ∪ shed
    assert not fleet.rejected
    for rid, toks in res.items():
        assert toks == want[rid], f"rid {rid} diverged under faults"
    for g in fleet.groups:
        g.worker.allocator.check()
        assert g.worker.allocator.pages_in_use == 0, \
            f"group {g.name} leaked pages after drain"


@pytest.mark.parametrize("name,spec,seed",
                         chaos_matrix(), ids=[e[0] for e in chaos_matrix()])
def test_fleet_survives_schedule_token_exact(mesh1, tiny_params,
                                             fault_free, name, spec, seed):
    """ACCEPTANCE: under every seeded schedule in the matrix — drops,
    corruption, stalls, retry-exhaustion abort, heartbeat-flap zombies
    and mid-tick crashes — every request finishes with EXACTLY the
    fault-free run's tokens and no surviving pool leaks a page."""
    inj = FaultInjector(FaultPlan.parse(spec), seed=seed)
    fleet = _chaos_fleet(mesh1, tiny_params, chaos=inj)
    res = fleet.run(_trace())
    assert inj.log(), f"schedule {name!r} fired no fault on this trace"
    _check_invariants(fleet, res, fault_free)


def test_fleet_chaos_replay_is_deterministic(mesh1, tiny_params):
    """Same (seed, spec) against the same trace: identical fault log
    signature, identical events, identical results."""
    _, spec, seed = next(e for e in chaos_matrix() if e[0] == "standard")

    def run():
        inj = FaultInjector(FaultPlan.parse(spec), seed=seed)
        fleet = _chaos_fleet(mesh1, tiny_params, chaos=inj)
        res = fleet.run(_trace())
        return res, inj.log(), inj.log_signature()

    assert run() == run()


def test_fleet_zombie_is_fenced_and_rejoins(mesh1, tiny_params,
                                            fault_free):
    """A heartbeat-flapped group is declared dead while still computing
    (zombie), its stale completions are fenced by epoch, its requests
    re-prefill elsewhere token-exactly, and when beats resume it rejoins
    at generation + 1."""
    inj = FaultInjector(FaultPlan.parse("hb_loss@6:g3~8"), seed=505)
    fleet = _chaos_fleet(mesh1, tiny_params, chaos=inj)
    res = fleet.run(_trace())
    _check_invariants(fleet, res, fault_free)
    kinds = [e.kind for e in fleet.events]
    assert "dead" in kinds and "rejoin" in kinds
    assert fleet.metrics.robust.zombie_rejoins >= 1
    assert fleet.fenced                      # the old epoch stays fenced
    rejoined = fleet.group(3)
    assert rejoined.generation >= 1
    assert (3, 0) in fleet.fenced


def test_fleet_transfer_abort_recovers_via_reprefill(mesh1, tiny_params,
                                                     fault_free):
    """A transfer that exhausts its retry budget rolls BOTH pools back
    and the ticket's request re-prefills — nothing is lost, the abort is
    visible in the robustness counters."""
    inj = FaultInjector(FaultPlan.parse("drop@2*12"), seed=404)
    fleet = _chaos_fleet(mesh1, tiny_params, chaos=inj)
    res = fleet.run(_trace())
    _check_invariants(fleet, res, fault_free)
    assert fleet.metrics.robust.transfer_aborts >= 1
    assert fleet.metrics.robust.transfer_retries >= 1


def test_fleet_slo_shed_is_explicit_and_conserving(mesh1, tiny_params):
    """With an impossibly tight TTFT SLO every arrival is shed — an
    EXPLICIT outcome (counted, evented), never a silent drop — and the
    conservation invariant counts shed as handled."""
    fleet = _chaos_fleet(mesh1, tiny_params, slo_ttft=1e-9)
    trace = _trace()
    res = fleet.run(trace)
    assert res == {}
    assert sorted(fleet.shed) == sorted(r.rid for r in trace)
    assert fleet.metrics.robust.shed_requests == len(trace)
    assert [e.kind for e in fleet.events].count("shed") == len(trace)
    for g in fleet.groups:
        g.worker.allocator.check()
        assert g.worker.allocator.pages_in_use == 0


def test_fleet_generous_slo_sheds_nothing(mesh1, tiny_params, fault_free):
    fleet = _chaos_fleet(mesh1, tiny_params, slo_ttft=1e9)
    res = fleet.run(_trace())
    assert not fleet.shed
    _check_invariants(fleet, res, fault_free)


# ---------------------------------------------------------------------------
# Driver plumbing
# ---------------------------------------------------------------------------

def test_driver_rejects_chaos_without_fleet():
    from repro.launch import serve as serve_mod
    assert serve_mod.main(["--smoke", "--chaos", "drop"]) == 1


def test_chaos_matrix_shape():
    m = chaos_matrix()
    assert len(m) >= 6
    names = [n for n, _, _ in m]
    assert len(set(names)) == len(names)
    for _, spec, seed in m:
        FaultPlan.parse(spec)                # every entry must parse
        assert isinstance(seed, int)
