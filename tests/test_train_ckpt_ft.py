"""Training loop, optimizer, checkpointing, data pipeline, fault tolerance."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import hardware as HW
from repro.core.planner import plan_zp_group, replan
from repro.core.profiler import ZPGroupShape
from repro.data import DataConfig, DataLoader, write_token_bin
from repro.ft import ElasticController, HeartbeatMonitor, StragglerDetector
from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.models.config import ShapeConfig
from repro.models.modules import Policy, RunConfig
from repro.train import optimizer as opt
from repro.train.loss import chunked_xent_from_hidden, cross_entropy
from repro.train.step import make_train_program

RUN = RunConfig(policy=Policy(compute_dtype=jnp.float32), moe_impl="gather")


# ---------------------------------------------------------------------------
# Optimizer / loss units
# ---------------------------------------------------------------------------

def test_adamw_matches_numpy_reference():
    cfg = opt.OptimizerConfig(peak_lr=1e-2, warmup_steps=0, total_steps=10,
                              weight_decay=0.0, grad_clip=0.0)
    p = {"w": jnp.array([[1.0, -2.0]]), "b": jnp.array([0.5])}
    g = {"w": jnp.array([[0.1, 0.2]]), "b": jnp.array([0.3])}
    st = opt.init_opt_state(p)
    p2, st2, _ = opt.adamw_update(cfg, p, g, st)
    # manual adam step 1: mhat = g, nhat = g^2 -> delta = g/|g| = sign(g)
    lr = float(opt.lr_schedule(cfg, 1))
    want = np.array([[1.0, -2.0]]) - lr * np.sign([[0.1, 0.2]])
    np.testing.assert_allclose(p2["w"], want, atol=1e-4)
    assert int(st2["step"]) == 1


def test_grad_clip_bounds_update():
    cfg = opt.OptimizerConfig(grad_clip=1.0, warmup_steps=0)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = opt.adamw_update(cfg, p, g, opt.init_opt_state(p))
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_lr_schedule_shape():
    cfg = opt.OptimizerConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                              end_lr_frac=0.1)
    assert float(opt.lr_schedule(cfg, 0)) == 0.0
    assert float(opt.lr_schedule(cfg, 10)) == pytest.approx(1.0)
    assert float(opt.lr_schedule(cfg, 100)) == pytest.approx(0.1, abs=1e-6)


def test_chunked_xent_matches_full():
    key = jax.random.PRNGKey(0)
    B, S, d, V = 2, 50, 16, 37
    hidden = jax.random.normal(key, (B, S, d))
    table = jax.random.normal(jax.random.fold_in(key, 1), (V, d))
    targets = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, V)
    logits = jnp.einsum("bsd,vd->bsv", hidden, table)
    want, wm = cross_entropy(logits, targets, z_loss_coef=1e-4)
    got, gm = chunked_xent_from_hidden(hidden, table, targets, chunk=16,
                                       z_loss_coef=1e-4)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    # grads too
    g1 = jax.grad(lambda h: chunked_xent_from_hidden(h, table, targets,
                                                     chunk=16)[0])(hidden)
    g2 = jax.grad(lambda h: cross_entropy(
        jnp.einsum("bsd,vd->bsv", h, table), targets)[0])(hidden)
    np.testing.assert_allclose(g1, g2, atol=1e-5)


# ---------------------------------------------------------------------------
# End-to-end training (loss decreases)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["mixtral-d2", "llama3.2-3b"])
def test_training_reduces_loss(mesh4, arch):
    cfg = registry.smoke_config(registry.get_config(arch))
    shape = ShapeConfig("t", "train", 64, 4)
    steps = 60
    program = make_train_program(
        cfg, mesh4, RUN, shape,
        opt_cfg=opt.OptimizerConfig(peak_lr=5e-3, warmup_steps=5,
                                    total_steps=steps))
    loader = DataLoader(DataConfig(cfg.vocab_size, 64, 4, seed=3))
    with mesh4:
        params = program.init_params()
        opt_state = program.init_opt(params)
    losses = []
    for _ in range(steps):
        with mesh4:
            params, opt_state, m = program.train_step(params, opt_state,
                                                      next(loader))
        losses.append(float(m["loss"]))
    assert sum(losses[-5:]) / 5 < sum(losses[:5]) / 5 - 0.1, losses


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    params = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    ostate = opt.init_opt_state(params)
    mgr.save(5, params, ostate, extra={"loader": {"step": 5}})
    step, p2, o2, extra = mgr.restore(params, ostate)
    assert step == 5 and extra["loader"]["step"] == 5
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), params, p2)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), ostate, o2)


def test_checkpoint_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    params = {"a": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, params, blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    params = {"a": jnp.ones(8)}
    mgr.save(1, params)
    # corrupt the array file
    path = os.path.join(str(tmp_path), "step_00000001", "arrays.npz")
    np.savez(path, **{"params\x1fa": np.zeros(8, np.float32)})
    with pytest.raises(IOError):
        mgr.restore(params)


def test_checkpoint_crash_mid_save_keeps_previous_step(tmp_path,
                                                       monkeypatch):
    """ACCEPTANCE (atomic publish, DESIGN.md §13): a crash BETWEEN the
    tmp-dir write and the rename leaves the previous checkpoint as the
    latest — the torn step is invisible to ``all_steps``/``restore`` and
    a later save of the same step recovers cleanly over the debris."""
    import repro.checkpoint.manager as mgr_mod
    mgr = CheckpointManager(str(tmp_path))
    params = {"a": jnp.arange(4.0)}
    mgr.save(1, params)

    real_rename = os.rename

    def crash_rename(src, dst):
        if os.path.basename(dst).startswith("step_"):
            raise RuntimeError("power loss mid-publish")
        return real_rename(src, dst)

    monkeypatch.setattr(mgr_mod.os, "rename", crash_rename)
    params2 = {"a": jnp.full(4, 9.0)}
    with pytest.raises(RuntimeError, match="power loss"):
        mgr.save(2, params2)
    # the torn step 2 never published: tmp dir on disk, invisible to reads
    assert os.path.isdir(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert mgr.all_steps() == [1]
    step, p, _, _ = mgr.restore(params)
    assert step == 1
    np.testing.assert_allclose(p["a"], np.arange(4.0))
    # power back on: the retried save publishes over the stale tmp debris
    monkeypatch.setattr(mgr_mod.os, "rename", real_rename)
    mgr.save(2, params2)
    assert mgr.all_steps() == [1, 2]
    step, p, _, _ = mgr.restore(params2)
    assert step == 2
    np.testing.assert_allclose(p["a"], np.full(4, 9.0))


def test_checkpoint_save_fsyncs_before_publish(tmp_path, monkeypatch):
    """Durability ordering: every file and directory involved in a save
    is fsync'd BEFORE the rename publishes the step (fsync-after-rename
    alone would allow a torn step to surface after a host crash)."""
    import repro.checkpoint.manager as mgr_mod
    order = []
    real_fsync, real_rename = os.fsync, os.rename
    monkeypatch.setattr(mgr_mod.os, "fsync",
                        lambda fd: (order.append("fsync"), real_fsync(fd))[1])
    monkeypatch.setattr(
        mgr_mod.os, "rename",
        lambda s, d: (order.append("rename"), real_rename(s, d))[1])
    CheckpointManager(str(tmp_path)).save(1, {"a": jnp.ones(2)})
    # arrays.npz + MANIFEST + tmp dir before the rename, parent dir after
    assert order.index("rename") >= 3
    assert order[-1] == "fsync" and order.count("rename") == 1


def test_checkpoint_elastic_reshard(tmp_path, mesh8, mesh4):
    """Save under one mesh, restore onto a different mesh (elastic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    x = jnp.arange(32.0).reshape(8, 4)
    sharded = jax.device_put(x, NamedSharding(mesh8, P("data", "model")))
    mgr.save(1, {"x": sharded})
    new_sh = {"x": NamedSharding(mesh4, P("model", None))}
    _, restored, _, _ = mgr.restore({"x": x}, shardings=new_sh)
    np.testing.assert_allclose(restored["x"], x)
    assert restored["x"].sharding == new_sh["x"]


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_resume():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    l1 = DataLoader(cfg)
    batches = [next(l1) for _ in range(5)]
    l2 = DataLoader(cfg, start_step=3)
    np.testing.assert_array_equal(batches[3]["tokens"],
                                  next(l2)["tokens"])


def test_data_host_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=4, seed=1)
    full = DataLoader(cfg).source.batch_at(0)["tokens"]
    assert full.shape == (4, 8)
    h0 = DataLoader(cfg, host_index=0, host_count=2).source.batch_at(0)
    h1 = DataLoader(cfg, host_index=1, host_count=2).source.batch_at(0)
    assert h0["tokens"].shape == (2, 8)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_memmap_source(tmp_path):
    path = str(tmp_path / "toks.bin")
    write_token_bin(path, 10_000, 50_000, seed=0)
    cfg = DataConfig(vocab_size=50_000, seq_len=32, global_batch=2,
                     path=path)
    l = DataLoader(cfg)
    b0 = next(l)
    b1 = next(l)
    assert b0["tokens"].shape == (2, 32)
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["targets"][:, :-1])
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat_detects_dead_host():
    clock = {"t": 0.0}
    mon = HeartbeatMonitor(["a", "b"], clock=lambda: clock["t"])
    clock["t"] = 20.0
    mon.beat("a")
    clock["t"] = 35.0
    assert mon.dead_hosts() == ["b"]


def test_straggler_detector_flags_slow_group():
    det = StragglerDetector(["attn", "exp"], z_thresh=3.0, patience=2)
    for _ in range(10):
        det.record("attn", 1.0)
        det.record("exp", 1.0)
    assert det.stragglers() == []
    for _ in range(6):
        det.record("exp", 3.0)
        det.stragglers()
    assert "exp" in det.stragglers()
    assert det.slow_factor("exp") > 2.0


def test_elastic_controller_shrinks_and_replans():
    cfg = registry.get_config("mixtral-d1")
    zp = ZPGroupShape(M=4, N=4, attn_class=HW.A40, exp_class=HW.V100)
    plan = plan_zp_group(cfg, zp, global_batch=16, seq_len=4096)
    ctl = ElasticController(cfg, plan, 16, 4096,
                            attn_hosts=["a0", "a1", "a2", "a3"],
                            exp_hosts=["e0", "e1", "e2", "e3"])
    # kill one attention host and one expert host
    ctl.heartbeat.last_seen["a3"] -= 1e6
    ctl.heartbeat.last_seen["e3"] -= 1e6
    ev = ctl.tick()
    assert ev.kind == "shrink"
    assert ev.plan.zp.M == 3 and ev.plan.zp.N == 3


def test_straggler_replan_increases_offload():
    cfg = registry.get_config("mixtral-d1")
    zp = ZPGroupShape(M=4, N=4, attn_class=HW.A40, exp_class=HW.V100)
    plan = plan_zp_group(cfg, zp, global_batch=16, seq_len=4096)
    slowed = replan(cfg, plan, 16, 4096, slow_factor=2.0)
    # a 2x slower expert class must shift at least as much work across
    assert sum(slowed.offload) >= sum(plan.offload)
    assert slowed.predicted.iter_time >= plan.predicted.iter_time


def test_replan_raises_when_group_not_viable():
    cfg = registry.get_config("mixtral-d1")
    zp = ZPGroupShape(M=1, N=1, attn_class=HW.A40, exp_class=HW.V100)
    plan = plan_zp_group(cfg, zp, global_batch=16, seq_len=4096)
    with pytest.raises(RuntimeError):
        replan(cfg, plan, 16, 4096, lost_exp=1)
