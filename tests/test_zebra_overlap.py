"""Overlapped zebra dispatch (DESIGN.md §8).

Covers: chunked a2a/compute pipelining parity (n_chunks > 1 matches the
serialized path and the fused oracle, forward AND gradients, including
zero-token experts inside a chunk and non-tile-multiple capacities), the
unified local+remote grouped GEMM (ops.moe_ffn_packed_multi — structurally
ONE grouped GEMM call per projection direction covering both expert sets),
the overlap-aware simulator/planner cost model, and the dense-mode routing
satellite (RunConfig defaults to the fused pipeline)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from test_moe_ffn import _count_eqns

from repro.core import zebra_spmd as Z
from repro.core.asym_ea import asym_ea_offload
from repro.core.simulator import CommTimes, exposed_comm, simulate_hetermoe
from repro.kernels import gmm as gmm_kernel
from repro.kernels import ops
from repro.models import modules, registry
from repro.models.config import LayerSpec, ModelConfig
from repro.models.modules import Policy, RunConfig
from repro.pytree import split_params

pytestmark = pytest.mark.zebra  # CI job slice (see .github/workflows/ci.yml)

RUN = RunConfig(policy=Policy(compute_dtype=jnp.float32), moe_impl="gather")
KEY = jax.random.PRNGKey(0)


def moe_cfg(arch="qwen3-moe-30b-a3b", cap=99.0, **kw):
    cfg = registry.smoke_config(registry.get_config(arch))
    return dataclasses.replace(cfg, capacity_factor=cap, **kw)


def rand(shape, k=0, scale=1.0):
    return jax.random.normal(jax.random.fold_in(KEY, k), shape,
                             jnp.float32) * scale


# ---------------------------------------------------------------------------
# ops.moe_ffn_packed_multi: unified local+remote grouped GEMM
# ---------------------------------------------------------------------------

def _dense_expert_ffn(buf, wg, wu, wo):
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    return jnp.einsum("ecf,efd->ecd", g * u, wo)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_moe_ffn_packed_multi_matches_separate(use_kernel):
    """Two segments with different, non-tile-multiple capacities and a
    zero-token expert (all-zero rows) inside the first segment: the ONE
    unified call matches per-segment moe_ffn_packed calls and the dense
    oracle, forward and gradients."""
    d, f = 32, 48
    b1 = rand((3, 25, d), k=1, scale=0.5).at[1].set(0.0)  # zero-token expert
    b2 = rand((2, 40, d), k=2, scale=0.5)
    ws = [(rand((g, d, f), k=3 + i, scale=0.1),
           rand((g, d, f), k=5 + i, scale=0.1),
           rand((g, f, d), k=7 + i, scale=0.1))
          for i, g in enumerate((3, 2))]
    (wg1, wu1, wo1), (wg2, wu2, wo2) = ws

    o1, o2 = ops.moe_ffn_packed_multi(
        [b1, b2], [wg1, wg2], [wu1, wu2], [wo1, wo2], use_kernel=use_kernel)
    np.testing.assert_allclose(
        np.asarray(o1), np.asarray(ops.moe_ffn_packed(
            b1, wg1, wu1, wo1, use_kernel=use_kernel)), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(o2), np.asarray(_dense_expert_ffn(b2, wg2, wu2, wo2)),
        atol=1e-4)

    def loss_multi(x1, x2):
        a, b = ops.moe_ffn_packed_multi(
            [x1, x2], [wg1, wg2], [wu1, wu2], [wo1, wo2],
            use_kernel=use_kernel)
        return jnp.sum(a ** 2) + jnp.sum(b ** 2)

    def loss_dense(x1, x2):
        return jnp.sum(_dense_expert_ffn(x1, wg1, wu1, wo1) ** 2) + \
            jnp.sum(_dense_expert_ffn(x2, wg2, wu2, wo2) ** 2)

    g1 = jax.grad(loss_multi, argnums=(0, 1))(b1, b2)
    g2 = jax.grad(loss_dense, argnums=(0, 1))(b1, b2)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_chunk_capacity():
    assert ops.chunk_capacity(24, 1) == (24, 24)
    assert ops.chunk_capacity(24, 2) == (32, 16)   # sublane-aligned chunks
    assert ops.chunk_capacity(24, 4) == (32, 8)
    assert ops.chunk_capacity(1, 2) == (16, 8)
    for c, q in [(8, 1), (40, 2), (100, 4), (7, 3)]:
        cp, cq = ops.chunk_capacity(c, q)
        assert cp == q * cq and cp >= c and cq % 8 == 0


def test_unified_one_grouped_gemm_per_direction():
    """ACCEPTANCE: the unified call covering BOTH segments (local + remote
    experts) lowers to exactly ONE custom_vjp and, inside it, exactly TWO
    grouped-GEMM kernel calls — one fused gate+up, one down projection:
    one grouped GEMM per direction."""
    d, f = 32, 48
    b1, b2 = rand((2, 16, d), k=1), rand((3, 32, d), k=2)
    wg = [rand((g, d, f), k=4) for g in (2, 3)]
    wu = [rand((g, d, f), k=5) for g in (2, 3)]
    wo = [rand((g, f, d), k=6) for g in (2, 3)]
    jx = jax.make_jaxpr(lambda x1, x2: ops.moe_ffn_packed_multi(
        [x1, x2], wg, wu, wo, use_kernel=True)[0])(b1, b2)
    vjps = _count_eqns(jx.jaxpr,
                       lambda e: e.primitive.name == "custom_vjp_call_jaxpr")
    assert len(vjps) == 1, [e.primitive.name for e in jx.jaxpr.eqns]
    kernels = _count_eqns(jx.jaxpr,
                          lambda e: e.primitive.name == "pallas_call")
    assert len(kernels) == 2, [e.primitive.name for e in kernels]


# ---------------------------------------------------------------------------
# SPMD engine: chunked dispatch parity + engine-level structure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_chunks,offload", [(2, 0), (2, 4), (4, 4)])
def test_alltoall_chunked_matches_oracle(mesh8, n_chunks, offload):
    """Chunked (n_chunks > 1) and offloaded dispatch matches the fused
    single-program oracle to fp32 tolerance. The smoke routing leaves some
    experts with zero tokens in some chunks; capacities are rounded to
    sublane (8) multiples, not GEMM-tile (128) multiples."""
    cfg = moe_cfg()
    ffn, _ = split_params(modules.init_moe(KEY, cfg))
    x = rand((8, 16, cfg.d_model), k=9, scale=0.3)
    y_ref, _ = modules.apply_moe(ffn, cfg, RUN, x)
    zcfg = Z.ZebraConfig(mode="alltoall", capacity_factor=99.0,
                         batch_axes=("data", "model"), n_chunks=n_chunks,
                         offload_experts=offload)
    with mesh8:
        moe_fn = Z.make_ep_moe(mesh8, cfg, RUN, zcfg)
        y, _ = jax.jit(moe_fn)(ffn, x.reshape(-1, cfg.d_model))
    np.testing.assert_allclose(y.reshape(x.shape), y_ref, atol=1e-4)


@pytest.mark.parametrize("n_chunks,n_chunks_combine", [(2, 2), (2, 6),
                                                       (1, 1), (2, None)])
def test_alltoall_combine_chunks_decoupled_parity(mesh8, n_chunks,
                                                  n_chunks_combine):
    """Decoupled combine chunking (ZebraConfig.n_chunks_combine): the
    combine all-to-all runs at a FINER granularity than dispatch (default
    2x — combine cotangents are f32 in the backward, 2x the wire volume)
    with no numeric effect: forward AND gradients match the serialized
    path at every (dispatch, combine) chunk pairing."""
    cfg = moe_cfg()
    ffn, _ = split_params(modules.init_moe(KEY, cfg))
    x2d = rand((128, cfg.d_model), k=11, scale=0.3)

    def run(n_c, n_cc):
        zcfg = Z.ZebraConfig(mode="alltoall", capacity_factor=99.0,
                             batch_axes=("data", "model"), n_chunks=n_c,
                             n_chunks_combine=n_cc)
        with mesh8:
            moe_fn = Z.make_ep_moe(mesh8, cfg, RUN, zcfg)
            y = jax.jit(moe_fn)(ffn, x2d)[0]
            g = jax.jit(jax.grad(
                lambda f, xx: jnp.sum(moe_fn(f, xx)[0] ** 2)))(ffn, x2d)
        return y, g

    y_ref, g_ref = run(1, 1)
    y, g = run(n_chunks, n_chunks_combine)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g_ref, g)))
    assert err < 1e-3, err


def test_combine_chunks_must_divide_dispatch_chunks(mesh8):
    cfg = moe_cfg()
    zcfg = Z.ZebraConfig(mode="alltoall", batch_axes=("data", "model"),
                         n_chunks=2, n_chunks_combine=3)
    with pytest.raises(AssertionError, match="multiple of n_chunks"):
        Z.make_ep_moe(mesh8, cfg, RUN, zcfg)


def test_alltoall_chunked_grads_match_serialized(mesh8):
    """Gradients through the chunked+offloaded pipeline equal the
    serialized (n_chunks=1, no offload) path's."""
    cfg = moe_cfg()
    ffn, _ = split_params(modules.init_moe(KEY, cfg))
    x2d = rand((128, cfg.d_model), k=10, scale=0.3)

    def grads(n_chunks, offload):
        zcfg = Z.ZebraConfig(mode="alltoall", capacity_factor=99.0,
                             batch_axes=("data", "model"),
                             n_chunks=n_chunks, offload_experts=offload)
        with mesh8:
            moe_fn = Z.make_ep_moe(mesh8, cfg, RUN, zcfg)
            return jax.jit(jax.grad(
                lambda f, xx: jnp.sum(moe_fn(f, xx)[0] ** 2)))(ffn, x2d)

    g_ser = grads(1, 0)
    g_chk = grads(2, 4)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g_ser, g_chk)))
    assert err < 1e-3, err


def test_alltoall_offload_single_unified_call(mesh8):
    """ACCEPTANCE (engine level): with offload_experts > 0 and n_chunks=1
    the whole expert hop — local AND remote experts — is ONE unified
    grouped-GEMM custom_vjp with one kernel call per projection
    direction."""
    cfg = moe_cfg()
    run = dataclasses.replace(RUN, use_gmm_kernel=True)
    ffn, _ = split_params(modules.init_moe(KEY, cfg))
    x2d = rand((128, cfg.d_model), k=11, scale=0.3)
    zcfg = Z.ZebraConfig(mode="alltoall", capacity_factor=99.0,
                         batch_axes=("data", "model"), n_chunks=1,
                         offload_experts=4)
    with mesh8:
        moe_fn = Z.make_ep_moe(mesh8, cfg, run, zcfg)
        jx = jax.make_jaxpr(moe_fn)(ffn, x2d)
    vjps = _count_eqns(jx.jaxpr,
                       lambda e: e.primitive.name == "custom_vjp_call_jaxpr")
    assert len(vjps) == 1
    kernels = _count_eqns(jx.jaxpr,
                          lambda e: e.primitive.name == "pallas_call")
    assert len(kernels) == 2


# ---------------------------------------------------------------------------
# Overlap-aware cost model (simulator / planner / Asym-EA)
# ---------------------------------------------------------------------------

def _sim_cfg(L, n):
    return ModelConfig(name="sim", family="moe", n_layers=L, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                       pattern=(LayerSpec(ffn="moe"),), n_experts=n, top_k=2)


def _times(t_attn=1.0, t_exp=1.0, t_exp_attn=0.75):
    from repro.core.profiler import LayerTimes
    return LayerTimes(t_attn=t_attn, t_exp=t_exp, t_exp_attn=t_exp_attn,
                      t_exp_on_exp=t_exp, t_attn_on_exp=2.0)


def test_exposed_comm_properties():
    assert exposed_comm(1.0, 0.5, 1) == 1.0          # serialized: all exposed
    assert exposed_comm(0.0, 1.0, 4) == 0.0
    # fully hidden tail: only the first chunk's wire time stays exposed
    assert abs(exposed_comm(1.0, 100.0, 4) - 0.25) < 1e-12
    # nothing to hide under: still the full transfer
    assert abs(exposed_comm(1.0, 0.0, 4) - 1.0) < 1e-12
    # monotone nonincreasing in n_chunks, bounded below by t_comm/q
    prev = exposed_comm(1.0, 0.8, 1)
    for q in (2, 3, 4, 8):
        cur = exposed_comm(1.0, 0.8, q)
        assert cur <= prev + 1e-12
        assert cur >= 1.0 / q - 1e-12
        prev = cur


def test_chunked_dispatch_shrinks_sim_iter_time():
    cfg = _sim_cfg(8, 8)
    t = _times(1.0, 1.2)
    comm = CommTimes(0.5, 0.5)
    z1 = simulate_hetermoe(cfg, t, comm, 4, 1, 1, n_chunks=1)
    z4 = simulate_hetermoe(cfg, t, comm, 4, 1, 1, n_chunks=4)
    assert z4.iter_time < z1.iter_time
    # compute totals are untouched — only exposed link time shrinks
    assert abs(z4.attn_busy - z1.attn_busy) < 1e-9


def test_asym_ea_does_not_double_count_hidden_a2a():
    """Serialized comm joins the bubble and increases offload; once the
    planner reports only the exposed residue of a chunked dispatch, the
    offload decision shrinks back toward the comm-free one. n_max is set
    high so the memory cap's alpha-damping does not mask the effect."""
    kw = dict(n_min=0, n_max=40)
    base = asym_ea_offload(8, 6, 1, 1, 1.0, 0.75, 1.2, **kw)
    full = asym_ea_offload(8, 6, 1, 1, 1.0, 0.75, 1.2,
                           t_comm_exposed=0.6, **kw)
    hidden = asym_ea_offload(8, 6, 1, 1, 1.0, 0.75, 1.2,
                             t_comm_exposed=exposed_comm(0.6, 1.2, 4), **kw)
    assert full.t_gather > hidden.t_gather > base.t_gather
    assert sum(full.offload) > sum(hidden.offload) >= sum(base.offload)


def test_planner_overlap_aware():
    """plan_zp_group sweeps n_chunks; the chosen plan is never worse than
    the forced-serialized plan and records the chunking it priced."""
    from repro.core import hardware as HW
    from repro.core import planner
    from repro.core.profiler import ZPGroupShape
    cfg = registry.get_config("mixtral-w1")
    zp = ZPGroupShape(M=4, N=4, attn_class=HW.A40, exp_class=HW.V100)
    serialized = planner.plan_zp_group(cfg, zp, 8, 1024, n_chunks=1)
    best = planner.plan_zp_group(cfg, zp, 8, 1024)
    assert serialized.n_chunks == 1
    assert best.n_chunks in (1, 2, 4)
    assert best.predicted.iter_time <= serialized.predicted.iter_time
    # overlap-aware LayerTimes carry the a2a wire times
    assert best.times.t_dispatch > 0.0 and best.times.t_combine > 0.0


# ---------------------------------------------------------------------------
# Satellites: dense-mode routing default + VMEM-budget block candidates
# ---------------------------------------------------------------------------

def test_default_runconfig_routes_through_fused_pipeline():
    """Serve/train paths (RunConfig defaults) ride the single-pack fused
    pipeline; the O(E) einsum stays behind the explicit 'dense' reference
    impl. Structural check: default-run apply_moe at a training shape has
    exactly the gather path's ONE pack scatter, not the dense mode's
    scatter-add gate table."""
    assert RunConfig().moe_impl == "gather"
    cfg = moe_cfg(cap=99.0)
    p, _ = split_params(modules.init_moe(KEY, cfg))
    x = rand((4, 256, cfg.d_model), k=12, scale=0.5)
    run = RunConfig(policy=Policy(compute_dtype=jnp.float32))
    jx = jax.make_jaxpr(lambda x_: modules.apply_moe(p, cfg, run, x_)[0])(x)
    scatters = _count_eqns(jx.jaxpr,
                           lambda e: e.primitive.name == "scatter")
    assert len(scatters) == 1, [e.primitive.name for e in scatters]


def test_glu_block_candidates_fit_vmem_budget():
    cands = gmm_kernel.glu_block_candidates()
    assert cands and (128, 128) in cands
    for bm, bn in cands:
        assert gmm_kernel.glu_vmem_bytes(bm, 128, bn) \
            <= gmm_kernel.VMEM_BUDGET_BYTES
    # budget actually binds: a deliberately absurd tile must be rejected
    assert not gmm_kernel.glu_block_candidates(ms=(8192,), ns=(8192,))
