"""Gradient compression (error feedback) + gradient accumulation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.models.config import ShapeConfig
from repro.models.modules import Policy, RunConfig
from repro.train import compression as comp
from repro.train import optimizer as opt
from repro.train.step import make_train_program

RUN = RunConfig(policy=Policy(compute_dtype=jnp.float32), moe_impl="gather")


def test_compress_roundtrip_bounded_error():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3.0
    q, scale = comp.compress(g)
    g_hat = comp.decompress(q, scale)
    assert float(jnp.max(jnp.abs(g - g_hat))) <= float(scale) / 2 + 1e-6


def test_error_feedback_removes_bias():
    """With error feedback, the accumulated applied gradient converges to
    the accumulated true gradient (bias -> 0)."""
    key = jax.random.PRNGKey(1)
    g_true = jax.random.normal(key, (64,)) * 1e-3  # small: heavy quant error
    err = jnp.zeros((64,))
    applied_sum = jnp.zeros((64,))
    for _ in range(200):
        corrected, new_err_fn = comp.apply_error_feedback(g_true, err)
        q, s = comp.compress(corrected)
        g_hat = comp.decompress(q, s)
        err = new_err_fn(g_hat)
        applied_sum = applied_sum + g_hat
    rel = float(jnp.linalg.norm(applied_sum - 200 * g_true)
                / jnp.linalg.norm(200 * g_true))
    assert rel < 0.02, rel
    # without error feedback the same setup keeps a persistent bias
    applied_nf = jnp.zeros((64,))
    for _ in range(200):
        q, s = comp.compress(g_true)
        applied_nf = applied_nf + comp.decompress(q, s)
    rel_nf = float(jnp.linalg.norm(applied_nf - 200 * g_true)
                   / jnp.linalg.norm(200 * g_true))
    assert rel < rel_nf


def test_compressed_psum_matches_mean(mesh8):
    """shard_map int8 psum with EF ~= exact mean within quant tolerance."""
    from jax.sharding import PartitionSpec as P
    key = jax.random.PRNGKey(2)
    grads = {"w": jax.random.normal(key, (8, 32))}
    err = {"w": jnp.zeros((8, 32))}

    def f(g, e):
        return comp.compressed_psum(g, e, "data")

    from repro.compat import shard_map
    out, new_err = jax.jit(shard_map(
        f, mesh8, in_specs=({"w": P("data", None)},
                            {"w": P("data", None)}),
        out_specs=({"w": P(None, None)}, {"w": P("data", None)})))(grads, err)
    want = jnp.mean(grads["w"].reshape(2, 4, 32), axis=0)
    # each data-shard row group averaged across the 2 'data' rows
    got = out["w"][:4]
    amax = float(jnp.max(jnp.abs(grads["w"])))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=amax / 127)


def test_grad_accumulation_matches_full_batch(mesh4):
    """accum_steps=2 gives the same update as the full-batch step."""
    cfg = registry.smoke_config(registry.get_config("llama3.2-3b"))
    shape = ShapeConfig("t", "train", 32, 4)
    ocfg = opt.OptimizerConfig(peak_lr=1e-3, warmup_steps=0, total_steps=5,
                               grad_clip=0.0)
    p_full = make_train_program(cfg, mesh4, RUN, shape, opt_cfg=ocfg)
    p_acc = make_train_program(cfg, mesh4, RUN, shape, opt_cfg=ocfg,
                               accum_steps=2)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (4, 32), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}
    with mesh4:
        params = p_full.init_params()
        o1 = p_full.init_opt(params)
        params2 = p_acc.init_params()  # fresh buffers (steps donate args)
        o2 = p_acc.init_opt(params2)
        pa, _, m1 = p_full.train_step(params, o1, batch)
        pb, _, m2 = p_acc.train_step(params2, o2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    # Adam divides by sqrt(nu): f32 reduction-order differences in the
    # grads are amplified to O(lr)-relative param deltas. lr=1e-3 here.
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), pa, pb)))
    assert err < 2e-4, err
