"""Unit tests for the module library (norms, rope, attention, MoE, RG-LRU)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import modules, registry
from repro.models.config import LayerSpec, ModelConfig
from repro.models.modules import Policy, RunConfig
from repro.pytree import split_params

KEY = jax.random.PRNGKey(0)
POL = Policy(compute_dtype=jnp.float32)
RUN = RunConfig(policy=POL)


def small_cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=128,
                pattern=(LayerSpec(),))
    base.update(kw)
    return ModelConfig(**base)


def test_rmsnorm_unit_scale():
    cfg = small_cfg()
    p, _ = split_params(modules.init_norm(cfg))
    x = jax.random.normal(KEY, (3, 5, 64)) * 7.0
    y = modules.apply_norm(p, x, POL)
    rms = jnp.sqrt(jnp.mean(y ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(KEY, (1, 8, 2, 32))
    pos = jnp.arange(8)[None, :]
    y = modules.apply_rope(x, pos, 1e4)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), atol=1e-4)
    # dot products depend only on relative offset
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 1, 1, 32))
    def dot_at(pq, pk):
        qr = modules.apply_rope(q, jnp.array([[pq]]), 1e4)
        kr = modules.apply_rope(k, jnp.array([[pk]]), 1e4)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4


def test_attention_mask_window():
    m = modules.attention_mask(jnp.arange(6), jnp.arange(6), True, 3)
    want = np.tril(np.ones((6, 6), bool)) & ~np.tril(np.ones((6, 6), bool), -3)
    np.testing.assert_array_equal(np.asarray(m), want)


def test_gqa_matches_repeated_heads():
    """GQA with KH groups == MHA with kv heads repeated."""
    B, S, H, KH, hd = 2, 16, 4, 2, 8
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KH, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KH, hd))
    mask = modules.attention_mask(jnp.arange(S), jnp.arange(S), True, 0)
    o1 = modules.ref_attention(q, k, v, mask, hd ** -0.5, 0.0, POL)
    kr = jnp.repeat(k, H // KH, axis=2)
    vr = jnp.repeat(v, H // KH, axis=2)
    o2 = modules.ref_attention(q, kr, vr, mask, hd ** -0.5, 0.0, POL)
    np.testing.assert_allclose(o1, o2, atol=1e-5)


def test_decode_cache_matches_full_forward():
    """Incremental KV-cache attention == full-sequence attention."""
    cfg = small_cfg()
    p, _ = split_params(modules.init_attention(KEY, cfg))
    B, S = 2, 12
    x = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    full, _ = modules.apply_attention(p, cfg, RUN, x, pos, causal=True)
    cache = modules.init_attention_cache(cfg, B, S, 0, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = modules.apply_attention(
            p, cfg, RUN, x[:, t:t + 1], pos[:, t:t + 1], causal=True,
            cache=cache, cache_index=jnp.asarray(t))
        outs.append(o)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(inc, full, atol=1e-4)


def test_ring_prefill_larger_than_window_then_decode():
    """Prefill with S >> window writes only the surviving keys; subsequent
    decode matches the full windowed computation (recurrentgemma@32k path)."""
    cfg = small_cfg(window=4)
    p, _ = split_params(modules.init_attention(KEY, cfg))
    B, S, W, extra = 1, 11, 4, 3
    x = jax.random.normal(KEY, (B, S + extra, cfg.d_model)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(S + extra), (B, S + extra))
    full, _ = modules.apply_attention(p, cfg, RUN, x, pos, causal=True,
                                      window=W)
    cache = modules.init_attention_cache(cfg, B, S + extra, W, jnp.float32)
    o_pre, cache = modules.apply_attention(
        p, cfg, RUN, x[:, :S], pos[:, :S], causal=True, window=W,
        cache=cache, cache_index=jnp.asarray(0))
    np.testing.assert_allclose(o_pre, full[:, :S], atol=1e-5)
    for t in range(S, S + extra):
        o, cache = modules.apply_attention(
            p, cfg, RUN, x[:, t:t + 1], pos[:, t:t + 1], causal=True,
            window=W, cache=cache, cache_index=jnp.asarray(t))
        np.testing.assert_allclose(o, full[:, t:t + 1], atol=1e-5)


def test_ring_cache_local_attention_matches_full():
    """Windowed ring-buffer cache == full computation with window mask."""
    cfg = small_cfg(window=4)
    p, _ = split_params(modules.init_attention(KEY, cfg))
    B, S, W = 1, 14, 4
    x = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    full, _ = modules.apply_attention(p, cfg, RUN, x, pos, causal=True,
                                      window=W)
    cache = modules.init_attention_cache(cfg, B, S, W, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = modules.apply_attention(
            p, cfg, RUN, x[:, t:t + 1], pos[:, t:t + 1], causal=True,
            window=W, cache=cache, cache_index=jnp.asarray(t))
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, axis=1), full, atol=1e-4)


def test_chunked_attention_matches_ref():
    B, S, H, KH, hd = 1, 300, 4, 2, 16
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KH, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KH, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    for unroll in (False, True):
        o = modules.chunked_attention(q, k, v, pos, pos, causal=True,
                                      window=0, scale=hd ** -0.5, softcap=0.0,
                                      policy=POL, chunk_q=128, unroll=unroll)
        m = modules.attention_mask(pos, pos, True, 0)
        want = modules.ref_attention(q, k, v, m, hd ** -0.5, 0.0, POL)
        np.testing.assert_allclose(o, want, atol=1e-5)


def test_moe_dense_equals_gather():
    cfg = small_cfg(family="moe", n_experts=4, top_k=2,
                    pattern=(LayerSpec(ffn="moe"),))
    p, _ = split_params(modules.init_moe(KEY, cfg))
    x = jax.random.normal(KEY, (2, 8, cfg.d_model)) * 0.5
    y1, a1 = modules.apply_moe(p, cfg, dataclasses.replace(RUN,
                                                           moe_impl="dense"), x)
    y2, a2 = modules.apply_moe(p, cfg, dataclasses.replace(RUN,
                                                           moe_impl="gather"), x)
    np.testing.assert_allclose(y1, y2, atol=1e-5)
    np.testing.assert_allclose(a1["moe_aux_loss"], a2["moe_aux_loss"],
                               atol=1e-6)


def test_moe_gather_with_gmm_kernel():
    cfg = small_cfg(family="moe", n_experts=4, top_k=2,
                    pattern=(LayerSpec(ffn="moe"),))
    p, _ = split_params(modules.init_moe(KEY, cfg))
    # M = 2*256*2 = 1024 > E*block_m/(E-1) threshold: stays on the packed
    # pipeline so the Pallas kernel path is actually exercised (smaller
    # shapes auto-route BOTH runs to the group-dense fallback).
    x = jax.random.normal(KEY, (2, 256, cfg.d_model)) * 0.5
    run_g = dataclasses.replace(RUN, moe_impl="gather")
    run_k = dataclasses.replace(RUN, moe_impl="gather", use_gmm_kernel=True)
    y1, _ = modules.apply_moe(p, cfg, run_g, x)
    y2, _ = modules.apply_moe(p, cfg, run_k, x)
    np.testing.assert_allclose(y1, y2, atol=1e-4)


def test_router_top_k_weights_normalized():
    cfg = small_cfg(family="moe", n_experts=8, top_k=3,
                    pattern=(LayerSpec(ffn="moe"),))
    p, _ = split_params(modules.init_moe(KEY, cfg))
    x = jax.random.normal(KEY, (16, cfg.d_model))
    w, idx, aux = modules.moe_route(p["router"], cfg, POL, x)
    np.testing.assert_allclose(jnp.sum(w, -1), 1.0, atol=1e-6)
    assert idx.shape == (16, 3)
    assert int(jnp.max(idx)) < 8
    # top-k indices are distinct per token
    assert all(len(set(np.asarray(idx)[i].tolist())) == 3 for i in range(16))


def test_rglru_scan_matches_loop():
    cfg = small_cfg(family="hybrid", lru_width=32)
    p, _ = split_params(modules.init_rglru(KEY, cfg))
    x = jax.random.normal(KEY, (1, 10, cfg.d_model)) * 0.5
    y_full, _ = modules.apply_rglru(p, cfg, RUN, x)
    # token-by-token with state
    st = modules.init_rglru_state(cfg, 1, jnp.float32)
    outs = []
    for t in range(10):
        o, st = modules.apply_rglru(p, cfg, RUN, x[:, t:t + 1], st)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), y_full, atol=1e-4)


def test_causal_conv1d_state_consistency():
    W, C = 4, 8
    conv_w = jax.random.normal(KEY, (W, C))
    conv_b = jnp.zeros((C,))
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 12, C))
    full, _ = modules.causal_conv1d(x, conv_w, conv_b)
    y1, st = modules.causal_conv1d(x[:, :7], conv_w, conv_b)
    y2, _ = modules.causal_conv1d(x[:, 7:], conv_w, conv_b, state=st)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), full, atol=1e-5)
