"""Prefix-cached copy-on-write paged KV (DESIGN.md §14).

Four layers of coverage, host-side first:

* property: random share / COW-fork / free / pin interleavings conserve
  pages exactly (refcount ``check()`` after EVERY op, full-pool drain at
  the end) and COW never mutates a page with refcount > 1;
* property: the radix :class:`PrefixIndex` serves exactly the
  longest-common-prefix line count a brute-force oracle over every
  inserted sequence predicts;
* engine: prefix caching ON is TOKEN-EXACT against OFF on a
  shared-prefix trace, COW forks actually fire, and a flushed cache
  leaves zero pages in use;
* disagg: a full-hit request reaches decode with ZERO KV transfer.

Runs under real hypothesis when installed and the vendored stub
(tests/_stubs) otherwise.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serve.kv_blocks import BlockAllocator, pages_for
from repro.serve.prefix_index import PrefixIndex

pytestmark = pytest.mark.prefix  # CI prefix-smoke job slice

PAGE = 4


def _alloc(n_pages=32, max_pages=8):
    return BlockAllocator(n_pages, PAGE, max_pages)


# ---------------------------------------------------------------------------
# Refcount + COW unit coverage
# ---------------------------------------------------------------------------

def test_share_pages_aliases_and_draws_only_the_tail():
    a = _alloc()
    assert a.allocate(1, 10)                 # 3 pages
    donor = list(a.tables[1])
    assert a.share_pages(2, 10, donor[:2])   # alias 2, draw 1 fresh
    assert a.tables[2][:2] == donor[:2]
    assert a.pages_in_use == 4               # 3 + 1 fresh, 2 aliased
    assert a.is_shared(donor[0]) and a.is_shared(donor[1])
    assert not a.is_shared(donor[2])
    a.check()


def test_share_is_all_or_nothing_and_keeps_donor_refs():
    a = BlockAllocator(4, PAGE, 8)
    assert a.allocate(1, 3 * PAGE)           # 3 of 4 pages
    donor = list(a.tables[1])
    # needs 2 fresh on top of 1 shared, only 1 free -> refused whole
    assert not a.share_pages(2, 3 * PAGE, donor[:1])
    assert a.ref[donor[0]] == 1              # incref rolled back
    a.check()


def test_cow_fork_gives_private_page_and_never_frees_the_shared_one():
    a = _alloc()
    assert a.allocate(1, 2 * PAGE)
    donor = list(a.tables[1])
    assert a.share_pages(2, 2 * PAGE, donor)
    old, new = a.cow_fork(2, 1)
    assert old == donor[1] and new != old
    assert a.tables[2] == [donor[0], new]
    assert a.ref[old] == 1                   # rid 1 still owns it
    assert a.ref[new] == 1 and not a.is_shared(new)
    assert a.n_cow_forks == 1
    a.check()
    with pytest.raises(AssertionError, match="exclusively-owned"):
        a.cow_fork(2, 1)                     # COW on a private page is a bug


def test_export_refuses_shared_pages():
    a = _alloc()
    assert a.allocate(1, PAGE)
    assert a.share_pages(2, PAGE, a.tables[1])
    with pytest.raises(AssertionError, match="shared"):
        a.export_pages(1)


def test_pin_outlives_owner_and_unpin_frees():
    a = _alloc()
    assert a.allocate(1, PAGE)
    page = a.tables[1][0]
    a.pin(page)
    a.free(1)
    assert page in a.ref and a.pages_in_use == 1   # survives the owner
    a.check()
    a.unpin(page)
    assert a.pages_in_use == 0
    a.check()


# ---------------------------------------------------------------------------
# Property: share/fork/free/pin interleavings conserve pages exactly
# ---------------------------------------------------------------------------

def _shared_slots(a, rid):
    return [i for i, p in enumerate(a.tables[rid]) if a.is_shared(p)]


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9),       # op selector
                          st.integers(0, 4),       # rid
                          st.integers(1, 40),      # token count
                          st.integers(0, 7)),      # aux (slot / donor pick)
                min_size=0, max_size=80))
def test_share_cow_free_interleavings_conserve_pages(script):
    a = _alloc(n_pages=24)
    pinned = []
    for sel, rid, n_tokens, aux in script:
        ops = ["pin", "unpin"]
        if rid in a.tables:
            ops += ["free", "extend"]
            if _shared_slots(a, rid):
                ops += ["cow_fork"]
        else:
            ops += ["share", "allocate"]
        op = ops[sel % len(ops)]
        free_before = a.n_free
        if op == "allocate":
            ok = a.allocate(rid, n_tokens)
            want = pages_for(n_tokens, PAGE)
            assert a.n_free == free_before - (want if ok else 0)
        elif op == "share":
            donors = [r for r in a.tables if a.tables[r]]
            shared = []
            if donors:
                donor = donors[aux % len(donors)]
                k = aux % (len(a.tables[donor]) + 1)
                shared = a.tables[donor][:k]
            want = pages_for(n_tokens, PAGE)
            shared = shared[:want]
            ok = a.share_pages(rid, n_tokens, shared)
            # conservation: only the tail beyond the aliased run is drawn
            assert a.n_free == free_before - \
                ((want - len(shared)) if ok else 0)
            if ok:
                assert a.tables[rid][:len(shared)] == shared
        elif op == "extend":
            ok = a.extend(rid, 1)
            assert a.n_free == free_before - (1 if ok else 0)
        elif op == "cow_fork":
            slots = _shared_slots(a, rid)
            slot = slots[aux % len(slots)]
            old = a.tables[rid][slot]
            ref_before = a.ref[old]
            try:
                _, new = a.cow_fork(rid, slot)
            except MemoryError:
                assert a.n_free == 0
            else:
                # COW never mutates the shared page: it is still resident
                # with exactly one reference moved off it.
                assert a.ref[old] == ref_before - 1 and old in a.ref
                assert a.ref[new] == 1
                assert a.n_free == free_before - 1
        elif op == "free":
            dying = sum(1 for p in set(a.tables[rid])
                        if a.ref[p] == a.tables[rid].count(p))
            a.free(rid)
            assert a.n_free == free_before + dying
        elif op == "pin":
            resident = sorted(a.ref)
            if resident:
                page = resident[aux % len(resident)]
                a.pin(page)
                pinned.append(page)
                assert a.n_free == free_before
        elif op == "unpin":
            if pinned:
                page = pinned.pop(aux % len(pinned))
                dying = a.ref[page] == 1
                a.unpin(page)
                assert a.n_free == free_before + (1 if dying else 0)
        a.check()                            # conservation, every step
        assert a.pages_in_use == a.n_pages - a.n_free
    for page in pinned:
        a.unpin(page)
    for rid in list(a.tables):
        a.free(rid)
    a.check()
    assert a.pages_in_use == 0               # nothing leaked, ever


# ---------------------------------------------------------------------------
# Property: radix index == brute-force longest-common-prefix oracle
# ---------------------------------------------------------------------------

_seq = st.lists(st.integers(0, 3), min_size=1, max_size=20)


@settings(max_examples=30, deadline=None)
@given(st.lists(_seq, min_size=0, max_size=10),    # inserted sequences
       st.lists(_seq, min_size=1, max_size=8))     # queries
def test_index_matches_prefix_oracle(inserted, queries):
    a = BlockAllocator(256, PAGE, 64)
    idx = PrefixIndex(a)
    for rid, toks in enumerate(inserted):
        assert a.allocate(rid, len(toks))
        idx.insert(toks, a.tables[rid])
        a.free(rid)                          # pins keep the pages alive
        idx.check()
        a.check()
    for toks in queries:
        pages, n = idx.lookup(toks)
        want = max((len(_lcp(toks, s)) for s in inserted), default=0)
        assert n == want, f"query {toks}: served {n}, oracle {want}"
        # the page run must cover exactly the served lines
        assert len(pages) == pages_for(n, PAGE) or \
            (n == 0 and not pages)
        idx.check()
    n_pinned = idx.n_pages
    assert idx.flush() == n_pinned
    a.check()
    assert a.pages_in_use == 0               # flush recycles EVERY page


def _lcp(a, b):
    out = []
    for x, y in zip(a, b):
        if x != y:
            break
        out.append(x)
    return out


# ---------------------------------------------------------------------------
# Eviction: leaf-first LRU, capacity bound, reclaim hook
# ---------------------------------------------------------------------------

def test_capacity_evicts_leaf_first_and_keeps_hot_interior():
    a = BlockAllocator(64, PAGE, 16)
    idx = PrefixIndex(a, capacity_pages=2)
    toks = list(range(3 * PAGE))             # a 3-page chain
    assert a.allocate(0, len(toks))
    idx.insert(toks, a.tables[0])
    a.free(0)
    assert idx.n_pages == 2                  # tail leaf evicted, not root
    idx.check()
    _, n = idx.lookup(toks)
    assert n == 2 * PAGE                     # surviving prefix still serves
    idx.check()
    a.check()


def test_reclaim_hook_unwedges_allocation():
    a = BlockAllocator(4, PAGE, 8)
    idx = PrefixIndex(a)
    toks = list(range(4 * PAGE))
    assert a.allocate(0, len(toks))          # whole pool
    idx.insert(toks, a.tables[0])
    a.free(0)
    assert a.n_free == 0                     # all four pages pinned
    assert a.allocate(1, 3 * PAGE)           # eviction makes room
    assert idx.n_evicted >= 3
    a.check()
    idx.check()


# ---------------------------------------------------------------------------
# Fairness: deficit round-robin admission
# ---------------------------------------------------------------------------

def _plan_order(fair, submits):
    from repro.serve.scheduler import PrefillScheduler, Request
    s = PrefillScheduler(64, prefill_chunk=64, fair=fair)
    for rid, tenant in submits:
        s.submit(Request(rid=rid, prompt=[1, 2, 3], max_new_tokens=1,
                         tenant=tenant))
    order = []
    while s.has_work():
        chunk = s.plan(64, has_slot=lambda: True, claim_slot=lambda: 0)
        assert chunk is not None and chunk.final
        s.finish_chunk(chunk)
        order.append(chunk.request.rid)
    return order


def test_fair_admission_interleaves_a_flooding_tenant():
    burst = [(i, 0) for i in range(4)] + [(4, 1), (5, 2)]
    assert _plan_order(False, burst) == [0, 1, 2, 3, 4, 5]  # FIFO starves
    order = _plan_order(True, burst)
    # deficit round-robin: tenants 1 and 2 are not stuck behind the burst
    assert order.index(4) <= 2 and order.index(5) <= 2


def test_fair_admission_resumes_preempted_first():
    from repro.serve.scheduler import PrefillScheduler, Request
    s = PrefillScheduler(64, prefill_chunk=64, fair=True)
    s.submit(Request(rid=0, prompt=[1], max_new_tokens=1, tenant=0))
    s.requeue_front(Request(rid=9, prompt=[1], max_new_tokens=4, tenant=5),
                    [7, 8])
    chunk = s.plan(64, has_slot=lambda: True, claim_slot=lambda: 0)
    assert chunk.request.rid == 9            # resume beats fairness
    assert chunk.tokens == [1, 7, 8]


# ---------------------------------------------------------------------------
# Engine: token-exactness, COW firing, recycle-no-leak  (device)
# ---------------------------------------------------------------------------

def _tiny_deployment(prefix_on, *, disagg=False, pool_pages=None):
    from repro.launch.mesh import make_mesh
    from repro.models import registry
    from repro.models.modules import Policy, RunConfig
    from repro.serve import (DisaggCfg, PagedCfg, PrefixCacheCfg,
                             ServeConfig, build_deployment)
    cfg = registry.smoke_config(registry.get_config("llama3.2-3b"))
    mesh = make_mesh((1, 1), ("data", "model"))
    run = RunConfig(policy=Policy(), attn_impl="ref", moe_impl="gather")
    sc = ServeConfig(
        slots=2, max_len=24, prefill_chunk=16,
        paged=PagedCfg(enabled=not disagg, page_size=8,
                       pool_pages=pool_pages),
        prefix=PrefixCacheCfg(enabled=prefix_on),
        disagg=DisaggCfg(enabled=disagg))
    return cfg, build_deployment(cfg, mesh, run, sc)


def _shared_trace(vocab):
    """Two exact-repeat prompts (12 tokens: one full 8-line page + a
    4-line tail) staggered so the first FINISHES before the second
    arrives — its registered partial tail page forces the sharer to
    COW-fork mid-page — plus one cold distinct prompt."""
    from repro.serve import Request
    rng = np.random.RandomState(3)
    p = rng.randint(0, vocab, size=(12,)).astype(int).tolist()
    q = rng.randint(0, vocab, size=(10,)).astype(int).tolist()
    return [Request(rid=0, prompt=list(p), max_new_tokens=6, arrival=0.0),
            Request(rid=1, prompt=list(q), max_new_tokens=5, arrival=1.0),
            Request(rid=2, prompt=list(p), max_new_tokens=6, arrival=40.0)]


def test_prefix_cache_is_token_exact_and_forks_before_writes():
    cfg, off = _tiny_deployment(False)
    trace = _shared_trace(cfg.vocab_size)
    baseline = off.run([r for r in trace])
    cfg, on = _tiny_deployment(True)
    got = on.run([r for r in trace])
    assert got == baseline                   # caching never changes tokens
    sched = on.sched
    assert sched.prefill.n_prefix_hits >= 1
    assert sched.prefill.n_tokens_skipped >= 8
    # rid 2 mounts rid 0's registered partial tail page and must fork it
    # before its first write lands.
    assert sched.allocator.n_cow_forks >= 1
    occ = on.page_occupancy()
    assert occ["prefix_hits"] == sched.prefill.n_prefix_hits
    assert occ["tokens_skipped"] == sched.prefill.n_tokens_skipped
    sched.allocator.check()
    sched.prefix_index.check()
    # recycle-no-leak over shared + COW-forked pages: after the cache
    # lets go, the pool is EXACTLY whole again.
    sched.prefix_index.flush()
    sched.allocator.check()
    assert sched.allocator.pages_in_use == 0


def test_disagg_full_hit_skips_the_transfer():
    cfg, off = _tiny_deployment(False)
    trace = _shared_trace(cfg.vocab_size)
    baseline = off.run([r for r in trace])
    cfg, eng = _tiny_deployment(True, disagg=True)
    got = eng.run([r for r in trace])
    assert got == baseline                   # exact across deployments too
    # rid 2's whole prompt was decode-resident: it reached decode with
    # ZERO KV transfer — only the two cold requests shipped pages.
    assert eng.n_full_hits == 1
    assert eng.transfer.stats.n_transfers == 2
    eng.prefill.allocator.check()
    eng.decode.allocator.check()
    eng.decode.sched.prefix_index.check()
    eng.decode.sched.prefix_index.flush()
    eng.decode.allocator.check()
    assert eng.decode.allocator.pages_in_use == 0
