"""Expert-parallel decode serving (DESIGN.md §11).

Covers the EP decode contract end to end: placement validation (an
ep_size that does not divide the expert count is REJECTED, never
truncated), greedy token-exact parity of the EP-sharded engine against
the replicated ``ContinuousBatchingEngine`` on a MoE Poisson trace,
token-exactness ACROSS a mid-trace placement re-balance (page/slot state
survives the params swap), the routing-EMA drift trigger, the
heterogeneity-aware placement planner strictly beating round-robin on a
Zipf-routed trace in ``simulate_serve_trace``, the per-device HBM
accounting, and the kernels' small-M auto-route evaluating its crossover
at the PER-SHARD group count G/ep_size.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import planner
from repro.core import simulator as sim
from repro.core.asym_ea import asym_ea_place, round_robin_placement
from repro.core.hardware import A40, V100
from repro.core.profiler import ep_decode_step_time, expert_param_bytes
from repro.kernels import ops
from repro.launch.mesh import make_mesh
from repro.launch.serve import build_trace
from repro.models import registry, stack
from repro.models.modules import Policy, RunConfig
from repro.pytree import split_params
from repro.serve import (ContinuousBatchingEngine, GREEDY, Scheduler,
                         make_continuous_program)
from repro.serve.ep_decode import (EPContinuousBatchingEngine,
                                   EPDecodeConfig, balanced_placement,
                                   ep_hbm_budget, placement_to_perm,
                                   validate_ep_config)
from repro.serve.metrics import RoutingEMA

pytestmark = pytest.mark.ep  # CI ep-smoke job slice

RUN = RunConfig(policy=Policy(compute_dtype=jnp.float32), attn_impl="ref",
                moe_impl="gather")


@pytest.fixture(scope="module")
def moe_cfg():
    return registry.smoke_config(registry.get_config("qwen3-moe-30b-a3b"))


@pytest.fixture(scope="module")
def moe_params(moe_cfg):
    return split_params(stack.init_model(jax.random.PRNGKey(0), moe_cfg))[0]


@pytest.fixture(scope="module")
def trace(moe_cfg):
    return build_trace(seed=0, n=4, rate=0.6, prompt_len=10, gen=8,
                       vocab=moe_cfg.vocab_size, sampling=GREEDY)


@pytest.fixture(scope="module")
def ref_results(moe_cfg, moe_params, trace):
    """The replicated engine's greedy output on the shared trace."""
    mesh = make_mesh((1, 1), ("data", "model"))
    prog = make_continuous_program(moe_cfg, mesh, RUN, n_slots=3, max_len=24)
    eng = ContinuousBatchingEngine(prog, moe_params,
                                   Scheduler(3, 24, prefill_chunk=4))
    return eng.run(list(trace))


@pytest.fixture(scope="module")
def ep_mesh():
    return make_mesh((1, 2), ("data", "model"))


@pytest.fixture(scope="module")
def ep_prog(moe_cfg, ep_mesh):
    return make_continuous_program(
        moe_cfg, ep_mesh, RUN, n_slots=3, max_len=24,
        ep=EPDecodeConfig(ep_size=2, n_chunks=2))


# -- placement algebra -------------------------------------------------------

def test_round_robin_placement():
    pl = round_robin_placement(8, 2)
    assert pl == ((0, 2, 4, 6), (1, 3, 5, 7))
    assert sorted(e for s in pl for e in s) == list(range(8))
    with pytest.raises(ValueError):
        round_robin_placement(8, 3)  # 3 does not divide 8
    with pytest.raises(ValueError):
        round_robin_placement(8, 0)


def test_asym_ea_place_hot_to_fast():
    # One hot expert, seven cold; shard 1 is the fast class.
    load = [0.02, 0.65, 0.02, 0.05, 0.05, 0.05, 0.08, 0.08]
    pl = asym_ea_place(load, [1.0, 3.0], 4)
    assert sorted(e for s in pl for e in s) == list(range(8))
    assert all(len(s) == 4 for s in pl)  # exact cardinality, never ragged
    assert 1 in pl[1], "hottest expert must land on the fast shard"


def test_asym_ea_place_validation():
    with pytest.raises(ValueError):
        asym_ea_place([0.5, 0.5, 0.5], [1.0, 1.0], 2)  # 3 != 2*2
    with pytest.raises(ValueError):
        asym_ea_place([0.5, 0.5], [1.0, 0.0], 1)  # non-positive speed


def test_balanced_placement_uniform_hist_is_exact_partition():
    pl = balanced_placement([1.0 / 8] * 8, 2)
    assert sorted(e for s in pl for e in s) == list(range(8))
    assert all(len(s) == 4 for s in pl)


def test_placement_to_perm_rejects():
    with pytest.raises(ValueError):
        placement_to_perm(((0, 1, 2, 3),), 8, 2)  # wrong shard count
    with pytest.raises(ValueError):
        placement_to_perm(((0, 1, 2), (3, 4, 5, 6, 7)), 8, 2)  # ragged
    with pytest.raises(ValueError):
        placement_to_perm(((0, 1, 2, 3), (3, 4, 5, 6)), 8, 2)  # dup/missing


def test_validate_ep_config_rejects(moe_cfg, ep_mesh):
    dense = registry.smoke_config(registry.get_config("llama3.2-3b"))
    with pytest.raises(ValueError):
        validate_ep_config(dense, ep_mesh, EPDecodeConfig(ep_size=2))
    # 3 does not divide 8 experts: rejected, never truncated.
    with pytest.raises(ValueError, match="truncate"):
        validate_ep_config(moe_cfg, ep_mesh, EPDecodeConfig(ep_size=3))
    mesh1 = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError):
        validate_ep_config(moe_cfg, mesh1, EPDecodeConfig(ep_size=2))
    with pytest.raises(ValueError):
        validate_ep_config(moe_cfg, ep_mesh,
                           EPDecodeConfig(ep_size=2, n_chunks=0))
    bad = EPDecodeConfig(ep_size=2,
                         placement=((0, 1, 2, 3), (3, 4, 5, 6)))
    with pytest.raises(ValueError):
        validate_ep_config(moe_cfg, ep_mesh, bad)


# -- routing EMA -------------------------------------------------------------

def test_routing_ema_drift():
    ema = RoutingEMA(4, decay=0.5)
    uniform = [0.25] * 4
    assert ema.drift(uniform) == 0.0  # empty EMA reads as uniform
    for _ in range(8):
        ema.update(np.array([8.0, 0.0, 0.0, 0.0]))
    m = ema.merged()
    assert np.isclose(m.sum(), 1.0)
    assert m[0] > 0.9
    assert ema.drift(uniform) > 0.5  # skew is visible as TV distance
    assert ema.drift(m) < 1e-9


# -- HBM accounting ----------------------------------------------------------

@pytest.mark.parametrize("ep_size", [2, 4])
def test_ep_hbm_budget_reduction(ep_size):
    cfg = registry.get_config("qwen3-moe-30b-a3b")
    b = ep_hbm_budget(cfg, hbm_bytes=A40.mem_bytes, ep_size=ep_size,
                      page_size=16)
    assert b["expert_bytes_total"] == expert_param_bytes(cfg)
    assert b["hbm_reduction"] >= ep_size  # exact partition of the stack
    # Freed expert HBM turns into KV pages: the EP pool can only grow.
    assert b["pool_pages_ep"] >= b["pool_pages_replicated"]


# -- planner: heterogeneity-aware placement ----------------------------------

def test_planned_beats_round_robin_on_zipf_trace():
    cfg = registry.get_config("qwen3-moe-30b-a3b")
    reqs, hist = sim.zipf_poisson_trace(0, 40, 2.0, 256, 128,
                                        cfg.n_experts, zipf_s=1.4)
    plan = planner.plan_ep_decode_group(
        cfg, (A40, V100), hist, reqs, decode_batch=8, ctx=1024,
        n_chunks=2, link_bw=min(A40.link_bw, V100.link_bw))
    assert plan.placement != plan.uniform
    assert plan.placement_ratio > 1.0        # per-step analytical win
    assert plan.placement_ratio_sim > 1.0    # strictly beats round-robin
    assert plan.predicted.makespan < plan.predicted_uniform.makespan
    assert plan.hbm_reduction >= plan.ep_size
    # The hottest expert sits on the higher-HBM-bandwidth shard.
    hot = max(range(cfg.n_experts), key=lambda e: plan.hist[e])
    fast = max(range(2), key=lambda j: (A40, V100)[j].hbm_bw)
    assert hot in plan.placement[fast]


def test_ep_decode_step_time_prefers_hot_on_fast():
    cfg = registry.get_config("qwen3-moe-30b-a3b")
    hist = [0.5, 0.3] + [0.2 / 6] * 6  # experts 0,1 hot
    hot_on_fast = ((2, 3, 4, 5), (0, 1, 6, 7))  # V100 (fast HBM) = shard 1
    hot_on_slow = ((0, 1, 6, 7), (2, 3, 4, 5))
    t_good = ep_decode_step_time(cfg, 8, 1024, hot_on_fast, (A40, V100),
                                 hist)
    t_bad = ep_decode_step_time(cfg, 8, 1024, hot_on_slow, (A40, V100),
                                hist)
    assert t_good < t_bad


def test_zipf_trace_is_deterministic_and_normalized():
    r1, h1 = sim.zipf_poisson_trace(7, 10, 1.0, 64, 32, 8)
    r2, h2 = sim.zipf_poisson_trace(7, 10, 1.0, 64, 32, 8)
    assert r1 == r2 and h1 == h2
    assert abs(sum(h1) - 1.0) < 1e-9
    assert len({round(x, 12) for x in h1}) > 1  # actually skewed


# -- kernels: small-M auto-route at per-shard group count --------------------

def _moe_inputs(M, G, d=16, f=32, seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    x = jax.random.normal(ks[0], (M, d), jnp.float32)
    wg = jax.random.normal(ks[1], (G, d, f), jnp.float32) * 0.1
    wu = jax.random.normal(ks[2], (G, d, f), jnp.float32) * 0.1
    wo = jax.random.normal(ks[3], (G, f, d), jnp.float32) * 0.1
    sizes = jnp.full((G,), M // G, jnp.int32)
    return x, wg, wu, wo, sizes


def test_moe_ffn_autoroute_uses_per_shard_groups(monkeypatch):
    # M=256, G=8, block_m=128: globally 256*7 > 8*128 (packed), but at
    # ep_size=4 the per-shard count Gs=2 gives 256*1 <= 2*128 (dense).
    calls = []
    real = ops.moe_ffn_group_dense
    monkeypatch.setattr(ops, "moe_ffn_group_dense",
                        lambda *a, **kw: calls.append(1) or real(*a, **kw))
    x, wg, wu, wo, sizes = _moe_inputs(256, 8)
    ops.moe_ffn(x, wg, wu, wo, sizes, small_m=None, ep_size=4,
                interpret=True, use_kernel=False)
    assert calls, "per-shard crossover must take the group-dense route"
    calls.clear()
    ops.moe_ffn(x, wg, wu, wo, sizes, small_m=None, ep_size=1,
                interpret=True, use_kernel=False)
    assert not calls, "global crossover must stay on the packed pipeline"


def test_packed_multi_autoroute_uses_per_shard_groups(monkeypatch):
    calls = []
    real = ops._packed_group_dense
    monkeypatch.setattr(ops, "_packed_group_dense",
                        lambda *a, **kw: calls.append(1) or real(*a, **kw))
    buf = jax.random.normal(jax.random.PRNGKey(0), (8, 32, 16), jnp.float32)
    _, wg, wu, wo, _ = _moe_inputs(256, 8)
    ops.moe_ffn_packed_multi([buf], [wg], [wu], [wo], small_m=None,
                             ep_size=4, interpret=True, use_kernel=False)
    assert calls
    calls.clear()
    ops.moe_ffn_packed_multi([buf], [wg], [wu], [wo], small_m=None,
                             ep_size=1, interpret=True, use_kernel=False)
    assert not calls


# -- the EP engine: token-exactness ------------------------------------------

def test_ep_engine_token_exact_vs_replicated(moe_params, trace, ref_results,
                                             ep_prog):
    eng = EPContinuousBatchingEngine(ep_prog, moe_params,
                                     Scheduler(3, 24, prefill_chunk=4))
    assert eng.run(list(trace)) == ref_results
    assert eng.ema.n_updates > 0  # routed-copy histograms did flow
    assert np.isclose(eng.ema.merged().sum(), 1.0)


def test_ep_engine_token_exact_across_rebalance(moe_params, trace,
                                                ref_results, ep_prog):
    """Mid-trace re-placement (params-only swap) must not disturb page
    tables, slot state, or sampling — the generated streams stay
    bit-identical to the replicated engine's."""
    eng = EPContinuousBatchingEngine(ep_prog, moe_params,
                                     Scheduler(3, 24, prefill_chunk=4))
    pending = sorted(trace, key=lambda r: r.arrival)
    n = 0
    while pending or eng.sched.has_work() or eng._active.any():
        while pending and pending[0].arrival <= eng.tick_count:
            eng.submit(pending.pop(0))
        eng.tick()
        n += 1
        if n == 5:  # mid-trace: slots live, pages allocated
            assert eng.rebalance(tuple(reversed(eng.placement)))
        assert n < 500
    assert eng.n_rebalances == 1
    assert eng.results == ref_results
