"""Disaggregated prefill/decode serving (DESIGN.md §10).

Covers the handoff contract end to end: allocator exactly-once page
ownership ACROSS export/import (the live -> exported -> released state
machine), the page-granular transfer path (structural pages-only
guarantee — no contiguous cache ever materializes), stale-line
unreachability in the destination pool after a transfer, greedy
token-exact parity of the disagg deployment against the unified
``ContinuousBatchingEngine`` on a Poisson trace, mid-stream decode-pool
OOM -> preempt + re-prefill determinism under REAL sampling, the
serving-mode planner picking the role split, and the simulated goodput
acceptance at an A40+V100-style speed ratio.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import planner
from repro.core import simulator as sim
from repro.core.hardware import A40, V100
from repro.core.profiler import (ZPGroupShape, decode_step_time,
                                 prefill_chunk_time, serve_profile)
from repro.launch.mesh import make_mesh
from repro.launch.serve import build_trace
from repro.models import stack
from repro.models.config import ModelConfig
from repro.models.modules import Policy, RunConfig
from repro.pytree import split_params
from repro.serve import (BlockAllocator, ContinuousBatchingEngine, GREEDY,
                         Request, SamplingParams, Scheduler,
                         make_continuous_program, pages_for)
from repro.serve.disagg import make_disagg

pytestmark = pytest.mark.disagg  # CI disagg-smoke job slice

RUN = RunConfig(policy=Policy(compute_dtype=jnp.float32), attn_impl="ref",
                moe_impl="gather")

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64)


@pytest.fixture(scope="module")
def mesh1():
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def tiny_params():
    return split_params(stack.init_model(jax.random.PRNGKey(0), TINY))[0]


def _prompt(seed, n, vocab=64):
    return np.random.RandomState(seed).randint(0, vocab, size=(n,)).tolist()


def _disagg(cfg, mesh, params, **kw):
    kw.setdefault("decode_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 6)
    return make_disagg(cfg, mesh, RUN, params, **kw)


# ---------------------------------------------------------------------------
# Allocator ownership transfer (host-side, no jax)
# ---------------------------------------------------------------------------

def test_allocator_exactly_once_across_export_import():
    """The three-state ownership machine: live -> exported -> released.
    check() holds at every step of the handoff, on both allocators."""
    src = BlockAllocator(n_pages=6, page_size=8, max_pages_per_seq=4)
    dst = BlockAllocator(n_pages=5, page_size=8, max_pages_per_seq=4)
    assert src.allocate(7, 20)  # 3 pages
    pages = src.export_pages(7)
    assert len(pages) == 3 and 7 not in src.tables
    src.check()  # exported pages still tracked exactly once
    assert src.n_free == 3  # NOT freed while the transfer is in flight
    got = dst.import_pages(7, 20)
    assert got is not None and len(got) == 3
    dst.check()
    src.release_exported(7)
    src.check()
    assert src.n_free == 6 and not src.exported
    # double export / double import are programming errors
    assert dst.n_lines(7) == 24
    dst.free(7)
    dst.check()
    assert dst.n_free == 5


def test_allocator_abort_export_restores_live_table():
    a = BlockAllocator(n_pages=4, page_size=8, max_pages_per_seq=4)
    assert a.allocate(1, 17)
    before = list(a.tables[1])
    a.export_pages(1)
    a.abort_export(1)
    assert a.tables[1] == before
    a.check()


def test_import_pages_all_or_nothing():
    dst = BlockAllocator(n_pages=2, page_size=8, max_pages_per_seq=4)
    assert dst.import_pages(0, 24) is None  # 3 pages > pool
    dst.check()
    assert dst.n_free == 2 and 0 not in dst.tables
    assert dst.import_pages(0, 16) is not None
    dst.check()


# ---------------------------------------------------------------------------
# Transfer path: pages only, structurally
# ---------------------------------------------------------------------------

def test_transfer_ships_pages_only_no_contiguous_cache(mesh1, tiny_params):
    """STRUCTURAL acceptance: every array that crosses the transfer path
    is page-granular [k <= chunk_pages, page_size, ...] — the handoff
    never re-materializes a contiguous [tokens, ...] cache — and exactly
    the request's allocated pages ship, not max_len worth."""
    max_len, ps = 32, 8
    ctl = _disagg(TINY, mesh1, tiny_params, max_len=max_len, page_size=ps,
                  transfer_chunk_pages=2)
    prompt = _prompt(3, 11)  # 11 tokens -> 2 pages (NOT max_len/ps = 4)
    res = ctl.run([Request(rid=0, prompt=prompt, max_new_tokens=4)])
    assert len(res[0]) == 4
    st = ctl.transfer.stats
    assert st.n_transfers == 1
    assert st.n_pages == pages_for(len(prompt), ps) == 2
    assert st.shipped_shapes, "nothing crossed the transfer engine"
    for shape in st.shipped_shapes:
        # tails: [k, page_size, ...]; scan-stacked blocks: [L, k, ps, ...]
        page_dims = shape if len(shape) in (2, 4) else shape[1:]
        assert page_dims[0] <= ctl.transfer.chunk_pages, shape
        assert page_dims[1] == ps, shape
        assert max_len not in shape, \
            f"contiguous max_len-sized buffer on the transfer path: {shape}"
    ctl.prefill.allocator.check()
    ctl.decode.allocator.check()
    assert ctl.prefill.allocator.n_free == ctl.prefill.allocator.n_pages
    assert ctl.decode.allocator.n_free == ctl.decode.allocator.n_pages


def test_stale_lines_unreachable_after_transfer(mesh1, tiny_params):
    """Serve A then B through the SAME destination pages (decode pool of
    exactly one sequence): B's tokens must match a fresh controller even
    though its imported pages overwrite only B's lines and A's stale KV
    sits beyond B's frontier in the same physical pages."""
    req_a = Request(rid=0, prompt=_prompt(21, 10), max_new_tokens=4)
    req_b = Request(rid=1, prompt=_prompt(22, 7), max_new_tokens=6)
    ctl = _disagg(TINY, mesh1, tiny_params, decode_slots=1, max_len=24,
                  decode_pages=3, record_logits=True)
    res = ctl.run([req_a, req_b])
    assert ctl.decode.allocator.pages_in_use == 0  # B reused A's pages
    fresh = _disagg(TINY, mesh1, tiny_params, decode_slots=1, max_len=24,
                    decode_pages=3, record_logits=True)
    res_f = fresh.run([Request(rid=1, prompt=req_b.prompt,
                               max_new_tokens=6)])
    assert res[1] == res_f[1]
    for a, b in zip(ctl.logits[1], fresh.logits[1]):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Engine parity: disagg vs unified continuous batching
# ---------------------------------------------------------------------------

def test_disagg_greedy_parity_with_unified_poisson(mesh1, tiny_params):
    """Token-exact greedy parity between the role-split deployment and the
    unified paged ContinuousBatchingEngine on a mixed Poisson trace."""
    trace = build_trace(seed=5, n=6, rate=0.7, prompt_len=14, gen=8,
                        vocab=TINY.vocab_size, sampling=GREEDY)

    prog = make_continuous_program(TINY, mesh1, RUN, n_slots=2, max_len=32,
                                   page_size=8)
    with mesh1:
        p = jax.device_put(tiny_params, prog.param_shardings)
    alloc = BlockAllocator(prog.n_pages, prog.page_size, prog.max_pages)
    unified = ContinuousBatchingEngine(
        prog, p, Scheduler(2, 32, prefill_chunk=6, allocator=alloc))
    res_u = unified.run([Request(rid=r.rid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens,
                                 arrival=r.arrival) for r in trace])

    ctl = _disagg(TINY, mesh1, tiny_params)
    res_d = ctl.run(trace)
    assert res_d == res_u
    assert not ctl.rejected and sorted(res_d) == [r.rid for r in trace]


def test_disagg_moe_poisson_matches_reference(mesh1):
    """Smoke MoE arch through the disagg deployment: every request
    completes and matches the unbatched greedy reference."""
    from repro.models import registry
    cfg = registry.smoke_config(registry.get_config("qwen3-moe-30b-a3b"))
    params0 = split_params(stack.init_model(jax.random.PRNGKey(0), cfg))[0]
    ctl = _disagg(cfg, mesh1, params0, max_len=30, prefill_chunk=4)
    trace = build_trace(seed=0, n=4, rate=0.6, prompt_len=16, gen=10,
                        vocab=cfg.vocab_size, sampling=GREEDY)
    res = ctl.run(trace)
    assert sorted(res) == [r.rid for r in trace]
    for r in trace:
        seq = jnp.asarray(r.prompt, jnp.int32)[None]
        want = []
        for _ in range(r.max_new_tokens):
            logits, _, _ = stack.apply_model(params0, cfg, RUN, seq)
            nxt = int(jnp.argmax(logits[0, -1]))
            want.append(nxt)
            seq = jnp.concatenate([seq, jnp.asarray([[nxt]], jnp.int32)], 1)
        assert res[r.rid] == want, (r.rid, res[r.rid], want)


def test_decode_pool_oom_preempts_and_reprefills(mesh1, tiny_params):
    """Mid-stream decode-pool OOM: the newest request is preempted, its
    decode pages free, and it REPLAYS prompt+generated through the prefill
    worker — token-for-token equal to the ample-pool run under real
    sampling (temperature/top-k/top-p), not just greedy."""
    sp = SamplingParams(temperature=0.8, top_k=5, top_p=0.9)
    reqs = [Request(rid=i, prompt=_prompt(60 + i, 9 + i),
                    max_new_tokens=12, sampling=sp) for i in range(3)]
    ample = _disagg(TINY, mesh1, tiny_params)
    res_a = ample.run([Request(rid=r.rid, prompt=r.prompt,
                               max_new_tokens=r.max_new_tokens,
                               sampling=sp) for r in reqs])
    assert ample.decode.sched.n_preempted == 0

    tight = _disagg(TINY, mesh1, tiny_params, decode_pages=5)
    res_t = tight.run(reqs)
    assert tight.decode.sched.n_preempted > 0, "pool was not tight enough"
    assert res_t == res_a
    # a preempted request's second trip re-exports fresh prefill pages
    assert tight.transfer.stats.n_transfers \
        >= len(reqs) + tight.decode.sched.n_preempted
    tight.decode.allocator.check()
    tight.prefill.allocator.check()


def test_disagg_per_tick_ownership_invariant(mesh1, tiny_params):
    """Drive a tight trace tick by tick and assert exactly-once page
    ownership on BOTH pools at every step, plus the decode-side device
    page-table mirror matching the decode allocator."""
    ctl = _disagg(TINY, mesh1, tiny_params, decode_pages=6)
    for i in range(4):
        ctl.submit(Request(rid=i, prompt=_prompt(i, 9 + i),
                           max_new_tokens=8))
    dec = ctl.decode
    while ctl.has_work() or dec.any_active():
        ctl.tick()
        ctl.prefill.allocator.check()
        dec.allocator.check()
        for slot in np.nonzero(dec._active)[0]:
            rid = int(dec._rid[slot])
            np.testing.assert_array_equal(
                dec._ptab[slot], dec.allocator.table(rid, dec.p.max_pages))
        assert ctl.tick_count < 500
    assert dec.allocator.pages_in_use == 0
    assert ctl.prefill.allocator.pages_in_use == 0


# ---------------------------------------------------------------------------
# Serving-mode planner + simulator
# ---------------------------------------------------------------------------

def _sim_trace(n=40, seed=0):
    rng = np.random.RandomState(seed)
    t, out = 0.0, []
    for _ in range(n):
        t += float(rng.exponential(0.25))
        out.append(sim.ServeRequest(arrival=t,
                                    prompt=int(rng.randint(512, 4096)),
                                    gen=int(rng.randint(64, 256))))
    return out


def test_serve_profile_matches_fig2_asymmetry():
    """The serving profile reproduces the paper's asymmetry: the newer
    class wins big on (attention-heavy) prefill, while decode — memory
    bound — is close, so the split prefill->new / decode->old follows."""
    from repro.models import registry
    cfg = registry.get_config("qwen3-moe-30b-a3b")
    long_ctx = 16384
    pre_a = prefill_chunk_time(cfg, 256, long_ctx, A40)
    pre_v = prefill_chunk_time(cfg, 256, long_ctx, V100)
    assert pre_v / pre_a > 1.5  # V100 lacks flash: attention gap grows
    dec_a = decode_step_time(cfg, 8, 2048, A40)
    dec_v = decode_step_time(cfg, 8, 2048, V100)
    assert dec_v / dec_a < 1.3  # decode stays efficient on the old class
    prof = serve_profile(cfg, A40, V100, chunk=256, ctx=long_ctx,
                         decode_batch=8)
    assert prof.t_page > 0 and prof.t_prefill_chunk_attn == pre_a


def test_plan_disagg_group_picks_role_split_and_goodput():
    """ACCEPTANCE: at an A40+V100 speed ratio the planner assigns prefill
    to the attention-strong class, decode to the expert class, and the
    simulated goodput of the split beats the unified lockstep engine by
    >= 1.2x on a mixed Poisson load (even though the unified baseline
    keeps BOTH devices' HBM worth of decode slots)."""
    from repro.models import registry
    cfg = registry.get_config("qwen3-moe-30b-a3b")
    zp = ZPGroupShape(M=1, N=1, attn_class=A40, exp_class=V100)
    plan = planner.plan_disagg_group(cfg, zp, _sim_trace(),
                                     prefill_chunk=256, ctx=2048,
                                     slots_per_device=8)
    assert (plan.prefill_attn, plan.prefill_exp) == (1, 0)
    assert (plan.decode_attn, plan.decode_exp) == (0, 1)
    assert plan.predicted.n_finished == 40
    assert plan.goodput_ratio >= 1.2
    assert plan.predicted.ttft_p50 < plan.predicted_unified.ttft_p50


def test_plan_disagg_group_hit_ratio_shifts_split():
    """A high expected prefix-cache hit ratio discounts the prefill leg,
    so the planner reassigns prefill devices to decode: on a decode-heavy
    load a 2+2 group plans 2 prefill devices cold but only 1 at 80% hits,
    banking the freed device as decode slots (and never losing goodput)."""
    from repro.models import registry
    rng = np.random.RandomState(0)
    t, trace = 0.0, []
    for _ in range(40):
        t += float(rng.exponential(0.2))
        trace.append(sim.ServeRequest(arrival=t,
                                      prompt=int(rng.randint(2048, 8192)),
                                      gen=int(rng.randint(256, 512))))
    cfg = registry.get_config("qwen3-moe-30b-a3b")
    zp = ZPGroupShape(M=2, N=2, attn_class=A40, exp_class=V100)
    cold = planner.plan_disagg_group(cfg, zp, trace, prefill_chunk=256,
                                     ctx=2048, slots_per_device=8)
    hot = planner.plan_disagg_group(cfg, zp, trace, prefill_chunk=256,
                                    ctx=2048, slots_per_device=8,
                                    expected_hit_ratio=0.8)
    n_pre_cold = cold.prefill_attn + cold.prefill_exp
    n_pre_hot = hot.prefill_attn + hot.prefill_exp
    assert n_pre_cold == 2 and n_pre_hot == 1  # the split moved
    assert hot.decode_attn + hot.decode_exp \
        > cold.decode_attn + cold.decode_exp
    assert hot.predicted.goodput >= cold.predicted.goodput
    assert hot.expected_hit_ratio == 0.8 and cold.expected_hit_ratio == 0.0
    with pytest.raises(ValueError):
        planner.plan_disagg_group(cfg, zp, trace, expected_hit_ratio=1.0)


def test_serve_simulator_conservation_and_monotonicity():
    """Sanity invariants: every request finishes exactly once; slower
    decode or prefill never raises goodput; the handoff cost only hurts."""
    trace = _sim_trace(20, seed=1)
    base = sim.simulate_serve_trace(trace, prefill_chunk=256,
                                    t_prefill_chunk=0.05,
                                    t_decode_step=0.03, decode_slots=8)
    assert base.n_finished == 20 and base.goodput > 0
    slower = sim.simulate_serve_trace(trace, prefill_chunk=256,
                                      t_prefill_chunk=0.05,
                                      t_decode_step=0.06, decode_slots=8)
    assert slower.goodput <= base.goodput
    shipped = sim.simulate_serve_trace(trace, prefill_chunk=256,
                                       t_prefill_chunk=0.05,
                                       t_decode_step=0.03, decode_slots=8,
                                       t_handoff=0.5)
    assert shipped.ttft_mean >= base.ttft_mean
    uni = sim.simulate_serve_trace(trace, prefill_chunk=256,
                                   t_prefill_chunk=0.05,
                                   t_decode_step=0.03, decode_slots=8,
                                   colocated=True)
    assert uni.n_finished == 20


# ---------------------------------------------------------------------------
# Dense ring-cache chunked prefill (pre-existing ROADMAP bug, fixed here)
# ---------------------------------------------------------------------------

def test_dense_ring_chunked_prefill_matches_whole_at_ring_crossings():
    """REGRESSION (ROADMAP): a prefill chunk crossing the ring edge used
    to evict lines earlier queries of the SAME chunk still needed
    (write-then-attend). Now attention reads the pre-write ring plus the
    fresh chunk keys, so dense chunked == whole prefill at every
    ring-crossing chunking, including chunks larger than the ring."""
    from repro.models.config import LayerSpec
    cfg = ModelConfig(name="tiny-win", family="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=64,
                      pattern=(LayerSpec(mixer="local_attn"),), window=8)
    params = split_params(stack.init_model(jax.random.PRNGKey(2), cfg))[0]
    prompt = jnp.asarray(_prompt(7, 21), jnp.int32)[None]
    whole, _, _ = stack.apply_model(params, cfg, RUN, prompt)
    whole = whole[:, -1]

    def chunked(chunks):
        state = stack.init_decode_state(cfg, 1, 32, jnp.float32)
        off = 0
        for c in chunks:
            logits, state, _ = stack.apply_model(
                params, cfg, RUN, prompt[:, off:off + c],
                decode_state=state, cache_index=jnp.asarray(off, jnp.int32),
                attend_to_cache=True)
            off += c
        return logits[:, -1]

    # ring C = window = 8; [6,6,6,3] crosses the edge mid-chunk, [13,8]
    # exercises the S >= C roll path with a non-empty cache.
    for chunks in ([6, 6, 6, 3], [5, 5, 5, 5, 1], [13, 8]):
        got = chunked(chunks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(whole),
                                   rtol=2e-4, atol=2e-4, err_msg=str(chunks))


def test_disagg_driver_exits_nonzero_on_unfinished(monkeypatch):
    """launch/serve.py --disagg must FAIL (non-zero) when any request is
    dropped or unfinished, so the CI disagg-smoke step actually gates."""
    from repro.launch import serve as serve_mod
    monkeypatch.setattr(serve_mod, "serve_arch",
                        lambda arch, args, serve_cfg=None: {"ok": False})
    assert serve_mod.main(["--smoke", "--disagg"]) == 1
    monkeypatch.setattr(serve_mod, "serve_arch",
                        lambda arch, args, serve_cfg=None: {"ok": True})
    assert serve_mod.main(["--smoke", "--disagg"]) == 0
