"""Algorithm 1 (Asym-EA) unit + property tests, incl. the paper's Fig. 6."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.asym_ea import (AsymEAPlan, asym_ea_offload,
                                divisibility_ok)

pytestmark = pytest.mark.zebra  # CI job slice (see .github/workflows/ci.yml)


def test_divisibility_rule():
    assert divisibility_ok(4, 4) and divisibility_ok(4, 8) \
        and divisibility_ok(8, 4)
    assert not divisibility_ok(4, 3)
    with pytest.raises(ValueError):
        asym_ea_offload(6, 4, 4, 3, 1.0, 1.0, 2.0)


def test_fig6_scenario():
    """Paper Fig. 6: expert GPUs 33% slower, n=6 experts, M=N=1.

    T_gather = T_E - T_A = 1/3. The first layer gathers its bubble; by
    layer 2 the accumulated bubble exceeds T_squeeze, so layers 2 and 3
    (0-indexed 1, 2) each offload one expert — exactly the paper's Fig. 6(b)
    placement ("we put one of the experts of the 2nd and 3rd layer to
    attention GPUs")."""
    TA = 1.0
    TE = 4.0 / 3.0
    TE_attn = TE * 3.0 / 4.0  # attention GPU computes experts 33% faster
    plan = asym_ea_offload(6, 6, 1, 1, TA, TE_attn, TE)
    assert plan.n1 == 1 and plan.n2 == 1
    assert abs(plan.t_gather - 1.0 / 3.0) < 1e-9
    # T_squeeze = (TE*N/n)*n2 + (TE_attn*N/n)*n1 = (4/3 + 1)/6 = 7/18
    assert abs(plan.t_squeeze - 7.0 / 18.0) < 1e-9
    # Fig. 6(b): no offload at layer 1, one expert at layers 2 and 3.
    assert plan.offload[:3] == (0, 1, 1)
    # steady state: leftover bubble (1/3 - 1/18 carried) keeps every later
    # layer offloading one chunk
    assert all(o == 1 for o in plan.offload[1:])


def test_no_offload_when_attention_slower():
    plan = asym_ea_offload(8, 4, 2, 2, t_attn=2.0, t_exp_attn=0.5, t_exp=1.0)
    assert plan.offload == (0, 0, 0, 0)


def test_memory_forced_offload():
    """n_min forces offload even with zero bubbles (expert GPUs too small)."""
    plan = asym_ea_offload(8, 4, 2, 2, t_attn=2.0, t_exp_attn=0.5,
                           t_exp=1.0, n_min=3)
    assert sum(plan.offload) >= 3
    assert all(o % plan.n2 == 0 for o in plan.offload)


def test_n_max_cap():
    plan = asym_ea_offload(8, 8, 1, 1, t_attn=0.1, t_exp_attn=0.05,
                           t_exp=1.0, n_max=2)
    assert sum(plan.offload) <= 2


def test_chunk_units_m_gt_n():
    # M=4, N=2: each attention GPU acquires n1=1; each expert GPU sheds n2=2
    plan = asym_ea_offload(8, 8, 4, 2, t_attn=0.5, t_exp_attn=0.2, t_exp=1.0)
    assert plan.n1 == 1 and plan.n2 == 2
    assert all(o % 2 == 0 for o in plan.offload)


def test_chunk_units_n_gt_m():
    # M=2, N=4: n1 = 2, n2 = 1
    plan = asym_ea_offload(8, 8, 2, 4, t_attn=0.5, t_exp_attn=0.2, t_exp=1.0)
    assert plan.n1 == 2 and plan.n2 == 1


@settings(max_examples=60, deadline=None)
@given(
    n=st.sampled_from([4, 8, 16, 32]),
    L=st.integers(1, 24),
    mn=st.sampled_from([(1, 1), (2, 2), (4, 2), (2, 4), (4, 8), (8, 4)]),
    t_attn=st.floats(0.05, 4.0),
    t_exp=st.floats(0.05, 4.0),
    ratio=st.floats(0.3, 1.0),
)
def test_invariants(n, L, mn, t_attn, t_exp, ratio):
    M, N = mn
    t_exp_attn = t_exp * ratio
    plan = asym_ea_offload(n, L, M, N, t_attn, t_exp_attn, t_exp)
    # offloads are whole chunks
    assert all(o % plan.n2 == 0 for o in plan.offload)
    # can never offload more experts than an expert GPU holds
    assert all(o <= n // N for o in plan.offload)
    # bubble accounting: total offloaded work never exceeds gatherable bubble
    if plan.t_gather > 0:
        chunks = sum(plan.offload) // plan.n2
        assert chunks * plan.t_squeeze <= L * plan.t_gather + 1e-9
    else:
        assert sum(plan.offload) == 0


@settings(max_examples=30, deadline=None)
@given(
    t_exp=st.floats(1.0, 4.0),
    ratio=st.floats(0.3, 1.0),
    n_max=st.integers(0, 16),
)
def test_nmax_respected(t_exp, ratio, n_max):
    plan = asym_ea_offload(16, 12, 2, 2, 0.2, t_exp * ratio, t_exp,
                           n_max=n_max)
    assert sum(plan.offload) <= max(n_max, 0)


def test_alpha_beta_exclusive():
    """Paper: at most one of alpha<1 / beta>1 is active."""
    p1 = asym_ea_offload(16, 12, 2, 2, 0.2, 0.5, 1.0, n_max=2)
    assert p1.alpha <= 1.0 and p1.beta == 1.0
    p2 = asym_ea_offload(16, 12, 2, 2, 0.9, 0.5, 1.0, n_min=10)
    assert p2.beta >= 1.0 and p2.alpha == 1.0


# ---------------------------------------------------------------------------
# GEMM-efficiency tier in serving placement speeds (DESIGN.md §11/§12)
# ---------------------------------------------------------------------------

def test_placement_speeds_roofline():
    from repro.core.asym_ea import placement_speeds
    from repro.core.hardware import A40, V100
    # fpb=0 degenerates to pure HBM bandwidth (the memory-bound default)
    assert placement_speeds((A40, V100)) == (A40.hbm_bw, V100.hbm_bw)
    # past the ridge point the compute roofline caps the rate
    f = 150.0
    sa, sv = placement_speeds((A40, V100), flops_per_byte=f)
    assert sa == pytest.approx(min(A40.hbm_bw,
                                   A40.peak_flops * A40.gemm_eff / f))
    assert sv == pytest.approx(min(V100.hbm_bw,
                                   V100.peak_flops * V100.gemm_eff / f))
    assert sv < sa  # V100 is the compute-weak class at high intensity


def test_compute_weak_class_gets_fewer_hot_experts():
    """Folding the per-class GEMM-efficiency tier into the speed term
    flips the hot-expert destination once arithmetic intensity crosses
    the weak class's ridge point: bandwidth-wise V100 (900 GB/s) beats
    A40 (696 GB/s), but compute-wise (peak*gemm_eff) it is the weaker
    class — so at decode batches large enough to leave the bandwidth
    roofline, the hot experts must migrate OFF the V100 shard."""
    from repro.core.asym_ea import asym_ea_place, placement_speeds
    from repro.core.hardware import A40, V100
    load = [2.0 ** -e for e in range(8)]  # sharply skewed: e0 is hot
    cap = 4

    def mass(placement, shard):
        return sum(load[e] for e in placement[shard])

    # memory-bound (fpb=0): V100's higher HBM bandwidth earns the hot set
    pl_bw = asym_ea_place(load, placement_speeds((A40, V100)), cap)
    assert 0 in pl_bw[1]
    # compute-bound (fpb past V100's ridge): A40's GEMM tier wins it back
    pl_c = asym_ea_place(load,
                         placement_speeds((A40, V100), flops_per_byte=150.0),
                         cap)
    assert 0 in pl_c[0]
    # and the weak class's total hot mass strictly drops
    assert mass(pl_c, 1) < mass(pl_bw, 1)
