"""Per-architecture smoke tests: reduced same-family config, one forward +
one real train step on CPU, asserting shapes and finiteness (deliverable f)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, PAPER_MODELS
from repro.launch.mesh import make_mesh
from repro.models import registry, stack
from repro.models.config import ShapeConfig
from repro.models.modules import Policy, RunConfig
from repro.pytree import split_params
from repro.train import optimizer as opt
from repro.train.step import make_train_program

RUN = RunConfig(policy=Policy(compute_dtype=jnp.float32), moe_impl="gather")


def _fronts(cfg, B, dtype=jnp.float32):
    out = {}
    if cfg.is_encdec:
        out["encoder_embeds"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                          dtype)
    if cfg.vision_seq > 0:
        out["vision_embeds"] = jnp.zeros(
            (B, cfg.vision_seq, cfg.vision_dim or cfg.d_model), dtype)
    return out


@pytest.mark.parametrize("arch", ASSIGNED + PAPER_MODELS)
def test_forward_smoke(arch):
    cfg = registry.smoke_config(registry.get_config(arch))
    params, _ = split_params(stack.init_model(jax.random.PRNGKey(0), cfg))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    logits, _, aux = stack.apply_model(params, cfg, RUN, tokens,
                                       **_fronts(cfg, B))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    for v in aux.values():
        assert bool(jnp.isfinite(v))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    cfg = registry.smoke_config(registry.get_config(arch))
    mesh = make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("smoke", "train", 32, 2)
    program = make_train_program(
        cfg, mesh, RUN, shape,
        opt_cfg=opt.OptimizerConfig(peak_lr=1e-3, warmup_steps=1,
                                    total_steps=4))
    with mesh:
        params = program.init_params()
        opt_state = program.init_opt(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, axis=1),
             **_fronts(cfg, 2)}
    with mesh:
        params2, opt_state, metrics = program.train_step(params, opt_state,
                                                         batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0.0


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-2.7b",
                                  "recurrentgemma-9b", "qwen3-moe-30b-a3b",
                                  "whisper-tiny"])
def test_decode_smoke(arch):
    """Prefill + 4 decode steps on the reduced config."""
    cfg = registry.smoke_config(registry.get_config(arch))
    params, _ = split_params(stack.init_model(jax.random.PRNGKey(0), cfg))
    B, S = 2, 16
    state = stack.init_decode_state(cfg, B, S + 8, jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    logits, state, _ = stack.apply_model(
        params, cfg, RUN, tokens, decode_state=state,
        cache_index=jnp.zeros((), jnp.int32), **_fronts(cfg, B))
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    for t in range(4):
        logits, state, _ = stack.apply_model(
            params, cfg, RUN, tok, decode_state=state,
            cache_index=jnp.asarray(S + t), **_fronts(cfg, B))
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "qwen3-moe-30b-a3b",
                                  "mamba2-2.7b"])
def test_decode_matches_full_forward(arch):
    """Greedy tokens from cached decode == argmax of the full forward."""
    cfg = registry.smoke_config(registry.get_config(arch))
    cfg = dataclasses.replace(cfg, capacity_factor=99.0)
    params, _ = split_params(stack.init_model(jax.random.PRNGKey(0), cfg))
    B, S = 1, 24
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    full_logits, _, _ = stack.apply_model(params, cfg, RUN, tokens,
                                          **_fronts(cfg, B))
    state = stack.init_decode_state(cfg, B, S, jnp.float32)
    inc_logits, _, _ = stack.apply_model(
        params, cfg, RUN, tokens, decode_state=state,
        cache_index=jnp.zeros((), jnp.int32), **_fronts(cfg, B))
    assert jnp.allclose(full_logits, inc_logits, atol=2e-3), \
        float(jnp.max(jnp.abs(full_logits - inc_logits)))


def test_applicable_shapes_skip_rules():
    """long_500k only for sub-quadratic archs (per the brief)."""
    names = {registry.get_config(a).name: registry.get_config(a)
             for a in ASSIGNED}
    runs_500k = {a for a, c in names.items()
                 if any(s.name == "long_500k"
                        for s in registry.applicable_shapes(c))}
    assert runs_500k == {"mamba2-2.7b", "recurrentgemma-9b"}
