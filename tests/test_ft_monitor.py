"""Direct unit coverage for repro.ft.monitor + repro.ft.elastic: the
fleet controller (serve/fleet, DESIGN.md §12) now leans on heartbeats
and straggler statistics for failure recovery and router speed scaling,
so the edge cases get pinned here — expiry ordering, all-dead windows,
membership churn (add/remove), slow_factor bounds, and the
dead-hosts-before-stragglers priority of ElasticController.tick.

Host-only (no jax compilation): stays in the tier-1 slice.
"""

import pytest

from repro.core import hardware as HW
from repro.core.planner import plan_zp_group
from repro.core.profiler import ZPGroupShape
from repro.ft import ElasticController, HeartbeatMonitor, StragglerDetector
from repro.ft.monitor import HeartbeatConfig
from repro.models import registry


def make_monitor(hosts, clock, interval=10.0, grace=3.0):
    return HeartbeatMonitor(
        hosts, HeartbeatConfig(interval_s=interval, grace_multiplier=grace),
        clock=lambda: clock["t"])


# ---------------------------------------------------------------------------
# HeartbeatMonitor
# ---------------------------------------------------------------------------

def test_heartbeat_expiry_ordering():
    # Hosts stop beating at different times; deaths surface in the same
    # order their grace windows expire, never early.
    clock = {"t": 0.0}
    mon = make_monitor(["a", "b", "c"], clock)
    clock["t"] = 5.0
    mon.beat("b")
    mon.beat("c")
    clock["t"] = 12.0
    mon.beat("c")
    # cutoff = t - 30: a expires at t>30, b at t>35, c at t>42
    clock["t"] = 30.0
    assert mon.dead_hosts() == []
    clock["t"] = 31.0
    assert mon.dead_hosts() == ["a"]
    clock["t"] = 36.0
    assert set(mon.dead_hosts()) == {"a", "b"}
    clock["t"] = 43.0
    assert set(mon.dead_hosts()) == {"a", "b", "c"}


def test_heartbeat_all_dead_and_recovery_via_remove():
    clock = {"t": 0.0}
    mon = make_monitor(["a", "b"], clock)
    clock["t"] = 100.0
    assert set(mon.dead_hosts()) == {"a", "b"}
    # The coordinator evicts as it reacts; dead_hosts() converges to [].
    mon.remove("a")
    assert mon.dead_hosts() == ["b"]
    mon.remove("b")
    assert mon.dead_hosts() == []
    mon.remove("b")  # idempotent


def test_heartbeat_add_starts_fresh_grace_window():
    clock = {"t": 0.0}
    mon = make_monitor(["a"], clock)
    clock["t"] = 100.0
    mon.beat("a")
    mon.add("late")  # joins long after t=0: must NOT be instantly dead
    assert mon.dead_hosts() == []
    clock["t"] = 131.0
    assert set(mon.dead_hosts()) == {"a", "late"}


def test_heartbeat_beat_unknown_host_tracks_it():
    # beat() on an unregistered host is an implicit add (the fleet wires
    # flipped groups through beat on the shared tick clock).
    clock = {"t": 0.0}
    mon = make_monitor(["a"], clock)
    mon.beat("new")
    assert "new" in mon.last_seen


# ---------------------------------------------------------------------------
# StragglerDetector
# ---------------------------------------------------------------------------

def test_straggler_empty_window_is_silent():
    det = StragglerDetector(["a", "b"])
    assert det.stragglers() == []          # no samples at all
    det.record("a", 1.0)
    det.record("a", 1.0)
    assert det.stragglers() == []          # < 4 samples: stats undefined
    assert det.slow_factor("a") == 1.0
    assert det.slow_factor("b") == 1.0     # group with an empty deque


def test_slow_factor_bounds():
    det = StragglerDetector(["fast", "fast2", "slow"])
    for _ in range(10):
        det.record("fast", 1.0)
        det.record("fast2", 1.0)
        det.record("slow", 2.0)
    # Never below 1.0 (a fast group is "not slow", not a speedup credit)
    assert det.slow_factor("fast") == 1.0
    assert det.slow_factor("slow") == pytest.approx(2.0)


def test_straggler_patience_gates_flagging():
    det = StragglerDetector(["a", "b"], z_thresh=3.0, patience=3)
    for _ in range(10):
        det.record("a", 1.0)
        det.record("b", 1.0)
    for _ in range(6):
        det.record("b", 5.0)
    # needs `patience` consecutive flagged windows, not one
    assert det.stragglers() == []
    assert det.stragglers() == []
    assert det.stragglers() == ["b"]


def test_straggler_add_remove_membership():
    det = StragglerDetector(["a"])
    det.add("b")
    for _ in range(10):
        det.record("a", 1.0)
        det.record("b", 1.0)
    det.remove("b")
    assert "b" not in det.times and "b" not in det.strikes
    det.remove("b")  # idempotent
    assert det.stragglers() == []
    det.add("a")     # add() of an existing group must not wipe its window
    assert len(det.times["a"]) == 10


# ---------------------------------------------------------------------------
# ElasticController event sequencing
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def zp_plan():
    cfg = registry.get_config("mixtral-d1")
    zp = ZPGroupShape(M=4, N=4, attn_class=HW.A40, exp_class=HW.V100)
    return cfg, plan_zp_group(cfg, zp, global_batch=16, seq_len=4096)


def make_controller(cfg, plan):
    return ElasticController(cfg, plan, 16, 4096,
                             attn_hosts=["a0", "a1", "a2", "a3"],
                             exp_hosts=["e0", "e1", "e2", "e3"])


def test_elastic_tick_healthy_is_none(zp_plan):
    cfg, plan = zp_plan
    ctl = make_controller(cfg, plan)
    ev = ctl.tick()
    assert ev.kind == "none" and ev.plan is None


def test_elastic_tick_dead_hosts_take_priority_over_straggler(zp_plan):
    # A dead expert host AND a straggling expert group in the same tick:
    # the hard failure (shrink) must win; the straggler replan would
    # otherwise keep a dead host in the plan.
    cfg, plan = zp_plan
    ctl = make_controller(cfg, plan)
    for _ in range(10):
        ctl.record_step(1.0, 1.0)
    for _ in range(6):
        ctl.record_step(1.0, 9.0)
        ctl.detector.stragglers()
    assert "exp" in ctl.detector.stragglers()  # straggler is live...
    ctl.heartbeat.last_seen["e3"] -= 1e6       # ...and e3 is dead
    ev = ctl.tick()
    assert ev.kind == "shrink"
    assert "e3" not in ctl.exp_hosts
    assert ev.plan.zp.N == 3


def test_elastic_tick_straggler_then_recovers(zp_plan):
    cfg, plan = zp_plan
    ctl = make_controller(cfg, plan)
    for _ in range(10):
        ctl.record_step(1.0, 1.0)
    for _ in range(6):
        ctl.record_step(1.0, 9.0)
        ctl.detector.stragglers()
    ev = ctl.tick()
    assert ev.kind == "straggler-replan"
    assert sum(ev.plan.offload) >= sum(plan.offload)
    # healthy samples clear the strikes; next tick is quiet
    for _ in range(20):
        ctl.record_step(1.0, 1.0)
    ctl.detector.stragglers()
    assert ctl.tick().kind == "none"
