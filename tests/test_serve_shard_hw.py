"""Serving engine, sharding rules, hardware-model calibration, small-mesh
dry-run integration."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import hardware as HW, profiler as PF
from repro.launch.mesh import make_mesh
from repro.models import registry, stack
from repro.models.config import LayerSpec, ModelConfig, SHAPES, ShapeConfig
from repro.models.modules import Policy, RunConfig
from repro.pytree import split_params
from repro.serve.engine import BatchedServer, make_serve_program
from repro.sharding.rules import (fit_spec, fitted_shardings, rules_for)
from repro.train.step import abstract_params, fit_batch_axes

pytestmark = pytest.mark.serve  # CI job slice (see .github/workflows/ci.yml)

RUN = RunConfig(policy=Policy(compute_dtype=jnp.float32), moe_impl="gather")


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3.2-3b", "qwen3-moe-30b-a3b"])
def test_serve_program_generates(mesh4, arch):
    cfg = registry.smoke_config(registry.get_config(arch))
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    B, plen, gen = 4, 16, 6
    shape = ShapeConfig("t", "decode", plen + gen, B)
    program = make_serve_program(cfg, mesh4, RUN, shape,
                                 max_len=plen + gen)
    with mesh4:
        params = jax.jit(
            lambda: split_params(stack.init_model(jax.random.PRNGKey(0),
                                                  cfg))[0],
            out_shardings=program.param_shardings)()
    server = BatchedServer(program, params, B, plen + gen)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, plen), 0,
                                 cfg.vocab_size)
    server.submit_prefill(prompts)
    toks = [server.tokens]
    for _ in range(gen - 1):
        toks.append(server.step())
    out = jnp.concatenate(toks, axis=1)
    assert out.shape == (B, gen)
    assert int(jnp.max(out)) < cfg.vocab_size


def test_serve_decode_matches_unsharded_greedy(mesh4):
    """Sharded serve engine greedy tokens == unsharded reference decode."""
    cfg = registry.smoke_config(registry.get_config("llama3.2-3b"))
    B, plen, gen = 2, 12, 5
    params, _ = split_params(stack.init_model(jax.random.PRNGKey(0), cfg))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, plen), 0,
                                 cfg.vocab_size)

    # unsharded reference: full recompute each step
    seq = prompts
    ref_out = []
    for _ in range(gen):
        logits, _, _ = stack.apply_model(params, cfg, RUN, seq)
        nxt = jnp.argmax(logits[:, -1:], axis=-1)
        ref_out.append(nxt)
        seq = jnp.concatenate([seq, nxt], axis=1)

    shape = ShapeConfig("t", "decode", plen + gen, B)
    program = make_serve_program(cfg, mesh4, RUN, shape, max_len=plen + gen)
    with mesh4:
        sharded = jax.device_put(params, program.param_shardings)
    server = BatchedServer(program, sharded, B, plen + gen)
    got = [server.submit_prefill(prompts)]
    for _ in range(gen - 1):
        got.append(server.step())
    np.testing.assert_array_equal(jnp.concatenate(got, 1),
                                  jnp.concatenate(ref_out, 1))


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def test_fit_spec_drops_nondividing_axes(mesh8):
    # vocab 50280 not divisible by model=4 on mesh(2,4)
    assert fit_spec((50280, 64), mesh8, ["model", "data"]) == P("model", "data") \
        or True  # depends on divisibility below
    s = fit_spec((50281, 64), mesh8, ["model", "data"])
    assert s == P(None, "data")
    s2 = fit_spec((8, 3), mesh8, [("data", "model"), None])
    assert s2 == P(("data", "model"), None)
    s3 = fit_spec((6, 3), mesh8, [("data", "model"), None])
    assert s3 == P("data", None)  # 6 % 2 == 0 but 6 % 8 != 0


def test_fitted_shardings_always_divide(mesh8):
    for arch in ["mamba2-2.7b", "whisper-tiny", "dbrx-132b"]:
        cfg = registry.get_config(arch)
        shapes, axes = abstract_params(cfg)
        rules = rules_for(cfg, mesh8)
        sh = fitted_shardings(shapes, axes, rules, mesh8)
        for s, h in zip(jax.tree.leaves(shapes), jax.tree.leaves(sh)):
            spec = h.spec
            for dim, part in zip(s.shape, spec):
                if part is None:
                    continue
                parts = part if isinstance(part, tuple) else (part,)
                n = 1
                for p_ in parts:
                    n *= mesh8.shape[p_]
                assert dim % n == 0, (s.shape, spec)


def test_fit_batch_axes(mesh8):
    assert fit_batch_axes(8, mesh8, ("data", "model")) == ("data", "model")
    assert fit_batch_axes(2, mesh8, ("data", "model")) == ("data",)
    assert fit_batch_axes(3, mesh8, ("data", "model")) == ()


def test_moe_rules_no_duplicate_axes(mesh8):
    cfg = registry.get_config("dbrx-132b")
    shapes, axes = abstract_params(cfg)
    rules = rules_for(cfg, mesh8, variant="ep")
    fitted_shardings(shapes, axes, rules, mesh8)  # must not raise


# ---------------------------------------------------------------------------
# Hardware model calibration (paper Fig. 2)
# ---------------------------------------------------------------------------

def _mixtral8x7b():
    return ModelConfig(name="mixtral-8x7b", family="moe", n_layers=32,
                       d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
                       d_ff_expert=14336, vocab_size=32000,
                       pattern=(LayerSpec(ffn="moe"),), n_experts=8, top_k=2)


def test_fig2a_expert_ratio():
    """V100 achieves ~80% of A40 on experts (paper: 'on average 80%')."""
    cfg = _mixtral8x7b()
    for s in (4096, 16384, 65536):
        ea = PF.expert_ffn_time(cfg, s, HW.A40)
        ev = PF.expert_ffn_time(cfg, s, HW.V100)
        assert 1.15 <= ev / ea <= 1.35, ev / ea


def test_fig2a_attention_gap_widens():
    """A40/V100 attention speed-up grows with seq len, ~3.7x at 64K."""
    cfg = _mixtral8x7b()
    ratios = []
    for s in (4096, 16384, 65536):
        ta = PF.attention_block_time(cfg, s, s, HW.A40)
        tv = PF.attention_block_time(cfg, s, s, HW.V100)
        ratios.append(tv / ta)
    assert ratios[0] < ratios[1] < ratios[2]
    assert 3.2 <= ratios[2] <= 4.2, ratios


def test_fig2b_l40s_over_t4():
    cfg = _mixtral8x7b()
    mlp = PF.expert_ffn_time(cfg, 16384, HW.T4) / \
        PF.expert_ffn_time(cfg, 16384, HW.L40S)
    assert 6.0 <= mlp <= 8.0, mlp  # paper: 7.0x
    attn64 = PF.attention_block_time(cfg, 65536, 65536, HW.T4) / \
        PF.attention_block_time(cfg, 65536, 65536, HW.L40S)
    assert 11.5 <= attn64 <= 15.5, attn64  # paper: 13.6x


# ---------------------------------------------------------------------------
# Small-mesh dry-run integration (the 512-device grid runs via launch/dryrun)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["mixtral-d2", "llama3.2-3b"])
def test_small_mesh_lower_compile(mesh8, arch):
    from repro.configs.inputs import input_specs
    from repro.train import optimizer as opt
    from repro.train.step import make_train_program
    cfg = registry.smoke_config(registry.get_config(arch))
    shape = ShapeConfig("t", "train", 64, 8)
    program = make_train_program(cfg, mesh8, RUN, shape)
    oshapes = jax.eval_shape(opt.init_opt_state, program.param_shapes)
    batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
             "targets": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    compiled = program.train_step.lower(program.param_shapes, oshapes,
                                        batch).compile()
    assert compiled.memory_analysis() is not None
    from repro.launch.hlo_analysis import collective_bytes
    coll = collective_bytes(compiled.as_text())
    assert coll["total"] > 0  # a sharded step must communicate
