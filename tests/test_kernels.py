"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps
+ gradient checks + hypothesis on grouping invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def rand(shape, dtype=jnp.float32, k=0):
    return jax.random.normal(jax.random.fold_in(KEY, k), shape, jnp.float32
                             ).astype(dtype)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,T,H,KH,hd", [
    (1, 128, 128, 2, 2, 64),
    (2, 200, 200, 4, 2, 32),   # padding + GQA
    (1, 96, 160, 4, 1, 64),    # cross lengths + MQA
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
def test_flash_matches_ref(dtype, B, S, T, H, KH, hd, causal, window):
    if causal and S != T:
        pytest.skip("causal assumes aligned q/kv ranges")
    q = rand((B, S, H, hd), dtype, 1)
    k = rand((B, T, KH, hd), dtype, 2)
    v = rand((B, T, KH, hd), dtype, 3)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    mask = ref.causal_window_mask(S, T, causal, window)
    want = ref.attention(q, k, v, mask=mask)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_flash_grads_match_ref():
    B, S, H, KH, hd = 2, 160, 4, 2, 32
    q, k, v = rand((B, S, H, hd), k=1), rand((B, S, KH, hd), k=2), \
        rand((B, S, KH, hd), k=3)

    def f_flash(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, causal=True) ** 2)

    def f_ref(q, k, v):
        m = ref.causal_window_mask(S, S, True, 0)
        return jnp.sum(ref.attention(q, k, v, mask=m) ** 2)

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=2e-3)


def test_flash_fully_masked_rows_are_zero():
    # window smaller than the gap -> early rows see nothing but themselves;
    # padded rows (from block padding) must not produce NaNs.
    q, k, v = rand((1, 130, 2, 32), k=1), rand((1, 130, 2, 32), k=2), \
        rand((1, 130, 2, 32), k=3)
    out = ops.flash_attention(q, k, v, causal=True, window=1)
    assert bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# Grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,N,G", [(64, 32, 48, 3), (300, 96, 80, 5),
                                     (128, 256, 128, 2)])
def test_gmm_matches_ref(dtype, M, K, N, G):
    lhs = rand((M, K), dtype, 1)
    rhs = rand((G, K, N), dtype, 2)
    sizes = jax.random.randint(jax.random.fold_in(KEY, 9), (G,), 0, M)
    sizes = (sizes * M // jnp.maximum(jnp.sum(sizes), 1)).astype(jnp.int32)
    sizes = sizes.at[-1].add(M - jnp.sum(sizes))
    out = ops.gmm(lhs, rhs, sizes)
    want = ref.gmm(lhs, rhs, sizes)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 60), min_size=2, max_size=6))
def test_gmm_group_sizes_property(sizes):
    """Any non-negative group partition (incl. empty groups) matches the
    oracle and lax.ragged_dot."""
    G = len(sizes)
    M = sum(sizes)
    if M == 0:
        return
    lhs = rand((M, 16), k=1)
    rhs = rand((G, 16, 24), k=2)
    gs = jnp.asarray(sizes, jnp.int32)
    out = ops.gmm(lhs, rhs, gs)
    np.testing.assert_allclose(out, ref.gmm(lhs, rhs, gs), atol=1e-4)
    np.testing.assert_allclose(out, jax.lax.ragged_dot(lhs, rhs, gs),
                               atol=1e-4)


def test_gmm_grads_match_ref():
    M, K, N, G = 96, 32, 40, 4
    lhs, rhs = rand((M, K), k=1), rand((G, K, N), k=2)
    gs = jnp.array([10, 0, 50, 36], jnp.int32)

    g1 = jax.grad(lambda l, r: jnp.sum(ops.gmm(l, r, gs) ** 2),
                  argnums=(0, 1))(lhs, rhs)
    g2 = jax.grad(lambda l, r: jnp.sum(ref.gmm(l, r, gs) ** 2),
                  argnums=(0, 1))(lhs, rhs)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=2e-3)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,T,h,hd,ns,chunk", [
    (1, 64, 2, 16, 8, 32),
    (2, 200, 3, 32, 16, 64),   # padding (200 % 64 != 0)
    (1, 256, 4, 64, 32, 128),
])
def test_ssd_kernel_matches_naive(dtype, b, T, h, hd, ns, chunk):
    x = rand((b, T, h, hd), dtype, 1)
    dt = jax.nn.softplus(rand((b, T, h), k=2))
    A = -jnp.exp(rand((h,), k=3))
    B = rand((b, T, ns), dtype, 4) * 0.5
    C = rand((b, T, ns), dtype, 5) * 0.5
    y0, s0 = ref.ssd_naive(x, dt, A, B, C)
    y1, s1 = ref.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    y2, s2 = ops.ssd(x, dt, A, B, C, chunk=chunk, use_kernel=True)
    tol = 5e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y0, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(y2, np.float32),
                               np.asarray(y0, np.float32), atol=tol)
    np.testing.assert_allclose(s1, s0, atol=tol)
    np.testing.assert_allclose(s2, s0, atol=tol)


def test_ssd_decode_steps_match_full():
    """Sequential decode over a prefix state == one full scan."""
    b, T, h, hd, ns = 1, 48, 2, 16, 8
    x = rand((b, T, h, hd), k=1)
    dt = jax.nn.softplus(rand((b, T, h), k=2))
    A = -jnp.exp(rand((h,), k=3))
    B, C = rand((b, T, ns), k=4) * 0.5, rand((b, T, ns), k=5) * 0.5
    y_full, s_full = ref.ssd_naive(x, dt, A, B, C)
    split = 32
    y1, s1 = ref.ssd_naive(x[:, :split], dt[:, :split], A, B[:, :split],
                           C[:, :split])
    ys = [y1]
    s = s1
    for t in range(split, T):
        yt, s = ref.ssd_decode_step(x[:, t:t + 1], dt[:, t:t + 1], A,
                                    B[:, t:t + 1], C[:, t:t + 1], s)
        ys.append(yt)
    y_inc = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_inc, y_full, atol=1e-4)
    np.testing.assert_allclose(s, s_full, atol=1e-4)


def test_ssd_kernel_grads():
    b, T, h, hd, ns = 1, 96, 2, 16, 8
    x = rand((b, T, h, hd), k=1)
    dt = jax.nn.softplus(rand((b, T, h), k=2))
    A = -jnp.exp(rand((h,), k=3))
    B, C = rand((b, T, ns), k=4) * 0.5, rand((b, T, ns), k=5) * 0.5
    gk = jax.grad(lambda x: jnp.sum(
        ops.ssd(x, dt, A, B, C, use_kernel=True)[0] ** 2))(x)
    gr = jax.grad(lambda x: jnp.sum(ref.ssd_naive(x, dt, A, B, C)[0] ** 2))(x)
    np.testing.assert_allclose(gk, gr, atol=3e-3)
