"""Theorem 1 (optimal zebra schedule) + discrete-event simulator tests."""

import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import schedule as S
from repro.core.profiler import LayerTimes
from repro.core.simulator import (CommTimes, simulate, simulate_distep,
                                  simulate_hetermoe)

pytestmark = pytest.mark.zebra  # CI job slice (see .github/workflows/ci.yml)


def times(t_attn=1.0, t_exp=1.0, t_exp_attn=0.75):
    return LayerTimes(t_attn=t_attn, t_exp=t_exp, t_exp_attn=t_exp_attn,
                      t_exp_on_exp=t_exp, t_attn_on_exp=2.0)


def test_canonical_schedule_valid():
    for L, R in [(1, 1), (2, 3), (5, 4), (8, 2)]:
        sched = S.canonical_schedule(L, R)
        S.validate(sched)
        assert len(sched.streams["attn_comp"]) == L * R * 2 + R  # A F/B + H
        assert len(sched.streams["exp_comp"]) == L * R * 2


def test_canonical_with_offload_valid():
    sched = S.canonical_schedule(4, 3, (0, 1, 0, 2))
    S.validate(sched)
    xs = [t for t in sched.streams["attn_comp"] if t[0] == "X"]
    assert len(xs) == 2 * 3 * 2  # two layers, R=3, fwd+bwd


def test_steady_state_utilization_fig6():
    """Fig. 6(a): experts 33% slower, R=3 -> attention busy 3/4 of each
    layer window in the forward steady state."""
    sched = S.canonical_schedule(30, 3)
    res = simulate(sched, times(1.0, 4.0 / 3.0), CommTimes(0, 0), 6, 1, 1)
    assert 0.70 <= res.attn_util <= 0.78  # 0.75 minus ramp effects
    assert res.exp_util >= 0.93


def test_asym_ea_reduces_iter_time_and_bubbles():
    t = times(1.0, 4.0 / 3.0, t_exp_attn=1.0)
    base = simulate_hetermoe(_cfg(12, 6), t, CommTimes(0, 0), 3, 1, 1)
    from repro.core.asym_ea import asym_ea_offload
    plan = asym_ea_offload(6, 12, 1, 1, 1.0, 1.0, 4.0 / 3.0)
    opt = simulate_hetermoe(_cfg(12, 6), t, CommTimes(0, 0), 3, 1, 1, plan)
    assert opt.iter_time < base.iter_time
    assert opt.attn_util > base.attn_util


def _cfg(L, n):
    import dataclasses

    from repro.models.config import LayerSpec, ModelConfig
    return ModelConfig(name="sim", family="moe", n_layers=L, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                       pattern=(LayerSpec(ffn="moe"),), n_experts=n, top_k=2)


def test_zebra_beats_distep():
    """Overlap (R=4 microbatches) must beat naive disaggregation (R=1,
    whole batch per step — per-task durations scale by R)."""
    R = 4
    t = times(1.0, 1.2)
    comm = CommTimes(0.1, 0.1)
    cfg = _cfg(8, 8)
    z = simulate_hetermoe(cfg, t, comm, R, 1, 1)
    t_whole = times(R * 1.0, R * 1.2)
    d = simulate_distep(cfg, t_whole, CommTimes(R * 0.1, R * 0.1), 1, 1)
    assert z.iter_time < d.iter_time
    # total compute is identical; only the schedule differs
    assert abs(z.attn_busy - d.attn_busy) < 1e-6


def _shuffle_stream(sched, stream, rng):
    """Random valid reorder of one stream (dependency-safe swaps only)."""
    tasks = list(sched.streams[stream])
    rng.shuffle(tasks)
    sched.streams[stream] = tasks
    return sched


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), L=st.integers(2, 4), R=st.integers(2, 4))
def test_theorem1_optimality_vs_permutations(seed, L, R):
    """No random reordering of the attention-compute stream beats the
    canonical Theorem-1 order (swaps that create dependency cycles are
    rejected by the simulator and skipped)."""
    t = times(1.0, 1.3)
    comm = CommTimes(0.05, 0.05)
    canon = simulate(S.canonical_schedule(L, R), t, comm, 4, 1, 1)
    rng = random.Random(seed)
    sched = S.canonical_schedule(L, R)
    _shuffle_stream(sched, "attn_comp", rng)
    try:
        perm = simulate(sched, t, comm, 4, 1, 1)
    except ValueError:
        return  # cyclic order: not a valid schedule
    assert canon.iter_time <= perm.iter_time + 1e-9


def test_simulator_respects_dependencies():
    """Start times honour data deps: E^F(l,j) >= end of D^F(l,j)."""
    sched = S.canonical_schedule(3, 2)
    t = times()
    res = simulate(sched, t, CommTimes(0.2, 0.2), 4, 1, 1)
    st_ = res.starts
    for l in range(3):
        for j in range(2):
            assert st_[S.E("F", l, j)] >= st_[S.D("F", l, j)] + 0.2 - 1e-9
            assert st_[S.A("B", l, j)] >= st_[S.D("B", l, j)]
