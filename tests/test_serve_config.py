"""ServeConfig deployment API (DESIGN.md §14.5).

The config object is THE deployment description and
:func:`build_deployment` THE construction path: these tests pin the
aggregated reject-don't-truncate validation (every violation in ONE
error), the unified ``--kill-group``/chaos fault-spec grammar, the
argparse round-trip, the config -> engine-type mapping, and that the
legacy ``make_continuous_program`` kwargs keep working.
"""

import argparse

import pytest

from repro.serve.config import (ChaosCfg, DisaggCfg, EPCfg, FleetCfg,
                                PagedCfg, PrefixCacheCfg, ServeConfig,
                                ServeConfigError, parse_kills)

pytestmark = pytest.mark.prefix  # CI prefix-smoke job slice


# ---------------------------------------------------------------------------
# One fault-spec grammar
# ---------------------------------------------------------------------------

def test_kill_grammar_accepts_legacy_and_chaos_forms():
    assert parse_kills(["2@8", "0@10"]) == [(8, 2), (10, 0)]
    # the shorthand IS sugar for a chaos crash entry; the full form works
    assert parse_kills(["crash_start@8:g2"]) == [(8, 2)]
    assert parse_kills(None) == []


@pytest.mark.parametrize("bad", [
    "nope", "2@", "@8", "2@8:g1",          # malformed / over-specified
    "drop%0.5",                             # wrong site
    "crash_start:g2",                       # crash entries need @TICK
    "crash_start@8",                        # ...and an explicit group
])
def test_kill_grammar_rejects(bad):
    with pytest.raises(ValueError, match="kill-group"):
        parse_kills([bad])


# ---------------------------------------------------------------------------
# Aggregated validation
# ---------------------------------------------------------------------------

def test_validate_collects_every_violation_in_one_error():
    sc = ServeConfig(slots=0, max_len=1,
                     paged=PagedCfg(enabled=True, page_size=0),
                     prefix=PrefixCacheCfg(enabled=True),
                     disagg=DisaggCfg(enabled=True),
                     fleet=FleetCfg(enabled=True),
                     chaos=ChaosCfg(spec="nope("))
    with pytest.raises(ServeConfigError) as e:
        sc.validate()
    msg = str(e.value)
    for frag in ("slots must be >= 1", "max_len must be >= 2",
                 "page_size must be >= 1", "mutually exclusive",
                 "--prefix-cache is not supported with --fleet",
                 "bad --chaos spec"):
        assert frag in msg, f"missing {frag!r} in {msg!r}"


@pytest.mark.parametrize("sc, frag", [
    (ServeConfig(prefix=PrefixCacheCfg(enabled=True)),
     "needs a paged deployment"),
    (ServeConfig(chaos=ChaosCfg(spec="drop%0.5")), "requires --fleet"),
    (ServeConfig(fleet=FleetCfg(kills=((3, 1),))), "requires --fleet"),
    (ServeConfig(fleet=FleetCfg(slo_ttft=1.0)), "requires --fleet"),
    (ServeConfig(fleet=FleetCfg(enabled=True, decode_groups=())),
     ">= 1 prefill and >= 1 decode group"),
    (ServeConfig(fleet=FleetCfg(enabled=True, decode_groups=("tpu9",))),
     "unknown device class"),
    (ServeConfig(ep=EPCfg(ep_size=2), fleet=FleetCfg(enabled=True)),
     "not supported with --fleet"),
    (ServeConfig(ep=EPCfg(ep_size=2, placement="magic")),
     "uniform"),
    (ServeConfig(paged=PagedCfg(enabled=True),
                 prefix=PrefixCacheCfg(enabled=True, capacity_pages=0)),
     "capacity_pages must be >= 1"),
])
def test_validate_rejects(sc, frag):
    with pytest.raises(ServeConfigError, match=frag):
        sc.validate()


def test_valid_configs_pass():
    ServeConfig().validate()
    ServeConfig(paged=PagedCfg(enabled=True),
                prefix=PrefixCacheCfg(enabled=True, fair=True)).validate()
    ServeConfig(disagg=DisaggCfg(enabled=True),
                prefix=PrefixCacheCfg(enabled=True)).validate()
    ServeConfig(fleet=FleetCfg(enabled=True, kills=((8, 2),),
                               slo_ttft=2.0),
                chaos=ChaosCfg(spec="drop%0.5*2")).validate()


def test_arch_dependent_validation():
    from repro.models import registry
    dense = registry.get_config("llama3.2-3b")
    moe = registry.get_config("qwen3-moe-30b-a3b")
    rec = registry.get_config("mamba2-2.7b")
    with pytest.raises(ServeConfigError, match="needs a MoE arch"):
        ServeConfig(ep=EPCfg(ep_size=2)).validate(model_cfg=dense)
    ServeConfig(ep=EPCfg(ep_size=2)).validate(model_cfg=moe)
    # recurrent mixers carry whole-history state: a skipped prefix would
    # corrupt it, so the combination is rejected, never truncated.
    with pytest.raises(ServeConfigError, match="recurrent"):
        ServeConfig(paged=PagedCfg(enabled=True),
                    prefix=PrefixCacheCfg(enabled=True)).validate(
                        model_cfg=rec)


# ---------------------------------------------------------------------------
# argparse round-trip
# ---------------------------------------------------------------------------

def _args(**over):
    base = dict(slots=3, prompt_len=40, gen=8, prefill_chunk=16,
                prefill_budget=None, seed=7, temperature=0.5, top_k=4,
                top_p=0.9, paged=True, page_size=8, pool_pages=20,
                prefill_pool_pages=None, prefix_cache=True,
                prefix_capacity=6, fair=True, disagg=False, fleet=False,
                prefill_groups="a40", decode_groups="2",
                fleet_elastic=False, kill_group=["1@5"], chaos=None,
                chaos_seed=0, slo_ttft=None, ep_size=0,
                ep_placement="uniform")
    base.update(over)
    return argparse.Namespace(**base)


def test_from_args_round_trip():
    sc = ServeConfig.from_args(_args())
    assert sc.slots == 3 and sc.max_len == 48 and sc.seed == 7
    assert sc.sampling.temperature == 0.5 and sc.sampling.top_k == 4
    assert sc.paged == PagedCfg(enabled=True, page_size=8, pool_pages=20)
    assert sc.prefix == PrefixCacheCfg(enabled=True, capacity_pages=6,
                                       fair=True)
    assert sc.fleet.decode_groups == ("v100", "v100")  # count form
    assert sc.fleet.kills == ((5, 1),)
    # from_args only PARSES; policy stays in validate — and this namespace
    # carries kills without --fleet, which validate rejects.
    with pytest.raises(ServeConfigError, match="requires --fleet"):
        sc.validate()
    ServeConfig.from_args(_args(kill_group=None)).validate()


def test_from_args_parse_errors_use_the_one_error_path():
    with pytest.raises(ServeConfigError, match="kill-group"):
        ServeConfig.from_args(_args(kill_group=["nope"]))


# ---------------------------------------------------------------------------
# build_deployment: config -> engine type  (device)
# ---------------------------------------------------------------------------

def _ctx():
    from repro.launch.mesh import make_mesh
    from repro.models import registry
    from repro.models.modules import Policy, RunConfig
    cfg = registry.smoke_config(registry.get_config("llama3.2-3b"))
    mesh = make_mesh((1, 1), ("data", "model"))
    run = RunConfig(policy=Policy(), attn_impl="ref", moe_impl="gather")
    return cfg, mesh, run


def test_build_deployment_maps_config_to_engine():
    from repro.serve import ContinuousBatchingEngine, build_deployment
    cfg, mesh, run = _ctx()
    sc = ServeConfig(slots=2, max_len=16)
    eng = build_deployment(cfg, mesh, run, sc)
    assert isinstance(eng, ContinuousBatchingEngine)
    assert eng.sched.allocator is None           # dense KV, no paging
    sc = ServeConfig(slots=2, max_len=16,
                     paged=PagedCfg(enabled=True, page_size=8),
                     prefix=PrefixCacheCfg(enabled=True))
    eng = build_deployment(cfg, mesh, run, sc)
    assert eng.sched.allocator is not None
    assert eng.sched.prefix_index is not None
    assert eng.sched.allocator.reclaim == eng.sched.prefix_index.evict


def test_build_deployment_validates_first():
    from repro.serve import build_deployment
    cfg, mesh, run = _ctx()
    sc = ServeConfig(prefix=PrefixCacheCfg(enabled=True))
    with pytest.raises(ServeConfigError, match="paged deployment"):
        build_deployment(cfg, mesh, run, sc)  # nothing half-constructed


def test_legacy_make_continuous_program_kwargs_still_work():
    from repro.serve import make_continuous_program
    cfg, mesh, run = _ctx()
    p = make_continuous_program(cfg, mesh, run, n_slots=2, max_len=16)
    assert p.n_slots == 2 and p.max_len == 16
    sc = ServeConfig(slots=3, max_len=24,
                     paged=PagedCfg(enabled=True, page_size=8))
    p = make_continuous_program(cfg, mesh, run, serve_cfg=sc)
    assert p.n_slots == 3 and p.page_size == 8
    with pytest.raises(AssertionError, match="serve_cfg or the legacy"):
        make_continuous_program(cfg, mesh, run)
