"""Tick-clock tracing + Perfetto export + idle attribution (DESIGN.md §15).

Covers the observability layer end to end: tracer determinism and the
zero-perturbation contract (tracing on/off yields bit-identical tokens),
hypothesis properties over random op scripts (spans well-nested per
track, flows always reference existing span/instant anchors, seeded
chaos replay gives bit-identical trace signatures), the exact idle
accounting identity ``sum(buckets) == ticks - busy`` on a REAL
fleet-under-chaos run whose exported trace carries spans from the
scheduler, engine, KV transfer, fleet controller and chaos injector plus
request flows crossing group tracks, and the a2a-exposed bucket of a
simulated zebra timeline reconciling against ``simulator.exposed_comm``
within 10%.
"""

import json

import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import schedule as S
from repro.core.profiler import LayerTimes
from repro.core.simulator import CommTimes, chaos_matrix, simulate
from repro.ft.chaos import FaultInjector, FaultPlan
from repro.models import stack
from repro.obs import trace as obs_trace
from repro.obs.export import to_chrome
from repro.obs.report import idle_report
from repro.obs.zebra import sim_to_trace
from repro.pytree import split_params
from repro.serve.fleet import make_fleet
from repro.serve.metrics import ServeMetrics

from tests.test_serve_disagg import RUN, TINY  # noqa: F401
from tests.test_serve_fleet import _trace, mesh1, tiny_params  # noqa: F401

pytestmark = pytest.mark.obs  # CI trace-smoke job slice


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------

_ctx = {}


def _mesh_params():
    """Module-lazy (1x1 mesh, tiny params) pair usable from @given tests —
    the hypothesis stub hides pytest fixtures from wrapped signatures."""
    if not _ctx:
        from repro.launch.mesh import make_mesh
        _ctx["mesh"] = make_mesh((1, 1), ("data", "model"))
        _ctx["params"] = split_params(
            stack.init_model(jax.random.PRNGKey(0), TINY))[0]
    return _ctx["mesh"], _ctx["params"]


def _fleet(mesh, params, chaos=None):
    return make_fleet(TINY, mesh, RUN, params, chaos=chaos,
                      prefill_classes=["a40", "a40"],
                      decode_classes=["v100", "v100"],
                      decode_slots=2, max_len=32, page_size=8,
                      prefill_chunk=6, metrics=ServeMetrics())


def _traced_fleet_run(mesh, params, spec=None, seed=0):
    inj = FaultInjector(FaultPlan.parse(spec), seed=seed) if spec else None
    tr = obs_trace.Tracer()
    with obs_trace.use(tr):
        fleet = _fleet(mesh, params, chaos=inj)
        # Pin the straggler factor: routing normally consults wall-clock
        # step timings (StragglerDetector), the one intentionally
        # non-deterministic input — tick-domain traces must not see it.
        fleet.router.slow_factor = lambda name: 1.0
        res = fleet.run(_trace())
    return tr, res, fleet


_STANDARD_SPEC = next(e[1] for e in chaos_matrix() if e[0] == "standard")


# ---------------------------------------------------------------------------
# Hypothesis properties over random op scripts (host-only Tracer)
# ---------------------------------------------------------------------------

_TRACKS = ("alpha", "beta")
_OPS = ("advance", "begin", "end", "instant", "flow_queued",
        "flow_step", "flow_finished", "idle")


def _run_script(script):
    """Interpret an op script leniently (end on an empty stack is skipped)
    and close every span left open, like an engine draining at exit."""
    tr = obs_trace.Tracer()
    tick, depth = 0, {t: 0 for t in _TRACKS}
    for sel, ti, rid in script:
        track = _TRACKS[ti % len(_TRACKS)]
        op = _OPS[sel % len(_OPS)]
        if op == "advance":
            tick += 1
            tr.advance(tick)
        elif op == "begin":
            tr.begin(track, f"work{rid}", rid=rid)
            depth[track] += 1
        elif op == "end":
            if depth[track]:
                tr.end(track)
                depth[track] -= 1
        elif op == "instant":
            tr.instant(track, "note", rid=rid)
        elif op == "idle":
            tr.mark_idle(track, obs_trace.IDLE_BUCKETS[rid
                                                       % len(obs_trace
                                                             .IDLE_BUCKETS)])
        else:
            stage = op[len("flow_"):]
            tr.flow(track, "queued" if stage == "queued" else
                    ("finished" if stage == "finished" else "prefill"), rid)
    for track, n in depth.items():
        for _ in range(n):
            tr.end(track)
    return tr


_SCRIPT = st.lists(st.tuples(st.integers(0, 7),    # op selector
                             st.integers(0, 1),    # track
                             st.integers(0, 5)),   # rid / bucket
                   min_size=0, max_size=120)


@settings(max_examples=30, deadline=None)
@given(_SCRIPT)
def test_spans_well_nested_per_track(script):
    """PROPERTY: exported span intervals on one track are either disjoint
    or strictly nested (stack discipline survives export), no span is
    flagged unclosed, and replaying the script is bit-identical."""
    tr = _run_script(script)
    obj = to_chrome(tr)
    xs = {}
    names = {(p, t): n for p, t, n in
             ((e["pid"], e["tid"], e["args"]["name"])
              for e in obj["traceEvents"]
              if e["ph"] == "M" and e["name"] == "thread_name")}
    for e in obj["traceEvents"]:
        if e["ph"] != "X":
            continue
        assert "unclosed" not in e["args"]
        xs.setdefault(names[(e["pid"], e["tid"])], []).append(
            (e["ts"], e["ts"] + e["dur"]))
    for track, ivals in xs.items():
        open_stack = []
        for t0, t1 in sorted(ivals):
            while open_stack and open_stack[-1] <= t0:
                open_stack.pop()
            if open_stack:              # overlapping => must be contained
                assert t1 <= open_stack[-1], (track, t0, t1, open_stack)
            open_stack.append(t1)
    assert tr.signature() == _run_script(script).signature()


@settings(max_examples=30, deadline=None)
@given(_SCRIPT)
def test_flows_reference_existing_spans(script):
    """PROPERTY: every flow event's parent eid names a span-begin or
    instant that exists on the same track, flow-start ("s") appears
    exactly at a rid's first stage, and "f" only for stage finished."""
    tr = _run_script(script)
    anchors = {ev.eid: ev for ev in tr.events if ev.ph in ("B", "i")}
    seen = set()
    for ev in tr.events:
        if ev.ph not in ("s", "t", "f"):
            continue
        assert ev.parent in anchors
        assert anchors[ev.parent].track == ev.track
        assert (ev.ph == "s") == (ev.flow_id not in seen)
        if ev.ph == "f":
            assert ev.name == "finished" and ev.flow_id in seen
        seen.add(ev.flow_id)


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 1000))
def test_seeded_chaos_trace_bit_identical(seed):
    """PROPERTY: the same (chaos seed, spec, request trace) produces a
    bit-identical span sequence across two runs — the §15 determinism
    contract extended from fault logs to whole traces."""
    mesh, params = _mesh_params()
    a, res_a, _ = _traced_fleet_run(mesh, params, _STANDARD_SPEC, seed)
    b, res_b, _ = _traced_fleet_run(mesh, params, _STANDARD_SPEC, seed)
    assert res_a == res_b
    assert a.signature() == b.signature()
    assert [e.name for e in a.events] == [e.name for e in b.events]


# ---------------------------------------------------------------------------
# Acceptance: real fleet under chaos — trace contents + exact idle sums
# ---------------------------------------------------------------------------

def test_fleet_chaos_trace_contents_and_idle_identity(mesh1, tiny_params):
    """ACCEPTANCE: one traced fleet+chaos run carries spans/instants from
    the scheduler, engine, KV transfer, fleet controller and chaos
    injector; request flows cross group tracks; the export is valid JSON
    with positive-duration X events; and per tick track the idle buckets
    sum to (ticks - busy) EXACTLY."""
    tr, res, fleet = _traced_fleet_run(mesh1, tiny_params,
                                       _STANDARD_SPEC, seed=3)
    assert res  # requests actually finished under chaos
    obj = to_chrome(tr, ticks=fleet.tick_count)
    json.loads(json.dumps(obj))  # Perfetto-loadable (valid strict JSON)

    by_track = {}
    for ev in tr.events:
        by_track.setdefault(ev.track, set()).add((ev.ph, ev.name))
    # engine spans on group tracks (prefill workers + decode workers)
    assert any(("B", "prefill") in v for t, v in by_track.items()
               if t.startswith("g"))
    assert any(("B", "decode") in v for t, v in by_track.items()
               if t.startswith("g"))
    # scheduler flow stages, fleet + chaos control plane, kv chunks
    stages = {ev.name for ev in tr.events if ev.ph in ("s", "t", "f")}
    assert {"queued", "admitted", "finished"} <= stages
    assert "fleet" in by_track and "chaos" in by_track
    assert any(t.startswith("xfer:") for t in by_track)
    assert any(("B", "chunk") in v for t, v in by_track.items()
               if t.startswith("xfer:"))
    # flows cross tracks: some rid has flow events on >= 2 distinct tracks
    rid_tracks = {}
    for ev in tr.events:
        if ev.ph in ("s", "t", "f"):
            rid_tracks.setdefault(ev.flow_id, set()).add(ev.track)
    assert any(len(ts) >= 2 for ts in rid_tracks.values())
    # every request that finished has a full s -> ... -> f chain
    finished = {ev.flow_id for ev in tr.events if ev.ph == "f"}
    assert finished >= set(res)

    for e in obj["traceEvents"]:
        if e["ph"] == "X":
            assert e["dur"] > 0

    rep = obj["reproIdle"]
    assert rep  # at least the group tracks
    for track, r in rep.items():
        if r["kind"] != "tick":
            continue
        assert r["ticks"] == fleet.tick_count
        assert sum(r["buckets"].values()) == r["idle"] \
            == r["ticks"] - r["busy"], track
        assert set(r["buckets"]) <= set(obs_trace.IDLE_BUCKETS)
    assert {"g0", "g1", "g2"} <= set(rep)
    # meta tracks (control plane) never get idle-attributed
    assert "fleet" not in rep and "chaos" not in rep


def test_tracing_disabled_is_bit_identical(mesh1, tiny_params):
    """ACCEPTANCE: running the same workload with tracing enabled vs
    disabled yields identical tokens — the tracer never touches RNG or
    control flow."""
    tr, traced, _ = _traced_fleet_run(mesh1, tiny_params,
                                      _STANDARD_SPEC, seed=3)
    assert tr.events  # the traced run actually recorded something
    assert obs_trace.TRACER is obs_trace.NULL  # use() uninstalled it
    inj = FaultInjector(FaultPlan.parse(_STANDARD_SPEC), seed=3)
    fleet = _fleet(mesh1, tiny_params, chaos=inj)
    fleet.router.slow_factor = lambda name: 1.0
    untraced = fleet.run(_trace())
    assert traced == untraced


def test_unified_engine_idle_attribution(mesh1, tiny_params):
    """The single-engine path marks exactly one idle bucket per idle tick
    on its "serve" track (drain ticks at the end of a run show up as
    queue-starved by default)."""
    from repro.serve import (ContinuousBatchingEngine, Request, Scheduler,
                             make_continuous_program)
    from tests.test_serve_disagg import _prompt
    prog = make_continuous_program(TINY, mesh1, RUN, n_slots=2, max_len=32)
    with mesh1:
        params = jax.device_put(tiny_params, prog.param_shardings)
    tr = obs_trace.Tracer()
    with obs_trace.use(tr):
        eng = ContinuousBatchingEngine(
            prog, params, Scheduler(2, 32, prefill_chunk=8))
        res = eng.run([Request(rid=0, prompt=_prompt(0, 6),
                               max_new_tokens=4),
                       Request(rid=1, prompt=_prompt(1, 9),
                               max_new_tokens=4)])
        ticks = eng.tick_count
    assert sorted(res) == [0, 1]
    rep = idle_report(tr, ticks=ticks)
    r = rep["serve"]
    assert r["busy"] > 0
    assert sum(r["buckets"].values()) == r["idle"] == ticks - r["busy"]


# ---------------------------------------------------------------------------
# Acceptance: simulated zebra timeline — a2a-exposed vs simulator
# ---------------------------------------------------------------------------

def test_zebra_a2a_exposed_reconciles_with_simulator():
    """ACCEPTANCE: on a comm-dominant zebra schedule the attention
    stream's a2a-exposed idle matches the union of exposed link busy time
    (simulator.exposed_comm prices the link tasks) within 10%, and
    chunked overlap shrinks both."""
    times = LayerTimes(t_attn=0.05, t_exp=0.05, t_exp_attn=0.05,
                       t_exp_on_exp=0.05, t_attn_on_exp=0.4)
    comm = CommTimes(dispatch=1.0, combine=1.0)
    sched = S.canonical_schedule(4, 3, n_chunks=1)
    res = simulate(sched, times, comm, 4, 1, 1)
    tr = obs_trace.Tracer()
    sim_to_trace(sched, res, tr)
    rep = idle_report(tr)

    ivals = sorted((res.starts[t], res.ends[t])
                   for s in ("link_a2e", "link_e2a")
                   for t in sched.streams[s] if res.ends[t] > res.starts[t])
    merged = []
    for t0, t1 in ivals:
        if merged and t0 <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], t1))
        else:
            merged.append((t0, t1))
    exposed_union = sum(t1 - t0 for t0, t1 in merged)

    a2a = rep["zebra:attn_comp"]["buckets"]["a2a-exposed"]
    assert abs(a2a - exposed_union) / exposed_union < 0.10
    # the time-track identity holds too (report self-check)
    for r in rep.values():
        assert r["_check"]

    # overlap (n_chunks=4) shrinks the exposed residue AND the bucket
    sched4 = S.canonical_schedule(4, 3, n_chunks=4)
    res4 = simulate(sched4, times, comm, 4, 1, 1)
    tr4 = obs_trace.Tracer()
    sim_to_trace(sched4, res4, tr4)
    rep4 = idle_report(tr4)
    assert res4.iter_time < res.iter_time
    assert rep4["zebra:attn_comp"]["buckets"]["a2a-exposed"] < a2a


# ---------------------------------------------------------------------------
# Exporter + registry plumbing
# ---------------------------------------------------------------------------

def test_export_embeds_registry_and_counters():
    tr = obs_trace.Tracer()
    tr.registry.register("unit", lambda: {"answer": 42})
    with obs_trace.use(tr):
        tr.advance(0)
        with tr.span("serve", "work", rid=1):
            tr.flow("serve", "queued", 1)
        tr.count("serve", "queue_depth", 3)
        tr.advance(1)
        tr.mark_idle("serve", "pool-OOM")
    obj = to_chrome(tr, ticks=2)
    assert obj["reproCounters"] == {"unit": {"answer": 42}}
    assert obj["reproIdle"]["serve"] == {
        "kind": "tick", "ticks": 2, "busy": 1, "idle": 1,
        "buckets": {"pool-OOM": 1}}
    phases = {e["ph"] for e in obj["traceEvents"]}
    assert {"M", "X", "s", "C", "i"} <= phases
    counter = next(e for e in obj["traceEvents"] if e["ph"] == "C")
    assert counter["args"] == {"value": 3}


def test_null_tracer_is_inert():
    """Disabled-path contract: NULL absorbs every call, reports not-busy,
    and the span context manager still runs the body."""
    n = obs_trace.NULL
    assert not n.enabled
    n.advance(5)
    n.begin("t", "x")
    n.end("t")
    n.flow("t", "queued", 1)
    n.mark_idle("t", "queue-starved")
    ran = []
    with n.span("t", "x"):
        ran.append(True)
    assert ran and n.busy_this_tick("t") is False
    assert idle_report(n) == {}
