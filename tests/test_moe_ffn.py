"""Single-pack fused MoE expert FFN (kernels/ops.moe_ffn) + gmm_glu_tiled.

Covers: forward/gradient parity against the pure-jnp oracle for both
execution paths (Pallas interpret + XLA tile-gather fallback), an expert
receiving zero tokens, non-tile-multiple group sizes, the already-packed
[E, C, d] variant, and the structural single-pack guarantee (exactly one
pack scatter + one unpack gather in the forward jaxpr).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import gmm as gmm_kernel
from repro.kernels import ops, ref
from repro.models import modules
from repro.models.config import LayerSpec, ModelConfig
from repro.models.modules import Policy, RunConfig
from repro.pytree import split_params

KEY = jax.random.PRNGKey(0)


def rand(shape, dtype=jnp.float32, k=0, scale=1.0):
    x = jax.random.normal(jax.random.fold_in(KEY, k), shape, jnp.float32)
    return (x * scale).astype(dtype)


def make_ffn(M, d, f, G, dtype=jnp.float32):
    x = rand((M, d), dtype, 1, 0.5)
    wg = rand((G, d, f), dtype, 2, 0.1)
    wu = rand((G, d, f), dtype, 3, 0.1)
    wo = rand((G, f, d), dtype, 4, 0.1)
    return x, wg, wu, wo


# ---------------------------------------------------------------------------
# moe_ffn parity (both execution paths)
# ---------------------------------------------------------------------------

# Group partitions: zero-token expert, non-tile-multiple sizes, all-one-group.
SIZE_CASES = [
    [37, 0, 90, 73],
    [0, 0, 200, 0],
    [1, 1, 1, 197],
    [50, 50, 50, 50],
]


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("sizes", SIZE_CASES)
def test_moe_ffn_matches_oracle(use_kernel, sizes):
    M, d, f, G = sum(sizes), 32, 48, len(sizes)
    x, wg, wu, wo = make_ffn(M, d, f, G)
    gs = jnp.asarray(sizes, jnp.int32)
    out = ops.moe_ffn(x, wg, wu, wo, gs, use_kernel=use_kernel, block_m=32)
    want = ref.moe_ffn(x, wg, wu, wo, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_moe_ffn_grads_match_oracle(use_kernel):
    sizes = [37, 0, 90, 73]
    M, d, f, G = sum(sizes), 32, 48, len(sizes)
    x, wg, wu, wo = make_ffn(M, d, f, G)
    gs = jnp.asarray(sizes, jnp.int32)

    g1 = jax.grad(
        lambda *a: jnp.sum(ops.moe_ffn(*a, gs, use_kernel=use_kernel,
                                       block_m=32) ** 2),
        argnums=(0, 1, 2, 3))(x, wg, wu, wo)
    g2 = jax.grad(
        lambda *a: jnp.sum(ref.moe_ffn(*a, gs) ** 2),
        argnums=(0, 1, 2, 3))(x, wg, wu, wo)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_moe_ffn_group_dense_matches_oracle():
    """Small-M fallback parity (values + grads), with and without fused
    row scales."""
    sizes = [37, 0, 90, 73]
    M, d, f, G = sum(sizes), 32, 48, len(sizes)
    x, wg, wu, wo = make_ffn(M, d, f, G)
    gs = jnp.asarray(sizes, jnp.int32)
    s = rand((M,), k=11, scale=0.5)

    out = ops.moe_ffn_group_dense(x, wg, wu, wo, gs)
    want = ref.moe_ffn(x, wg, wu, wo, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)

    # auto-routing picks it at decode shapes (M*(G-1) <= G*block_m)
    xs_small = x[:24]
    gs_small = jnp.asarray([10, 0, 9, 5], jnp.int32)
    auto = ops.moe_ffn(xs_small, wg, wu, wo, gs_small)
    np.testing.assert_allclose(
        np.asarray(auto),
        np.asarray(ops.moe_ffn_group_dense(xs_small, wg, wu, wo, gs_small)),
        atol=1e-6)

    g1 = jax.grad(lambda *a: jnp.sum(
        ops.moe_ffn_group_dense(*a[:4], gs, row_scales=a[4]) ** 2),
        argnums=(0, 1, 2, 3, 4))(x, wg, wu, wo, s)
    g2 = jax.grad(lambda *a: jnp.sum(
        (ref.moe_ffn(*a[:4], gs) * a[4][:, None]) ** 2),
        argnums=(0, 1, 2, 3, 4))(x, wg, wu, wo, s)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_moe_ffn_packed_path_row_scales(use_kernel):
    """Fused row-scale combine on the packed pipeline: values + all grads
    (incl. d(scales), which needs the rematerialized unscaled rows)."""
    sizes = [37, 0, 90, 73]
    M, d, f, G = sum(sizes), 32, 48, len(sizes)
    x, wg, wu, wo = make_ffn(M, d, f, G)
    gs = jnp.asarray(sizes, jnp.int32)
    s = rand((M,), k=12, scale=0.5)

    out = ops.moe_ffn(x, wg, wu, wo, gs, row_scales=s, small_m=False,
                      use_kernel=use_kernel, block_m=32)
    want = ref.moe_ffn(x, wg, wu, wo, gs) * s[:, None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)

    g1 = jax.grad(lambda *a: jnp.sum(
        ops.moe_ffn(*a[:4], gs, row_scales=a[4], small_m=False,
                    use_kernel=use_kernel, block_m=32) ** 2),
        argnums=(0, 1, 2, 3, 4))(x, wg, wu, wo, s)
    g2 = jax.grad(lambda *a: jnp.sum(
        (ref.moe_ffn(*a[:4], gs) * a[4][:, None]) ** 2),
        argnums=(0, 1, 2, 3, 4))(x, wg, wu, wo, s)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_moe_ffn_bf16():
    sizes = [64, 96, 40]
    M, d, f, G = sum(sizes), 32, 64, len(sizes)
    x, wg, wu, wo = make_ffn(M, d, f, G, jnp.bfloat16)
    gs = jnp.asarray(sizes, jnp.int32)
    out = ops.moe_ffn(x, wg, wu, wo, gs, use_kernel=False, block_m=32)
    want = ref.moe_ffn(x, wg, wu, wo, gs)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=5e-2)


# ---------------------------------------------------------------------------
# Already-packed [E, C, d] variant (zebra dispatch buffers)
# ---------------------------------------------------------------------------

def _dense_expert_ffn(buf, wg, wu, wo):
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    return jnp.einsum("ecf,efd->ecd", g * u, wo)


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("E,C", [(3, 16), (1, 8), (4, 40), (2, 25)])
def test_moe_ffn_packed_matches_dense(use_kernel, E, C):
    d, f = 32, 48
    buf = rand((E, C, d), k=6, scale=0.5)
    wg = rand((E, d, f), k=2, scale=0.1)
    wu = rand((E, d, f), k=3, scale=0.1)
    wo = rand((E, f, d), k=4, scale=0.1)
    out = ops.moe_ffn_packed(buf, wg, wu, wo, use_kernel=use_kernel)
    want = _dense_expert_ffn(buf, wg, wu, wo)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)

    gp = jax.grad(lambda b: jnp.sum(
        ops.moe_ffn_packed(b, wg, wu, wo, use_kernel=use_kernel) ** 2))(buf)
    gd = jax.grad(lambda b: jnp.sum(
        _dense_expert_ffn(b, wg, wu, wo) ** 2))(buf)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gd), atol=2e-3)


# ---------------------------------------------------------------------------
# gmm_glu_tiled vs reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_glu_tiled_matches_ref(dtype):
    M, K, N, G = 160, 32, 48, 4
    bm = 32
    lhs = rand((M, K), dtype, 1, 0.5)
    w12 = rand((G, K, 2 * N), dtype, 2, 0.1)
    gs = jnp.array([37, 0, 90, 33], jnp.int32)
    dest, tile_group, Mp = ops._pack_meta(gs, M, G, bm)
    lhs_p = jnp.zeros((Mp, K), lhs.dtype).at[dest].set(lhs)
    out_p = gmm_kernel.gmm_glu_tiled(lhs_p, w12, tile_group, block_m=bm,
                                     interpret=True)
    out = jnp.take(out_p, dest, axis=0)
    want = ref.gmm_glu(lhs, w12, gs)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


# ---------------------------------------------------------------------------
# Structural single-pack guarantee
# ---------------------------------------------------------------------------

def _count_eqns(jaxpr, pred, acc=None):
    from jax.core import ClosedJaxpr, Jaxpr
    acc = [] if acc is None else acc

    def visit(v):
        if isinstance(v, ClosedJaxpr):
            _count_eqns(v.jaxpr, pred, acc)
        elif isinstance(v, Jaxpr):
            _count_eqns(v, pred, acc)
        elif isinstance(v, (list, tuple)):
            for u in v:
                visit(u)

    for eqn in jaxpr.eqns:
        if pred(eqn):
            acc.append(eqn)
        for v in eqn.params.values():
            visit(v)
    return acc


def test_moe_ffn_single_pack_scatter_gather():
    """The fused kernel-path forward contains exactly ONE pack scatter and
    ONE d-wide unpack gather (the remaining gathers are 1-D metadata
    lookups over [G]-sized arrays)."""
    sizes = [37, 0, 90, 73]
    M, d, f, G = sum(sizes), 32, 48, len(sizes)
    x, wg, wu, wo = make_ffn(M, d, f, G)
    gs = jnp.asarray(sizes, jnp.int32)
    jx = jax.make_jaxpr(
        lambda x_: ops.moe_ffn(x_, wg, wu, wo, gs, use_kernel=True,
                               block_m=32))(x)
    scatters = _count_eqns(
        jx.jaxpr, lambda e: e.primitive.name.startswith("scatter"))
    wide_gathers = _count_eqns(
        jx.jaxpr, lambda e: e.primitive.name == "gather"
        and e.invars[0].aval.ndim >= 2)
    assert len(scatters) == 1, [e.primitive.name for e in scatters]
    assert len(wide_gathers) == 1


def test_apply_moe_gather_single_pack():
    """Whole gather-mode MoE layer at a training shape (M > E*block_m so
    the packed pipeline is taken): one pack scatter (.set) total; every
    other scatter is an int/combine ADD (bincount histograms + the
    segment-sum combine), never a d-wide repack. The fused row-scale
    combine must not add a second d-wide pass."""
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, d_ff_expert=64,
                      vocab_size=64, n_experts=4, top_k=2,
                      pattern=(LayerSpec(ffn="moe"),))
    run = RunConfig(policy=Policy(compute_dtype=jnp.float32),
                    moe_impl="gather", use_gmm_kernel=True)
    p, _ = split_params(modules.init_moe(KEY, cfg))
    x = rand((4, 128, cfg.d_model), k=9, scale=0.5)  # M = 1024 > 4*128
    jx = jax.make_jaxpr(
        lambda x_: modules.apply_moe(p, cfg, run, x_)[0])(x)
    set_scatters = _count_eqns(
        jx.jaxpr, lambda e: e.primitive.name == "scatter")
    assert len(set_scatters) == 1, [e.primitive.name for e in set_scatters]


def test_apply_moe_decode_shape_uses_group_dense():
    """Decode shapes (M <= E*block_m) skip the packed pipeline entirely:
    no pack scatter in the jaxpr at all (ROADMAP small-M fallback)."""
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, d_ff_expert=64,
                      vocab_size=64, n_experts=4, top_k=2,
                      pattern=(LayerSpec(ffn="moe"),))
    run = RunConfig(policy=Policy(compute_dtype=jnp.float32),
                    moe_impl="gather")
    p, _ = split_params(modules.init_moe(KEY, cfg))
    x = rand((4, 1, cfg.d_model), k=9, scale=0.5)  # M = 8 (decode step)
    jx = jax.make_jaxpr(
        lambda x_: modules.apply_moe(p, cfg, run, x_)[0])(x)
    set_scatters = _count_eqns(
        jx.jaxpr, lambda e: e.primitive.name == "scatter")
    assert len(set_scatters) == 0, [e.primitive.name for e in set_scatters]


# ---------------------------------------------------------------------------
# Full-layer parity (gather+fused vs dense), forward AND backward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("seq", [8, 256])  # group-dense / packed regimes
def test_apply_moe_gather_fused_grads_match_dense(use_kernel, seq):
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, d_ff_expert=64,
                      vocab_size=64, n_experts=4, top_k=2,
                      pattern=(LayerSpec(ffn="moe"),))
    pol = Policy(compute_dtype=jnp.float32)
    run_d = RunConfig(policy=pol, moe_impl="dense")
    run_g = RunConfig(policy=pol, moe_impl="gather",
                      use_gmm_kernel=use_kernel)
    p, _ = split_params(modules.init_moe(KEY, cfg))
    x = rand((2, seq, cfg.d_model), k=9, scale=0.5)

    def loss(run):
        def fn(p_, x_):
            y, aux = modules.apply_moe(p_, cfg, run, x_)
            return jnp.sum(y ** 2) + aux["moe_aux_loss"]
        return fn

    y_d, _ = modules.apply_moe(p, cfg, run_d, x)
    y_g, _ = modules.apply_moe(p, cfg, run_g, x)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_g), atol=1e-5)

    gd = jax.grad(loss(run_d), argnums=(0, 1))(p, x)
    gg = jax.grad(loss(run_g), argnums=(0, 1))(p, x)
    for a, b in zip(jax.tree.leaves(gd), jax.tree.leaves(gg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)
