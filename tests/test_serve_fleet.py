"""Elastic multi-group serving fleet (DESIGN.md §12).

Covers the fleet control plane end to end: router placement policy
(host-only stubs), the production diurnal trace generator, exact
percentile helpers, fleet-simulator invariants (conservation, zero-loss
kill recovery, elastic-beats-static on a shifting-bottleneck trace),
``plan_fleet`` static-split sweeps, and the REAL fleet — greedy
token-exact parity against the unified ``ContinuousBatchingEngine``,
mid-trace group kills (decode and prefill) recovering token-exactly with
``BlockAllocator.check()`` holding on every surviving pool, the forced
role flip that revives a decode-less fleet, and topology validation.
"""

import jax
import numpy as np
import pytest

from repro.core import planner
from repro.core import simulator as sim
from repro.core.hardware import A40, V100
from repro.launch.serve import build_trace, parse_group_spec, parse_kills
from repro.models import stack
from repro.pytree import split_params
from repro.serve import (BlockAllocator, ContinuousBatchingEngine, GREEDY,
                         Request, Scheduler, make_continuous_program)
from repro.serve.fleet import FleetRouter, SimGroup, make_fleet, \
    simulate_fleet_trace
from repro.serve.metrics import percentile, percentiles

from tests.test_serve_disagg import RUN, TINY, _prompt  # noqa: F401

pytestmark = pytest.mark.fleet  # CI fleet-smoke job slice


@pytest.fixture(scope="module")
def mesh1():
    from repro.launch.mesh import make_mesh
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def tiny_params():
    return split_params(stack.init_model(jax.random.PRNGKey(0), TINY))[0]


# ---------------------------------------------------------------------------
# Exact percentiles (serve/metrics)
# ---------------------------------------------------------------------------

def test_percentile_exact_interpolation():
    xs = [4.0, 1.0, 3.0, 2.0]
    assert percentile(xs, 0.0) == 1.0
    assert percentile(xs, 1.0) == 4.0
    assert percentile(xs, 0.5) == pytest.approx(2.5)
    assert percentile(xs, 1 / 3) == pytest.approx(2.0)
    assert percentile([7.0], 0.99) == 7.0
    assert np.isnan(percentile([], 0.5))
    with pytest.raises(ValueError):
        percentile(xs, 1.5)
    with pytest.raises(ValueError):
        percentile(xs, -0.1)


def test_percentiles_dict_keys():
    xs = list(range(101))
    d = percentiles(xs)
    assert d == {"p50": 50.0, "p95": 95.0, "p99": 99.0}


# ---------------------------------------------------------------------------
# Router policy (host-only stubs)
# ---------------------------------------------------------------------------

class _G:
    """Minimal group view implementing the router protocol."""

    def __init__(self, gid, cls, queued=0, active=0, can=True):
        self.gid, self.cls = gid, cls
        self.name = f"g{gid}"
        self._q, self._a, self._can = queued, active, can

    def queued_prefill_tokens(self):
        return self._q

    def n_active(self):
        return self._a

    def can_accept_ticket(self, n_tokens):
        return self._can


def test_router_prefers_fast_class_at_equal_backlog():
    r = FleetRouter(prefill_speed={"a40": 2.0, "v100": 1.0})
    fast, slow = _G(0, "a40", queued=10), _G(1, "v100", queued=10)
    assert r.place_request([slow, fast], 8) is fast
    # enough backlog on the fast class flips the decision
    fast._q = 100
    assert r.place_request([slow, fast], 8) is slow
    assert r.place_request([], 8) is None


def test_router_ticket_filters_and_head_of_line():
    r = FleetRouter(decode_speed={"a40": 1.0, "v100": 1.0})
    full = _G(0, "a40", active=1, can=False)
    free = _G(1, "v100", active=3, can=True)
    assert r.place_ticket([full, free], 16) is free
    assert r.place_ticket([full], 16) is None   # head-of-line: no target
    # least occupancy-per-speed wins among the eligible
    emptier = _G(2, "v100", active=1, can=True)
    assert r.place_ticket([full, free, emptier], 16) is emptier


def test_router_slow_factor_steers_away_from_straggler():
    r = FleetRouter(prefill_speed={"a40": 1.0},
                    slow_factor=lambda name: 4.0 if name == "g0" else 1.0)
    slow, ok = _G(0, "a40", queued=10), _G(1, "a40", queued=20)
    # g0 has less backlog but is 4x degraded: g1 wins
    assert r.place_request([slow, ok], 8) is ok


# ---------------------------------------------------------------------------
# Production trace generator
# ---------------------------------------------------------------------------

def test_production_trace_shape_and_determinism():
    a = sim.production_trace(3, 400, base_rate=20.0, period_s=60.0)
    b = sim.production_trace(3, 400, base_rate=20.0, period_s=60.0)
    assert len(a) == 400
    assert [(r.arrival, r.prompt, r.gen) for r in a] == \
        [(r.arrival, r.prompt, r.gen) for r in b]
    assert sim.production_trace(4, 400, base_rate=20.0)[0].arrival != \
        a[0].arrival or True  # different seed allowed to differ
    assert all(a[i].arrival <= a[i + 1].arrival for i in range(len(a) - 1))
    assert all(1 <= r.prompt <= 16384 and 1 <= r.gen <= 2048 for r in a)


def test_production_trace_diurnal_mix_swings():
    """The interactive fraction must actually swing with the phase:
    interactive requests (short prompt / long gen) dominate the peak,
    batch requests (long prompt / short gen) the trough."""
    reqs = sim.production_trace(0, 4000, base_rate=40.0, diurnal_amp=0.8,
                                period_s=40.0, prompt_med=512, gen_med=64,
                                interactive_frac_amp=0.45)
    import math
    up = [r for r in reqs
          if math.sin(2 * math.pi * r.arrival / 40.0) > 0.7]
    down = [r for r in reqs
            if math.sin(2 * math.pi * r.arrival / 40.0) < -0.7]
    assert len(up) > 50 and len(down) > 50
    # peak phase also carries more arrivals per unit time (thinning)
    mean_prompt_up = sum(r.prompt for r in up) / len(up)
    mean_prompt_down = sum(r.prompt for r in down) / len(down)
    assert mean_prompt_up < mean_prompt_down
    mean_gen_up = sum(r.gen for r in up) / len(up)
    mean_gen_down = sum(r.gen for r in down) / len(down)
    assert mean_gen_up > mean_gen_down


# ---------------------------------------------------------------------------
# Fleet simulator
# ---------------------------------------------------------------------------

def _sim_groups(roles, t_pre=0.01, t_dec=0.02, slots=8):
    return [SimGroup(gid=i, cls="x", role=r, t_prefill_chunk=t_pre,
                     t_decode_step=t_dec, decode_slots=slots)
            for i, r in enumerate(roles)]


def _poisson_sim_trace(n=60, seed=0, rate=4.0, prompt=(64, 512),
                       gen=(16, 64)):
    rng = np.random.RandomState(seed)
    t, out = 0.0, []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate))
        out.append(sim.ServeRequest(arrival=t,
                                    prompt=int(rng.randint(*prompt)),
                                    gen=int(rng.randint(*gen))))
    return out


def test_fleet_sim_conservation():
    trace = _poisson_sim_trace()
    res = simulate_fleet_trace(trace,
                               _sim_groups(["prefill", "decode", "decode"]),
                               prefill_chunk=256)
    assert res.n_requests == len(trace)
    assert res.n_finished == len(trace)
    assert res.goodput > 0 and res.makespan > 0
    assert res.n_flips == 0


def test_fleet_sim_kill_loses_nothing_and_prices_recovery():
    """A killed decode group's requests all still finish (re-prefill via
    the router) and the recovery gap lands in max-ITL, not in silence."""
    trace = _poisson_sim_trace(n=40)
    base = simulate_fleet_trace(
        trace, _sim_groups(["prefill", "decode", "decode"]),
        prefill_chunk=256)
    killed = simulate_fleet_trace(
        trace, _sim_groups(["prefill", "decode", "decode"]),
        prefill_chunk=256, kills=[(base.makespan * 0.3, 1)],
        detect_delay=0.5)
    assert killed.n_finished == len(trace)
    # the detect window + replay shows up in the worst inter-token gap
    assert killed.itl_p99 > base.itl_p99 + 0.2


def test_fleet_sim_kill_prefill_group_recovers():
    trace = _poisson_sim_trace(n=40)
    res = simulate_fleet_trace(
        trace, _sim_groups(["prefill", "prefill", "decode", "decode"]),
        prefill_chunk=256, kills=[(0.5, 0)], detect_delay=0.5)
    assert res.n_finished == len(trace)


def test_fleet_sim_elastic_beats_static_on_diurnal_trace():
    """ACCEPTANCE (simulated): on a trace whose bottleneck role shifts
    between an interactive (decode-bound) peak and a batch
    (prefill-bound) trough, the elastic fleet's goodput-under-SLO beats
    the SAME groups frozen in their best static split, and it actually
    flips roles to do it. (The full profiled-classes 1.2x gate runs in
    benchmarks/bench_serve.py --fleet.)"""
    trace = sim.production_trace(0, 1200, base_rate=26.0, diurnal_amp=0.5,
                                 period_s=90.0, prompt_med=1650,
                                 prompt_sigma=0.9, gen_med=64,
                                 gen_sigma=0.8, interactive_frac_amp=0.45,
                                 prompt_cap=8192, gen_cap=1024)
    # profiled-shape service times (a40/v100-like, mixtral-d1 scale)
    t_pre, t_dec = 0.0065, 0.0044
    slo_ttft, slo_itl = 2.0, 1.0

    def run(roles, elastic):
        groups = [SimGroup(gid=i, cls="x", role=r, t_prefill_chunk=t_pre,
                           t_decode_step=t_dec, decode_slots=8)
                  for i, r in enumerate(roles)]
        return simulate_fleet_trace(trace, groups, prefill_chunk=256,
                                    elastic=elastic, slo_ttft=slo_ttft,
                                    slo_itl=slo_itl)

    statics = [run(r, False) for r in
               (("prefill", "prefill", "prefill", "decode"),
                ("prefill", "prefill", "decode", "decode"),
                ("prefill", "decode", "decode", "decode"))]
    best = max(s.goodput_under_slo for s in statics)
    el = run(("prefill", "prefill", "decode", "decode"), True)
    assert el.n_flips > 0
    assert el.goodput_under_slo > best


def test_fleet_sim_never_flips_last_prefill_group():
    trace = _poisson_sim_trace(n=30, gen=(64, 256))  # decode-heavy
    groups = _sim_groups(["prefill", "decode"])
    simulate_fleet_trace(trace, groups, prefill_chunk=256, elastic=True,
                         wait_hi=0.0)
    assert groups[0].role == "prefill"  # only prefill group never flips


def test_plan_fleet_sweeps_static_splits():
    from repro.models import registry
    cfg = registry.get_config("mixtral-d1")
    trace = _poisson_sim_trace(n=30, rate=8.0)
    plan = planner.plan_fleet(cfg, (A40, A40, V100), trace,
                              prefill_chunk=256, ctx=2048, decode_slots=8,
                              slo_ttft=5.0, slo_itl=2.0)
    assert plan.n_prefill >= 1 and plan.n_decode >= 1
    assert plan.n_prefill + plan.n_decode == 3
    assert plan.predicted_static.n_finished == len(trace)
    assert plan.goodput_ratio_sim > 0
    with pytest.raises(ValueError):
        planner.plan_fleet(cfg, (A40,), trace, slo_ttft=5.0, slo_itl=2.0)


# ---------------------------------------------------------------------------
# Real fleet: parity, kills, flips (tiny model, CPU)
# ---------------------------------------------------------------------------

def _fleet(cfg, mesh, params, **kw):
    kw.setdefault("prefill_classes", ["a40"])
    kw.setdefault("decode_classes", ["v100", "v100"])
    kw.setdefault("decode_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 6)
    return make_fleet(cfg, mesh, RUN, params, **kw)


def _unified_results(mesh, params, trace):
    prog = make_continuous_program(TINY, mesh, RUN, n_slots=2, max_len=32,
                                   page_size=8)
    with mesh:
        p = jax.device_put(params, prog.param_shardings)
    alloc = BlockAllocator(prog.n_pages, prog.page_size, prog.max_pages)
    eng = ContinuousBatchingEngine(
        prog, p, Scheduler(2, 32, prefill_chunk=6, allocator=alloc))
    return eng.run([Request(rid=r.rid, prompt=r.prompt,
                            max_new_tokens=r.max_new_tokens,
                            arrival=r.arrival) for r in trace])


def _trace(n=8, seed=5, rate=0.5):
    return build_trace(seed=seed, n=n, rate=rate, prompt_len=14, gen=8,
                       vocab=TINY.vocab_size, sampling=GREEDY)


def test_fleet_greedy_parity_with_unified(mesh1, tiny_params):
    trace = _trace()
    fleet = _fleet(TINY, mesh1, tiny_params)
    res = fleet.run(trace)
    assert res == _unified_results(mesh1, tiny_params, trace)
    assert not fleet.rejected
    for g in fleet.groups:
        g.worker.allocator.check()
        assert g.worker.allocator.pages_in_use == 0


def test_fleet_kill_decode_group_zero_loss_token_exact(mesh1, tiny_params):
    """ACCEPTANCE: killing a decode group mid-trace loses zero requests —
    every request's tokens are EXACTLY the uninterrupted run's (the
    recovered ones re-prefill prompt + generated and continue bit-exact),
    and the exactly-once page invariant holds on every surviving pool."""
    trace = _trace()
    want = _fleet(TINY, mesh1, tiny_params).run(trace)

    fleet = _fleet(TINY, mesh1, tiny_params)
    res = fleet.run(trace, kills=[(8, 2)])
    assert res == want
    assert not fleet.rejected
    kinds = [e.kind for e in fleet.events]
    assert "dead" in kinds and "recover" in kinds
    assert all(g.gid != 2 for g in fleet.groups)  # evicted from the fleet
    for g in fleet.groups:
        g.worker.allocator.check()
        assert g.worker.allocator.pages_in_use == 0


def test_fleet_kill_prefill_group_recovers(mesh1, tiny_params):
    trace = _trace()
    want = _fleet(TINY, mesh1, tiny_params).run(trace)
    fleet = _fleet(TINY, mesh1, tiny_params,
                   prefill_classes=["a40", "a40"])
    res = fleet.run(trace, kills=[(2, 0)])
    assert res == want
    assert not fleet.rejected
    assert [e.kind for e in fleet.events].count("dead") == 1
    for g in fleet.groups:
        g.worker.allocator.check()


def test_fleet_forced_flip_revives_decode_less_fleet(mesh1, tiny_params):
    """Kill the ONLY decode group with elastic on: a prefill group is
    conscripted into decode (forced flip), its displaced work re-routes,
    and the trace still finishes token-exactly."""
    trace = _trace()
    want = _fleet(TINY, mesh1, tiny_params).run(trace)
    fleet = _fleet(TINY, mesh1, tiny_params,
                   prefill_classes=["a40", "a40"], decode_classes=["v100"],
                   elastic=True)
    res = fleet.run(trace, kills=[(8, 2)])
    assert res == want
    flips = [e for e in fleet.events if e.kind == "flip"]
    assert flips and flips[0].detail == "-> decode"
    assert len(fleet.decode_groups()) >= 1
    for g in fleet.groups:
        g.worker.allocator.check()


def test_fleet_without_elastic_stalls_when_decode_dies(mesh1, tiny_params):
    fleet = _fleet(TINY, mesh1, tiny_params,
                   prefill_classes=["a40", "a40"], decode_classes=["v100"])
    with pytest.raises(RuntimeError, match="exceeded"):
        fleet.run(_trace(), kills=[(8, 2)], max_ticks=120)


def test_make_fleet_rejects_invalid_topologies(mesh1, tiny_params):
    with pytest.raises(ValueError, match="unknown device class"):
        _fleet(TINY, mesh1, tiny_params, prefill_classes=["h100x"])
    with pytest.raises(ValueError, match=">= 1 prefill"):
        _fleet(TINY, mesh1, tiny_params, decode_classes=[])


def test_fleet_submit_rejects_oversized_request(mesh1, tiny_params):
    fleet = _fleet(TINY, mesh1, tiny_params)
    trace = _trace(n=2) + [Request(rid=99, prompt=_prompt(9, 40),
                                   max_new_tokens=8, sampling=GREEDY,
                                   arrival=0.0)]
    res = fleet.run(trace)
    assert fleet.rejected == [99]
    assert sorted(res) == [0, 1]


# ---------------------------------------------------------------------------
# Driver plumbing
# ---------------------------------------------------------------------------

def test_parse_group_spec_and_kills():
    assert parse_group_spec("a40,v100", "x") == ["a40", "v100"]
    assert parse_group_spec("3", "a40") == ["a40", "a40", "a40"]
    assert parse_group_spec(" v100 , v100 ", "x") == ["v100", "v100"]
    assert parse_group_spec("", "x") == []
    assert parse_kills(["2@8", "0@10"]) == [(8, 2), (10, 0)]
    assert parse_kills(None) == []
    with pytest.raises(ValueError, match="GID@TICK"):
        parse_kills(["nope"])


def test_fleet_driver_exits_nonzero_on_failure(monkeypatch):
    from repro.launch import serve as serve_mod
    monkeypatch.setattr(serve_mod, "serve_arch",
                        lambda arch, args, serve_cfg=None: {"ok": False})
    assert serve_mod.main(["--smoke", "--fleet"]) == 1
    monkeypatch.setattr(serve_mod, "serve_arch",
                        lambda arch, args, serve_cfg=None: {"ok": True})
    assert serve_mod.main(["--smoke", "--fleet"]) == 0
