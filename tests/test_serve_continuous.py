"""Continuous-batching serving subsystem (DESIGN.md §7).

Covers the scheduler invariants (token-budget chunking, admission,
recycling), the fused sampler (greedy / top-k / top-p + the per-request
determinism contract), per-slot cache writes (vector cache_index),
chunked-prefill == whole-prefill logits, decode parity with the lockstep
engine, slot recycling never leaking KV across requests, and the
mixed-length Poisson acceptance trace on a smoke MoE config.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_mesh
from repro.launch.serve import build_trace
from repro.models import modules, registry, stack
from repro.models.config import LayerSpec, ModelConfig, ShapeConfig
from repro.models.modules import Policy, RunConfig
from repro.pytree import split_params
from repro.serve import (BatchedServer, ContinuousBatchingEngine, GREEDY,
                         Request, SamplingParams, Scheduler, ServeMetrics,
                         make_continuous_program, make_serve_program)
from repro.serve.sampling import request_keys, sample_tokens

pytestmark = pytest.mark.serve  # CI job slice (see .github/workflows/ci.yml)

RUN = RunConfig(policy=Policy(compute_dtype=jnp.float32), attn_impl="ref",
                moe_impl="gather")

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64)


@pytest.fixture(scope="module")
def mesh1():
    return make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def tiny_params():
    return split_params(stack.init_model(jax.random.PRNGKey(0), TINY))[0]


def _prompt(seed, n, vocab=64):
    return np.random.RandomState(seed).randint(0, vocab, size=(n,)).tolist()


def _ref_greedy(params, cfg, run, prompt, n, eos=None):
    """Unbatched reference: full recompute each step, greedy."""
    seq = jnp.asarray(prompt, jnp.int32)[None]
    out = []
    for _ in range(n):
        logits, _, _ = stack.apply_model(params, cfg, run, seq)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        if eos is not None and nxt == eos:
            break
        seq = jnp.concatenate([seq, jnp.asarray([[nxt]], jnp.int32)], 1)
    return out


# ---------------------------------------------------------------------------
# Scheduler invariants (host-side, no jax)
# ---------------------------------------------------------------------------

def test_scheduler_chunking_budget_recycle():
    sched = Scheduler(2, max_len=64, prefill_chunk=8, token_budget=8)
    for rid, (plen, gen) in enumerate([(20, 3), (5, 2), (5, 1)]):
        sched.submit(Request(rid=rid, prompt=list(range(plen)),
                             max_new_tokens=gen))
    assert sched.queue_depth == 3

    # r0 is chunked 8 / 3 (budget-clipped) / 8 / 1 — never more than the
    # per-call budget, chunks strictly sequential.
    c = sched.plan_prefill(8)
    assert (c.slot, c.start, c.length, c.final) == (0, 0, 8, False)
    assert not sched.finish_prefill_chunk(c)
    c = sched.plan_prefill(3)  # budget smaller than a chunk clips it
    assert (c.start, c.length) == (8, 3)
    assert not sched.finish_prefill_chunk(c)
    c = sched.plan_prefill(99)  # chunk size still caps the slice
    assert (c.start, c.length) == (11, 8)
    assert not sched.finish_prefill_chunk(c)
    c = sched.plan_prefill(8)
    assert (c.start, c.length, c.final) == (19, 1, True)
    assert sched.finish_prefill_chunk(c)
    assert not sched.activate(c, first_token=42)  # 3 tokens to go
    assert sched.n_active == 1 and sched.results[0] == [42]

    # r1 takes the remaining slot; r2 must wait (no free slot).
    c1 = sched.plan_prefill(8)
    assert c1.slot == 1 and c1.final
    assert sched.finish_prefill_chunk(c1)
    assert not sched.activate(c1, first_token=7)
    assert sched.plan_prefill(8) is None  # r2 queued, both slots busy
    assert sched.queue_depth == 1

    # r1 finishes (gen=2) -> slot 1 recycled -> r2 admitted into it.
    assert sched.note_token(1, 9)
    assert sched.results[1] == [7, 9] and sched.free == [1]
    c2 = sched.plan_prefill(8)
    assert c2.slot == 1 and c2.request.rid == 2
    assert sched.finish_prefill_chunk(c2)
    assert sched.activate(c2, first_token=3)  # max_new == 1: done at once
    assert sched.results[2] == [3] and sched.free == [1]

    # r0 still live; finishes after its remaining tokens.
    assert not sched.note_token(0, 1)
    assert sched.note_token(0, 2)
    assert not sched.has_work()


def test_scheduler_rejects_oversize():
    sched = Scheduler(1, max_len=10, prefill_chunk=4)
    with pytest.raises(ValueError):
        sched.submit(Request(rid=0, prompt=list(range(8)),
                             max_new_tokens=4))
    with pytest.raises(ValueError):
        sched.submit(Request(rid=1, prompt=[], max_new_tokens=4))
    assert sched.n_rejected == 2 and not sched.has_work()


# ---------------------------------------------------------------------------
# Sampler
# ---------------------------------------------------------------------------

def test_sampler_greedy_topk_topp():
    base = jax.random.PRNGKey(0)
    logits = jnp.asarray(np.random.RandomState(0).randn(4, 33), jnp.float32)
    rids = jnp.arange(4, dtype=jnp.int32)
    ngen = jnp.zeros((4,), jnp.int32)
    keys = request_keys(base, rids, ngen)
    amax = np.asarray(jnp.argmax(logits, -1))

    # temperature 0 -> greedy
    got = sample_tokens(logits, keys, jnp.zeros(4), jnp.zeros(4, jnp.int32),
                        jnp.ones(4))
    np.testing.assert_array_equal(np.asarray(got), amax)
    # top_k = 1 -> argmax at any temperature
    got = sample_tokens(logits, keys, jnp.full((4,), 7.0),
                        jnp.ones(4, jnp.int32), jnp.ones(4))
    np.testing.assert_array_equal(np.asarray(got), amax)
    # tiny top_p -> argmax survives alone
    got = sample_tokens(logits, keys, jnp.full((4,), 7.0),
                        jnp.zeros(4, jnp.int32), jnp.full((4,), 1e-6))
    np.testing.assert_array_equal(np.asarray(got), amax)
    # top_k cut: samples always land in the top-k set
    for trial in range(5):
        ks = request_keys(base, rids, jnp.full((4,), trial, jnp.int32))
        got = np.asarray(sample_tokens(logits, ks, jnp.full((4,), 2.0),
                                       jnp.full((4,), 5, jnp.int32),
                                       jnp.ones(4)))
        topk = np.asarray(jax.lax.top_k(logits, 5)[1])
        for b in range(4):
            assert got[b] in topk[b]


def test_sampler_deterministic_across_batch_composition():
    """key(rid, n) only — the same request samples the same token whatever
    its slot, neighbours, or batch size (DESIGN.md §7.4)."""
    base = jax.random.PRNGKey(3)
    row = jnp.asarray(np.random.RandomState(1).randn(17), jnp.float32)
    other = jnp.asarray(np.random.RandomState(2).randn(17), jnp.float32)
    t = jnp.asarray([1.3], jnp.float32)
    alone = sample_tokens(row[None], request_keys(base, jnp.asarray([7]),
                                                  jnp.asarray([3])),
                          t, jnp.zeros(1, jnp.int32), jnp.ones(1))
    batched = sample_tokens(
        jnp.stack([other, row]),
        request_keys(base, jnp.asarray([5, 7]), jnp.asarray([0, 3])),
        jnp.asarray([0.9, 1.3]), jnp.zeros(2, jnp.int32), jnp.ones(2))
    assert int(alone[0]) == int(batched[1])


# ---------------------------------------------------------------------------
# Per-slot cache writes (vector cache_index)
# ---------------------------------------------------------------------------

def test_vector_cache_index_matches_scalar(tiny_params):
    p, _ = split_params(modules.init_attention(jax.random.PRNGKey(1), TINY))
    x = jnp.asarray(np.random.RandomState(0).randn(2, 1, TINY.d_model),
                    jnp.float32)
    pos = jnp.asarray([[3], [3]], jnp.int32)
    cache = modules.init_attention_cache(TINY, 2, 8, 0, jnp.float32)
    o_s, c_s = modules.apply_attention(p, TINY, RUN, x, pos, causal=True,
                                       cache=cache,
                                       cache_index=jnp.asarray(3, jnp.int32))
    o_v, c_v = modules.apply_attention(p, TINY, RUN, x, pos, causal=True,
                                       cache=cache,
                                       cache_index=jnp.asarray([3, 3],
                                                               jnp.int32))
    np.testing.assert_allclose(np.asarray(o_s), np.asarray(o_v), atol=1e-6)
    for k in ("k", "v", "pos"):
        np.testing.assert_array_equal(np.asarray(c_s[k]), np.asarray(c_v[k]))


def test_inactive_slot_writes_nothing():
    p, _ = split_params(modules.init_attention(jax.random.PRNGKey(1), TINY))
    x = jnp.asarray(np.random.RandomState(0).randn(2, 1, TINY.d_model),
                    jnp.float32)
    pos = jnp.asarray([[2], [-1]], jnp.int32)
    cache = modules.init_attention_cache(TINY, 2, 8, 0, jnp.float32)
    _, c = modules.apply_attention(p, TINY, RUN, x, pos, causal=True,
                                   cache=cache,
                                   cache_index=jnp.asarray([2, -1],
                                                           jnp.int32))
    assert np.asarray(c["pos"][0])[2] == 2  # active row wrote its line
    np.testing.assert_array_equal(np.asarray(c["pos"][1]),
                                  np.full((8,), -1))  # dead row untouched
    np.testing.assert_array_equal(np.asarray(c["k"][1]), np.zeros_like(
        np.asarray(c["k"][1])))


# ---------------------------------------------------------------------------
# Chunked prefill == whole prefill
# ---------------------------------------------------------------------------

def test_chunked_prefill_matches_whole(mesh1, tiny_params):
    prog = make_continuous_program(TINY, mesh1, RUN, n_slots=1, max_len=32)
    with mesh1:
        params = jax.device_put(tiny_params, prog.param_shardings)
    prompt = jnp.asarray(_prompt(5, 13), jnp.int32)[None]

    with mesh1:
        ps_w = prog.init_pstate()
        ps_w, l_w = prog.prefill_step(params, ps_w, prompt,
                                      jnp.asarray(0, jnp.int32))
        ps_c = prog.init_pstate()
        off = 0
        for c in (5, 5, 3):
            ps_c, l_c = prog.prefill_step(params, ps_c,
                                          prompt[:, off:off + c],
                                          jnp.asarray(off, jnp.int32))
            off += c

    np.testing.assert_allclose(np.asarray(l_w), np.asarray(l_c),
                               rtol=2e-5, atol=2e-5)
    for a, b in zip(jax.tree.leaves(ps_w), jax.tree.leaves(ps_c)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-5, atol=2e-5)
    # and both match the cache-free structural forward
    logits, _, _ = stack.apply_model(tiny_params, TINY, RUN, prompt)
    np.testing.assert_allclose(np.asarray(l_w), np.asarray(logits[:, -1]),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Decode parity with the lockstep engine
# ---------------------------------------------------------------------------

def test_active_mask_decode_parity_with_lockstep(mesh1, tiny_params):
    B, plen, gen = 2, 9, 6
    prompts = jnp.asarray([_prompt(11, plen), _prompt(12, plen)], jnp.int32)

    shape = ShapeConfig("t", "decode", plen + gen, B)
    sprog = make_serve_program(TINY, mesh1, RUN, shape, max_len=plen + gen)
    with mesh1:
        sparams = jax.device_put(tiny_params, sprog.param_shardings)
    server = BatchedServer(sprog, sparams, B, plen + gen)
    got = [server.submit_prefill(prompts)]
    for _ in range(gen - 1):
        got.append(server.step())
    lock = np.asarray(jnp.concatenate(got, axis=1))

    prog = make_continuous_program(TINY, mesh1, RUN, n_slots=B,
                                   max_len=plen + gen)
    with mesh1:
        params = jax.device_put(tiny_params, prog.param_shardings)
    reqs = [Request(rid=b, prompt=list(map(int, prompts[b])),
                    max_new_tokens=gen) for b in range(B)]
    eng = ContinuousBatchingEngine(
        prog, params, Scheduler(B, plen + gen, prefill_chunk=plen))
    res = eng.run(reqs)
    for b in range(B):
        assert res[b] == list(map(int, lock[b])), (b, res[b], lock[b])


# ---------------------------------------------------------------------------
# Slot recycling never leaks KV
# ---------------------------------------------------------------------------

def test_slot_recycle_no_kv_leak(mesh1, tiny_params):
    """Prefill request A into slot 0, finish it, admit B into slot 0: B's
    logits must match a fresh single-request run bit-for-bit-close."""
    prog = make_continuous_program(TINY, mesh1, RUN, n_slots=1, max_len=24)
    with mesh1:
        params = jax.device_put(tiny_params, prog.param_shardings)
    req_a = Request(rid=0, prompt=_prompt(21, 10), max_new_tokens=4)
    req_b = Request(rid=1, prompt=_prompt(22, 7), max_new_tokens=6)

    eng = ContinuousBatchingEngine(
        prog, params, Scheduler(1, 24, prefill_chunk=6), record_logits=True)
    res = eng.run([req_a, req_b])

    fresh = ContinuousBatchingEngine(
        prog, params, Scheduler(1, 24, prefill_chunk=6), record_logits=True)
    res_f = fresh.run([Request(rid=1, prompt=req_b.prompt,
                               max_new_tokens=6)])

    assert res[1] == res_f[1]
    assert len(eng.logits[1]) == len(fresh.logits[1]) == 6
    for a, b in zip(eng.logits[1], fresh.logits[1]):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
    # and the recycled run still matches the unbatched reference
    assert res[1] == _ref_greedy(tiny_params, TINY, RUN, req_b.prompt, 6)


def test_chunked_prefill_ring_cache_wrap(mesh1):
    """Sliding-window arch: prefill chunks that cross the ring edge must
    WRAP (per-position modular scatter), not clamp. Window 8, chunks of 5
    over a 13-token prompt wrap twice; greedy continuation must match the
    cache-free reference."""
    cfg = ModelConfig(name="tiny-win", family="dense", n_layers=2,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=64,
                      pattern=(LayerSpec(mixer="local_attn"),), window=8)
    params0 = split_params(stack.init_model(jax.random.PRNGKey(2), cfg))[0]
    prog = make_continuous_program(cfg, mesh1, RUN, n_slots=1, max_len=24)
    with mesh1:
        params = jax.device_put(params0, prog.param_shardings)
    req = Request(rid=0, prompt=_prompt(31, 13), max_new_tokens=6)
    eng = ContinuousBatchingEngine(
        prog, params, Scheduler(1, 24, prefill_chunk=5))
    res = eng.run([req])
    assert res[0] == _ref_greedy(params0, cfg, RUN, req.prompt, 6)


def test_oversized_request_rejected_not_fatal(mesh1, tiny_params):
    """An inadmissible request in a trace is rejected; the rest of the
    trace keeps serving."""
    prog = make_continuous_program(TINY, mesh1, RUN, n_slots=1, max_len=16)
    with mesh1:
        params = jax.device_put(tiny_params, prog.param_shardings)
    good = Request(rid=0, prompt=_prompt(41, 6), max_new_tokens=4)
    bad = Request(rid=1, prompt=_prompt(42, 20), max_new_tokens=4)
    eng = ContinuousBatchingEngine(
        prog, params, Scheduler(1, 16, prefill_chunk=8))
    res = eng.run([bad, good])
    assert eng.rejected == [1]
    assert sorted(res) == [0] and len(res[0]) == 4


# ---------------------------------------------------------------------------
# Acceptance: mixed-length Poisson trace on a smoke MoE config
# ---------------------------------------------------------------------------

def test_poisson_trace_moe_acceptance(mesh1):
    """Requests finish and free slots while others are mid-decode (asserted
    via per-request completion ticks), outputs match the unbatched greedy
    reference."""
    cfg = registry.smoke_config(registry.get_config("qwen3-moe-30b-a3b"))
    max_len = 30
    prog = make_continuous_program(cfg, mesh1, RUN, n_slots=2,
                                   max_len=max_len)
    params0, _ = split_params(stack.init_model(jax.random.PRNGKey(0), cfg))
    with mesh1:
        params = jax.device_put(params0, prog.param_shardings)

    trace = build_trace(seed=0, n=5, rate=0.6, prompt_len=16, gen=12,
                        vocab=cfg.vocab_size, sampling=GREEDY)
    metrics = ServeMetrics()
    eng = ContinuousBatchingEngine(
        prog, params, Scheduler(2, max_len, prefill_chunk=4),
        metrics=metrics)
    res = eng.run(trace)

    # every request completed with its full budget (no EOS in the trace)
    assert sorted(res) == [r.rid for r in trace]
    for r in trace:
        assert len(res[r.rid]) == r.max_new_tokens

    # continuous behaviour: more requests than slots; at least one request
    # was admitted after another finished (slot recycled) and at some tick
    # two requests decoded concurrently.
    tr = metrics.requests
    assert len(trace) > prog.n_slots
    recycled = [(i.rid, j.rid) for i in tr.values() for j in tr.values()
                if i.finish_tick is not None
                and j.first_token_tick is not None
                and j.first_token_tick > i.finish_tick]
    assert recycled, "no slot was recycled during the trace"
    assert metrics.summary()["max_concurrent_active"] >= 2

    # greedy parity with the unbatched reference, per request
    for r in trace:
        want = _ref_greedy(params0, cfg, RUN, r.prompt, r.max_new_tokens)
        assert res[r.rid] == want, (r.rid, res[r.rid], want)
