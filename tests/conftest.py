"""Test configuration: 8 emulated host devices for sharding/zebra tests.

(The 512-device override is reserved for launch/dryrun.py per the brief;
tests use a small fixed pool so meshes up to 2x4 are available.)
"""

import os
import pathlib
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

try:  # offline container: fall back to the vendored deterministic stub
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).parent / "_stubs"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh8():
    from repro.launch.mesh import make_mesh
    return make_mesh((2, 4), ("data", "model"))


@pytest.fixture(scope="session")
def mesh4():
    from repro.launch.mesh import make_mesh
    return make_mesh((2, 2), ("data", "model"))
