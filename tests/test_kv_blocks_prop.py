"""Property-based BlockAllocator coverage (DESIGN.md §9, §13).

Drives the allocator through random sequences of EVERY ownership
operation — allocate / extend / free, the export three-state machine,
and the import lease machine (``begin_import`` / ``commit_import`` /
``abort_import``) — with ``check()`` asserted after every single step, a
pure-python mirror model cross-checking the page accounting, and the
all-or-nothing contract verified on every refusal. Runs under real
hypothesis when installed and under the vendored deterministic stub
(tests/_stubs) otherwise.
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.serve.kv_blocks import BlockAllocator, pages_for

pytestmark = pytest.mark.serve  # CI serve-smoke job slice

N_PAGES = 24
PAGE_SIZE = 4
MAX_PAGES = 8


def _fresh():
    return BlockAllocator(N_PAGES, PAGE_SIZE, MAX_PAGES)


# ---------------------------------------------------------------------------
# Lease machine unit coverage
# ---------------------------------------------------------------------------

def test_lease_commit_promotes_to_live_table():
    a = _fresh()
    pages = a.begin_import(7, 10)            # 3 pages under lease
    assert pages is not None and len(pages) == pages_for(10, PAGE_SIZE)
    assert a.pages_in_use == 3               # leased pages are IN USE
    assert 7 not in a.tables                 # ...but in no live table
    a.check()
    a.commit_import(7)
    assert a.tables[7] == pages and 7 not in a.leases
    a.check()
    a.free(7)
    assert a.pages_in_use == 0


def test_lease_abort_returns_every_page():
    a = _fresh()
    a.begin_import(7, 10)
    a.abort_import(7)
    assert a.pages_in_use == 0 and 7 not in a.leases and 7 not in a.tables
    a.check()


def test_lease_is_all_or_nothing():
    a = _fresh()
    for rid, n_pages in ((1, MAX_PAGES), (2, MAX_PAGES),
                         (3, N_PAGES - 2 * MAX_PAGES - 1)):
        assert a.allocate(rid, n_pages * PAGE_SIZE)      # drain to 1 free
    free_before = a.n_free
    assert a.begin_import(9, 2 * PAGE_SIZE) is None      # needs 2, has 1
    assert a.n_free == free_before                       # nothing grabbed
    a.check()


def test_lease_rejects_conflicting_rids():
    a = _fresh()
    a.begin_import(7, 4)
    with pytest.raises(AssertionError, match="already importing"):
        a.begin_import(7, 4)
    a.commit_import(7)
    with pytest.raises(AssertionError, match="already owns"):
        a.begin_import(7, 4)


def test_import_pages_wrapper_is_begin_plus_commit():
    a = _fresh()
    pages = a.import_pages(3, 9)
    assert pages == a.tables[3] and 3 not in a.leases
    a.check()


def test_check_catches_a_leaked_lease_page():
    a = _fresh()
    a.begin_import(7, 4)
    a.leases[7].pop()                        # corrupt: drop a leased page
    with pytest.raises(AssertionError, match="leak"):
        a.check()


def test_release_slot_returns_unused_claim_and_rejects_live():
    from repro.serve.scheduler import DecodeScheduler
    s = DecodeScheduler(2, allocator=_fresh())
    slot = s.claim_slot()
    assert not s.has_free() or s.free        # one slot left at most
    s.release_slot(slot)                     # admission rolled back
    assert slot in s.free
    slot = s.claim_slot()
    s.running[slot] = object()               # now live: releasing is a bug
    with pytest.raises(AssertionError, match="live"):
        s.release_slot(slot)


# ---------------------------------------------------------------------------
# Property: random op sequences, check() after EVERY op
# ---------------------------------------------------------------------------

def _legal_ops(a: BlockAllocator, rid: int):
    """Ops applicable to ``rid`` in its current (disjoint) ownership
    state: live table / in-transit export / in-flight lease / nowhere."""
    if rid in a.tables:
        return ["extend", "free", "export"]
    if rid in a.exported:
        return ["release_exported", "abort_export"]
    if rid in a.leases:
        return ["commit_import", "abort_import"]
    return ["allocate", "begin_import", "import_pages"]


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9),       # op selector
                          st.integers(0, 4),       # rid
                          st.integers(0, 40)),     # token count
                min_size=0, max_size=80))
def test_allocator_invariants_under_random_ops(script):
    a = _fresh()
    for sel, rid, n_tokens in script:
        ops = _legal_ops(a, rid)
        op = ops[sel % len(ops)]
        free_before = a.n_free
        if op == "allocate":
            ok = a.allocate(rid, n_tokens)
            want = pages_for(n_tokens, PAGE_SIZE)
            if ok:
                assert len(a.tables[rid]) == want
                assert a.n_free == free_before - want
            else:                            # all-or-nothing refusal
                assert a.n_free == free_before and rid not in a.tables
                assert want > free_before or want > MAX_PAGES
        elif op == "extend":
            had = len(a.tables[rid])
            ok = a.extend(rid, 1)
            assert len(a.tables[rid]) == had + (1 if ok else 0)
        elif op == "free":
            owned = len(a.tables.get(rid, ()))
            a.free(rid)
            assert a.n_free == free_before + owned
        elif op == "export":
            pages = a.export_pages(rid)
            assert a.exported[rid] == pages and rid not in a.tables
            assert a.n_free == free_before   # exported pages stay in use
        elif op == "release_exported":
            n = len(a.exported[rid])
            a.release_exported(rid)
            assert a.n_free == free_before + n
        elif op == "abort_export":
            pages = list(a.exported[rid])
            a.abort_export(rid)
            assert a.tables[rid] == pages    # back in the live table
            assert a.n_free == free_before
        elif op == "begin_import":
            got = a.begin_import(rid, n_tokens)
            want = pages_for(n_tokens, PAGE_SIZE)
            if got is None:
                assert a.n_free == free_before and rid not in a.leases
                assert want > free_before or want > MAX_PAGES
            else:
                assert len(got) == want
                assert a.n_free == free_before - want
        elif op == "commit_import":
            pages = list(a.leases[rid])
            a.commit_import(rid)
            assert a.tables[rid] == pages and rid not in a.leases
            assert a.n_free == free_before   # ownership moved, not freed
        elif op == "abort_import":
            n = len(a.leases[rid])
            a.abort_import(rid)
            assert a.n_free == free_before + n and rid not in a.leases
        elif op == "import_pages":
            a.import_pages(rid, n_tokens)
        a.check()                            # exactly-once, every step
        assert a.pages_in_use == N_PAGES - a.n_free
    # drain: every path back to the free list restores the full pool
    for rid in list(a.leases):
        a.abort_import(rid)
    for rid in list(a.exported):
        a.release_exported(rid)
    for rid in list(a.tables):
        a.free(rid)
    a.check()
    assert a.pages_in_use == 0
