"""Chrome-trace / Perfetto JSON exporter (§15).

Writes the object form of the Chrome trace-event format
(``{"traceEvents": [...], ...}``), which both chrome://tracing and
ui.perfetto.dev load directly. Mapping:

* tracer pids ("serve", "fleet", "zebra-sim", "train") -> trace processes,
  named via ``process_name`` metadata events;
* tracks -> threads within their pid, named via ``thread_name`` metadata,
  ordered by declaration (``thread_sort_index``);
* spans -> complete "X" events (B/E pairs are joined here via the explicit
  parent eid, so out-of-order simulated timelines export correctly and a
  dangling open span — a crash mid-span — is closed at the trace horizon);
* instants -> "i" (thread scope), flows -> "s"/"t"/"f" sharing ``id``
  per request, counters -> "C".

The exporter also embeds two repo-specific top-level keys (legal per the
spec, ignored by viewers): ``reproCounters`` (the obs registry snapshot)
and ``reproIdle`` (the idle-attribution report) — so one artifact carries
the timeline, the final counters, and the idle accounting together.
``benchmarks/check_trace.py`` validates this exact shape in CI.
"""

from __future__ import annotations

import json
from typing import Optional

from repro.obs.report import idle_report


def to_chrome(tracer, ticks: Optional[int] = None) -> dict:
    """Convert a Tracer to the Chrome trace-event object form."""
    pids = {}
    events = []

    def pid_of(name):
        if name not in pids:
            pids[name] = len(pids) + 1
            events.append({"ph": "M", "name": "process_name",
                           "pid": pids[name], "tid": 0,
                           "args": {"name": name}})
        return pids[name]

    tids = {}
    for track, meta in tracer.tracks.items():
        pid = pid_of(meta["pid"])
        tid = meta["sort"] + 1
        tids[track] = (pid, tid)
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tid, "args": {"name": track}})
        events.append({"ph": "M", "name": "thread_sort_index", "pid": pid,
                       "tid": tid, "args": {"sort_index": meta["sort"]}})

    # Join B/E pairs into X events (parent eid on E names its B).
    opens = {}
    max_ts = max((ev.ts for ev in tracer.events), default=0.0)
    closed = {}
    for ev in tracer.events:
        if ev.ph == "B":
            opens[ev.eid] = ev
        elif ev.ph == "E" and ev.parent in opens:
            b = opens.pop(ev.parent)
            closed[b.eid] = (b, ev.ts, ev.args)
    for eid, b in opens.items():
        closed[eid] = (b, max_ts, {"unclosed": True})

    def clean(args):
        return {k: v for k, v in args.items() if v is not None}

    for ev in tracer.events:
        if ev.track not in tids:
            continue
        pid, tid = tids[ev.track]
        if ev.ph == "B":
            b, t1, eargs = closed[ev.eid]
            events.append({"ph": "X", "name": ev.name, "pid": pid,
                           "tid": tid, "ts": ev.ts,
                           "dur": max(t1 - ev.ts, 1e-3),
                           "args": clean({**ev.args, **eargs})})
        elif ev.ph == "E":
            continue
        elif ev.ph == "i":
            events.append({"ph": "i", "name": ev.name, "pid": pid,
                           "tid": tid, "ts": ev.ts, "s": "t",
                           "args": clean(ev.args)})
        elif ev.ph in ("s", "t", "f"):
            e = {"ph": ev.ph, "name": "req", "cat": "request",
                 "pid": pid, "tid": tid, "ts": ev.ts,
                 "id": str(ev.flow_id), "args": clean(ev.args)}
            if ev.ph == "f":
                e["bp"] = "e"  # bind to enclosing slice
            events.append(e)
        elif ev.ph == "C":
            events.append({"ph": "C", "name": ev.name, "pid": pid,
                           "tid": tid, "ts": ev.ts, "args": ev.args})

    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "reproCounters": tracer.registry.snapshot(),
        "reproIdle": idle_report(tracer, ticks=ticks),
    }


def write_chrome_trace(tracer, path: str,
                       ticks: Optional[int] = None) -> dict:
    """Export ``tracer`` to ``path`` as Perfetto-loadable JSON; returns
    the exported object (the launch drivers print its idle report)."""
    obj = to_chrome(tracer, ticks=ticks)
    with open(path, "w") as f:
        json.dump(obj, f)
        f.write("\n")
    return obj
