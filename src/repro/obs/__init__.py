"""Observability spine (DESIGN.md §15): deterministic tick-clock tracing,
a unified counters/gauges registry, Perfetto export, and idle-time
attribution — the measured counterpart to the analytic profiler/simulator
stack. Disabled by default; ``trace.install(Tracer())`` turns it on and
costs nothing when off (no-op stubs)."""

from repro.obs.export import to_chrome, write_chrome_trace
from repro.obs.registry import Registry
from repro.obs.report import format_report, idle_report
from repro.obs.trace import (IDLE_BUCKETS, NULL, NullTracer, Tracer,
                             current, install, use)
from repro.obs.zebra import sim_to_trace

__all__ = [
    "IDLE_BUCKETS", "NULL", "NullTracer", "Registry", "Tracer",
    "current", "format_report", "idle_report", "install", "sim_to_trace",
    "to_chrome", "use", "write_chrome_trace",
]
