"""Deterministic span/event tracer on the engines' tick clock (§15).

Every serving engine in this repo already carries an integer tick counter
(``ContinuousBatchingEngine.tick_count``, ``DisaggController.tick_count``,
``FleetController.tick_count``); the tracer adopts that counter as its time
base, so a trace is a pure function of the request trace + seeds — two runs
of the same seeded workload produce bit-identical event sequences (the same
determinism contract ``ft.chaos.FaultInjector.log_signature`` keeps for
fault logs). Wall-clock readings are OPT-IN annotations (``wall=True``)
layered on top; they never participate in ordering or idle attribution.

Timestamps: one tick is ``TICK_US`` microseconds of Perfetto time; events
within a tick are separated by a per-tick emission counter, so intra-tick
ordering in the viewer is exactly emission order. Simulated timelines
(``obs.zebra``) use seconds-domain tracks instead (``span_at``); the two
domains live under different pids and never mix arithmetic.

Disabled-by-default, zero cost when off: the module-level ``TRACER`` is a
``NullTracer`` whose methods are empty; hot paths call
``trace.TRACER.begin(...)`` unconditionally and pay one attribute lookup +
one no-op call per event when tracing is off. Nothing in the tracer touches
RNG state or engine control flow, so enabling it cannot perturb tokens
(tests assert bit-identical outputs either way).
"""

from __future__ import annotations

import contextlib
import dataclasses
import time as _time
from typing import Dict, List, Optional, Tuple

TICK_US = 1_000_000  # one engine tick == 1s of Perfetto time

#: Idle-attribution buckets (§15): every idle tick of every track lands in
#: exactly one of these, so per track sum(buckets) == ticks - busy exactly.
IDLE_BUCKETS = ("queue-starved", "pool-OOM", "a2a-exposed", "transfer-wait",
                "drain", "fault-stall")


@dataclasses.dataclass
class Event:
    """One trace event. ``ph`` follows the Chrome trace-event phases this
    repo emits: B/E (span begin/end), i (instant), s/t/f (flow),
    C (counter)."""

    __slots__ = ("ph", "track", "name", "ts", "tick", "args", "eid",
                 "parent", "flow_id")

    ph: str
    track: str
    name: str
    ts: float
    tick: Optional[int]
    args: dict
    eid: int
    parent: Optional[int]   # eid of the innermost open span (flows/instants)
    flow_id: Optional[int]  # request id for s/t/f events


class NullTracer:
    """The disabled tracer: every method is an inert stub so instrumented
    hot paths cost one no-op call when tracing is off."""

    __slots__ = ()
    enabled = False

    def advance(self, tick):
        pass

    def declare_track(self, track, pid="serve", kind="tick", sort=None):
        pass

    def begin(self, track, name, **args):
        pass

    def end(self, track, **args):
        pass

    @contextlib.contextmanager
    def span(self, track, name, **args):
        yield

    def instant(self, track, name, **args):
        pass

    def flow(self, track, stage, rid, **args):
        pass

    def count(self, track, name, value):
        pass

    def mark_idle(self, track, bucket, **args):
        pass

    def span_at(self, track, name, t0, t1, **args):
        pass

    def busy_this_tick(self, track):
        return False


NULL = NullTracer()

#: The current tracer. Hot paths read ``trace.TRACER`` at call time (never
#: ``from ... import TRACER``, which would freeze the binding).
TRACER = NULL


def install(tracer) -> None:
    """Install ``tracer`` as the process-wide current tracer (None -> off)."""
    global TRACER
    TRACER = tracer if tracer is not None else NULL


def current():
    return TRACER


@contextlib.contextmanager
def use(tracer):
    """Scoped install/uninstall (tests; the launch drivers use install())."""
    prev = TRACER
    install(tracer)
    try:
        yield tracer
    finally:
        install(prev)


class Tracer:
    """The enabled tracer. See the module docstring for the contract."""

    enabled = True

    def __init__(self, wall: bool = False):
        self.wall = wall
        self.events: List[Event] = []
        self.tracks: Dict[str, dict] = {}
        self._now: int = 0          # current tick
        self._sub: int = 0          # intra-tick emission counter
        self._eid: int = 0
        self.max_tick: int = 0
        self._stacks: Dict[str, List[Tuple[int, Event]]] = {}
        self._last_busy: Dict[str, int] = {}
        self._flow_seen: set = set()
        from repro.obs.registry import Registry
        self.registry = Registry()

    # -- clock ------------------------------------------------------------

    def advance(self, tick: int) -> None:
        """Advance the tick clock. Called once per engine/controller tick;
        re-advancing to the CURRENT tick is a no-op (a controller and the
        engines it drives share one clock, and resetting the intra-tick
        counter would reorder the controller's earlier events)."""
        if tick == self._now:
            return
        self._now = tick
        self._sub = 0
        if tick > self.max_tick:
            self.max_tick = tick

    @property
    def now(self) -> int:
        return self._now

    def _ts(self) -> float:
        ts = self._now * TICK_US + self._sub
        self._sub += 1
        return ts

    # -- track metadata ---------------------------------------------------

    def declare_track(self, track: str, pid: str = "serve",
                      kind: str = "tick", sort: Optional[int] = None):
        """Register track metadata. ``kind``: "tick" (engine tick clock,
        idle-attributed per tick), "time" (simulated seconds), "comm"
        (simulated link stream — overlap with its spans classifies a gap
        as a2a-exposed), "meta" (control-plane, excluded from the idle
        report)."""
        if track not in self.tracks:
            self.tracks[track] = {"pid": pid, "kind": kind,
                                  "sort": len(self.tracks) if sort is None
                                  else sort}

    def _ensure(self, track: str):
        if track not in self.tracks:
            self.declare_track(track)

    # -- span / instant / flow / counter emission -------------------------

    def _emit(self, ph, track, name, ts, tick, args, parent=None,
              flow_id=None) -> Event:
        ev = Event(ph, track, name, ts, tick, args, self._eid, parent,
                   flow_id)
        self._eid += 1
        self.events.append(ev)
        return ev

    def _open(self, track):
        st = self._stacks.get(track)
        return st[-1][0] if st else None

    def begin(self, track: str, name: str, **args) -> None:
        """Open a span on ``track`` at the current tick."""
        self._ensure(track)
        if self.wall:
            args["wall_s"] = _time.perf_counter()
        ev = self._emit("B", track, name, self._ts(), self._now, args,
                        parent=self._open(track))
        self._stacks.setdefault(track, []).append((ev.eid, ev))
        if self.tracks[track]["kind"] == "tick":
            self._last_busy[track] = self._now

    def end(self, track: str, **args) -> None:
        """Close the innermost open span on ``track``."""
        st = self._stacks.get(track)
        if not st:
            raise ValueError(f"end() with no open span on track {track!r}")
        eid, b = st.pop()
        if self.wall:
            args["wall_s"] = _time.perf_counter()
        self._emit("E", track, b.name, self._ts(), self._now, args,
                   parent=eid)
        if self.tracks[track]["kind"] == "tick":
            self._last_busy[track] = self._now

    @contextlib.contextmanager
    def span(self, track: str, name: str, **args):
        self.begin(track, name, **args)
        try:
            yield
        finally:
            self.end(track)

    def instant(self, track: str, name: str, **args) -> None:
        self._ensure(track)
        self._emit("i", track, name, self._ts(), self._now, args,
                   parent=self._open(track))

    def flow(self, track: str, stage: str, rid, **args) -> None:
        """Request-lifecycle flow event (queued -> ... -> finished). The
        first stage seen for ``rid`` emits a flow-start, "finished" a
        flow-finish, everything else a flow-step; each rides on an instant
        (its ``parent``) so it is visible and anchored even outside a span,
        and additionally references the innermost open span when one
        exists."""
        self._ensure(track)
        anchor = self._open(track)
        if anchor is None:
            self.instant(track, stage, rid=rid, **args)
            anchor = self.events[-1].eid
        # A flow always opens with "s" on its first stage — even if that
        # stage is "finished" (a dangling "f" with no "s" would be an
        # unanchored arrow in the viewer); "f" only terminates a started
        # flow.
        if rid not in self._flow_seen:
            ph = "s"
        elif stage == "finished":
            ph = "f"
        else:
            ph = "t"
        self._flow_seen.add(rid)
        self._emit(ph, track, stage, self._ts(), self._now,
                   dict(args, rid=rid), parent=anchor, flow_id=rid)

    def count(self, track: str, name: str, value) -> None:
        self._ensure(track)
        self._emit("C", track, name, self._ts(), self._now,
                   {"value": value})

    # -- idle attribution hooks -------------------------------------------

    def mark_idle(self, track: str, bucket: str, **args) -> None:
        """Attribute the current tick of ``track`` to one idle bucket.
        Engines call this when a tick did no work on that track; the
        report (obs.report.idle_report) falls back to queue-starved for
        unmarked idle ticks."""
        assert bucket in IDLE_BUCKETS, bucket
        self._ensure(track)
        self._emit("i", track, "idle", self._ts(), self._now,
                   dict(args, bucket=bucket), parent=self._open(track))

    def busy_this_tick(self, track: str) -> bool:
        """Whether ``track`` opened/closed any span during the current
        tick (controllers use this to decide which groups to mark idle)."""
        return self._last_busy.get(track) == self._now

    # -- simulated-time spans (obs.zebra) ---------------------------------

    def span_at(self, track: str, name: str, t0: float, t1: float,
                **args) -> None:
        """Complete span on a seconds-domain track (simulated timelines).
        ``t0``/``t1`` are seconds; stored as Perfetto microseconds."""
        self._ensure(track)
        b = self._emit("B", track, name, t0 * 1e6, None, args)
        self._emit("E", track, name, t1 * 1e6, None, {}, parent=b.eid)

    # -- introspection ----------------------------------------------------

    def signature(self) -> str:
        """sha256 over the deterministic event sequence (wall-clock args
        excluded) — the trace analogue of FaultInjector.log_signature."""
        import hashlib
        h = hashlib.sha256()
        for ev in self.events:
            args = {k: v for k, v in sorted(ev.args.items())
                    if k != "wall_s"}
            h.update(repr((ev.ph, ev.track, ev.name, ev.ts, ev.tick,
                           args, ev.eid, ev.parent, ev.flow_id)).encode())
        return h.hexdigest()
