"""Zebra schedule -> trace converter (§15).

The zebra SPMD engine's overlap happens inside one XLA program (the chunk
pipeline is scheduled by XLA's async runtime), so there is no host-visible
per-chunk clock to instrument — exactly why HeterMoE itself validates
zebra with its simulator. This module lays the simulator's task timeline
(``core.simulator.simulate`` start/end times over the paper's four FIFO
streams) onto seconds-domain tracer tracks, one track per stream:

    <prefix>:attn_comp   attention-class compute   (kind "time")
    <prefix>:exp_comp    expert-class compute      (kind "time")
    <prefix>:link_a2e    attn->exp link, EXPOSED residue only ("comm")
    <prefix>:link_e2a    exp->attn link, EXPOSED residue only ("comm")

Link spans carry only the exposed part of each all-to-all (the simulator
prices D/C tasks via ``exposed_comm``), so the idle report's a2a-exposed
bucket — compute-track gaps overlapping link spans — reconciles against
the analytic model directly (tests hold them within 10%).
"""

from __future__ import annotations

_STREAM_KIND = {"attn_comp": "time", "exp_comp": "time",
                "link_a2e": "comm", "link_e2a": "comm"}


def sim_to_trace(sched, result, tracer, *, pid: str = "zebra-sim",
                 prefix: str = "zebra") -> None:
    """Emit the simulated zebra timeline of ``(sched, result)`` —
    a ``core.schedule.ZebraSchedule`` plus the ``SimResult`` that
    ``core.simulator.simulate`` produced for it — onto ``tracer``."""
    if not getattr(tracer, "enabled", False):
        return
    if not result.ends:
        raise ValueError("SimResult has no task end times; re-run "
                         "simulator.simulate() to populate ends")
    for stream, tasks in sched.streams.items():
        kind = _STREAM_KIND.get(stream, "time")
        track = f"{prefix}:{stream}"
        tracer.declare_track(track, pid=pid, kind=kind)
        for t in tasks:
            kind_c, phase, layer, mb = t
            t0, t1 = result.starts[t], result.ends[t]
            if t1 <= t0:  # zero-duration (fully hidden a2a, empty offload)
                continue
            tracer.span_at(track, f"{kind_c}^{phase} l{layer} mb{mb}",
                           t0, t1, layer=layer, microbatch=mb,
                           chunks=sched.n_chunks)
