"""Idle-time attribution (§15): where did each group's ticks go?

HeterMoE's metric of merit is GPU idle time; this report walks a tracer's
span timeline per track and accounts for every unit of time the track was
NOT inside a busy span, bucketed into the §15 idle taxonomy:

    queue-starved   nothing to run (empty queue / pipeline warmup)
    pool-OOM        work exists but the page pool cannot back it
    a2a-exposed     waiting on the exposed residue of a dispatch/combine
    transfer-wait   decode group waiting on an inbound KV migration
    drain           group is draining toward a role flip / shutdown
    fault-stall     dead, stalled, or quarantined by a fault

Two track domains:

* tick tracks (the real engines): each tick in [0, ticks) is either busy
  (>= 1 span touched it) or idle; idle ticks take the bucket of the
  ``mark_idle`` instant the engine emitted at that tick, else default to
  queue-starved. Exactly one classification per tick, so per track
  ``sum(buckets.values()) == ticks - busy`` EXACTLY — the report can never
  under- or over-account (tests assert the identity).
* time tracks (simulated zebra timelines, seconds domain): gaps between
  spans over [0, horizon] are measured in seconds; the part of a gap that
  overlaps a busy span on any sibling "comm" track is a2a-exposed (the
  stream is provably waiting on a link), the part before the track's first
  span is queue-starved (pipeline warmup), after its last span is drain,
  and the rest queue-starved. Reconciled against
  ``simulator.exposed_comm`` in tests (within 10%).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple


def _spans(tracer, track: str) -> List[Tuple[float, float, Optional[int],
                                             Optional[int]]]:
    """Closed spans on ``track`` as (ts0, ts1, tick0, tick1), pairing E
    events with their B via the explicit parent eid. Dangling opens are
    closed at the track's max event ts (a crash mid-span still accounts)."""
    opens: Dict[int, object] = {}
    out = []
    max_ts = 0.0
    for ev in tracer.events:
        if ev.track != track:
            continue
        max_ts = max(max_ts, ev.ts)
        if ev.ph == "B":
            opens[ev.eid] = ev
        elif ev.ph == "E" and ev.parent in opens:
            b = opens.pop(ev.parent)
            out.append((b.ts, ev.ts, b.tick, ev.tick))
    for b in opens.values():
        out.append((b.ts, max_ts, b.tick, b.tick))
    out.sort()
    return out


def _merge(ivals):
    """Merge overlapping [t0, t1) intervals."""
    merged = []
    for t0, t1 in sorted(ivals):
        if merged and t0 <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], t1))
        else:
            merged.append((t0, t1))
    return merged


def _overlap(t0: float, t1: float, ivals) -> float:
    return sum(max(0.0, min(t1, b) - max(t0, a)) for a, b in ivals)


def _tick_track(tracer, track: str, ticks: int) -> dict:
    busy_ticks = set()
    for _, _, k0, k1 in _spans(tracer, track):
        if k0 is None:
            continue
        busy_ticks.update(range(k0, (k1 if k1 is not None else k0) + 1))
    busy_ticks = {t for t in busy_ticks if t < ticks}
    marks: Dict[int, str] = {}
    for ev in tracer.events:
        if ev.track == track and ev.ph == "i" and ev.name == "idle":
            marks[ev.tick] = ev.args.get("bucket", "queue-starved")
    buckets: Dict[str, int] = {}
    for t in range(ticks):
        if t in busy_ticks:
            continue
        b = marks.get(t, "queue-starved")
        buckets[b] = buckets.get(b, 0) + 1
    return {"kind": "tick", "ticks": ticks, "busy": len(busy_ticks),
            "idle": ticks - len(busy_ticks), "buckets": buckets}


def _time_track(tracer, track: str, comm_ivals, horizon: float) -> dict:
    spans = [(t0, t1) for t0, t1, _, _ in _spans(tracer, track)]
    busy = _merge(spans)
    busy_s = sum(t1 - t0 for t0, t1 in busy)
    end = horizon if horizon else (busy[-1][1] if busy else 0.0)
    buckets = {}

    def add(b, v):
        if v > 1e-12:
            buckets[b] = buckets.get(b, 0.0) + v

    first = busy[0][0] if busy else end
    last = busy[-1][1] if busy else 0.0
    add("queue-starved", first)                      # warmup
    add("drain", max(0.0, end - last))               # wind-down
    prev = first
    for t0, t1 in busy:
        if t0 > prev:                                # interior gap
            a2a = _overlap(prev, t0, comm_ivals)
            add("a2a-exposed", a2a)
            add("queue-starved", (t0 - prev) - a2a)
        prev = max(prev, t1)
    total = sum(buckets.values())
    return {"kind": "time", "horizon_s": end / 1e6, "busy_s": busy_s / 1e6,
            "idle_s": (end - busy_s) / 1e6,
            "buckets": {k: v / 1e6 for k, v in buckets.items()},
            "_check": abs((end - busy_s) - total) < 1e-6}


def idle_report(tracer, ticks: Optional[int] = None) -> dict:
    """Per-track idle attribution. ``ticks`` overrides the tick horizon
    for tick tracks (default: tracer.max_tick + 1 — the number of ticks
    the clock actually advanced through). Returns
    ``{track: {kind, ticks|horizon_s, busy, idle, buckets}}``; "meta"
    tracks (control plane: chaos, router) are excluded."""
    if not getattr(tracer, "enabled", False):
        return {}
    n_ticks = ticks if ticks is not None else tracer.max_tick + 1
    comm_by_pid: Dict[str, list] = {}
    for track, meta in tracer.tracks.items():
        if meta["kind"] == "comm":
            comm_by_pid.setdefault(meta["pid"], []).extend(
                (t0, t1) for t0, t1, _, _ in _spans(tracer, track))
    horizon_by_pid: Dict[str, float] = {}
    for track, meta in tracer.tracks.items():
        if meta["kind"] in ("time", "comm"):
            for _, t1, _, _ in _spans(tracer, track):
                horizon_by_pid[meta["pid"]] = max(
                    horizon_by_pid.get(meta["pid"], 0.0), t1)
    out = {}
    for track, meta in tracer.tracks.items():
        if meta["kind"] == "tick":
            out[track] = _tick_track(tracer, track, n_ticks)
        elif meta["kind"] == "time":
            out[track] = _time_track(
                tracer, track, _merge(comm_by_pid.get(meta["pid"], [])),
                horizon_by_pid.get(meta["pid"], 0.0))
    return out


def format_report(report: dict) -> str:
    """Human-readable one-line-per-track summary for the launch drivers."""
    lines = []
    for track in sorted(report):
        r = report[track]
        if r["kind"] == "tick":
            bk = " ".join(f"{k}={v}" for k, v in sorted(r["buckets"].items()))
            lines.append(f"  {track:<12} ticks={r['ticks']} busy={r['busy']} "
                         f"idle={r['idle']}" + (f" [{bk}]" if bk else ""))
        else:
            bk = " ".join(f"{k}={v:.4f}s"
                          for k, v in sorted(r["buckets"].items()))
            lines.append(f"  {track:<12} horizon={r['horizon_s']:.4f}s "
                         f"busy={r['busy_s']:.4f}s" + (f" [{bk}]" if bk
                                                       else ""))
    return "\n".join(lines)
