"""Unified counters/gauges registry (§15).

Before this existed every metrics holder kept its own shape:
``serve.metrics.ServeMetrics`` (latency traces + queue gauges),
``RobustnessCounters`` (chaos-recovery counters synced off the transfer
engine), ``RoutingEMA`` (per-layer routing mass). The registry does not
replace any of them — it is the one namespace they RE-REGISTER into, so an
exporter (or a debugger at a breakpoint) can snapshot every counter in the
process with one call, and the trace JSON carries the final values next to
the event timeline.

Providers are lazy: ``register(name, fn)`` stores a zero-arg callable and
``snapshot()`` invokes them all, so registering costs nothing per tick and
the values are read exactly when asked for (end of run, or on demand).
"""

from __future__ import annotations

from typing import Callable, Dict


class Registry:
    """Named snapshot providers + explicit scalar counters/gauges."""

    def __init__(self):
        self._providers: Dict[str, Callable[[], dict]] = {}
        self._scalars: Dict[str, float] = {}

    # -- provider interface (ServeMetrics / RobustnessCounters / ...) -----

    def register(self, name: str, snapshot_fn: Callable[[], dict]) -> None:
        """Register (or replace) a named snapshot provider. ``snapshot_fn``
        returns a JSON-trivial dict when the registry is snapshot."""
        self._providers[name] = snapshot_fn

    def unregister(self, name: str) -> None:
        self._providers.pop(name, None)

    # -- scalar interface --------------------------------------------------

    def inc(self, name: str, delta: float = 1.0) -> None:
        self._scalars[name] = self._scalars.get(name, 0.0) + delta

    def set(self, name: str, value: float) -> None:
        self._scalars[name] = value

    def get(self, name: str, default: float = 0.0) -> float:
        return self._scalars.get(name, default)

    # -- snapshot ----------------------------------------------------------

    def snapshot(self) -> dict:
        """One merged view: ``{"scalars": {...}, "<provider>": {...}}``.
        Provider failures surface as an ``error`` entry rather than
        tearing down an export at the end of an otherwise-good run."""
        out: dict = {}
        if self._scalars:
            out["scalars"] = dict(sorted(self._scalars.items()))
        for name, fn in self._providers.items():
            try:
                out[name] = fn()
            except Exception as e:  # pragma: no cover - defensive
                out[name] = {"error": f"{type(e).__name__}: {e}"}
        return out
