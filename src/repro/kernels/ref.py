"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret mode
on CPU, real lowering on TPU). They are also the execution path used on
backends without Pallas support (this CPU container), so they must be
jit/grad-friendly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_BIG_NEG = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Attention oracle
# ---------------------------------------------------------------------------

def attention(q, k, v, mask=None, scale=None, softcap: float = 0.0):
    """GQA attention. q: [B,S,H,hd]; k/v: [B,T,KH,hd]; mask: [S,T] or [B,S,T].

    Returns [B,S,H,hd] in q.dtype; softmax in f32.
    """
    B, S, H, hd = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = hd ** -0.5 if scale is None else scale
    qf = q.reshape(B, S, KH, G, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qf, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    if mask is not None:
        m = mask if mask.ndim == 3 else mask[None]
        logits = jnp.where(m[:, None, None, :, :], logits, _BIG_NEG)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(q.dtype), v)
    return out.reshape(B, S, H, hd)


def causal_window_mask(q_len: int, kv_len: int, causal: bool, window: int,
                       q_offset: int = 0):
    """Structural mask used by the flash kernel path."""
    qp = jnp.arange(q_len) + q_offset
    kp = jnp.arange(kv_len)
    m = jnp.ones((q_len, kv_len), bool)
    if causal:
        m &= kp[None, :] <= qp[:, None]
    if window > 0:
        m &= (qp[:, None] - kp[None, :]) < window
    return m


# ---------------------------------------------------------------------------
# Grouped matmul (MoE expert GEMM) oracle
# ---------------------------------------------------------------------------

def gmm(lhs, rhs, group_sizes, preferred_element_type=None):
    """lhs: [M,K] rows sorted by group; rhs: [G,K,N]; group_sizes: [G] int32.

    out[m] = lhs[m] @ rhs[g(m)]   where g(m) is the group row m belongs to.
    """
    M = lhs.shape[0]
    G = rhs.shape[0]
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(M)
    # group id per row: number of groups fully before this row
    gid = jnp.sum(row[:, None] >= ends[None, :], axis=-1)
    gid = jnp.clip(gid, 0, G - 1)
    out_dtype = preferred_element_type or lhs.dtype
    rhs_per_row = jnp.take(rhs, gid, axis=0)  # [M,K,N]
    out = jnp.einsum("mk,mkn->mn", lhs, rhs_per_row,
                     preferred_element_type=jnp.float32)
    return out.astype(out_dtype)


def gmm_glu(lhs, rhs_stacked, group_sizes, preferred_element_type=None):
    """Fused-GLU grouped matmul oracle (mirror of gmm.gmm_glu_tiled).

    lhs: [M,K]; rhs_stacked: [G,K,2N] with gate weights in [..., :N] and up
    weights in [..., N:]. out[m] = silu(lhs[m] @ gate_g) * (lhs[m] @ up_g).
    """
    N = rhs_stacked.shape[-1] // 2
    gu = gmm(lhs, rhs_stacked, group_sizes,
             preferred_element_type=jnp.float32)
    out = jax.nn.silu(gu[:, :N]) * gu[:, N:]
    return out.astype(preferred_element_type or lhs.dtype)


def moe_ffn(x_sorted, wi_gate, wi_up, wo, group_sizes):
    """Whole-expert-FFN oracle: the ground truth for ops.moe_ffn.

    x_sorted: [M,d] rows sorted by expert; wi_*: [G,d,f]; wo: [G,f,d].
    """
    g = jax.nn.silu(gmm(x_sorted, wi_gate, group_sizes,
                        preferred_element_type=jnp.float32))
    u = gmm(x_sorted, wi_up, group_sizes,
            preferred_element_type=jnp.float32)
    return gmm((g * u).astype(x_sorted.dtype), wo, group_sizes)


# ---------------------------------------------------------------------------
# SSD (mamba2 state-space duality) oracles
# ---------------------------------------------------------------------------

def ssd_naive(x, dt, A, B, C, initial_state=None):
    """Sequential ground truth. All f32 internally.

    x: [b, T, h, d]; dt: [b, T, h]; A: [h]; B,C: [b, T, n].
    Returns (y [b,T,h,d], final_state [b,h,d,n]).
    """
    b, T, h, d = x.shape
    n = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    a = jnp.exp(dtf * A[None, None, :])  # [b,T,h]
    xbar = xf * dtf[..., None]  # [b,T,h,d]
    S0 = (jnp.zeros((b, h, d, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(S, inp):
        a_t, xb_t, B_t, C_t = inp  # [b,h], [b,h,d], [b,n], [b,n]
        S = S * a_t[..., None, None] + xb_t[..., None] * B_t[:, None, None, :]
        y_t = jnp.einsum("bhdn,bn->bhd", S, C_t)
        return S, y_t

    inputs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(xbar, 1, 0),
              jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    S_final, ys = jax.lax.scan(step, S0, inputs)
    y = jnp.moveaxis(ys, 0, 1)  # [b,T,h,d]
    return y.astype(x.dtype), S_final


def ssd_chunked(x, dt, A, B, C, chunk: int = 128, initial_state=None):
    """Chunked (dual-form) SSD — the jnp mirror of the Pallas kernel.

    Same signature/returns as ssd_naive. Matmul-dominant: suitable for
    training on backends without Pallas.
    """
    b, T, h, d = x.shape
    n = B.shape[-1]
    Q = min(chunk, T)
    pad = (-T) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // Q

    xf = x.astype(jnp.float32).reshape(b, nc, Q, h, d)
    dtf = dt.astype(jnp.float32).reshape(b, nc, Q, h)
    Bf = B.astype(jnp.float32).reshape(b, nc, Q, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, Q, n)

    la = dtf * A[None, None, None, :]  # [b,nc,Q,h] log-decay
    cum = jnp.cumsum(la, axis=2)  # inclusive cumsum within chunk
    total = cum[:, :, -1, :]  # [b,nc,h]
    xbar = xf * dtf[..., None]

    # Intra-chunk: masked decay matrix L[i,j] = exp(cum_i - cum_j), j <= i.
    # The exponent is clamped BEFORE exp: for masked j > i it is positive
    # and can overflow; where() would then leak inf*0 = NaN into the vjp.
    G = jnp.einsum("bcin,bcjn->bcij", Cf, Bf)  # [b,nc,Q,Q]
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [b,nc,Q,Q,h]
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.exp(jnp.where(tri, diff, -60.0)) * tri
    M = G[..., None] * L  # [b,nc,Q,Q,h]
    y_intra = jnp.einsum("bcijh,bcjhd->bcihd", M, xbar)

    # Per-chunk state contribution and inter-chunk recurrence.
    w = jnp.exp(total[:, :, None, :] - cum)  # [b,nc,Q,h]
    S_local = jnp.einsum("bcjn,bcjh,bcjhd->bchdn", Bf, w, xbar)
    S0 = (jnp.zeros((b, h, d, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def chunk_step(S, inp):
        S_loc, tot = inp  # [b,h,d,n], [b,h]
        S_prev = S
        S = S * jnp.exp(tot)[..., None, None] + S_loc
        return S, S_prev

    S_final, S_prevs = jax.lax.scan(
        chunk_step, S0,
        (jnp.moveaxis(S_local, 1, 0), jnp.moveaxis(total, 1, 0)))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)  # [b,nc,h,d,n] state entering chunk
    y_inter = jnp.einsum("bcin,bchdn,bcih->bcihd", Cf, S_prevs, jnp.exp(cum))

    y = (y_intra + y_inter).reshape(b, Tp, h, d)[:, :T]
    return y.astype(x.dtype), S_final


def ssd_decode_step(x, dt, A, B, C, state):
    """Single-token (or short-S) sequential decode update.

    x: [b, S, h, d]; state: [b, h, d, n] f32. Returns (y, new_state).
    """
    y, new_state = ssd_naive(x, dt, A, B, C, initial_state=state)
    return y, new_state
