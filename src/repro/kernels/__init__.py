"""Pallas TPU kernels (flash attention, grouped matmul, SSD scan).

Each kernel has a pure-jnp oracle in :mod:`repro.kernels.ref` and a jit'd
public wrapper in :mod:`repro.kernels.ops`. On non-TPU backends the wrappers
run the kernel bodies in interpret mode (tests) or fall back to references
(production CPU path).
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
