"""Pallas TPU flash attention (forward + backward), GQA-aware.

TPU adaptation of the paper's motivating kernel class: the entire HeterMoE
observation (Fig. 2) is that attention efficiency tracks the availability of
an IO-aware fused kernel per device generation. This is that kernel for the
TPU memory hierarchy: q blocks resident in VMEM, k/v streamed block-by-block
over the sequential grid dimension, online softmax in f32 VREGs, MXU-aligned
128x128 tiles.

Layout contract (wrapper handles transposes/padding):
    q:  [B, H,  Sq, hd]     k/v: [B, KH, Skv, hd]     H = KH * G
Masks are structural (causal and/or sliding window) — arbitrary mask arrays
take the reference path in ops.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

_NEG = -0.7 * float(jnp.finfo(jnp.float32).max)

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _block_mask(q_start, k_start, bq, bk, q_len, kv_len, causal, window):
    """[bq, bk] bool mask for one tile, from global positions."""
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = (qpos < q_len) & (kpos < kv_len)
    if causal:
        m &= kpos <= qpos
    if window > 0:
        m &= (qpos - kpos) < window
    return m


def _tile_live(iq, ik, bq, bk, causal, window):
    """Whether tile (iq, ik) can contain any unmasked entry."""
    q_start = iq * bq
    k_start = ik * bk
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + bq - 1
    if window > 0:
        live &= (q_start - (k_start + bk - 1)) < window
    return live


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc, m_s, l_s, *,
                scale, causal, window, q_len, kv_len, softcap, n_k):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    bq = q_ref.shape[2]
    bk = k_ref.shape[2]

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc[...] = jnp.zeros_like(acc)

    @pl.when(_tile_live(iq, ik, bq, bk, causal, window))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        mask = _block_mask(iq * bq, ik * bk, bq, bk, q_len, kv_len,
                           causal, window)
        s = jnp.where(mask, s, _NEG)
        m_prev = m_s[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_s[:, 0] = l_s[:, 0] * alpha + jnp.sum(p, axis=-1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_s[:, 0] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_s[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc[...] / denom[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = jnp.where(l == 0.0, _NEG, m_s[:, 0] + jnp.log(denom))


def flash_forward(q, k, v, *, scale, causal, window, softcap,
                  q_len=None, kv_len=None, block_q=DEFAULT_BLOCK_Q,
                  block_k=DEFAULT_BLOCK_K, interpret=False):
    """q: [B,H,Sq,hd]; k/v: [B,KH,Skv,hd] (pre-padded to block multiples).

    Returns (o [B,H,Sq,hd], lse [B,H,Sq] f32). ``q_len``/``kv_len`` are the
    *true* (unpadded) lengths used for masking; default = padded shapes.
    """
    B, H, Sq, hd = q.shape
    KH, Skv = k.shape[1], k.shape[2]
    G = H // KH
    n_q = Sq // block_q
    n_k = Skv // block_k

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        q_len=q_len or Sq, kv_len=kv_len or Skv, softcap=softcap, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_acc, *, scale, causal, window, q_len, kv_len, n_k):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    bq = q_ref.shape[2]
    bk = k_ref.shape[2]

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    @pl.when(_tile_live(iq, ik, bq, bk, causal, window))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]  # [bq]
        delta = delta_ref[0, 0]  # [bq]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _block_mask(iq * bq, ik * bk, bq, bk, q_len, kv_len,
                           causal, window)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _finish():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *,
                scale, causal, window, q_len, kv_len, n_q):
    ik = pl.program_id(2)
    iq = pl.program_id(3)
    bk = k_ref.shape[2]
    bq = q_ref.shape[2]

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when(_tile_live(iq, ik, bq, bk, causal, window))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _block_mask(iq * bq, ik * bk, bq, bk, q_len, kv_len,
                           causal, window)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)  # [bq, bk]
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(iq == n_q - 1)
    def _finish():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def flash_backward(q, k, v, o, lse, do, *, scale, causal, window,
                   q_len=None, kv_len=None, block_q=DEFAULT_BLOCK_Q,
                   block_k=DEFAULT_BLOCK_K, interpret=False):
    """Returns (dq [B,H,Sq,hd], dk, dv [B,KH,Skv,hd])."""
    B, H, Sq, hd = q.shape
    KH, Skv = k.shape[1], k.shape[2]
    G = H // KH
    n_q = Sq // block_q
    n_k = Skv // block_k
    q_len = q_len or Sq
    kv_len = kv_len or Skv
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)  # [B,H,Sq]

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          window=window, q_len=q_len, kv_len=kv_len, n_k=n_k),
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dk/dv per *query* head (accumulated over q blocks); grouped-summed to
    # kv heads afterwards. Keeps the sequential dim free of write races.
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          window=window, q_len=q_len, kv_len=kv_len, n_q=n_q),
        grid=(B, H, n_k, n_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, ik, iq: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, ik, iq: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, ik, iq: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, ik, iq: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, ik, iq: (b, h, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, ik, iq: (b, h, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ik, iq: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ik, iq: (b, h, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Skv, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Skv, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk = dk_h.reshape(B, KH, G, Skv, hd).sum(axis=2).astype(k.dtype)
    dv = dv_h.reshape(B, KH, G, Skv, hd).sum(axis=2).astype(v.dtype)
    return dq, dk, dv
