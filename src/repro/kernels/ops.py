"""Public jit'd wrappers around the Pallas kernels.

Responsibilities: layout transposes, tile padding, GQA grouping, custom_vjp
stitching, and backend selection (real Mosaic lowering on TPU, interpret
mode everywhere else — same kernel body, Python-executed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as fa
from repro.kernels import gmm as gmm_kernel
from repro.kernels import ref
from repro.kernels import ssd as ssd_kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, axis, multiple):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_flash(causal, window, scale, softcap, block_q, block_k, interpret,
                q_len, kv_len):
    """Build a custom_vjp flash fn for one static config (cached)."""

    kw = dict(scale=scale, causal=causal, window=window,
              block_q=block_q, block_k=block_k, interpret=interpret,
              q_len=q_len, kv_len=kv_len)

    @jax.custom_vjp
    def flash(q, k, v):
        o, _ = fa.flash_forward(q, k, v, softcap=softcap, **kw)
        return o

    def fwd(q, k, v):
        o, lse = fa.flash_forward(q, k, v, softcap=softcap, **kw)
        return o, (q, k, v, o, lse)

    def bwd(res, do):
        q, k, v, o, lse = res
        if softcap > 0:
            raise NotImplementedError(
                "flash backward with softcap: use attn_impl='ref'")
        dq, dk, dv = fa.flash_backward(q, k, v, o, lse, do, **kw)
        return dq, dk, dv

    flash.defvjp(fwd, bwd)
    return flash


def flash_attention(q, k, v, *, causal: bool, window: int = 0,
                    scale: float | None = None, softcap: float = 0.0,
                    block_q: int = fa.DEFAULT_BLOCK_Q,
                    block_k: int = fa.DEFAULT_BLOCK_K,
                    interpret: bool | None = None):
    """q: [B,S,H,hd]; k/v: [B,T,KH,hd] -> [B,S,H,hd].

    Structural masking only (causal / sliding window / padding). For
    arbitrary masks (ring caches, packed segments) use the reference path.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    interpret = _interpret_default() if interpret is None else interpret
    block_q = min(block_q, _round_up(S, 128))
    block_k = min(block_k, _round_up(T, 128))

    # [B,S,H,hd] -> [B,H,S,hd], pad sequence dims to block multiples.
    qt = _pad_to(jnp.swapaxes(q, 1, 2), 2, block_q)
    kt = _pad_to(jnp.swapaxes(k, 1, 2), 2, block_k)
    vt = _pad_to(jnp.swapaxes(v, 1, 2), 2, block_k)

    flash = _make_flash(causal, window, float(scale), float(softcap),
                        block_q, block_k, interpret, S, T)
    o = flash(qt, kt, vt)
    return jnp.swapaxes(o[:, :, :S], 1, 2)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Paged decode attention (serving, DESIGN.md §9)
# ---------------------------------------------------------------------------

def paged_kv_positions(page_table, page_size: int):
    """Structural key positions of a paged cache view.

    page_table: [B, MP] int32 (-1 = unallocated). Returns [B, MP*page_size]
    int32: line l of table slot j is position j*page_size + l; lines of
    unallocated slots are -1 (masked out by `attention_mask`). Positions are
    NEVER read from the pool — stale lines in recycled pages carry arbitrary
    stored positions, but their structural position exceeds the new owner's
    causal frontier, which is what keeps them unreachable (§9.2).
    """
    B, MP = page_table.shape
    pos = (jnp.arange(MP, dtype=jnp.int32)[:, None] * page_size
           + jnp.arange(page_size, dtype=jnp.int32)[None, :])  # [MP, ps]
    pos = jnp.broadcast_to(pos[None], (B, MP, page_size))
    return jnp.where(page_table[:, :, None] >= 0, pos, -1).reshape(B, -1)


def paged_gather_kv(k_pool, v_pool, page_table):
    """Gather a per-slot contiguous KV view from the paged pool.

    k_pool/v_pool: [P, page_size, KH, hd]; page_table: [B, MP].
    Returns (k [B, MP*ps, KH, hd], v, kv_pos [B, MP*ps]) — the XLA fallback
    view consumed by the masked reference attention; the Pallas kernel
    reads the same pages block-by-block without materializing it.
    """
    B, MP = page_table.shape
    ps, KH, hd = k_pool.shape[1], k_pool.shape[2], k_pool.shape[3]
    ptc = jnp.maximum(page_table, 0)
    k = jnp.take(k_pool, ptc, axis=0).reshape(B, MP * ps, KH, hd)
    v = jnp.take(v_pool, ptc, axis=0).reshape(B, MP * ps, KH, hd)
    return k, v, paged_kv_positions(page_table, ps)


def paged_decode_attention(q, k_pool, v_pool, page_table, q_pos, *,
                           scale: float | None = None, softcap: float = 0.0,
                           window: int = 0, use_kernel: bool | None = None,
                           interpret: bool | None = None):
    """Single-token decode attention over the paged KV pool.

    q: [B, H, hd] (one query per slot); k_pool/v_pool: [P, ps, KH, hd];
    page_table: [B, MP] int32; q_pos: [B] int32 (current write position of
    each slot; < 0 = dead slot, output row is zeros). Returns [B, H, hd].

    use_kernel=None picks the Pallas kernel on TPU and the XLA
    gather-then-mask fallback elsewhere (interpret-mode Pallas stays a test
    vehicle, forced via use_kernel=True off-TPU).
    """
    B, H, hd = q.shape
    KH = k_pool.shape[2]
    G = H // KH
    scale = hd ** -0.5 if scale is None else scale
    use_kernel = _use_kernel_default() if use_kernel is None else use_kernel

    if not use_kernel:
        k, v, kv_pos = paged_gather_kv(k_pool, v_pool, page_table)
        qf = q.reshape(B, KH, G, hd).astype(jnp.float32)
        s = jnp.einsum("bkgh,btkh->bkgt", qf, k.astype(jnp.float32),
                       preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        mask = (kv_pos >= 0) & (kv_pos <= q_pos[:, None])
        if window > 0:
            mask &= (q_pos[:, None] - kv_pos) < window
        s = jnp.where(mask[:, None, None, :], s,
                      -0.7 * float(jnp.finfo(jnp.float32).max))
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgt,btkh->bkgh", p.astype(q.dtype), v)
        out = jnp.where((q_pos >= 0)[:, None, None, None], out, 0)
        return out.reshape(B, H, hd)

    from repro.kernels import paged_attention as pa
    interpret = _interpret_default() if interpret is None else interpret
    # Pad the GQA group to a sublane multiple and head_dim to the lane width.
    Gp = _round_up(G, 8)
    hdp = _round_up(hd, 128)
    qk = q.reshape(B, KH, G, hd)
    if Gp != G:
        qk = jnp.pad(qk, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    if hdp != hd:
        qk = jnp.pad(qk, ((0, 0), (0, 0), (0, 0), (0, hdp - hd)))
        k_pool = jnp.pad(k_pool, ((0, 0), (0, 0), (0, 0), (0, hdp - hd)))
        v_pool = jnp.pad(v_pool, ((0, 0), (0, 0), (0, 0), (0, hdp - hd)))
    out = pa.paged_decode_forward(qk, k_pool, v_pool, page_table, q_pos,
                                  scale=scale, softcap=softcap,
                                  window=window, interpret=interpret)
    out = out[:, :, :G, :hd].reshape(B, H, hd)
    return jnp.where((q_pos >= 0)[:, None, None], out, 0)


# ---------------------------------------------------------------------------
# Grouped matmul (MoE experts)
# ---------------------------------------------------------------------------

def _pack_meta(group_sizes, m: int, n_groups: int, block_m: int):
    """Destination row for each sorted row + group id per m-tile.

    Static padded size: every group padded up to a block_m multiple. Group
    lookups are O(M log G) ``searchsorted`` binary searches against the
    cumulative group ends (``ends`` is non-decreasing, so ``side='right'``
    maps row r to the first group whose end exceeds r) — NOT O(M·G)
    comparison matrices. Rows beyond sum(group_sizes) land in the last
    group and produce unspecified output (callers always pass
    m == sum(group_sizes)).
    """
    padded = ((group_sizes + block_m - 1) // block_m) * block_m
    p_starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                jnp.cumsum(padded)[:-1].astype(jnp.int32)])
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(m)
    gid = jnp.clip(jnp.searchsorted(ends, row, side="right"),
                   0, n_groups - 1)
    dest = p_starts[gid] + (row - starts[gid])

    mp = _round_up(m, block_m) + n_groups * block_m  # static upper bound
    n_tiles = mp // block_m
    tile_ends = jnp.cumsum(padded // block_m)
    tile = jnp.arange(n_tiles)
    tile_group = jnp.clip(
        jnp.searchsorted(tile_ends, tile, side="right"),
        0, n_groups - 1).astype(jnp.int32)
    return dest, tile_group, mp


@functools.lru_cache(maxsize=None)
def _make_gmm_packed(block_m, block_k, block_n, interpret, n_groups,
                     out_dtype_name):
    out_dtype = jnp.dtype(out_dtype_name)

    @jax.custom_vjp
    def gmm_packed(lhs_p, rhs, tile_group):
        return gmm_kernel.gmm_tiled(lhs_p, rhs, tile_group, block_m=block_m,
                                    block_k=block_k, block_n=block_n,
                                    interpret=interpret, out_dtype=out_dtype)

    def fwd(lhs_p, rhs, tile_group):
        return gmm_packed(lhs_p, rhs, tile_group), (lhs_p, rhs, tile_group)

    def bwd(res, dout):
        lhs_p, rhs, tile_group = res
        dout = dout.astype(jnp.float32)
        dlhs = gmm_kernel.gmm_tiled(
            dout, jnp.swapaxes(rhs, 1, 2).astype(jnp.float32), tile_group,
            block_m=block_m, block_k=block_n, block_n=block_k,
            interpret=interpret, out_dtype=lhs_p.dtype)
        drhs = gmm_kernel.gmm_dw_tiled(
            lhs_p.astype(jnp.float32), dout, tile_group, n_groups,
            block_m=block_m, block_k=block_k, block_n=block_n,
            interpret=interpret).astype(rhs.dtype)
        dtile = np.zeros(tile_group.shape, dtype=jax.dtypes.float0)
        return dlhs, drhs, dtile

    gmm_packed.defvjp(fwd, bwd)
    return gmm_packed


def gmm(lhs, rhs, group_sizes, *, block_m: int = 128, block_k: int = 128,
        block_n: int = 128, interpret: bool | None = None):
    """Grouped matmul: lhs [M,K] sorted by group; rhs [G,K,N]; sizes [G].

    Pallas-backed mirror of jax.lax.ragged_dot / ref.gmm.
    """
    interpret = _interpret_default() if interpret is None else interpret
    M, K = lhs.shape
    G = rhs.shape[0]
    dest, tile_group, Mp = _pack_meta(group_sizes.astype(jnp.int32), M, G,
                                      block_m)
    lhs_p = _scatter_rows(lhs, dest, Mp)
    fn = _make_gmm_packed(block_m, block_k, block_n, interpret, G,
                          jnp.dtype(lhs.dtype).name)
    out_p = fn(lhs_p, rhs, tile_group)
    return _gather_rows(out_p, dest)


# ---------------------------------------------------------------------------
# Single-pack fused MoE expert FFN (packed domain end to end)
# ---------------------------------------------------------------------------
#
# ops.gmm packs/unpacks the token-copy activation inside EVERY call, so the
# three expert GEMMs of a GLU FFN cost three scatter/gather pairs forward
# (and their transposes backward). moe_ffn instead computes the pack
# metadata once, scatters into the tile-aligned layout once, runs
# gate/up/down entirely in the packed domain (gate+up fused into one
# lhs-read via gmm_glu_tiled), and gathers back once — a single custom_vjp
# whose backward re-uses the metadata and recomputes activations
# (stage-granular remat, the paper's §6.1 checkpointing setting) instead of
# storing them or letting XLA transpose three separate scatter/gather
# pairs. See DESIGN.md §5.


def _scatter_rows(values, dest, mp: int, dtype=None):
    """values [M, d] -> packed [Mp, d]; the ONE pack scatter (dest is
    strictly increasing and unique by construction)."""
    out = jnp.zeros((mp, values.shape[1]), dtype or values.dtype)
    return out.at[dest].set(values.astype(out.dtype), unique_indices=True,
                            indices_are_sorted=True)


def _gather_rows(packed, dest):
    """Packed [Mp, d] -> [M, d]; the ONE unpack gather."""
    return jnp.take(packed, dest, axis=0, unique_indices=True,
                    indices_are_sorted=True)


def _tiles_gemm_xla(lhs_p, rhs, tile_group, block_m: int, out_dtype):
    """XLA fallback for gmm_tiled: the packed domain expressed as a batched
    matmul over m-tiles, with the per-tile weight selected by ``tile_group``.

    O(Mp·K·N) — unlike lax.ragged_dot, whose CPU lowering runs a dense
    masked dot per group (O(G·M·K·N)). Used on backends without Mosaic so
    the single-pack pipeline is the fast path everywhere.
    """
    Mp, K = lhs_p.shape
    n_m = Mp // block_m
    lt = lhs_p.reshape(n_m, block_m, K)
    rt = jnp.take(rhs, tile_group, axis=0)  # [n_m, K, N]
    out = jnp.einsum("tmk,tkn->tmn", lt, rt,
                     preferred_element_type=jnp.float32)
    return out.reshape(Mp, rhs.shape[-1]).astype(out_dtype)


def _tiles_dw_xla(lhs_p, dout_p, tile_group, n_groups: int, block_m: int):
    """XLA fallback for gmm_dw_tiled: per-tile outer products reduced per
    group with a segment sum. drhs[g] = sum_{tiles t of g} lhs_t^T @ dout_t.
    """
    Mp, K = lhs_p.shape
    N = dout_p.shape[1]
    n_m = Mp // block_m
    lt = lhs_p.reshape(n_m, block_m, K).astype(jnp.float32)
    dt = dout_p.reshape(n_m, block_m, N).astype(jnp.float32)
    per_tile = jnp.einsum("tmk,tmn->tkn", lt, dt,
                          preferred_element_type=jnp.float32)
    return jax.ops.segment_sum(per_tile, tile_group,
                               num_segments=n_groups)


@functools.lru_cache(maxsize=None)
def _make_moe_ffn(block_m, block_k, block_n, interpret, n_groups,
                  use_kernel, pack, out_dtype_name, scaled):
    """custom_vjp over the whole packed-domain GLU FFN (cached per config).

    pack=True: inputs are expert-sorted rows + a dest map (scatter in /
    gather out). pack=False: inputs are already tile-aligned (the zebra
    engines' capacity-packed [E, C, d] buffers flattened) and dest is a
    0-length dummy.

    scaled=True: a per-row [M] scale (the router combine weight) is
    multiplied into the unpacked rows, fusing the combine's weighting into
    the ONE unpack gather — gather mode touches each output row exactly
    once. Its gradient is exact at the cost of one extra grouped GEMM in
    the backward (the unscaled output rows are rematerialized).
    """
    out_dtype = jnp.dtype(out_dtype_name)
    blk = dict(block_m=block_m, block_k=block_k, block_n=block_n,
               interpret=interpret)

    def _gemm(lhs_p, rhs, tile_group, out_dt):
        if use_kernel:
            return gmm_kernel.gmm_tiled(lhs_p, rhs, tile_group,
                                        out_dtype=out_dt, **blk)
        return _tiles_gemm_xla(lhs_p, rhs, tile_group, block_m, out_dt)

    def _dw(lhs_p, dout_p, tile_group, dt):
        if use_kernel:
            return gmm_kernel.gmm_dw_tiled(
                lhs_p.astype(jnp.float32), dout_p, tile_group, n_groups,
                **blk).astype(dt)
        return _tiles_dw_xla(lhs_p, dout_p, tile_group, n_groups,
                             block_m).astype(dt)

    @jax.custom_vjp
    def ffn(x, wi_gate, wi_up, wo, scales, dest, tile_group):
        mp = tile_group.shape[0] * block_m
        x_p = _scatter_rows(x, dest, mp) if pack else x
        if use_kernel:
            h_p = gmm_kernel.gmm_glu_tiled_pair(x_p, wi_gate, wi_up,
                                                tile_group,
                                                out_dtype=out_dtype, **blk)
        else:
            g = _tiles_gemm_xla(x_p, wi_gate, tile_group, block_m,
                                jnp.float32)
            u = _tiles_gemm_xla(x_p, wi_up, tile_group, block_m,
                                jnp.float32)
            h_p = (jax.nn.silu(g) * u).astype(out_dtype)
        out_p = _gemm(h_p, wo, tile_group, out_dtype)
        out = _gather_rows(out_p, dest) if pack else out_p
        if scaled:
            out = out * scales.astype(out.dtype)[:, None]
        return out

    def fwd(x, wi_gate, wi_up, wo, scales, dest, tile_group):
        # Residuals are the INPUTS only: packed activations are recomputed
        # in bwd (stage-granular remat), re-using the pack metadata.
        return (ffn(x, wi_gate, wi_up, wo, scales, dest, tile_group),
                (x, wi_gate, wi_up, wo, scales, dest, tile_group))

    def bwd(res, dout):
        x, wi_gate, wi_up, wo, scales, dest, tile_group = res
        mp = tile_group.shape[0] * block_m
        dout_f = dout.astype(jnp.float32)
        d_rows = dout_f * scales.astype(jnp.float32)[:, None] if scaled \
            else dout_f
        if pack:
            x_p = _scatter_rows(x, dest, mp)
            dout_p = _scatter_rows(d_rows, dest, mp, jnp.float32)
        else:
            x_p = x
            dout_p = d_rows
        # Recompute pre-activations (f32) in the packed domain.
        g_p = _gemm(x_p, wi_gate, tile_group, jnp.float32)
        u_p = _gemm(x_p, wi_up, tile_group, jnp.float32)
        sg = jax.lax.logistic(g_p)
        act = g_p * sg  # silu(g)
        h_p = act * u_p
        if scaled:
            # d(scale_r) = dout_r · y_r needs the unscaled output rows —
            # one extra grouped GEMM (stage remat, nothing stored).
            y_p = _gemm(h_p, wo, tile_group, jnp.float32)
            y_rows = _gather_rows(y_p, dest) if pack else y_p
            dscales = jnp.sum(dout_f * y_rows, axis=-1).astype(scales.dtype)
        else:
            dscales = jnp.zeros(scales.shape, scales.dtype)
        dwo = _dw(h_p, dout_p, tile_group, wo.dtype)
        dh_p = _gemm(dout_p, jnp.swapaxes(wo, 1, 2).astype(jnp.float32),
                     tile_group, jnp.float32)
        dg_p = dh_p * u_p * (sg * (1.0 + g_p * (1.0 - sg)))  # silu'
        du_p = dh_p * act
        dwg = _dw(x_p, dg_p, tile_group, wi_gate.dtype)
        dwu = _dw(x_p, du_p, tile_group, wi_up.dtype)
        dx_p = _gemm(dg_p, jnp.swapaxes(wi_gate, 1, 2).astype(jnp.float32),
                     tile_group, jnp.float32) \
            + _gemm(du_p, jnp.swapaxes(wi_up, 1, 2).astype(jnp.float32),
                    tile_group, jnp.float32)
        dx = (_gather_rows(dx_p, dest) if pack else dx_p).astype(x.dtype)
        return (dx, dwg, dwu, dwo, dscales,
                np.zeros(dest.shape, jax.dtypes.float0),
                np.zeros(tile_group.shape, jax.dtypes.float0))

    ffn.defvjp(fwd, bwd)
    return ffn


def _use_kernel_default() -> bool:
    # Mosaic lowering on TPU; elsewhere the XLA tile-gather path is the
    # fast one (interpret-mode Pallas is a test vehicle, not a backend).
    return jax.default_backend() == "tpu"


def moe_ffn_group_dense(x_sorted, wi_gate, wi_up, wo, group_sizes, *,
                        row_scales=None):
    """Small-M (decode-shape) expert FFN: dense per-group GEMMs + a per-row
    select. O(G·M·d·f) arithmetic — G× the packed pipeline's — but no pack
    scatter, no per-tile weight gather, and none of the packed path's
    ~G·block_m pad rows, which dominate below M ≈ block_m·G/(G−1)
    (`bench_moe_ffn.py` records the crossover in BENCH_moe_ffn.json).
    Autodiff-native: at small M the [G, M, f] intermediates are cheap to
    store, so no custom_vjp / remat is needed.
    """
    M = x_sorted.shape[0]
    G = wi_gate.shape[0]
    ends = jnp.cumsum(group_sizes.astype(jnp.int32))
    gid = jnp.clip(jnp.searchsorted(ends, jnp.arange(M), side="right"),
                   0, G - 1)
    g = jnp.einsum("md,gdf->gmf", x_sorted, wi_gate,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("md,gdf->gmf", x_sorted, wi_up,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x_sorted.dtype)
    y = jnp.einsum("gmf,gfd->gmd", h, wo,
                   preferred_element_type=jnp.float32)
    y = y[gid, jnp.arange(M)]
    if row_scales is not None:
        y = y * row_scales.astype(jnp.float32)[:, None]
    return y.astype(x_sorted.dtype)


def moe_ffn(x_sorted, wi_gate, wi_up, wo, group_sizes, *,
            row_scales=None, block_m: int = 128, block_k: int = 128,
            block_n: int = 128, interpret: bool | None = None,
            use_kernel: bool | None = None, small_m: bool | None = None,
            ep_size: int = 1):
    """Whole GLU expert FFN over expert-sorted rows, packed once.

    x_sorted: [M, d] rows sorted by group (M == sum(group_sizes));
    wi_gate/wi_up: [G, d, f]; wo: [G, f, d]; group_sizes: [G] int32.
    Returns [M, d] = (silu(x @ wi_gate_g) * (x @ wi_up_g)) @ wo_g per row,
    times row_scales[r] when given ([M] router combine weights — fused
    into the one unpack gather so each output row is touched once).

    Exactly ONE pack scatter and ONE unpack gather per forward; the fused
    backward re-uses the pack metadata and rematerializes activations.

    small_m: True forces / False forbids the group-dense fallback
    (`moe_ffn_group_dense`); None auto-routes to it when
    M * (G - 1) <= G * block_m, i.e. M ≲ block_m · G/(G-1): the packed
    pipeline always pays ~G·block_m pad rows while group-dense pays
    (G-1)·M extra dense rows, so they break even near block_m — measured
    at mixtral-w1/4 ratios the crossover sits between 128 and 256 rows
    (BENCH_moe_ffn.json `small_m`). Decode shapes (M = slots · top_k) sit
    far below it.

    ep_size: number of expert-parallel shards the G groups are spread
    over. Under EP each shard computes only G/ep_size groups, so the
    auto-route crossover is evaluated at the PER-SHARD group count — at
    the global G the pad-row cost ratio is over-estimated by ~ep_size and
    sharded decode would always take the packed path.
    """
    M, _ = x_sorted.shape
    G = wi_gate.shape[0]
    if small_m is None:
        Gs = max(G // max(int(ep_size), 1), 1)
        small_m = M * (Gs - 1) <= Gs * block_m
    if small_m:
        return moe_ffn_group_dense(x_sorted, wi_gate, wi_up, wo,
                                   group_sizes, row_scales=row_scales)
    interpret = _interpret_default() if interpret is None else interpret
    use_kernel = _use_kernel_default() if use_kernel is None else use_kernel
    dest, tile_group, _ = _pack_meta(group_sizes.astype(jnp.int32), M, G,
                                     block_m)
    scaled = row_scales is not None
    fn = _make_moe_ffn(block_m, block_k, block_n, interpret, G, use_kernel,
                       True, jnp.dtype(x_sorted.dtype).name, scaled)
    scales = row_scales if scaled else jnp.zeros((0,), x_sorted.dtype)
    return fn(x_sorted, wi_gate, wi_up, wo, scales, dest, tile_group)


def chunk_capacity(C: int, n_chunks: int) -> tuple:
    """Pad a per-expert capacity so it splits into ``n_chunks`` equal,
    sublane-aligned slices (the zebra engines' chunked-dispatch layout).
    Returns (C_padded, C_chunk) with C_padded == n_chunks * C_chunk and
    C_chunk a multiple of 8 (pad rows are zero and inert end to end)."""
    q = max(int(n_chunks), 1)
    cq = _round_up(max(-(-C // q), 1), 8)
    return cq * q, cq


def moe_ffn_packed(buf, wi_gate, wi_up, wo, *, block_m: int | None = None,
                   block_k: int = 128, block_n: int = 128,
                   interpret: bool | None = None,
                   use_kernel: bool | None = None,
                   small_m: bool | None = False, ep_size: int = 1):
    """moe_ffn for ALREADY capacity-packed [E, C, d] buffers (the zebra
    engines' dispatch layout): every expert owns exactly C contiguous rows,
    so the buffer IS the packed domain — no sort, no pack scatter, no
    unpack gather. Returns [E, C, d].
    """
    return moe_ffn_packed_multi(
        [buf], [wi_gate], [wi_up], [wo], block_m=block_m, block_k=block_k,
        block_n=block_n, interpret=interpret, use_kernel=use_kernel,
        small_m=small_m, ep_size=ep_size)[0]


def _packed_group_dense(bufs, wi_gates, wi_ups, wos):
    """Group-dense evaluation of capacity-packed segments (small-M route).

    Flattens every [G_i, C_i, d] segment to rows with UNIFORM group sizes
    (capacity C_i per group) and evaluates via `moe_ffn_group_dense` —
    autodiff-native, no custom_vjp, no tile padding. Returns the same
    list-of-[G_i, C_i, d] as the packed pipeline."""
    d = bufs[0].shape[-1]
    rows = [b.reshape(-1, d) for b in bufs]
    lhs = rows[0] if len(rows) == 1 else jnp.concatenate(rows, axis=0)
    sizes = jnp.concatenate(
        [jnp.full((b.shape[0],), b.shape[1], jnp.int32) for b in bufs])
    wg = wi_gates[0] if len(bufs) == 1 else jnp.concatenate(wi_gates, axis=0)
    wu = wi_ups[0] if len(bufs) == 1 else jnp.concatenate(wi_ups, axis=0)
    wo_ = wos[0] if len(bufs) == 1 else jnp.concatenate(wos, axis=0)
    out = moe_ffn_group_dense(lhs, wg, wu, wo_, sizes)
    outs, off = [], 0
    for b in bufs:
        g, c = b.shape[0], b.shape[1]
        outs.append(out[off:off + g * c].reshape(g, c, d))
        off += g * c
    return outs


def moe_ffn_packed_multi(bufs, wi_gates, wi_ups, wos, *,
                         block_m: int | None = None, block_k: int = 128,
                         block_n: int = 128, interpret: bool | None = None,
                         use_kernel: bool | None = None,
                         small_m: bool | None = False, ep_size: int = 1):
    """ONE grouped-GEMM GLU FFN over SEVERAL capacity-packed buffers.

    bufs[i]: [G_i, C_i, d] (capacities may differ per segment);
    wi_gates[i]/wi_ups[i]: [G_i, d, f]; wos[i]: [G_i, f, d].

    The segments' weight stacks are concatenated into a single
    [G_total, ...] stack and their rows into one tile-aligned lhs with
    unified per-tile group metadata, so the whole call lowers to exactly
    ONE gate+up fused grouped GEMM plus ONE down-projection grouped GEMM —
    one grouped GEMM per projection direction covering every group of every
    segment, under a single custom_vjp (recompute backward). The zebra
    engines use this to run local (attention-side offloaded / replicated)
    and remote experts in one call instead of two fragmented GEMM pipelines
    (DESIGN.md §8). Returns a list of [G_i, C_i, d] outputs.

    small_m: None auto-routes to the group-dense evaluation
    (`_packed_group_dense`) using the same crossover as `moe_ffn` —
    total rows vs per-shard group count, with `ep_size` discounting the
    group count the way `moe_ffn` does. The EP decode hop passes
    small_m=None so tiny decode buffers skip the tile-padded pipeline;
    the default (False) preserves the training engines' recompute-backward
    custom_vjp path unconditionally.
    """
    assert len(bufs) == len(wi_gates) == len(wi_ups) == len(wos)
    assert bufs, "need at least one packed segment"
    d = bufs[0].shape[-1]
    if small_m is None:
        G_tot = sum(b.shape[0] for b in bufs)
        n_rows = sum(b.shape[0] * b.shape[1] for b in bufs)
        Gs = max(G_tot // max(int(ep_size), 1), 1)
        small_m = n_rows * (Gs - 1) <= Gs * (block_m or 128)
    if small_m:
        return _packed_group_dense(bufs, wi_gates, wi_ups, wos)
    interpret = _interpret_default() if interpret is None else interpret
    use_kernel = _use_kernel_default() if use_kernel is None else use_kernel
    # Engines round capacities to multiples of 8; pad odd capacities up
    # rather than degrading to sub-sublane tiles (zero rows are inert in
    # both the outputs and the weight gradients).
    caps = [_round_up(b.shape[1], 8) for b in bufs]
    if block_m is None:
        block_m = next(b for b in (128, 64, 32, 16, 8)
                       if all(c % b == 0 for c in caps))
    assert all(c % block_m == 0 for c in caps), (caps, block_m)
    rows, tiles, n_tot = [], [], 0
    for buf, cp in zip(bufs, caps):
        g, c = buf.shape[0], buf.shape[1]
        if cp != c:
            buf = jnp.pad(buf, ((0, 0), (0, cp - c), (0, 0)))
        rows.append(buf.reshape(g * cp, d))
        tiles.append(jnp.repeat(
            jnp.arange(n_tot, n_tot + g, dtype=jnp.int32), cp // block_m))
        n_tot += g
    lhs = rows[0] if len(rows) == 1 else jnp.concatenate(rows, axis=0)
    tile_group = tiles[0] if len(tiles) == 1 else jnp.concatenate(tiles)
    wg = wi_gates[0] if len(bufs) == 1 else jnp.concatenate(wi_gates, axis=0)
    wu = wi_ups[0] if len(bufs) == 1 else jnp.concatenate(wi_ups, axis=0)
    wo_ = wos[0] if len(bufs) == 1 else jnp.concatenate(wos, axis=0)
    fn = _make_moe_ffn(block_m, block_k, block_n, interpret, n_tot,
                       use_kernel, False, jnp.dtype(lhs.dtype).name, False)
    dest = jnp.zeros((0,), jnp.int32)  # unused in the no-pack variant
    scales = jnp.zeros((0,), lhs.dtype)  # unused in the unscaled variant
    out = fn(lhs, wg, wu, wo_, scales, dest, tile_group)
    outs, off = [], 0
    for buf, cp in zip(bufs, caps):
        g, c = buf.shape[0], buf.shape[1]
        outs.append(out[off:off + g * cp].reshape(g, cp, d)[:, :c])
        off += g * cp
    return outs


# ---------------------------------------------------------------------------
# SSD (mamba2)
# ---------------------------------------------------------------------------

def ssd(x, dt, A, B, C, *, chunk: int = 128, use_kernel: bool = False,
        interpret: bool | None = None):
    """mamba2 SSD scan. x: [b,T,h,hd]; dt: [b,T,h]; A: [h]; B/C: [b,T,ns].

    Returns (y [b,T,h,hd], final_state [b,h,hd,ns] f32).
    use_kernel=False -> chunked jnp reference (autodiff-native).
    use_kernel=True  -> Pallas forward, reference-recompute backward.
    """
    if not use_kernel:
        return ref.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    interpret = _interpret_default() if interpret is None else interpret
    return _ssd_kernel_call(x, dt, A, B, C, chunk, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd_kernel_call(x, dt, A, B, C, chunk, interpret):
    b, T, h, hd = x.shape
    ns = B.shape[-1]
    Q = min(chunk, _round_up(T, 128))
    la = (dt.astype(jnp.float32) * A[None, None, :]).swapaxes(1, 2)  # [b,h,T]
    xbar = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    xbar = jnp.moveaxis(xbar, 2, 1)  # [b,h,T,hd]
    # pad T to chunk multiple; la=0, xbar=0 => padding is a no-op in the scan
    lap = _pad_to(la.reshape(b * h, T), 1, Q)
    xbp = _pad_to(xbar.reshape(b * h, T, hd), 1, Q)
    Bp = _pad_to(B.astype(jnp.float32), 1, Q)
    Cp = _pad_to(C.astype(jnp.float32), 1, Q)
    y, state = ssd_kernel.ssd_pallas(xbp, lap, Bp, Cp, h, chunk=Q,
                                     interpret=interpret)
    y = y[:, :T].reshape(b, h, T, hd).swapaxes(1, 2).astype(x.dtype)
    return y, state.reshape(b, h, hd, ns)


def _ssd_fwd(x, dt, A, B, C, chunk, interpret):
    out = _ssd_kernel_call(x, dt, A, B, C, chunk, interpret)
    return out, (x, dt, A, B, C)


def _ssd_bwd(chunk, interpret, res, cts):
    x, dt, A, B, C = res
    # Backward = autodiff of the chunked reference (recompute; stage-level
    # remat — matches the paper's activation-checkpointing training setup).
    _, vjp = jax.vjp(lambda *a: ref.ssd_chunked(*a, chunk=chunk),
                     x, dt, A, B, C)
    return vjp(cts)


_ssd_kernel_call.defvjp(_ssd_fwd, _ssd_bwd)
