"""Public jit'd wrappers around the Pallas kernels.

Responsibilities: layout transposes, tile padding, GQA grouping, custom_vjp
stitching, and backend selection (real Mosaic lowering on TPU, interpret
mode everywhere else — same kernel body, Python-executed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as fa
from repro.kernels import gmm as gmm_kernel
from repro.kernels import ref
from repro.kernels import ssd as ssd_kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, axis, multiple):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_flash(causal, window, scale, softcap, block_q, block_k, interpret,
                q_len, kv_len):
    """Build a custom_vjp flash fn for one static config (cached)."""

    kw = dict(scale=scale, causal=causal, window=window,
              block_q=block_q, block_k=block_k, interpret=interpret,
              q_len=q_len, kv_len=kv_len)

    @jax.custom_vjp
    def flash(q, k, v):
        o, _ = fa.flash_forward(q, k, v, softcap=softcap, **kw)
        return o

    def fwd(q, k, v):
        o, lse = fa.flash_forward(q, k, v, softcap=softcap, **kw)
        return o, (q, k, v, o, lse)

    def bwd(res, do):
        q, k, v, o, lse = res
        if softcap > 0:
            raise NotImplementedError(
                "flash backward with softcap: use attn_impl='ref'")
        dq, dk, dv = fa.flash_backward(q, k, v, o, lse, do, **kw)
        return dq, dk, dv

    flash.defvjp(fwd, bwd)
    return flash


def flash_attention(q, k, v, *, causal: bool, window: int = 0,
                    scale: float | None = None, softcap: float = 0.0,
                    block_q: int = fa.DEFAULT_BLOCK_Q,
                    block_k: int = fa.DEFAULT_BLOCK_K,
                    interpret: bool | None = None):
    """q: [B,S,H,hd]; k/v: [B,T,KH,hd] -> [B,S,H,hd].

    Structural masking only (causal / sliding window / padding). For
    arbitrary masks (ring caches, packed segments) use the reference path.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    interpret = _interpret_default() if interpret is None else interpret
    block_q = min(block_q, _round_up(S, 128))
    block_k = min(block_k, _round_up(T, 128))

    # [B,S,H,hd] -> [B,H,S,hd], pad sequence dims to block multiples.
    qt = _pad_to(jnp.swapaxes(q, 1, 2), 2, block_q)
    kt = _pad_to(jnp.swapaxes(k, 1, 2), 2, block_k)
    vt = _pad_to(jnp.swapaxes(v, 1, 2), 2, block_k)

    flash = _make_flash(causal, window, float(scale), float(softcap),
                        block_q, block_k, interpret, S, T)
    o = flash(qt, kt, vt)
    return jnp.swapaxes(o[:, :, :S], 1, 2)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Grouped matmul (MoE experts)
# ---------------------------------------------------------------------------

def _pack_meta(group_sizes, m: int, n_groups: int, block_m: int):
    """Destination row for each sorted row + group id per m-tile.

    Static padded size: every group padded up to a block_m multiple.
    """
    padded = ((group_sizes + block_m - 1) // block_m) * block_m
    p_starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                jnp.cumsum(padded)[:-1].astype(jnp.int32)])
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(m)
    gid = jnp.clip(jnp.sum(row[:, None] >= ends[None, :], axis=-1),
                   0, n_groups - 1)
    dest = p_starts[gid] + (row - starts[gid])

    mp = _round_up(m, block_m) + n_groups * block_m  # static upper bound
    n_tiles = mp // block_m
    tile_ends = jnp.cumsum(padded // block_m)
    tile = jnp.arange(n_tiles)
    tile_group = jnp.clip(
        jnp.sum(tile[:, None] >= tile_ends[None, :], axis=-1),
        0, n_groups - 1).astype(jnp.int32)
    return dest, tile_group, mp


@functools.lru_cache(maxsize=None)
def _make_gmm_packed(block_m, block_k, block_n, interpret, n_groups,
                     out_dtype_name):
    out_dtype = jnp.dtype(out_dtype_name)

    @jax.custom_vjp
    def gmm_packed(lhs_p, rhs, tile_group):
        return gmm_kernel.gmm_tiled(lhs_p, rhs, tile_group, block_m=block_m,
                                    block_k=block_k, block_n=block_n,
                                    interpret=interpret, out_dtype=out_dtype)

    def fwd(lhs_p, rhs, tile_group):
        return gmm_packed(lhs_p, rhs, tile_group), (lhs_p, rhs, tile_group)

    def bwd(res, dout):
        lhs_p, rhs, tile_group = res
        dout = dout.astype(jnp.float32)
        dlhs = gmm_kernel.gmm_tiled(
            dout, jnp.swapaxes(rhs, 1, 2).astype(jnp.float32), tile_group,
            block_m=block_m, block_k=block_n, block_n=block_k,
            interpret=interpret, out_dtype=lhs_p.dtype)
        drhs = gmm_kernel.gmm_dw_tiled(
            lhs_p.astype(jnp.float32), dout, tile_group, n_groups,
            block_m=block_m, block_k=block_k, block_n=block_n,
            interpret=interpret).astype(rhs.dtype)
        dtile = np.zeros(tile_group.shape, dtype=jax.dtypes.float0)
        return dlhs, drhs, dtile

    gmm_packed.defvjp(fwd, bwd)
    return gmm_packed


def gmm(lhs, rhs, group_sizes, *, block_m: int = 128, block_k: int = 128,
        block_n: int = 128, interpret: bool | None = None):
    """Grouped matmul: lhs [M,K] sorted by group; rhs [G,K,N]; sizes [G].

    Pallas-backed mirror of jax.lax.ragged_dot / ref.gmm.
    """
    interpret = _interpret_default() if interpret is None else interpret
    M, K = lhs.shape
    G = rhs.shape[0]
    dest, tile_group, Mp = _pack_meta(group_sizes.astype(jnp.int32), M, G,
                                      block_m)
    lhs_p = jnp.zeros((Mp, K), lhs.dtype).at[dest].set(lhs)
    fn = _make_gmm_packed(block_m, block_k, block_n, interpret, G,
                          jnp.dtype(lhs.dtype).name)
    out_p = fn(lhs_p, rhs, tile_group)
    return jnp.take(out_p, dest, axis=0)


# ---------------------------------------------------------------------------
# SSD (mamba2)
# ---------------------------------------------------------------------------

def ssd(x, dt, A, B, C, *, chunk: int = 128, use_kernel: bool = False,
        interpret: bool | None = None):
    """mamba2 SSD scan. x: [b,T,h,hd]; dt: [b,T,h]; A: [h]; B/C: [b,T,ns].

    Returns (y [b,T,h,hd], final_state [b,h,hd,ns] f32).
    use_kernel=False -> chunked jnp reference (autodiff-native).
    use_kernel=True  -> Pallas forward, reference-recompute backward.
    """
    if not use_kernel:
        return ref.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    interpret = _interpret_default() if interpret is None else interpret
    return _ssd_kernel_call(x, dt, A, B, C, chunk, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd_kernel_call(x, dt, A, B, C, chunk, interpret):
    b, T, h, hd = x.shape
    ns = B.shape[-1]
    Q = min(chunk, _round_up(T, 128))
    la = (dt.astype(jnp.float32) * A[None, None, :]).swapaxes(1, 2)  # [b,h,T]
    xbar = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    xbar = jnp.moveaxis(xbar, 2, 1)  # [b,h,T,hd]
    # pad T to chunk multiple; la=0, xbar=0 => padding is a no-op in the scan
    lap = _pad_to(la.reshape(b * h, T), 1, Q)
    xbp = _pad_to(xbar.reshape(b * h, T, hd), 1, Q)
    Bp = _pad_to(B.astype(jnp.float32), 1, Q)
    Cp = _pad_to(C.astype(jnp.float32), 1, Q)
    y, state = ssd_kernel.ssd_pallas(xbp, lap, Bp, Cp, h, chunk=Q,
                                     interpret=interpret)
    y = y[:, :T].reshape(b, h, T, hd).swapaxes(1, 2).astype(x.dtype)
    return y, state.reshape(b, h, hd, ns)


def _ssd_fwd(x, dt, A, B, C, chunk, interpret):
    out = _ssd_kernel_call(x, dt, A, B, C, chunk, interpret)
    return out, (x, dt, A, B, C)


def _ssd_bwd(chunk, interpret, res, cts):
    x, dt, A, B, C = res
    # Backward = autodiff of the chunked reference (recompute; stage-level
    # remat — matches the paper's activation-checkpointing training setup).
    _, vjp = jax.vjp(lambda *a: ref.ssd_chunked(*a, chunk=chunk),
                     x, dt, A, B, C)
    return vjp(cts)


_ssd_kernel_call.defvjp(_ssd_fwd, _ssd_bwd)
