"""Pallas TPU kernel for the mamba2 SSD (state-space duality) scan.

The SSD dual form splits the sequence into chunks: within a chunk the
recurrence is a (masked, decay-weighted) quadratic form computed on the MXU;
across chunks a small [state x head_dim] recurrence is carried. On TPU the
chunk axis becomes the sequential grid dimension and the carried state lives
in a VMEM scratch buffer (HBM -> VMEM once per (batch*head)), which is the
TPU-native replacement for the CUDA kernel's shared-memory state.

Layouts (wrapper transposes):
    xbar: [BH, T, hd]   — x * dt, head-major flattened
    la:   [BH, T]       — dt * A (log decay), per head
    B, C: [Bb, T, ns]    — shared across heads (n_groups=1)
Outputs: y [BH, T, hd]; final_state [BH, hd, ns].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _ssd_kernel(xbar_ref, la_ref, b_ref, c_ref, y_ref, state_ref, s_scratch,
                *, n_chunks):
    c_idx = pl.program_id(1)
    Q = xbar_ref.shape[1]

    @pl.when(c_idx == 0)
    def _init():
        s_scratch[...] = jnp.zeros_like(s_scratch)

    xb = xbar_ref[0].astype(jnp.float32)  # [Q, hd]
    la = la_ref[0].astype(jnp.float32)  # [Q]
    Bm = b_ref[0].astype(jnp.float32)  # [Q, ns]
    Cm = c_ref[0].astype(jnp.float32)  # [Q, ns]

    cum = jnp.cumsum(la)  # inclusive
    total = cum[-1]

    # Intra-chunk quadratic term (MXU): (C B^T ⊙ L) xbar
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, Q]
    diff = cum[:, None] - cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tri = col <= row
    L = jnp.exp(jnp.where(tri, diff, -60.0)) * tri  # clamp: no inf*0
    y = jax.lax.dot_general(G * L, xb, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, hd]

    # Inter-chunk term from carried state S [ns, hd].
    S = s_scratch[...]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # State update: S <- exp(total) S + (B ⊙ w)^T xbar
    w = jnp.exp(total - cum)  # [Q]
    s_new = jnp.exp(total) * S + jax.lax.dot_general(
        Bm * w[:, None], xb, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_scratch[...] = s_new
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(c_idx == n_chunks - 1)
    def _finish():
        state_ref[0] = s_new.T.astype(state_ref.dtype)  # [hd, ns]


def ssd_pallas(xbar, la, B, C, n_heads: int, *, chunk=128, interpret=False):
    """xbar: [BH, T, hd]; la: [BH, T]; B/C: [Bb, T, ns]; T % chunk == 0."""
    BH, T, hd = xbar.shape
    ns = B.shape[-1]
    n_chunks = T // chunk
    h = n_heads

    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=n_chunks),
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk), lambda bh, c: (bh, c)),
            pl.BlockSpec((1, chunk, ns), lambda bh, c: (bh // h, c, 0)),
            pl.BlockSpec((1, chunk, ns), lambda bh, c: (bh // h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, hd, ns), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, hd), xbar.dtype),
            jax.ShapeDtypeStruct((BH, hd, ns), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((ns, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xbar, la, B, C)
    return y, state
