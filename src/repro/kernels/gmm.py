"""Pallas TPU grouped matmul (MoE expert GEMM) — MegaBlocks adapted to TPU.

MegaBlocks frames dropless-MoE expert compute as a block-sparse GEMM driven
by a CSR-like topology. TPUs have no hardware gather/CSR GEMM, so the TPU
adaptation (see DESIGN.md §5) is: the wrapper repacks expert-sorted rows so
every group starts at a tile boundary (padding each group to a multiple of
``block_m``); the kernel is then a dense tiled matmul whose *rhs* tile is
selected per m-tile through a scalar-prefetched ``tile_group`` map. Padding
rows are zero and their outputs are dropped on unpack, so no in-kernel
masking is needed; cost is <= G*(block_m-1) phantom rows.

Kernel signature:
    lhs:  [Mp, K]   rows sorted by group, group-start tile-aligned
    rhs:  [G, K, N] per-group weights
    tile_group: [Mp / block_m] int32 — group id of each m-tile (prefetched)
    out:  [Mp, N]
Accumulation over the sequential k-tile grid dim in an f32 VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _gmm_kernel(tile_group, lhs_ref, rhs_ref, out_ref, acc, *, n_k):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        lhs_ref[...].astype(jnp.float32), rhs_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _finish():
        out_ref[...] = acc[...].astype(out_ref.dtype)


def gmm_tiled(lhs, rhs, tile_group, *, block_m=128, block_k=128, block_n=128,
              interpret=False, out_dtype=None):
    """Dense tiled grouped matmul over tile-aligned groups.

    lhs: [Mp, K]; rhs: [G, K, N]; tile_group: [Mp//block_m] int32.
    """
    Mp, K = lhs.shape
    G, _, N = rhs.shape
    assert Mp % block_m == 0
    # Pad K and N to tile multiples.
    pk = (-K) % block_k
    pn = (-N) % block_n
    if pk:
        lhs = jnp.pad(lhs, ((0, 0), (0, pk)))
        rhs = jnp.pad(rhs, ((0, 0), (0, pk), (0, 0)))
    if pn:
        rhs = jnp.pad(rhs, ((0, 0), (0, 0), (0, pn)))
    Kp, Np = K + pk, N + pn
    n_m, n_n, n_k = Mp // block_m, Np // block_n, Kp // block_k
    out_dtype = out_dtype or lhs.dtype

    out = pl.pallas_call(
        functools.partial(_gmm_kernel, n_k=n_k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_m, n_n, n_k),
            in_specs=[
                pl.BlockSpec((block_m, block_k),
                             lambda im, jn, ik, tg: (im, ik)),
                pl.BlockSpec((1, block_k, block_n),
                             lambda im, jn, ik, tg: (tg[im], ik, jn)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda im, jn, ik, tg: (im, jn)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tile_group, lhs, rhs)
    return out[:, :N]


# ---------------------------------------------------------------------------
# VMEM budgeting for block-size autotuning
# ---------------------------------------------------------------------------

VMEM_BUDGET_BYTES = 16 * 1024 * 1024  # per-core VMEM, v4/v5e class


def glu_vmem_bytes(block_m: int, block_k: int, block_n: int,
                   lhs_dtype=jnp.bfloat16, rhs_dtype=jnp.bfloat16) -> int:
    """Peak VMEM working set of one gmm_glu_tiled grid step.

    Streamed operands (lhs tile, gate+up rhs tiles, out tile) are
    double-buffered by the Pallas pipeline (2x); the two f32 accumulator
    scratches are single instances that live across the k-loop.
    """
    lb = jnp.dtype(lhs_dtype).itemsize
    rb = jnp.dtype(rhs_dtype).itemsize
    streamed = (block_m * block_k * lb          # lhs tile
                + 2 * block_k * block_n * rb    # gate + up rhs tiles
                + block_m * block_n * lb)       # fused output tile
    scratch = 2 * block_m * block_n * 4         # two f32 accumulators
    return 2 * streamed + scratch


def glu_block_candidates(block_k: int = 128,
                         vmem_budget: int = VMEM_BUDGET_BYTES,
                         lhs_dtype=jnp.bfloat16, rhs_dtype=jnp.bfloat16,
                         ms=(512, 256, 128, 64), ns=(512, 256, 128)):
    """(block_m, block_n) sweep candidates for gmm_glu_tiled that fit the
    VMEM budget, largest tiles first (MXU-aligned multiples of 128 plus a
    64-row sublane option for capacity-chunked buffers)."""
    out = []
    for bm in ms:
        for bn in ns:
            if glu_vmem_bytes(bm, block_k, bn, lhs_dtype,
                              rhs_dtype) <= vmem_budget:
                out.append((bm, bn))
    return out


def _gmm_glu_kernel(tile_group, lhs_ref, rhs_g_ref, rhs_u_ref, out_ref,
                    acc_g, acc_u, *, n_k):
    """Fused GLU grouped matmul: out = silu(lhs @ rhs_g) * (lhs @ rhs_u).

    Each lhs m-tile is read from HBM ONCE and feeds both the gate and the up
    GEMM; the activation (silu * mul) is applied on the f32 accumulators in
    VMEM before the single flush, so the intermediate ``g``/``u`` tensors
    never round-trip through HBM (DESIGN.md §5.3).
    """
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_u[...] = jnp.zeros_like(acc_u)

    lhs = lhs_ref[...].astype(jnp.float32)
    acc_g[...] += jax.lax.dot_general(
        lhs, rhs_g_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    acc_u[...] += jax.lax.dot_general(
        lhs, rhs_u_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _finish():
        g = acc_g[...]
        out_ref[...] = (g * jax.lax.logistic(g) * acc_u[...]
                        ).astype(out_ref.dtype)


def gmm_glu_tiled(lhs, rhs_stacked, tile_group, *, block_m=128, block_k=128,
                  block_n=128, interpret=False, out_dtype=None):
    """Fused GLU grouped matmul over tile-aligned groups, stacked weights.

    lhs: [Mp, K]; rhs_stacked: [G, K, 2N] (gate weights in [..., :N], up
    weights in [..., N:]); tile_group: [Mp//block_m] int32.
    Returns [Mp, N] = silu(lhs @ gate) * (lhs @ up) per group.
    """
    G, _, N2 = rhs_stacked.shape
    assert N2 % 2 == 0
    N = N2 // 2
    K = lhs.shape[1]
    if (-K) % block_k == 0 and (-N) % block_n == 0:
        # Tile-aligned halves (the production case): index straight into
        # the stacked tensor — the up tile of output column-block jn lives
        # at column-block jn + N/block_n. No slice/pad copies.
        return _gmm_glu_call(lhs, rhs_stacked, rhs_stacked, tile_group,
                             N // block_n, N, block_m=block_m,
                             block_k=block_k, block_n=block_n,
                             interpret=interpret, out_dtype=out_dtype)
    return gmm_glu_tiled_pair(lhs, rhs_stacked[:, :, :N],
                              rhs_stacked[:, :, N:], tile_group,
                              block_m=block_m, block_k=block_k,
                              block_n=block_n, interpret=interpret,
                              out_dtype=out_dtype)


def gmm_glu_tiled_pair(lhs, rhs_gate, rhs_up, tile_group, *, block_m=128,
                       block_k=128, block_n=128, interpret=False,
                       out_dtype=None):
    """gmm_glu_tiled with gate/up as separate [G, K, N] arrays — lets
    callers holding unstacked expert weights (the param layout) skip the
    [G, K, 2N] restack copy entirely."""
    K = lhs.shape[1]
    N = rhs_gate.shape[-1]
    pk = (-K) % block_k
    pn = (-N) % block_n
    if pk:
        lhs = jnp.pad(lhs, ((0, 0), (0, pk)))
        rhs_gate = jnp.pad(rhs_gate, ((0, 0), (0, pk), (0, 0)))
        rhs_up = jnp.pad(rhs_up, ((0, 0), (0, pk), (0, 0)))
    if pn:
        rhs_gate = jnp.pad(rhs_gate, ((0, 0), (0, 0), (0, pn)))
        rhs_up = jnp.pad(rhs_up, ((0, 0), (0, 0), (0, pn)))
    return _gmm_glu_call(lhs, rhs_gate, rhs_up, tile_group, 0, N,
                         block_m=block_m, block_k=block_k, block_n=block_n,
                         interpret=interpret, out_dtype=out_dtype)


def _gmm_glu_call(lhs, rhs_g, rhs_u, tile_group, u_off, N, *, block_m,
                  block_k, block_n, interpret, out_dtype):
    """Shared pallas_call: lhs/rhs already tile-padded; the up tile of
    output column-block jn is read at column-block jn + u_off of rhs_u."""
    Mp, Kp = lhs.shape
    assert Mp % block_m == 0
    Np = ((N + block_n - 1) // block_n) * block_n
    n_m, n_n, n_k = Mp // block_m, Np // block_n, Kp // block_k
    out_dtype = out_dtype or lhs.dtype

    out = pl.pallas_call(
        functools.partial(_gmm_glu_kernel, n_k=n_k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_m, n_n, n_k),
            in_specs=[
                pl.BlockSpec((block_m, block_k),
                             lambda im, jn, ik, tg: (im, ik)),
                pl.BlockSpec((1, block_k, block_n),
                             lambda im, jn, ik, tg: (tg[im], ik, jn)),
                pl.BlockSpec((1, block_k, block_n),
                             lambda im, jn, ik, tg: (tg[im], ik,
                                                     jn + u_off)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n),
                                   lambda im, jn, ik, tg: (im, jn)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32),
                            pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tile_group, lhs, rhs_g, rhs_u)
    return out[:, :N]


def _dw_kernel(tile_group, lhs_ref, dout_ref, drhs_ref, acc, *, n_m,
               tile_group_host=None):
    """drhs[g] = sum over that group's row tiles of lhs_tile^T @ dout_tile.

    Grid (k, n, m) with m sequential; the output block index (tg[im], k, n)
    revisits the same block for consecutive tiles of one group (groups are
    contiguous), so we zero the accumulator at each group start and flush at
    each group end (Pallas TPU output-revisiting semantics).
    """
    im = pl.program_id(2)
    first = im == 0
    if n_m > 1:
        prev = tile_group[jnp.maximum(im - 1, 0)]
        first = jnp.logical_or(first, tile_group[im] != prev)

    @pl.when(first)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        lhs_ref[...].astype(jnp.float32), dout_ref[...].astype(jnp.float32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    last = im == n_m - 1
    if n_m > 1:
        nxt = tile_group[jnp.minimum(im + 1, n_m - 1)]
        last = jnp.logical_or(last, tile_group[im] != nxt)

    @pl.when(last)
    def _finish():
        drhs_ref[0] = acc[...].astype(drhs_ref.dtype)


def gmm_dw_tiled(lhs, dout, tile_group, n_groups, *, block_m=128, block_k=128,
                 block_n=128, interpret=False, out_dtype=jnp.float32):
    """Gradient wrt rhs: [G, K, N] from tile-aligned lhs [Mp,K], dout [Mp,N].

    Groups with no tiles produce zero blocks (their buffers are only flushed
    if visited; we initialize via a zero-fill pass on the host side instead).
    """
    Mp, K = lhs.shape
    N = dout.shape[1]
    pk = (-K) % block_k
    pn = (-N) % block_n
    if pk:
        lhs = jnp.pad(lhs, ((0, 0), (0, pk)))
    if pn:
        dout = jnp.pad(dout, ((0, 0), (0, pn)))
    Kp, Np = K + pk, N + pn
    n_m, n_k, n_n = Mp // block_m, Kp // block_k, Np // block_n

    drhs = pl.pallas_call(
        functools.partial(_dw_kernel, n_m=n_m),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_k, n_n, n_m),
            in_specs=[
                pl.BlockSpec((block_m, block_k),
                             lambda ik, jn, im, tg: (im, ik)),
                pl.BlockSpec((block_m, block_n),
                             lambda ik, jn, im, tg: (im, jn)),
            ],
            out_specs=pl.BlockSpec((1, block_k, block_n),
                                   lambda ik, jn, im, tg: (tg[im], ik, jn)),
            scratch_shapes=[pltpu.VMEM((block_k, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_groups, Kp, Np), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tile_group, lhs, dout)
    drhs = drhs[:, :K, :N]
    # Tiles only flush blocks they visit; a group that received zero rows
    # never flushes -> mask its (undefined) block to zero.
    visited = jnp.zeros((n_groups,), bool).at[tile_group].set(True)
    return jnp.where(visited[:, None, None], drhs, 0.0)
