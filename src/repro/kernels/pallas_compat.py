"""jax-version compatibility shims for Pallas TPU APIs.

The pinned container jax (0.4.37) predates two renames we rely on:

* ``pltpu.TPUCompilerParams`` became ``pltpu.CompilerParams`` in jax 0.5.x.
  Both spellings accept the same ``dimension_semantics`` field, so a single
  alias suffices.

Import ``CompilerParams`` from here instead of ``pltpu`` in every kernel
module so the kernels lower on both the pinned jax and newer releases.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
