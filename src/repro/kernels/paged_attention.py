"""Pallas TPU paged decode attention (DESIGN.md §9.3).

Flash-style single-token decode over a PAGED KV cache: the physical pool is
``[n_pages, page_size, KH, hd]`` shared by every slot, and each slot's pages
are block-gathered through a scalar-prefetched page table — the same
prefetched-index contract as ``gmm_glu_tiled``'s ``tile_group`` map, applied
to the sequential kv dimension of a decode flash kernel. One grid step
streams ONE physical page into VMEM (its index computed from the prefetched
table before the body runs, so the DMA pipeline still runs ahead) and folds
it into the online softmax.

Masking is structural (DESIGN.md §9.2): line ``l`` of table slot ``j`` is key
position ``j * page_size + l``; positions beyond the slot's query position
(its causal frontier) are masked, which is also what makes recycled pages'
stale lines unreachable — no per-line validity state is read.

Layout contract (wrapper in ops.py handles padding/reshapes):
    q:     [B, KH, Gp, hdp]   Gp = GQA group padded to sublane multiple
    k/v:   [P, page_size, KH, hdp]
    page_table / page_valid: [B, MP] int32 (prefetched; table pre-clamped)
    q_pos: [B] int32 (the slot's current key-write position; < 0 = dead)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

_NEG = -0.7 * float(jnp.finfo(jnp.float32).max)


def _paged_decode_kernel(pt_ref, valid_ref, qpos_ref, q_ref, k_ref, v_ref,
                         o_ref, acc, m_s, l_s, *, scale, softcap, window,
                         page_size, n_pages_seq):
    b = pl.program_id(0)
    jp = pl.program_id(2)

    @pl.when(jp == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc[...] = jnp.zeros_like(acc)

    q_pos = qpos_ref[b]
    live = (valid_ref[b, jp] > 0) & (q_pos >= 0)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)        # [Gp, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)     # [page_size, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        # Structural key positions: line l of table slot jp sits at
        # jp * page_size + l. Causal frontier + optional sliding window.
        kpos = jp * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = kpos <= q_pos
        if window > 0:
            mask &= (q_pos - kpos) < window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_s[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_s[:, 0] = l_s[:, 0] * alpha + jnp.sum(p, axis=-1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[:, 0] = m_new

    @pl.when(jp == n_pages_seq - 1)
    def _finish():
        l = l_s[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc[...] / denom[:, None]).astype(o_ref.dtype)


def paged_decode_forward(q, k_pool, v_pool, page_table, q_pos, *, scale,
                         softcap=0.0, window=0, interpret=False):
    """q: [B, KH, Gp, hd]; pools: [P, page_size, KH, hd];
    page_table: [B, MP] int32 (-1 = unallocated slot); q_pos: [B] int32.

    Returns [B, KH, Gp, hd] attention output (zeros for dead slots —
    callers mask). The raw table is split into a clamped index array (for
    the BlockSpec index map) plus a validity array (for in-kernel masking);
    both ride the scalar-prefetch channel.
    """
    B, KH, Gp, hd = q.shape
    P, page_size = k_pool.shape[0], k_pool.shape[1]
    MP = page_table.shape[1]

    pt = jnp.maximum(page_table, 0).astype(jnp.int32)
    valid = (page_table >= 0).astype(jnp.int32)
    qp = q_pos.astype(jnp.int32)

    kernel = functools.partial(
        _paged_decode_kernel, scale=float(scale), softcap=float(softcap),
        window=int(window), page_size=page_size, n_pages_seq=MP)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, KH, MP),
            in_specs=[
                pl.BlockSpec((1, 1, Gp, hd),
                             lambda b, h, jp, pt, vl, qp: (b, h, 0, 0)),
                pl.BlockSpec((1, page_size, 1, hd),
                             lambda b, h, jp, pt, vl, qp:
                             (pt[b, jp], 0, h, 0)),
                pl.BlockSpec((1, page_size, 1, hd),
                             lambda b, h, jp, pt, vl, qp:
                             (pt[b, jp], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, Gp, hd),
                                   lambda b, h, jp, pt, vl, qp: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((Gp, hd), jnp.float32),
                pltpu.VMEM((Gp, 1), jnp.float32),
                pltpu.VMEM((Gp, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KH, Gp, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pt, valid, qp, q, k_pool, v_pool)
