"""Data pipeline: deterministic, shardable, resumable.

Two sources behind one interface:
  * SyntheticSource — PRNG tokens keyed by (seed, step); zero I/O, fully
    deterministic, used by smoke tests and dry-runs.
  * MemmapSource — flat token .bin on disk (np.uint16/uint32 memmap),
    sequence-chunked; deterministic mapping (step, host) -> file offsets so
    restarting at step k reproduces the exact stream (checkpoint/resume).

Batches are {"tokens": [B, S], "targets": [B, S]} with targets = next-token
shift. Multi-host: each host materializes only its batch shard
(host_index/host_count), matching jax.make_array_from_process_local_data.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: Optional[str] = None  # memmap .bin (None -> synthetic)
    dtype: str = "uint16"


class SyntheticSource:
    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 host_count: int = 1):
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        assert cfg.global_batch % host_count == 0

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        b_loc = cfg.global_batch // self.host_count
        key = jax.random.fold_in(jax.random.fold_in(
            jax.random.PRNGKey(cfg.seed), step), self.host_index)
        toks = jax.random.randint(key, (b_loc, cfg.seq_len + 1), 0,
                                  cfg.vocab_size, jnp.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class MemmapSource:
    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 host_count: int = 1):
        assert cfg.path is not None
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.data = np.memmap(cfg.path, dtype=np.dtype(cfg.dtype), mode="r")
        self.n_seqs = (len(self.data) - 1) // cfg.seq_len
        if self.n_seqs < 1:
            raise ValueError("dataset smaller than one sequence")

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        b_loc = cfg.global_batch // self.host_count
        base = step * cfg.global_batch + self.host_index * b_loc
        rows = [(base + i) % self.n_seqs for i in range(b_loc)]
        toks = np.stack([
            self.data[r * cfg.seq_len:(r + 1) * cfg.seq_len + 1]
            for r in rows]).astype(np.int32)
        toks = np.minimum(toks, cfg.vocab_size - 1)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "targets": jnp.asarray(toks[:, 1:])}


class DataLoader:
    """Step-indexed loader with checkpointable position."""

    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 host_count: int = 1, start_step: int = 0):
        src_cls = MemmapSource if cfg.path else SyntheticSource
        self.source = src_cls(cfg, host_index, host_count)
        self.step = start_step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = self.source.batch_at(self.step)
        self.step += 1
        return b

    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, st: dict) -> None:
        self.step = int(st["step"])


def write_token_bin(path: str, n_tokens: int, vocab_size: int,
                    seed: int = 0, dtype: str = "uint16") -> str:
    """Generate a token .bin for examples/tests."""
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, min(vocab_size, np.iinfo(np.dtype(dtype)).max),
                       size=(n_tokens,), dtype=np.dtype(dtype))
    arr.tofile(path)
    return path
