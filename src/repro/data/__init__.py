from repro.data.pipeline import (DataConfig, DataLoader, MemmapSource,
                                 SyntheticSource, write_token_bin)

__all__ = ["DataConfig", "DataLoader", "MemmapSource", "SyntheticSource",
           "write_token_bin"]
