"""Cross-version jax API shims.

The container pins jax 0.4.37; several APIs this codebase targets were
renamed or moved on the way to jax 0.5+:

* ``jax.shard_map``        — lived at ``jax.experimental.shard_map`` (with
  the replication-check kwarg spelled ``check_rep`` instead of
  ``check_vma``).
* ``jax.sharding.AxisType`` — absent; handled in ``repro.launch.mesh``.
* ``pltpu.CompilerParams`` — spelled ``TPUCompilerParams``; handled in
  ``repro.kernels.pallas_compat``.

Keep every version branch here (or in the two modules above) so kernels and
engines stay clean.
"""

from __future__ import annotations

import jax


def shard_map(fn, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
