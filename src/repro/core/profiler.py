"""Analytical profiler — stands in for the paper's §5 measurement profiler.

Produces the quantities Algorithm 1 and the simulator consume:

    T_A^Attn : one layer's attention block (incl. QKV/O projections, gate)
               for one microbatch, on an attention-GPU class.
    T_E^Exp  : one layer's expert compute for one microbatch on one expert
               GPU (depends on the tokens it receives, not which experts).
    T_E^Attn : a single expert FFN with the same per-GPU batch on an
               attention-GPU class.
    memory   : per-expert and attention-side memory -> n_min / n_max.

Timing model per module: max(FLOP term, HBM-traffic term) with per-class
efficiency constants (hardware.py). Backward = 2x forward (paper §4.2: the
assignment optimized on forward times reduces both).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.hardware import DeviceClass
from repro.models.config import ModelConfig

BYTES = 2  # bf16/fp16 compute per the paper's mixed-precision setup


@dataclasses.dataclass(frozen=True)
class LayerTimes:
    """Per-microbatch forward times (seconds) for one layer.

    Follows the paper's §5 profiler semantics: T_E^Attn is ONE expert FFN
    over the full per-expert-GPU token batch B on an attention GPU (one
    expert's actual share is then T_E^Attn * N / n).

    Overlap-aware extension (DESIGN.md §8): t_dispatch / t_combine carry
    the per-microbatch all-to-all wire times (zero when no link bandwidth
    was supplied), so consumers can price the EXPOSED residue of chunked,
    double-buffered dispatch (simulator.exposed_comm) instead of the full
    serialized transfer.
    """

    t_attn: float       # T_A^Attn on the attention class
    t_exp: float        # T_E^Exp on the expert class (its full token load)
    t_exp_attn: float   # T_E^Attn on the attention class (full B tokens)
    t_exp_on_exp: float      # one expert FFN, full B tokens, expert class
    t_attn_on_exp: float     # attention block on the expert class (EP baseline)
    t_dispatch: float = 0.0  # dispatch all-to-all wire time, one direction
    t_combine: float = 0.0   # combine all-to-all wire time, one direction


def gemm_time(flops: float, bytes_moved: float, dev: DeviceClass) -> float:
    return max(flops / (dev.peak_flops * dev.gemm_eff),
               bytes_moved / dev.hbm_bw)


def attention_core_time(flops: float, bytes_moved: float,
                        dev: DeviceClass) -> float:
    if dev.has_flash_attention:
        return flops / (dev.peak_flops * dev.attn_eff)
    # Unfused attention: low achieved compute efficiency AND S-matrix HBM
    # traffic — whichever binds.
    return max(flops / (dev.peak_flops * dev.attn_eff_nofa),
               bytes_moved / dev.hbm_bw)


def attention_block_time(cfg: ModelConfig, tokens_per_gpu: int, seq_len: int,
                         dev: DeviceClass) -> float:
    """One layer's attention block (projections + SDPA + router) forward."""
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    n_seq = max(tokens_per_gpu // seq_len, 1)
    proj_flops = 2 * tokens_per_gpu * d * (2 * h * hd + 2 * kh * hd)
    proj_bytes = BYTES * d * (2 * h * hd + 2 * kh * hd)
    t = gemm_time(proj_flops, proj_bytes, dev)
    # SDPA core: 2 matmuls, causal halves the work.
    causal_frac = 0.5 if cfg.causal else 1.0
    core_flops = 2 * 2 * n_seq * seq_len * seq_len * h * hd * causal_frac
    # Unfused: S materialized in HBM ~4 passes (write S, read S, write P,
    # read P), fp16.
    core_bytes = 4 * n_seq * h * seq_len * seq_len * BYTES * causal_frac
    t += attention_core_time(core_flops, core_bytes, dev)
    if cfg.is_moe:  # router
        t += gemm_time(2 * tokens_per_gpu * d * cfg.n_experts,
                       BYTES * d * cfg.n_experts, dev)
    return t


def expert_ffn_time(cfg: ModelConfig, tokens: int, dev: DeviceClass) -> float:
    """One expert FFN over `tokens` tokens, forward."""
    d, f = cfg.d_model, cfg.d_ff_expert
    n_mats = 3 if cfg.mlp_act == "swiglu" else 2
    flops = 2 * tokens * d * f * n_mats
    byts = BYTES * d * f * n_mats
    return gemm_time(flops, byts, dev)


def mixer_nonattn_time(cfg: ModelConfig, tokens: int, dev: DeviceClass) -> float:
    """SSD / RG-LRU mixers (for completeness in non-MoE archs)."""
    d = cfg.d_model
    if cfg.ssm_state:
        din = cfg.ssm_expand * d
        flops = 2 * tokens * d * (2 * din + 2 * cfg.ssm_state) \
            + 2 * tokens * din * d \
            + 2 * tokens * cfg.ssm_chunk * (din + 2 * cfg.ssm_state)
        return gemm_time(flops, BYTES * 3 * d * din, dev)
    w = cfg.lru_width
    flops = 2 * tokens * (2 * d * w + 2 * w * w + w * d)
    return gemm_time(flops, BYTES * (2 * d * w + 2 * w * w + w * d), dev)


@dataclasses.dataclass(frozen=True)
class ZPGroupShape:
    """A zebra-parallelism group: M attention devices + N expert devices."""

    M: int
    N: int
    attn_class: DeviceClass
    exp_class: DeviceClass


def a2a_time(cfg: ModelConfig, mb_tokens: int, link_bw: float, M: int,
             N: int) -> float:
    """One-direction all-to-all wire time for one microbatch: every routed
    token copy crosses the bipartite cut once per direction (paper: no
    extra communication vs EP)."""
    byts = mb_tokens * max(cfg.top_k, 1) * cfg.d_model * BYTES
    agg_bw = link_bw * min(M, N) if min(M, N) else link_bw
    return byts / agg_bw


def profile_layer(cfg: ModelConfig, zp: ZPGroupShape, global_batch: int,
                  seq_len: int, num_microbatches: int,
                  link_bw: Optional[float] = None) -> LayerTimes:
    """The paper-profiler quantities for one (model, ZP group, batch).

    With ``link_bw`` the returned LayerTimes also carries the dispatch /
    combine all-to-all wire times (the overlap-aware fields)."""
    mb_tokens = global_batch * seq_len // num_microbatches
    tokens_per_attn_gpu = mb_tokens // zp.M
    # Each expert GPU receives (top_k-weighted) token copies for its experts.
    copies = mb_tokens * max(cfg.top_k, 1)
    tokens_per_exp_gpu = copies // max(zp.N, 1)

    t_attn = attention_block_time(cfg, tokens_per_attn_gpu,
                                  seq_len, zp.attn_class)
    t_exp = expert_ffn_time(cfg, tokens_per_exp_gpu, zp.exp_class)
    t_exp_attn = expert_ffn_time(cfg, tokens_per_exp_gpu, zp.attn_class)
    t_exp_on_exp = expert_ffn_time(cfg, tokens_per_exp_gpu, zp.exp_class)
    t_attn_on_exp = attention_block_time(cfg, tokens_per_attn_gpu, seq_len,
                                         zp.exp_class)
    t_a2a = a2a_time(cfg, mb_tokens, link_bw, zp.M, zp.N) if link_bw else 0.0
    return LayerTimes(t_attn=t_attn, t_exp=t_exp, t_exp_attn=t_exp_attn,
                      t_exp_on_exp=t_exp_on_exp,
                      t_attn_on_exp=t_attn_on_exp,
                      t_dispatch=t_a2a, t_combine=t_a2a)


# ---------------------------------------------------------------------------
# Serving-mode profile (DESIGN.md §10)
# ---------------------------------------------------------------------------
#
# The serving analogue of LayerTimes: the two quantities the disaggregation
# planner trades off are t_prefill_chunk (a chunked-prefill slice — the
# attention-heavy, compute-bound task the NEWER class dominates, exactly
# the Fig. 2 attention gap) and t_decode_step (one batched decode step —
# KV reads + expert/FFN weight reads, memory-bound, where the older class
# stays efficient). Both are profiled per device class so plan_disagg_group
# can sweep role splits the same way Asym-EA sweeps expert offload.

@dataclasses.dataclass(frozen=True)
class ServeProfile:
    """Per-class serving step times (seconds) + the KV handoff wire time."""

    t_prefill_chunk_attn: float  # one chunk slice on the attention class
    t_prefill_chunk_exp: float   # ... on the expert class
    t_decode_step_attn: float    # one batched decode step on the attn class
    t_decode_step_exp: float     # ... on the expert class
    t_page: float                # one KV page across the inter-group link
    chunk: int                   # prefill chunk the times were profiled at
    decode_batch: int            # decode batch the step times assume


def serve_ffn_time(cfg: ModelConfig, tokens: int, dev: DeviceClass) -> float:
    """Whole-FFN time at serving batch sizes. Small-M MoE decode is weight-
    read bound (the group-dense regime, DESIGN.md §5.5): HBM traffic covers
    every ACTIVATED expert's weights, not one expert's."""
    d = cfg.d_model
    n_mats = 3 if cfg.mlp_act == "swiglu" else 2
    if cfg.is_moe:
        f = cfg.d_ff_expert
        copies = tokens * max(cfg.top_k, 1)
        n_act = min(cfg.n_experts, max(copies, 1))
        return gemm_time(2 * copies * d * f * n_mats,
                         BYTES * n_act * d * f * n_mats, dev)
    return gemm_time(2 * tokens * d * cfg.d_ff * n_mats,
                     BYTES * d * cfg.d_ff * n_mats, dev)


def prefill_chunk_time(cfg: ModelConfig, chunk: int, ctx: int,
                       dev: DeviceClass) -> float:
    """One whole-stack chunked-prefill slice: ``chunk`` new tokens
    attending over a ``ctx``-line cache. Compute-bound: the SDPA core is
    chunk x ctx and runs at the class's (un)fused attention efficiency —
    this is where the generation gap bites (Fig. 2a)."""
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj_flops = 2 * chunk * d * (2 * h * hd + 2 * kh * hd)
    proj_bytes = BYTES * d * (2 * h * hd + 2 * kh * hd)
    t = gemm_time(proj_flops, proj_bytes, dev)
    core_flops = 2 * 2 * chunk * ctx * h * hd
    core_bytes = 4 * h * chunk * ctx * BYTES
    t += attention_core_time(core_flops, core_bytes, dev)
    if cfg.is_moe:
        t += gemm_time(2 * chunk * d * cfg.n_experts,
                       BYTES * d * cfg.n_experts, dev)
    t += serve_ffn_time(cfg, chunk, dev)
    return cfg.n_layers * t


def decode_step_time(cfg: ModelConfig, batch: int, ctx: int,
                     dev: DeviceClass) -> float:
    """One batched decode step (1 token per slot) at context ``ctx``:
    KV-cache reads + FFN weight reads dominate, so the roofline's HBM leg
    binds on both classes — the old generation loses little here."""
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj_flops = 2 * batch * d * (2 * h * hd + 2 * kh * hd)
    proj_bytes = BYTES * d * (2 * h * hd + 2 * kh * hd)
    t = gemm_time(proj_flops, proj_bytes, dev)
    core_flops = 2 * 2 * batch * ctx * h * hd
    kv_bytes = batch * ctx * 2 * kh * hd * BYTES  # the whole cache, once
    eff = dev.attn_eff if dev.has_flash_attention else dev.attn_eff_nofa
    t += max(core_flops / (dev.peak_flops * eff), kv_bytes / dev.hbm_bw)
    if cfg.is_moe:
        t += gemm_time(2 * batch * d * cfg.n_experts,
                       BYTES * d * cfg.n_experts, dev)
    t += serve_ffn_time(cfg, batch, dev)
    t = cfg.n_layers * t
    # Unembedding head (decode samples every step; prefill only at the end,
    # where it is amortized over the whole prompt and left out).
    t += gemm_time(2 * batch * d * cfg.vocab_size,
                   BYTES * d * cfg.vocab_size, dev)
    return t


def ep_decode_step_time(cfg: ModelConfig, batch: int, ctx: int,
                        placement, shard_classes, hist, *,
                        n_chunks: int = 1,
                        link_bw: Optional[float] = None) -> float:
    """One EP-sharded batched decode step (DESIGN.md §11).

    The attention / router / head legs run replicated, so the slowest
    class present paces them. The expert hop is the max over shards of
    each shard's time for ITS experts under the observed routing
    distribution ``hist``: expected token copies give the FLOP leg and
    expected ACTIVATED experts give the weight-read leg — decode is
    weight-read bound (serve_ffn_time's regime), and a hot expert is read
    every step while a cold one is rarely touched, which is the lever
    heterogeneity-aware placement pulls (hot -> high-HBM-bandwidth class).
    With ``link_bw`` the dispatch+combine all-to-alls price only their
    EXPOSED residue after ``n_chunks`` double-buffered capacity chunks
    (simulator.exposed_comm), mirroring the zebra training cost model.
    """
    from repro.core.simulator import exposed_comm  # lazy: avoid cycle
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k = max(cfg.top_k, 1)
    n_mats = 3 if cfg.mlp_act == "swiglu" else 2
    f = cfg.d_ff_expert
    tot = sum(hist) or 1.0
    p = [x / tot for x in hist]
    # P(expert activated by >= 1 of the batch*k routed copies).
    a = [1.0 - (1.0 - pe) ** (batch * k) for pe in p]

    def attn_leg(dev):
        proj_flops = 2 * batch * d * (2 * h * hd + 2 * kh * hd)
        proj_bytes = BYTES * d * (2 * h * hd + 2 * kh * hd)
        t = gemm_time(proj_flops, proj_bytes, dev)
        core_flops = 2 * 2 * batch * ctx * h * hd
        kv_bytes = batch * ctx * 2 * kh * hd * BYTES
        eff = dev.attn_eff if dev.has_flash_attention else dev.attn_eff_nofa
        t += max(core_flops / (dev.peak_flops * eff), kv_bytes / dev.hbm_bw)
        t += gemm_time(2 * batch * d * cfg.n_experts,
                       BYTES * d * cfg.n_experts, dev)
        return t

    t_attn = max(attn_leg(c) for c in shard_classes)
    t_exp = 0.0
    for experts, dev in zip(placement, shard_classes):
        copies = sum(p[e] for e in experts) * batch * k
        n_act = sum(a[e] for e in experts)
        t_exp = max(t_exp, gemm_time(2 * copies * d * f * n_mats,
                                     BYTES * n_act * d * f * n_mats, dev))
    t_comm = 0.0
    if link_bw:
        ep_size = max(len(placement), 1)
        t_wire = a2a_time(cfg, batch, link_bw, ep_size, ep_size)
        t_comm = 2 * exposed_comm(t_wire, t_exp, n_chunks)
    t = cfg.n_layers * (t_attn + t_exp + t_comm)
    t += max(gemm_time(2 * batch * d * cfg.vocab_size,
                       BYTES * d * cfg.vocab_size, c)
             for c in shard_classes)
    return t


def expert_param_bytes(cfg: ModelConfig) -> int:
    """Expert weight residency (wi_gate+wi_up+wo, every layer, bf16) —
    what replicated serving charges EVERY decode device and EP sharding
    divides by ep_size (assumes every layer is MoE, like the serve-mode
    step-time models above)."""
    n_mats = 3 if cfg.mlp_act == "swiglu" else 2
    return cfg.n_layers * cfg.n_experts * n_mats * cfg.d_model \
        * cfg.d_ff_expert * BYTES


def kv_page_bytes(cfg: ModelConfig, page_size: int) -> int:
    """Payload bytes of one physical KV page across every attention
    layer's pools (k + v in bf16 plus the int32 position pool) — what one
    page costs on the handoff link."""
    per_layer = 2 * page_size * cfg.n_kv_heads * cfg.head_dim * BYTES \
        + page_size * 4
    return cfg.n_layers * per_layer


def serve_profile(cfg: ModelConfig, attn_class: DeviceClass,
                  exp_class: DeviceClass, *, chunk: int, ctx: int,
                  decode_batch: int, page_size: int = 16,
                  link_bw: Optional[float] = None) -> ServeProfile:
    """Profile both classes for both serving roles (the planner needs the
    off-role times too: a unified deployment runs BOTH phases on the
    slower class's clock)."""
    bw = link_bw if link_bw else min(attn_class.link_bw, exp_class.link_bw)
    return ServeProfile(
        t_prefill_chunk_attn=prefill_chunk_time(cfg, chunk, ctx, attn_class),
        t_prefill_chunk_exp=prefill_chunk_time(cfg, chunk, ctx, exp_class),
        t_decode_step_attn=decode_step_time(cfg, decode_batch, ctx,
                                            attn_class),
        t_decode_step_exp=decode_step_time(cfg, decode_batch, ctx,
                                           exp_class),
        t_page=kv_page_bytes(cfg, page_size) / bw,
        chunk=chunk, decode_batch=decode_batch)


# ---------------------------------------------------------------------------
# Memory estimation -> n_min / n_max for Asym-EA
# ---------------------------------------------------------------------------

def expert_memory_bytes(cfg: ModelConfig, tokens_per_expert: int) -> float:
    """Weights + grads + Adam states + activations for ONE expert FFN."""
    n_mats = 3 if cfg.mlp_act == "swiglu" else 2
    w = n_mats * cfg.d_model * cfg.d_ff_expert
    weight_grad_opt = w * (BYTES + BYTES + 8)  # bf16 w, bf16 g, f32 m+v
    acts = tokens_per_expert * cfg.d_ff_expert * BYTES * 2  # ckpt boundary
    return weight_grad_opt + acts


def attention_side_memory_bytes(cfg: ModelConfig, tokens_per_gpu: int) -> float:
    """Non-expert params + states + activations per attention GPU."""
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    per_layer = d * (2 * h * hd + 2 * kh * hd) + 2 * d
    if cfg.is_moe:
        per_layer += d * cfg.n_experts
    w = per_layer * cfg.n_layers + 2 * cfg.vocab_size * d
    weight_grad_opt = w * (BYTES + BYTES + 8)
    # activation checkpointing: one activation per layer boundary + working set
    acts = cfg.n_layers * tokens_per_gpu * d * BYTES \
        + 6 * tokens_per_gpu * d * BYTES
    return weight_grad_opt + acts


def asym_ea_memory_bounds(cfg: ModelConfig, zp: ZPGroupShape,
                          global_batch: int, seq_len: int,
                          num_microbatches: int):
    """(n_min, n_max): total experts that MUST / CAN move to attention GPUs.

    n_min: experts that do not fit on the N expert GPUs (summed over layers).
    n_max: spare capacity per attention GPU in expert units.
    """
    mb_tokens = global_batch * seq_len // num_microbatches
    tokens_per_expert = mb_tokens * max(cfg.top_k, 1) // max(cfg.n_experts, 1)
    e_mem = expert_memory_bytes(cfg, tokens_per_expert)
    total_expert_mem = cfg.n_layers * cfg.n_experts * e_mem
    exp_capacity = zp.N * zp.exp_class.mem_bytes * 0.9
    n_min = max(0, math.ceil((total_expert_mem - exp_capacity) / e_mem))

    a_mem = attention_side_memory_bytes(cfg, mb_tokens // zp.M)
    spare = zp.attn_class.mem_bytes * 0.9 - a_mem
    n_max_per_gpu = max(0, int(spare // e_mem))
    return n_min, n_max_per_gpu * zp.M
