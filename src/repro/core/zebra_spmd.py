"""Zebra parallelism — single-program (SPMD) engine.

The paper's ZP overlaps (a) attention compute of microbatch k with expert
compute of microbatch k-1 and (b) compute with dispatch/combine all-to-alls,
using CUDA streams. The TPU/XLA adaptation: the MoE layer is executed as a
``lax.scan`` software pipeline whose step k computes

    attention(mb k)     ||     dispatch+experts+combine(mb k-1)

with no data dependence between the two halves — XLA's async scheduler then
overlaps them and the collectives, which is the TPU-native equivalent of
multi-stream scheduling (DESIGN.md §2). Autodiff of the scan reverses the
pipeline, reproducing the paper's backward zigzag for free.

Two expert-parallel dispatch modes (ZebraConfig.mode):

  * "alltoall"   — paper-faithful EP: token batch sharded over the expert
    ("model") axis too; tokens are capacity-packed per expert and exchanged
    with ``lax.all_to_all`` (dispatch), computed on their expert shard, and
    exchanged back (combine). Microbatching requires global_batch >=
    R * n_batch_shards. With ``n_chunks > 1`` the dispatch buffer streams
    in capacity chunks double-buffered against the expert GEMMs, and with
    ``offload_experts > 0`` the leading experts stay replicated
    attention-side, folded into the first chunk's unified grouped GEMM
    (DESIGN.md §8).
  * "replicated" — TPU-native hybrid (TP attention + EP experts): batch is
    sharded over "data" only, so activations are replicated across the
    expert axis; each expert shard *selects* its own tokens locally (the
    dispatch all-to-all becomes free) and partial outputs are combined with
    a psum. Enables zebra pipelining at full-pod scale where the per-chip
    batch is 1 sequence.

Both modes are numerically equivalent to models/modules.apply_moe up to
capacity drops (tests use capacity_factor >= n_experts/top_k for equality).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.models import modules
from repro.models.config import LayerSpec, ModelConfig
from repro.models.modules import RunConfig


@dataclasses.dataclass(frozen=True)
class ZebraConfig:
    num_microbatches: int = 4
    mode: str = "replicated"  # replicated | alltoall
    ep_axis: str = "model"
    batch_axes: tuple = ("data",)  # axes the token batch is sharded over
    capacity_factor: float = 1.25
    pipeline: bool = True  # False -> sequential EP (paper's "EP"/DistEP)
    # Chunked dispatch (alltoall mode): the [E, C, d] dispatch buffer is
    # split into n_chunks capacity slices; the all-to-all of chunk k+1 has
    # no data dependence on the expert GEMM of chunk k, so XLA's async
    # scheduler double-buffers communication under compute (DESIGN.md §8).
    n_chunks: int = 1
    # Combine-side chunk count (alltoall mode), decoupled from dispatch:
    # combine cotangents are f32 in the backward — 2x the wire volume of
    # the bf16 dispatch at equal chunk count — so the reverse all-to-all
    # needs finer slicing to hide under the same expert compute. None
    # defaults to 2x the dispatch chunks (1 when dispatch is serialized);
    # must be a multiple of n_chunks so every dispatch chunk's output
    # splits into whole combine sub-chunks.
    n_chunks_combine: Optional[int] = None
    # Asym-EA-style offload (alltoall mode): experts [0, offload_experts)
    # live replicated on every shard ("attention-side"); their tokens skip
    # the all-to-all entirely and their GEMM is folded into the FIRST
    # chunk's unified grouped call (ops.moe_ffn_packed_multi), filling the
    # bubble while chunk 0 of the remote dispatch is in flight.
    offload_experts: int = 0


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Local capacity packing (shared by both modes)
# ---------------------------------------------------------------------------

def _pack(x, idx, E: int, C: int):
    """Pack tokens into fixed [E, C, d] buffers by routed expert.

    x: [T, d]; idx: [T, k]. Returns (buf [E,C,d], meta). Tokens beyond
    capacity are dropped (residual passthrough, standard GShard semantics).

    All d-wide data movement is GATHERS driven by cheap int32 index maps
    (scatters of [*, d] values are slow on TPU and are charged ~2x the
    traffic in the HLO byte model).
    """
    T, d = x.shape
    k = idx.shape[1]
    flat = idx.reshape(-1)
    order = jnp.argsort(flat, stable=True)
    sorted_e = jnp.take(flat, order)
    counts = jnp.bincount(flat, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < C
    slot = sorted_e * C + jnp.where(keep, pos_in_e, 0)
    tok = order // k
    # slot -> source-row map (cheap int32 scatter; dropped entries write to
    # a trash slot so they can never shadow a kept slot). Row T of the
    # padded source is the zero row.
    slot_or_trash = jnp.where(keep, slot, E * C)
    idx_map = jnp.full((E * C + 1,), T, jnp.int32).at[slot_or_trash].set(
        tok.astype(jnp.int32))[:E * C]
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    buf = jnp.take(x_pad, idx_map, axis=0)  # [E*C, d] gather
    return buf.reshape(E, C, d), (tok, slot, keep, order)


def _unpack(buf, meta, weights, T: int):
    """Weighted combine back to [T, d] — inverse-permutation gather +
    reshape-sum over the k copies (no d-wide scatter)."""
    tok, slot, keep, order = meta
    d = buf.shape[-1]
    k = order.shape[0] // T
    vals = jnp.take(buf.reshape(-1, d), slot, axis=0)  # [T*k, d] sorted
    w = jnp.take(weights.reshape(-1), order)
    vals = vals * jnp.where(keep, w, 0.0).astype(vals.dtype)[:, None]
    inv = jnp.argsort(order)  # inverse permutation -> token-major order
    return jnp.take(vals, inv, axis=0).reshape(T, k, d).sum(axis=1)


def _experts_dense(wi_gate, wi_up, wo, buf, cd, use_kernel: bool = False):
    """Per-expert FFN over packed buffers. buf: [E_loc, C, d].

    The capacity-packed buffer is ALREADY the tile-aligned packed domain
    (uniform C rows per expert), so with use_kernel it feeds straight into
    the fused grouped-GEMM pipeline (ops.moe_ffn_packed) with no sort, no
    pack scatter and no unpack gather; otherwise a batched einsum, which is
    what XLA schedules best on non-Pallas backends.
    """
    if use_kernel:
        from repro.kernels import ops as kops  # lazy: avoid cycles
        return kops.moe_ffn_packed(buf, wi_gate.astype(cd),
                                   wi_up.astype(cd), wo.astype(cd),
                                   use_kernel=True)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wi_gate.astype(cd)))
    u = jnp.einsum("ecd,edf->ecf", buf, wi_up.astype(cd))
    return jnp.einsum("ecf,efd->ecd", g * u, wo.astype(cd))


# ---------------------------------------------------------------------------
# Expert-parallel MoE FFN (shard_map)
# ---------------------------------------------------------------------------

def make_ep_moe(mesh: Mesh, cfg: ModelConfig, run: RunConfig,
                zcfg: ZebraConfig) -> Callable:
    """Returns moe_fn(ffn_params, x2d [T,d]) -> (y2d, aux), sharded."""
    E = cfg.n_experts
    k = cfg.top_k
    ep = zcfg.ep_axis
    n_ep = mesh.shape[ep]
    n_loc = zcfg.offload_experts if zcfg.mode == "alltoall" else 0
    E_rem = E - n_loc
    assert 0 <= n_loc < E, f"offload_experts {n_loc} out of range for E={E}"
    assert E_rem % n_ep == 0, \
        f"remote experts {E_rem} must divide over {ep}={n_ep}"
    E_loc = E_rem // n_ep
    Q = max(int(zcfg.n_chunks), 1)
    Qc = zcfg.n_chunks_combine if zcfg.n_chunks_combine \
        else (2 * Q if Q > 1 else 1)
    Qc = max(int(Qc), Q)
    assert Qc % Q == 0, \
        f"n_chunks_combine {Qc} must be a multiple of n_chunks {Q}"
    cd = run.policy.compute_dtype

    ba = tuple(zcfg.batch_axes)
    if zcfg.mode == "alltoall" and ep not in ba:
        ba = ba + (ep,)
    batch_spec = P(ba, None)
    from repro.sharding.rules import ep_ffn_specs
    ffn_specs = ep_ffn_specs(ep, offload=n_loc > 0)

    def local_route(router_w, x):
        weights, idx, aux = modules.moe_route(router_w, cfg, run.policy, x)
        # aux losses are means over the (sharded) token dim -> pmean.
        aux = {k_: jax.lax.pmean(v, ba) for k_, v in aux.items()}
        return weights, idx, aux

    if zcfg.mode == "replicated":
        def fn(ffn, x):  # x: [T_loc, d] (replicated over ep axis)
            T = x.shape[0]
            weights, idx, aux = local_route(ffn["router"], x)
            my = jax.lax.axis_index(ep)
            e_off = my * E_loc
            local = (idx >= e_off) & (idx < e_off + E_loc)
            idx_loc = jnp.where(local, idx - e_off, E_loc)  # E_loc = drop
            C = max(_round_up(int(T * k / E * zcfg.capacity_factor), 8), 8)
            buf, meta = _pack(x, idx_loc, E_loc + 1, C)
            out = _experts_dense(ffn["wi_gate"], ffn["wi_up"], ffn["wo"],
                                 buf[:E_loc], cd,
                                 use_kernel=run.use_gmm_kernel)
            out = jnp.concatenate(
                [out, jnp.zeros((1, C, x.shape[1]), out.dtype)], axis=0)
            y = _unpack(out, meta, weights, T)
            y = jax.lax.psum(y, ep)  # combine partial expert outputs
            return y, aux

    else:  # alltoall: chunked, double-buffered packed-domain dispatch
        from repro.kernels import ops as kops  # lazy: avoid cycles
        # The alltoall hop always rides the ops.moe_ffn machinery (not the
        # replicated mode's batched einsum): the unified local+remote call
        # and the per-chunk slices need its tile_group metadata, and its
        # recompute-backward custom_vjp keeps only chunk INPUTS resident —
        # with n_chunks > 1 an autodiff einsum would store every chunk's
        # activations across the whole unrolled pipeline instead.
        uk = True if run.use_gmm_kernel else None  # None -> backend default

        def remote_ffn(ffn, r):
            return kops.moe_ffn_packed(r, ffn["wi_gate"].astype(cd),
                                       ffn["wi_up"].astype(cd),
                                       ffn["wo"].astype(cd), use_kernel=uk)

        def fn(ffn, x):  # x: [T_loc, d], batch sharded over ep axis as well
            T, d = x.shape
            weights, idx, aux = local_route(ffn["router"], x)
            C0 = max(_round_up(int(T * k / E * zcfg.capacity_factor), 8), 8)
            # Capacity padded so it splits into Qc equal sublane-aligned
            # COMBINE sub-chunks (pad rows are zero and inert end to end);
            # each dispatch chunk covers Qc/Q of them.
            C, Cqc = kops.chunk_capacity(C0, Qc)
            Cq = C // Q
            buf, meta = _pack(x, idx, E, C)  # [E, C, d] — packed domain
            loc = buf[:n_loc]                # local (offloaded) experts
            rem = buf[n_loc:].reshape(n_ep, E_loc, C, d)
            # Dispatch: one all-to-all per capacity chunk, all issued
            # before any expert GEMM — chunk q+1's exchange has no data
            # dependence on chunk q's compute, so the collectives hide
            # behind expert compute instead of preceding it (the backward
            # of this unrolled loop transposes chunk-by-chunk and keeps
            # the same independence, mirroring the overlap).
            recv = [jax.lax.all_to_all(
                        jax.lax.dynamic_slice_in_dim(rem, q * Cq, Cq, axis=2),
                        ep, split_axis=0, concat_axis=0, tiled=False)
                    for q in range(Q)]
            outs = []
            for q in range(Q):
                r = jnp.swapaxes(recv[q], 0, 1).reshape(E_loc, n_ep * Cq, d)
                if q == 0 and n_loc:
                    # Local + remote experts in ONE grouped GEMM per
                    # projection direction: the offloaded experts' GEMM
                    # fills the bubble while later chunks are in flight.
                    out_l, o = kops.moe_ffn_packed_multi(
                        [loc, r],
                        [ffn["wi_gate_loc"].astype(cd),
                         ffn["wi_gate"].astype(cd)],
                        [ffn["wi_up_loc"].astype(cd),
                         ffn["wi_up"].astype(cd)],
                        [ffn["wo_loc"].astype(cd), ffn["wo"].astype(cd)],
                        use_kernel=uk)
                else:
                    o = remote_ffn(ffn, r)
                # Combine: chunk q's reverse all-to-alls are issued before
                # chunk q+1's GEMM — same hiding on the way back, at the
                # FINER combine granularity (Qc/Q sub-chunks per dispatch
                # chunk): the backward transposes these into the f32
                # cotangent dispatch, whose 2x volume is why combine
                # defaults to twice the dispatch chunk count.
                o = jnp.swapaxes(o.reshape(E_loc, n_ep, Cq, d), 0, 1)
                for s in range(Qc // Q):
                    outs.append(jax.lax.all_to_all(
                        o[:, :, s * Cqc:(s + 1) * Cqc], ep, split_axis=0,
                        concat_axis=0, tiled=False))
            back = outs[0] if len(outs) == 1 else \
                jnp.concatenate(outs, axis=2)
            out_full = back.reshape(E_rem, C, d)
            if n_loc:
                # Combine consumes ONE packed [E, C, d] output.
                out_full = jnp.concatenate([out_l.astype(out_full.dtype),
                                            out_full], axis=0)
            y = _unpack(out_full, meta, weights, T)
            return y, aux

    in_specs = (ffn_specs, batch_spec)
    out_specs = (batch_spec, P())

    def moe_fn(ffn_params, x2d):
        fp = {"router": ffn_params["router"]}
        for k_ in ("wi_gate", "wi_up", "wo"):
            if n_loc:
                fp[k_ + "_loc"] = ffn_params[k_][:n_loc]
            fp[k_] = ffn_params[k_][n_loc:]
        sm = _shard_map(fn, mesh, in_specs, out_specs)
        return sm(fp, x2d)

    return moe_fn


# ---------------------------------------------------------------------------
# Zebra-pipelined MoE layer (the layer_override for models/stack.py)
# ---------------------------------------------------------------------------

def make_layer_override(mesh: Mesh, cfg: ModelConfig, run: RunConfig,
                        zcfg: ZebraConfig) -> Callable:
    """Build the stack-level layer override implementing zebra parallelism."""
    moe_fn = make_ep_moe(mesh, cfg, run, zcfg)

    def override(layer_params, spec: LayerSpec, x, positions):
        B, S, d = x.shape
        R = zcfg.num_microbatches if zcfg.pipeline else 1
        while R > 1 and B % R:
            R -= 1

        def attn_part(mb_x, mb_pos):
            h, _ = modules.apply_mixer_part(layer_params, cfg, run, spec,
                                            mb_x, mb_pos)
            u = modules.apply_norm(layer_params["norm2"], h, run.policy)
            return h, u

        def expert_part(h, u):
            y2, aux = moe_fn(layer_params["ffn"], u.reshape(-1, d))
            return h + y2.reshape(h.shape).astype(h.dtype), aux

        if R == 1:
            h, u = attn_part(x, positions)
            y, aux = expert_part(h, u)
            return y, aux

        xs = x.reshape(R, B // R, S, d)
        ps = positions.reshape(R, B // R, S)

        h0, u0 = attn_part(xs[0], ps[0])

        def body(carry, inp):
            h_prev, u_prev = carry
            mb_x, mb_pos = inp
            # These two halves are data-independent: XLA overlaps the expert
            # compute + collectives of mb k-1 with attention of mb k.
            y_prev, aux = expert_part(h_prev, u_prev)
            h_k, u_k = attn_part(mb_x, mb_pos)
            return (h_k, u_k), (y_prev, aux)

        if cfg.unroll:
            carry = (h0, u0)
            ys_l, auxs_l = [], []
            for kk in range(1, R):
                carry, (y_prev, a) = body(carry, (xs[kk], ps[kk]))
                ys_l.append(y_prev)
                auxs_l.append(a)
            ys = jnp.stack(ys_l)  # R >= 2 here
            auxs = jax.tree.map(lambda *vs: jnp.stack(vs), *auxs_l)
            h_l, u_l = carry
        else:
            (h_l, u_l), (ys, auxs) = jax.lax.scan(body, (h0, u0),
                                                  (xs[1:], ps[1:]))
        y_last, aux_last = expert_part(h_l, u_l)
        y = jnp.concatenate([ys, y_last[None]], axis=0).reshape(B, S, d)
        # aux losses are per-token means: average them over microbatches.
        aux = jax.tree.map(lambda a, b: (jnp.sum(a, axis=0) + b) / R, auxs,
                           aux_last)
        return y, aux

    return override
