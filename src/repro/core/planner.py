"""ZP-group planning: profile -> Asym-EA -> simulate -> pick.

The Optimizer box of the paper's Fig. 3: given a ZP group (M attention
devices of one class, N expert devices of another), a model and batch
geometry, it produces a `ZebraPlan` — microbatch count, per-layer Asym-EA
offloads, and the predicted iteration time / utilizations — by running
Algorithm 1 on profiler outputs and validating candidates in the simulator.
Also provides the elastic replanning entry point used by repro.ft.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core import profiler as P
from repro.core import simulator as sim
from repro.core.asym_ea import (AsymEAPlan, asym_ea_offload, divisibility_ok)
from repro.core.hardware import DeviceClass
from repro.core.profiler import LayerTimes, ZPGroupShape
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ZebraPlan:
    zp: ZPGroupShape
    R: int
    offload: tuple
    times: LayerTimes
    comm: sim.CommTimes
    predicted: sim.SimResult
    predicted_no_asym: sim.SimResult
    n_min: int
    n_max: int
    n_chunks: int = 1  # dispatch chunking the prediction was priced at

    @property
    def tokens_per_iter(self) -> int:
        return self._tokens

    def throughput(self, global_batch: int, seq_len: int) -> float:
        return global_batch * seq_len / self.predicted.iter_time


def plan_zp_group(cfg: ModelConfig, zp: ZPGroupShape, global_batch: int,
                  seq_len: int, R: Optional[int] = None,
                  candidates: Sequence[int] = (2, 4, 8, 16),
                  use_asym: bool = True, n_chunks: Optional[int] = None,
                  chunk_candidates: Sequence[int] = (1, 2, 4)) -> ZebraPlan:
    """Pick (R, n_chunks, offload) minimizing simulated iteration time.

    Dispatch chunking is priced through the overlap-aware cost model: the
    link streams carry only the EXPOSED all-to-all residue (DESIGN.md §8),
    and the same residue — not the full wire time — feeds Algorithm 1's
    bubble estimate so Asym-EA no longer offloads experts to pay for
    communication that chunking already hid."""
    best = None
    rs = [R] if R else [r for r in candidates if global_batch % r == 0] or [1]
    qs = [n_chunks] if n_chunks else list(chunk_candidates) or [1]
    link_bw = min(zp.attn_class.link_bw, zp.exp_class.link_bw)
    for r in rs:
        times = P.profile_layer(cfg, zp, global_batch, seq_len, r,
                                link_bw=link_bw)
        # The overlap-aware LayerTimes is the single source of the a2a
        # wire times; CommTimes is just its simulator-facing view.
        comm = sim.CommTimes(dispatch=times.t_dispatch,
                             combine=times.t_combine)
        n_min, n_max = P.asym_ea_memory_bounds(cfg, zp, global_batch,
                                               seq_len, r)
        # express n_max in per-expert-GPU units (sum(O) bound; see asym_ea)
        n_max_units = n_max // max(zp.N, 1)
        for q in qs:
            no_asym = sim.simulate_hetermoe(cfg, times, comm, r, zp.M, zp.N,
                                            n_chunks=q)
            chosen = no_asym
            offload = tuple([0] * cfg.n_layers)
            if use_asym and cfg.is_moe and divisibility_ok(zp.M, zp.N):
                exposed = (sim.exposed_comm(comm.dispatch, times.t_exp, q)
                           + sim.exposed_comm(comm.combine, times.t_exp, q))
                try:
                    plan = asym_ea_offload(
                        cfg.n_experts, cfg.n_layers, zp.M, zp.N,
                        t_attn=times.t_attn, t_exp_attn=times.t_exp_attn,
                        t_exp=times.t_exp, n_min=n_min // max(zp.N, 1),
                        n_max=n_max_units, t_comm_exposed=exposed)
                    with_asym = sim.simulate_hetermoe(cfg, times, comm, r,
                                                      zp.M, zp.N, plan,
                                                      n_chunks=q)
                    if with_asym.iter_time < chosen.iter_time:
                        chosen = with_asym
                        offload = plan.offload
                except ValueError:
                    pass
            zp_plan = ZebraPlan(zp=zp, R=r, offload=offload, times=times,
                                comm=comm, predicted=chosen,
                                predicted_no_asym=no_asym, n_min=n_min,
                                n_max=n_max, n_chunks=q)
            if best is None or chosen.iter_time < best.predicted.iter_time:
                best = zp_plan
    return best


def sweep_ratios(cfg: ModelConfig, attn_class: DeviceClass,
                 exp_class: DeviceClass, M: int, Ns: Sequence[int],
                 global_batch: int, seq_len: int,
                 n_chunks: Optional[int] = None):
    """Fig. 10: HeterMoE throughput vs expert-GPU count at fixed M.
    Pass n_chunks=1 for the paper-faithful serialized-dispatch model."""
    out = {}
    for N in Ns:
        zp = ZPGroupShape(M=M, N=N, attn_class=attn_class,
                          exp_class=exp_class)
        out[N] = plan_zp_group(cfg, zp, global_batch, seq_len,
                               n_chunks=n_chunks)
    return out


# ---------------------------------------------------------------------------
# Disaggregated-serving planning (DESIGN.md §10)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DisaggPlan:
    """Role assignment for a heterogeneous serving group: which devices
    prefill and which decode, plus the simulated evidence for the pick."""

    zp: ZPGroupShape
    prefill_attn: int   # attention-class devices assigned to prefill
    prefill_exp: int    # expert-class devices assigned to prefill
    profile: P.ServeProfile
    predicted: sim.ServeSimResult
    predicted_unified: sim.ServeSimResult
    expected_hit_ratio: float = 0.0  # prefix-cache discount the plan assumed

    @property
    def decode_attn(self) -> int:
        return self.zp.M - self.prefill_attn

    @property
    def decode_exp(self) -> int:
        return self.zp.N - self.prefill_exp

    @property
    def goodput_ratio(self) -> float:
        u = self.predicted_unified.goodput
        return self.predicted.goodput / u if u > 0 else float("inf")

    @property
    def ttft_ratio(self) -> float:
        d = self.predicted.ttft_p50
        return self.predicted_unified.ttft_p50 / d if d > 0 else float("inf")


def plan_disagg_group(cfg: ModelConfig, zp: ZPGroupShape, trace, *,
                      prefill_chunk: int = 256, ctx: int = 2048,
                      slots_per_device: int = 8,
                      page_size: int = 16,
                      expected_hit_ratio: float = 0.0) -> DisaggPlan:
    """Pick the prefill:decode device split maximizing simulated goodput —
    the serving analogue of Asym-EA's offload sweep (same shape: profile
    both classes on both roles, sweep assignments, validate candidates in
    the simulator, keep the best).

    ``trace`` is a list of :class:`~repro.core.simulator.ServeRequest`.
    The unified baseline runs the whole mixed group as ONE lockstep
    data-parallel engine (slowest class paces both phases); disagg
    candidates assign ``a`` attention-class + ``e`` expert-class devices
    to prefill (that many parallel batch-1 streams) and the rest to
    decode, paying the page-handoff wire time per migrated request.

    ``expected_hit_ratio`` (in [0, 1)) is the anticipated prefix-cache hit
    fraction, e.g. a measured ``PrefixCache`` hit rate from a prior run or
    the deployment's known prompt-template overlap. Cache-hit tokens skip
    prefill compute entirely (the disagg engine's cached-admit path even
    skips the page handoff for them), so the prefill leg — chunk time AND
    handoff volume — is discounted by ``1 - hit`` while the decode leg is
    untouched; a high-hit workload therefore plans fewer prefill devices
    and banks the freed devices as decode slots."""
    if not 0.0 <= expected_hit_ratio < 1.0:
        raise ValueError(f"expected_hit_ratio must be in [0, 1), "
                         f"got {expected_hit_ratio}")
    prof = P.serve_profile(cfg, zp.attn_class, zp.exp_class,
                           chunk=prefill_chunk, ctx=ctx,
                           decode_batch=slots_per_device,
                           page_size=page_size)
    discount = 1.0 - expected_hit_ratio
    avg_prompt = sum(r.prompt for r in trace) / max(len(trace), 1)
    t_handoff = -(-avg_prompt // page_size) * prof.t_page * discount

    unified = sim.simulate_serve_trace(
        trace, prefill_chunk=prefill_chunk,
        t_prefill_chunk=max(prof.t_prefill_chunk_attn,
                            prof.t_prefill_chunk_exp) * discount,
        t_decode_step=max(prof.t_decode_step_attn, prof.t_decode_step_exp),
        decode_slots=slots_per_device * (zp.M + zp.N), colocated=True)

    best = None
    for a in range(zp.M + 1):
        for e in range(zp.N + 1):
            n_pre, n_dec = a + e, (zp.M - a) + (zp.N - e)
            if n_pre < 1 or n_dec < 1:
                continue
            t_chunk = max([prof.t_prefill_chunk_attn] * (a > 0) +
                          [prof.t_prefill_chunk_exp] * (e > 0)) * discount
            t_step = max([prof.t_decode_step_attn] * (zp.M - a > 0) +
                         [prof.t_decode_step_exp] * (zp.N - e > 0))
            res = sim.simulate_serve_trace(
                trace, prefill_chunk=prefill_chunk, t_prefill_chunk=t_chunk,
                t_decode_step=t_step,
                decode_slots=slots_per_device * n_dec,
                n_prefill_streams=n_pre, t_handoff=t_handoff)
            cand = DisaggPlan(zp=zp, prefill_attn=a, prefill_exp=e,
                              profile=prof, predicted=res,
                              predicted_unified=unified,
                              expected_hit_ratio=expected_hit_ratio)
            if best is None or res.goodput > best.predicted.goodput \
                    or (res.goodput == best.predicted.goodput
                        and res.ttft_p50 < best.predicted.ttft_p50):
                best = cand
    return best


# ---------------------------------------------------------------------------
# EP decode-group placement planning (DESIGN.md §11)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EPDecodePlan:
    """Heterogeneity-aware expert placement for an EP-sharded decode
    group: which experts live on which device, plus the analytical and
    simulated evidence for the pick."""

    shard_classes: tuple
    hist: tuple
    placement: tuple          # asym_ea_place under the routing histogram
    uniform: tuple            # round-robin baseline
    t_step_planned: float
    t_step_uniform: float
    predicted: sim.ServeSimResult          # trace under planned placement
    predicted_uniform: sim.ServeSimResult  # same trace, round-robin
    expert_bytes_total: int
    expert_bytes_per_device: int

    @property
    def ep_size(self) -> int:
        return len(self.shard_classes)

    @property
    def placement_ratio(self) -> float:
        """Uniform / planned decode-step time (>1: planning won)."""
        return self.t_step_uniform / self.t_step_planned \
            if self.t_step_planned > 0 else float("inf")

    @property
    def placement_ratio_sim(self) -> float:
        """Uniform / planned simulated trace makespan (>1: planning won)."""
        p = self.predicted.makespan
        return self.predicted_uniform.makespan / p if p > 0 else float("inf")

    @property
    def hbm_reduction(self) -> float:
        """Replicated / per-device expert-weight residency (~ep_size)."""
        return self.expert_bytes_total / max(self.expert_bytes_per_device, 1)


def plan_ep_decode_group(cfg: ModelConfig, shard_classes: Sequence,
                         hist: Sequence[float], trace, *,
                         decode_batch: int = 8, ctx: int = 2048,
                         prefill_chunk: int = 256, n_chunks: int = 1,
                         link_bw: Optional[float] = None) -> EPDecodePlan:
    """Asym-EA for serving (DESIGN.md §11): place experts across a
    heterogeneous decode group under an observed routing histogram.

    Decode is weight-read bound, so an expert's load is its probability of
    being ACTIVATED by a batched step — ``1-(1-p_e)^(B*k)`` — and a shard's
    speed for that load is its class's HBM bandwidth. Greedy LPT
    (asym_ea_place) sends hot experts to the high-bandwidth class; the
    round-robin baseline and the planned placement are then priced by
    ``profiler.ep_decode_step_time`` and replayed through
    ``simulate_serve_trace`` on the same trace, so ``placement_ratio_sim``
    carries end-to-end (not just per-step) evidence."""
    from repro.core.asym_ea import (asym_ea_place, placement_speeds,
                                    round_robin_placement)
    if not cfg.is_moe:
        raise ValueError("EP decode planning needs a MoE config")
    ep_size = len(shard_classes)
    if ep_size < 1 or cfg.n_experts % ep_size:
        raise ValueError(
            f"ep_size={ep_size} must divide n_experts={cfg.n_experts}")
    tot = sum(hist) or 1.0
    p = [x / tot for x in hist]
    bk = decode_batch * max(cfg.top_k, 1)
    loads = [1.0 - (1.0 - pe) ** bk for pe in p]
    # Arithmetic intensity of one expert's GEMM ≈ rows per ACTIVATED expert
    # (bf16: 2*m flops per 2 weight bytes → flops/byte = m). At realistic
    # decode batches this stays far left of the roofline knee, so speeds
    # reduce to HBM bandwidth — but a compute-weak class (gemm_eff) now
    # caps out honestly instead of being priced at full bandwidth.
    fpb = bk / max(sum(loads), 1e-9)
    placement = asym_ea_place(loads,
                              placement_speeds(shard_classes,
                                               flops_per_byte=fpb),
                              cfg.n_experts // ep_size)
    uniform = round_robin_placement(cfg.n_experts, ep_size)

    def step_time(pl):
        return P.ep_decode_step_time(cfg, decode_batch, ctx, pl,
                                     shard_classes, p, n_chunks=n_chunks,
                                     link_bw=link_bw)

    t_planned, t_uniform = step_time(placement), step_time(uniform)
    # Shared prefill clock: both deployments prefill identically (EP only
    # reshapes the decode-time expert hop), so any consistent chunk time
    # keeps the simulated comparison placement-only.
    t_chunk = max(P.prefill_chunk_time(cfg, prefill_chunk, ctx, c)
                  for c in shard_classes)

    def replay(t_step):
        return sim.simulate_serve_trace(
            trace, prefill_chunk=prefill_chunk, t_prefill_chunk=t_chunk,
            t_decode_step=t_step, decode_slots=decode_batch, colocated=True)

    total = P.expert_param_bytes(cfg)
    return EPDecodePlan(
        shard_classes=tuple(shard_classes), hist=tuple(p),
        placement=placement, uniform=uniform,
        t_step_planned=t_planned, t_step_uniform=t_uniform,
        predicted=replay(t_planned), predicted_uniform=replay(t_uniform),
        expert_bytes_total=total,
        expert_bytes_per_device=-(-total // ep_size))


# ---------------------------------------------------------------------------
# Fleet planning (DESIGN.md §12)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FleetPlan:
    """Static role split for a heterogeneous serving fleet plus the
    simulated evidence that elastic reassignment beats it."""

    classes: tuple            # device class per group (by gid)
    roles: tuple              # best static role per group ('prefill'|'decode')
    predicted_static: object       # FleetSimResult of the best static split
    predicted_elastic: object      # same trace, elastic flips enabled
    slo_ttft: float
    slo_itl: float

    @property
    def n_prefill(self) -> int:
        return sum(r == "prefill" for r in self.roles)

    @property
    def n_decode(self) -> int:
        return sum(r == "decode" for r in self.roles)

    @property
    def goodput_ratio_sim(self) -> float:
        """Elastic / best-static goodput-under-SLO (>1: elastic won)."""
        s = self.predicted_static.goodput_under_slo
        e = self.predicted_elastic.goodput_under_slo
        return e / s if s > 0 else float("inf")


def plan_fleet(cfg: ModelConfig, group_classes: Sequence[DeviceClass],
               trace, *, prefill_chunk: int = 256, ctx: int = 2048,
               decode_slots: int = 8, page_size: int = 16,
               slo_ttft: float, slo_itl: float,
               control_dt: float = 1.0, flip_delay: float = 0.5,
               link_bw: Optional[float] = None) -> FleetPlan:
    """Sweep every static prefill:decode role assignment of
    ``group_classes`` (≥1 group per role) through the fleet simulator,
    keep the split with the best goodput-under-SLO, then replay the same
    trace with elastic role flips enabled from that split — the fleet
    analogue of Asym-EA's offload sweep, with ``goodput_ratio_sim`` as
    the evidence that reassignment beats any static answer on a
    diurnal trace whose bottleneck role shifts over time."""
    from repro.serve.fleet.sim import SimGroup, simulate_fleet_trace
    if len(group_classes) < 2:
        raise ValueError("a fleet needs at least 2 groups (1 per role)")
    bw = link_bw or min(c.link_bw for c in group_classes)
    avg_prompt = sum(r.prompt for r in trace) / max(len(trace), 1)
    t_handoff = -(-avg_prompt // page_size) * \
        (P.kv_page_bytes(cfg, page_size) / bw)
    t_pre = {c.name: P.prefill_chunk_time(cfg, prefill_chunk, ctx, c)
             for c in group_classes}
    t_dec = {c.name: P.decode_step_time(cfg, decode_slots, ctx, c)
             for c in group_classes}

    def make_groups(roles):
        return [SimGroup(gid=i, cls=c.name, role=roles[i],
                         t_prefill_chunk=t_pre[c.name],
                         t_decode_step=t_dec[c.name],
                         decode_slots=decode_slots)
                for i, c in enumerate(group_classes)]

    def run(roles, elastic):
        return simulate_fleet_trace(
            trace, make_groups(roles), prefill_chunk=prefill_chunk,
            t_handoff=t_handoff, elastic=elastic, control_dt=control_dt,
            flip_delay=flip_delay, slo_ttft=slo_ttft, slo_itl=slo_itl)

    n = len(group_classes)
    best_roles, best = None, None
    for mask in range(1, 2 ** n - 1):  # ≥1 prefill AND ≥1 decode
        roles = tuple("prefill" if mask >> i & 1 else "decode"
                      for i in range(n))
        res = run(roles, elastic=False)
        key = (res.goodput_under_slo, res.goodput, -res.ttft_p99)
        if best is None or key > best[0]:
            best_roles, best = roles, (key, res)
    elastic = run(best_roles, elastic=True)
    return FleetPlan(classes=tuple(c.name for c in group_classes),
                     roles=best_roles, predicted_static=best[1],
                     predicted_elastic=elastic,
                     slo_ttft=slo_ttft, slo_itl=slo_itl)


def replan(cfg: ModelConfig, plan: ZebraPlan, global_batch: int,
           seq_len: int, *, lost_attn: int = 0, lost_exp: int = 0,
           slow_factor: float = 1.0) -> ZebraPlan:
    """Elastic / straggler replanning (repro.ft): recompute the ZP plan for
    a shrunken group or a slowed expert class (straggler mitigation via
    expert re-placement — the same Asym-EA mechanism that balances
    generations also rebalances around degraded devices)."""
    exp_class = plan.zp.exp_class
    if slow_factor != 1.0:
        exp_class = dataclasses.replace(
            exp_class, name=exp_class.name + "-degraded",
            peak_flops=exp_class.peak_flops / slow_factor,
            hbm_bw=exp_class.hbm_bw / slow_factor)
    M = plan.zp.M - lost_attn
    N = plan.zp.N - lost_exp
    if M < 1 or N < 1:
        raise RuntimeError("ZP group no longer viable; trigger full restart")
    zp = ZPGroupShape(M=M, N=N, attn_class=plan.zp.attn_class,
                      exp_class=exp_class)
    # Keep the original plan's dispatch-chunking cost model so degraded
    # predictions stay comparable to the baseline they replace.
    return plan_zp_group(cfg, zp, global_batch, seq_len,
                         n_chunks=plan.n_chunks)
