"""Zebra-parallelism task schedule — Theorem 1 of the paper.

Tasks:  A (attention compute), E (expert compute), D (dispatch all-to-all),
C (combine all-to-all), H (head + loss + head-backward, attention group),
X (Asym-EA offloaded expert compute on attention GPUs).
Phases: F (forward) / B (backward).

Streams (per the paper's three-streams-per-GPU design, §4.1):
    attn_comp — A, H, X on attention GPUs
    exp_comp  — E on expert GPUs
    link_a2e  — D^F and C^B (attention -> expert direction)
    link_e2a  — C^F and D^B (expert -> attention direction)
Dispatch/combine ride different directions, hence never contend (paper).

The canonical per-stream orders below are exactly Theorem 1's; the
simulator computes start times from data dependencies + per-stream FIFO, and
the property test checks no valid reordering beats the canonical order.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, List, Tuple

Task = Tuple[str, str, int, int]  # (kind, phase, layer, microbatch)


def A(p, l, j): return ("A", p, l, j)  # noqa: E704


def E(p, l, j): return ("E", p, l, j)  # noqa: E704


def D(p, l, j): return ("D", p, l, j)  # noqa: E704


def C(p, l, j): return ("C", p, l, j)  # noqa: E704


def H(j): return ("H", "F", -1, j)  # noqa: E704


def X(p, l, j): return ("X", p, l, j)  # noqa: E704


STREAM_OF = {
    ("A", "F"): "attn_comp", ("A", "B"): "attn_comp",
    ("H", "F"): "attn_comp",
    ("X", "F"): "attn_comp", ("X", "B"): "attn_comp",
    ("E", "F"): "exp_comp", ("E", "B"): "exp_comp",
    ("D", "F"): "link_a2e", ("C", "B"): "link_a2e",
    ("C", "F"): "link_e2a", ("D", "B"): "link_e2a",
}


def stream_of(task: Task) -> str:
    return STREAM_OF[(task[0], task[1])]


@dataclasses.dataclass
class ZebraSchedule:
    L: int
    R: int
    offload: tuple  # per-layer o_l (0 = no Asym-EA at that layer)
    streams: Dict[str, List[Task]]
    # Sub-microbatch dispatch chunking (DESIGN.md §8): each D/C task is a
    # pipeline of n_chunks slices double-buffered against the matching E
    # task, so the simulator prices only its EXPOSED residue on the link
    # streams. Task ordering and dependencies are unchanged — chunking is
    # strictly finer-grained than the (layer, microbatch) task system.
    n_chunks: int = 1

    def all_tasks(self) -> List[Task]:
        return [t for s in self.streams.values() for t in s]


def dependencies(task: Task, L: int, offload: tuple) -> List[Task]:
    """Data-dependency predecessors of a task (paper §4.1 + Asym-EA §4.2)."""
    kind, phase, l, j = task
    has_x = offload[l] > 0 if 0 <= l < L else False
    deps: List[Task] = []
    if kind == "A" and phase == "F":
        if l > 0:
            deps.append(C("F", l - 1, j))
    elif kind == "D" and phase == "F":
        deps.append(A("F", l, j))
    elif kind == "E" and phase == "F":
        deps.append(D("F", l, j))
    elif kind == "X" and phase == "F":
        deps.append(D("F", l, j))  # needs tokens from other attention GPUs
    elif kind == "C" and phase == "F":
        deps.append(E("F", l, j))
        if has_x:
            deps.append(X("F", l, j))
    elif kind == "H":
        deps.append(C("F", L - 1, j))
    elif kind == "C" and phase == "B":
        deps.append(H(j) if l == L - 1 else A("B", l + 1, j))
    elif kind == "E" and phase == "B":
        deps.append(C("B", l, j))
    elif kind == "X" and phase == "B":
        deps.append(C("B", l, j))
    elif kind == "D" and phase == "B":
        deps.append(E("B", l, j))
        if has_x:
            deps.append(X("B", l, j))
    elif kind == "A" and phase == "B":
        deps.append(D("B", l, j))
    return deps


def canonical_schedule(L: int, R: int, offload: tuple = None,
                       n_chunks: int = 1) -> ZebraSchedule:
    """Theorem 1's optimal per-stream orders (+ Asym-EA X-task placement:
    offloaded expert compute goes after the layer's attention microbatches,
    paper §4.2). ``n_chunks`` records the sub-microbatch dispatch chunking
    the engines run with (see ZebraSchedule)."""
    offload = tuple(offload) if offload else tuple([0] * L)
    attn: List[Task] = []
    expc: List[Task] = []
    a2e: List[Task] = []
    e2a: List[Task] = []

    # ---- forward, layers 0..L-2
    for l in range(L - 1):
        attn += [A("F", l, j) for j in range(R)]
        if offload[l]:
            attn += [X("F", l, j) for j in range(R)]
        expc += [E("F", l, j) for j in range(R)]
        a2e += [D("F", l, j) for j in range(R)]
        e2a += [C("F", l, j) for j in range(R)]
    # ---- layer L-1: interleave fwd/bwd per microbatch (Theorem 1)
    lL = L - 1
    for j in range(R):
        attn += [A("F", lL, j)]
        if offload[lL]:
            attn += [X("F", lL, j)]
        attn += [H(j), A("B", lL, j)]
        expc += [E("F", lL, j), E("B", lL, j)]
        a2e += [D("F", lL, j), C("B", lL, j)]
        e2a += [C("F", lL, j), D("B", lL, j)]
        if offload[lL]:
            attn.insert(len(attn) - 1, X("B", lL, j))
    # ---- backward, layers L-2..0
    for l in range(L - 2, -1, -1):
        a2e += [C("B", l, j) for j in range(R)]
        expc += [E("B", l, j) for j in range(R)]
        if offload[l]:
            attn += [X("B", l, j) for j in range(R)]
        e2a += [D("B", l, j) for j in range(R)]
        attn += [A("B", l, j) for j in range(R)]

    return ZebraSchedule(L, R, offload, {
        "attn_comp": attn, "exp_comp": expc,
        "link_a2e": a2e, "link_e2a": e2a,
    }, n_chunks=max(int(n_chunks), 1))


def validate(sched: ZebraSchedule) -> None:
    """Check stream assignment and intra-stream dependency sanity."""
    for name, tasks in sched.streams.items():
        for t in tasks:
            assert stream_of(t) == name, (t, name)
        assert len(set(tasks)) == len(tasks), f"duplicate task in {name}"
    # Every dependency must exist somewhere.
    have = set(sched.all_tasks())
    for t in sched.all_tasks():
        for d in dependencies(t, sched.L, sched.offload):
            assert d in have, (t, d)
