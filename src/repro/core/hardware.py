"""Device-class models for the analytical profiler and simulator.

The container has no heterogeneous hardware, so the paper's profiler (§5) is
replaced by an analytical model per device class. The GPU classes carry
efficiency constants calibrated so the model reproduces the paper's Fig. 2
measurements (see tests/test_hardware_model.py):

  * A40 / V100 (Fig. 2a): experts — V100 ~80% of A40; attention — gap grows
    from ~1.7-2x at 4K to 3.7x at 64K (V100 lacks FlashAttention: its
    attention core runs at unfused-kernel efficiency).
  * L40S / T4 (Fig. 2b): MLP 7.0x; attention 9.9x @4K -> 13.6x @64K.

TPU classes use the brief's v5e constants (197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI); v5e plays the "new generation" role and v3 (no usable
fused attention path in this framing) plays the "old generation" role in the
heterogeneous multi-pod scenario.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DeviceClass:
    name: str
    peak_flops: float          # fp16/bf16 tensor peak, FLOP/s
    hbm_bw: float              # bytes/s
    mem_bytes: float
    has_flash_attention: bool
    gemm_eff: float            # achieved fraction of peak on large GEMMs
    attn_eff: float            # achieved fraction of peak on fused attention
    attn_eff_nofa: float       # achieved fraction on unfused attention core
    link_bw: float = 12.5e9    # bytes/s per direction to the ZP-group fabric


# GPU classes (paper's testbeds) ------------------------------------------------
V100 = DeviceClass("v100", 125e12, 900e9, 16e9, False, 0.43, 0.0, 0.118)
A40 = DeviceClass("a40", 149.7e12, 696e9, 48e9, True, 0.45, 0.40, 0.18)
T4 = DeviceClass("t4", 65e12, 300e9, 16e9, False, 0.35, 0.0, 0.155)
L40S = DeviceClass("l40s", 362e12, 864e9, 48e9, True, 0.45, 0.40, 0.18)
A100 = DeviceClass("a100", 312e12, 2039e9, 80e9, True, 0.47, 0.42, 0.20)

# TPU classes ----------------------------------------------------------------
TPU_V5E = DeviceClass("tpu-v5e", 197e12, 819e9, 16e9, True, 0.55, 0.45, 0.20,
                      link_bw=50e9)
TPU_V4 = DeviceClass("tpu-v4", 275e12, 1228e9, 32e9, True, 0.55, 0.45, 0.20,
                     link_bw=50e9)
TPU_V3 = DeviceClass("tpu-v3", 123e12, 900e9, 32e9, False, 0.50, 0.0, 0.14,
                     link_bw=35e9)

CLASSES = {c.name: c for c in
           [V100, A40, T4, L40S, A100, TPU_V5E, TPU_V4, TPU_V3]}

# Roofline constants for the target deployment (per the brief).
ROOFLINE_PEAK_FLOPS = 197e12   # TPU v5e bf16
ROOFLINE_HBM_BW = 819e9
ROOFLINE_ICI_BW = 50e9         # per link


def get(name: str) -> DeviceClass:
    return CLASSES[name]
