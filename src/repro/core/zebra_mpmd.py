"""Zebra parallelism — MPMD engine (the paper-faithful disaggregation).

Two disjoint device groups run two different programs, exactly as HeterMoE
deploys on mixed-generation clusters:

    attention group (M devices, "newer"):  embeddings, attention blocks,
        routers, combines, head/loss, and any Asym-EA-offloaded experts.
    expert group    (N devices, "older"):  expert FFNs only, sharded
        expert-parallel.

A host-side scheduler walks Theorem 1's task order over (layer, microbatch);
JAX's async dispatch turns that issue order into overlapped execution — the
TPU/JAX equivalent of the paper's CUDA-stream scheduling. Activations cross
groups as capacity-packed [E, C, d] buffers via jax.device_put (the bipartite
dispatch/combine all-to-alls; volumes identical to EP, per the paper's
no-extra-communication argument). With n_chunks > 1 each expert hop is a
chunked, double-buffered pipeline (DESIGN.md §8): the device_put of capacity
chunk k+1 is issued before chunk k's expert program, forward and backward,
so transfers hide under expert compute at sub-microbatch granularity, and
the combine consumes ONE packed [E, C, d] output assembled from the local
(attention-side) rows and the streamed remote chunks.

Backward uses stage-granular recompute (activation checkpointing, the
paper's §6.1 setting): each stage's VJP re-executes its forward inside jit.
The gate-score "residual branch" (§5 Implementation) is handled by
accumulating both cotangent paths — through the expert outputs' combine
weights and through the dispatched tokens — at the attention-output
boundary before the attention-stage backward runs.

On this CPU container the engine is a *correctness* demonstrator (all
emulated devices share one core); throughput claims live in the simulator.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import zebra_spmd as zs
from repro.models import modules, stack
from repro.obs import trace as obs_trace
from repro.models.config import LayerSpec, ModelConfig
from repro.models.modules import RunConfig
from repro.pytree import split_params


def _round_up(x, m):
    return ((x + m - 1) // m) * m


@dataclasses.dataclass
class MPMDPlan:
    """Expert placement: per layer, how many experts live on the attention
    group (= offload[l] * N, Asym-EA §4.2). Experts [0, n_att) -> attention
    group; [n_att, E) -> expert group."""

    n_experts: int
    offload: tuple  # per-layer experts offloaded per expert device
    N: int

    def n_attn_experts(self, layer: int) -> int:
        return self.offload[layer] * self.N


class ZebraMPMD:
    """Disaggregated MoE training over two device groups."""

    def __init__(self, cfg: ModelConfig, run: RunConfig, attn_devices,
                 exp_devices, num_microbatches: int = 2,
                 offload: Optional[tuple] = None,
                 capacity_factor: Optional[float] = None,
                 n_chunks: int = 1):
        assert cfg.is_moe, "MPMD zebra engine is for MoE architectures"
        assert not cfg.tail_specs, "use pattern-aligned layer counts"
        self.cfg = cfg
        self.run = run
        self.R = num_microbatches
        self.Q = max(int(n_chunks), 1)
        self.M = len(attn_devices)
        self.N = len(exp_devices)
        self.attn_mesh = Mesh(np.array(attn_devices), ("adata",))
        self.exp_mesh = Mesh(np.array(exp_devices), ("expert",))
        offload = tuple(offload) if offload else tuple([0] * cfg.n_layers)
        self.plan = MPMDPlan(cfg.n_experts, offload, self.N)
        self.cf = capacity_factor or cfg.capacity_factor
        self.spec = cfg.pattern[0]
        self._build_stages()

    # ------------------------------------------------------------------
    # Parameter placement
    # ------------------------------------------------------------------

    def shard_params(self, params):
        """Split a fused param tree into (attn_side, exp_side) trees placed
        on their meshes. Expert weights are split per layer by the plan."""
        a_sh = NamedSharding(self.attn_mesh, P())
        e_sh = NamedSharding(self.exp_mesh, P("expert"))
        cfg = self.cfg

        blocks = params["blocks"]["pos0"]
        attn_side = {"embed": jax.device_put(params["embed"], a_sh),
                     "final_norm": jax.device_put(params["final_norm"], a_sh)}
        if "lm_head" in params:
            attn_side["lm_head"] = jax.device_put(params["lm_head"], a_sh)
        attn_layers, exp_layers = [], []
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda x: x[l], blocks)
            n_att = self.plan.n_attn_experts(l)
            ffn = lp.pop("ffn")
            a_ffn = {"router": ffn["router"]}
            for k in ("wi_gate", "wi_up", "wo"):
                a_ffn[k] = ffn[k][:n_att]
            e_ffn = {k: ffn[k][n_att:] for k in ("wi_gate", "wi_up", "wo")}
            lp["ffn"] = a_ffn
            attn_layers.append(jax.device_put(lp, a_sh))
            exp_layers.append(jax.device_put(e_ffn, e_sh))
        attn_side["layers"] = attn_layers
        return attn_side, exp_layers

    # ------------------------------------------------------------------
    # Stage programs (jitted once per engine)
    # ------------------------------------------------------------------

    def _build_stages(self):
        cfg, run, spec = self.cfg, self.run, self.spec
        cd = run.policy.compute_dtype
        E = cfg.n_experts

        def embed(p_embed, tokens, positions):
            return modules.apply_embedding(p_embed, cfg, run.policy, tokens,
                                           positions)

        def attn_route(p_layer, x, positions):
            """Attention block + router + dispatch packing (attention mesh).

            Returns h (residual base), packed remote buffer, local expert
            buffer, and routing metadata arrays."""
            h, _ = modules.apply_mixer_part(p_layer, cfg, run, spec, x,
                                            positions)
            u = modules.apply_norm(p_layer["norm2"], h, run.policy)
            B, S, d = u.shape
            u2 = u.reshape(-1, d)
            weights, idx, aux = modules.moe_route(
                p_layer["ffn"]["router"], cfg, run.policy, u2)
            n_att = p_layer["ffn"]["wi_gate"].shape[0]
            from repro.kernels.ops import chunk_capacity
            C0 = max(_round_up(int(u2.shape[0] * cfg.top_k / E * self.cf),
                               8), 8)
            # Capacity padded so the remote buffer splits into Q equal
            # chunk slices for the pipelined dispatch (pad rows inert).
            C, _ = chunk_capacity(C0, self.Q)
            buf, (tok, slot, keep, order) = zs._pack(u2, idx, E, C)
            return (h, buf[n_att:], buf[:n_att], weights, tok, slot, keep,
                    order, aux)

        def expert_fwd(p_exp, buf):
            """Expert-group program: grouped FFN straight over the
            capacity-packed [E, C, d] dispatch buffer (no re-sort/re-pack;
            the buffer is already the packed domain)."""
            return zs._experts_dense(p_exp["wi_gate"], p_exp["wi_up"],
                                     p_exp["wo"], buf, cd,
                                     use_kernel=run.use_gmm_kernel)

        def local_expert_fwd(p_layer, buf_local):
            f = p_layer["ffn"]
            if f["wi_gate"].shape[0] == 0:
                return buf_local
            return zs._experts_dense(f["wi_gate"], f["wi_up"], f["wo"],
                                     buf_local, cd,
                                     use_kernel=run.use_gmm_kernel)

        def assemble(out_local, *out_chunks):
            """Stitch the local output and the streamed remote chunk
            outputs into ONE packed [E, C, d] buffer (capacity-major for
            the remote part) — the single output `combine` consumes."""
            rem = out_chunks[0] if len(out_chunks) == 1 else \
                jnp.concatenate(out_chunks, axis=1)
            return jnp.concatenate([out_local.astype(rem.dtype), rem],
                                   axis=0)

        def combine(h, out, weights, tok, slot, keep, order):
            """Weighted combine over ONE packed [E, C, d] expert output."""
            B, S, d = h.shape
            y2 = zs._unpack(out, (tok, slot, keep, order), weights, B * S)
            return h + y2.reshape(h.shape).astype(h.dtype)

        def head_loss(p, x, targets):
            xn = modules.apply_norm(p["final_norm"], x, run.policy)
            logits = modules.apply_unembedding(
                p["embed"], p.get("lm_head"), cfg, run.policy, xn)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, targets[..., None],
                                       axis=-1)[..., 0]
            return jnp.mean(nll)

        self.embed_f = jax.jit(embed)
        self.attn_route_f = jax.jit(attn_route)
        self.expert_f = jax.jit(expert_fwd)
        self.local_expert_f = jax.jit(local_expert_fwd)
        self.assemble_f = jax.jit(assemble)
        self.combine_f = jax.jit(combine)
        self.head_loss_f = jax.jit(head_loss)

        # Backward (stage-recompute VJPs) --------------------------------
        self.head_bwd = jax.jit(lambda p, x, t: jax.grad(
            head_loss, argnums=(0, 1))(p, x, t))

        def combine_bwd(h, out, weights, tok, slot, keep, order, g):
            _, vjp = jax.vjp(
                lambda h_, o_, w: combine(h_, o_, w, tok, slot, keep, order),
                h, out, weights)
            return vjp(g)  # (dh, d_out_packed, d_weights)

        self.combine_bwd_f = jax.jit(combine_bwd)

        def expert_bwd(p_exp, buf, g):
            _, vjp = jax.vjp(lambda p, b: expert_fwd(p, b), p_exp, buf)
            return vjp(g)  # (d_params, d_buf)

        self.expert_bwd_f = jax.jit(expert_bwd)

        def local_expert_bwd(p_layer, buf, g):
            _, vjp = jax.vjp(lambda p, b: local_expert_fwd(p, b), p_layer,
                             buf)
            return vjp(g)

        self.local_expert_bwd_f = jax.jit(local_expert_bwd)

        def attn_route_bwd(p_layer, x, positions, g_h, g_buf_remote,
                           g_buf_local, g_weights):
            """Backward of attn_route. The cotangent of h arrives already
            accumulated from BOTH branches (expert path via dispatched
            tokens g_buf*, gate path via g_weights + residual g_h) — the
            paper's two-branch backward handling."""
            def fwd(p, x_):
                h, br, bl, w, *_meta, _aux = attn_route(p, x_, positions)
                return (h, br, bl, w)
            _, vjp = jax.vjp(fwd, p_layer, x)
            return vjp((g_h, g_buf_remote, g_buf_local, g_weights))

        self.attn_route_bwd_f = jax.jit(attn_route_bwd)

        def embed_bwd(p_embed, tokens, positions, g):
            _, vjp = jax.vjp(lambda p: embed(p, tokens, positions), p_embed)
            return vjp(g)[0]

        self.embed_bwd_f = jax.jit(embed_bwd)

    # ------------------------------------------------------------------
    # Forward + backward in Theorem-1 issue order
    # ------------------------------------------------------------------

    def _to_exp(self, x):
        return jax.device_put(x, NamedSharding(self.exp_mesh, P("expert")))

    def _to_attn(self, x):
        return jax.device_put(x, NamedSharding(self.attn_mesh, P()))

    def train_step(self, attn_side, exp_layers, tokens, targets):
        """One full training iteration. Returns (loss, grads_attn,
        grads_exp) living on their home meshes."""
        cfg, R = self.cfg, self.R
        B = tokens.shape[0]
        assert B % R == 0
        toks = tokens.reshape(R, B // R, -1)
        tgts = targets.reshape(R, B // R, -1)
        S = toks.shape[-1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                     (B // R, S))
        L = cfg.n_layers

        # ---- forward: layer-major, microbatch-minor (Theorem 1) ----
        batch_sh = NamedSharding(self.attn_mesh, P("adata"))
        x: Dict = {}
        saved: Dict = {}
        tr = obs_trace.TRACER
        track = "zebra-mpmd"
        if tr.enabled:
            tr.declare_track(track, pid="train")
        for j in range(R):
            with tr.span(track, f"embed mb{j}", microbatch=j):
                tj = jax.device_put(toks[j], batch_sh)
                x[(0, j)] = self.embed_f(attn_side["embed"], tj, positions)
        Q = self.Q
        for l in range(L):
            for j in range(R):
                tr.begin(track, f"F l{l} mb{j}", layer=l, microbatch=j,
                         chunks=Q)
                out = self.attn_route_f(attn_side["layers"][l], x[(l, j)],
                                        positions)
                (h, buf_r, buf_l, w, tok, slot, keep, order, aux) = out
                Cq = buf_r.shape[1] // Q
                # Chunked dispatch: the device_put of chunk q+1 is issued
                # BEFORE the expert GEMM of chunk q, so JAX's async
                # dispatch overlaps the transfer with compute — the D/E
                # pipelining of Theorem 1 at sub-microbatch granularity.
                sent = [self._to_exp(buf_r[:, :Cq])]
                outs = []
                for q in range(Q):
                    if q + 1 < Q:
                        sent.append(self._to_exp(
                            buf_r[:, (q + 1) * Cq:(q + 2) * Cq]))
                    o = self.expert_f(exp_layers[l], sent[q])
                    outs.append(self._to_attn(o))   # combine a2a, chunk q
                # Local (offloaded) experts run on the attention mesh
                # while the remote chunks are in flight.
                o_loc = self.local_expert_f(attn_side["layers"][l], buf_l)
                out_full = self.assemble_f(o_loc, *outs)
                y = self.combine_f(h, out_full, w, tok, slot, keep, order)
                saved[(l, j)] = (h, buf_r, buf_l, w, tok, slot, keep, order,
                                 out_full)
                x[(l + 1, j)] = y
                tr.end(track)

        # ---- head + backward, Theorem-1 reverse order ----
        grads_a = jax.tree.map(jnp.zeros_like, attn_side)
        grads_e = [jax.tree.map(jnp.zeros_like, p) for p in exp_layers]
        losses = []
        g_x: Dict = {}
        for j in range(R):
            with tr.span(track, f"head mb{j}", microbatch=j):
                head_in = {"final_norm": attn_side["final_norm"],
                           "embed": attn_side["embed"]}
                if "lm_head" in attn_side:
                    head_in["lm_head"] = attn_side["lm_head"]
                losses.append(self.head_loss_f(head_in, x[(L, j)], tgts[j]))
                gp, gx = self.head_bwd(head_in, x[(L, j)], tgts[j])
                for k in ("final_norm", "embed", "lm_head"):
                    if k in gp:
                        grads_a[k] = jax.tree.map(jnp.add, grads_a[k],
                                                  gp[k])
                g_x[(L, j)] = gx

        for l in range(L - 1, -1, -1):
            for j in range(R):
                tr.begin(track, f"B l{l} mb{j}", layer=l, microbatch=j,
                         chunks=Q)
                (h, buf_r, buf_l, w, tok, slot, keep, order,
                 out_full) = saved.pop((l, j))
                n_att = buf_l.shape[0]
                dh, d_out, dw = self.combine_bwd_f(
                    h, out_full, w, tok, slot, keep, order, g_x[(l + 1, j)])
                d_ol, d_or = d_out[:n_att], d_out[n_att:]
                Cq = d_or.shape[1] // Q
                # Chunked grad dispatch (C^B): ship chunk q+1's cotangent
                # and recompute input while chunk q's expert backward runs.
                sent = [(self._to_exp(d_or[:, :Cq]),
                         self._to_exp(buf_r[:, :Cq]))]
                d_chunks = []
                for q in range(Q):
                    if q + 1 < Q:
                        sl = slice((q + 1) * Cq, (q + 2) * Cq)
                        sent.append((self._to_exp(d_or[:, sl]),
                                     self._to_exp(buf_r[:, sl])))
                    g_q, b_q = sent[q]
                    gpe, d_buf_q = self.expert_bwd_f(exp_layers[l], b_q, g_q)
                    grads_e[l] = jax.tree.map(jnp.add, grads_e[l], gpe)
                    d_chunks.append(self._to_attn(d_buf_q))  # D^B, chunk q
                d_buf_r = d_chunks[0] if Q == 1 else \
                    jnp.concatenate(d_chunks, axis=1)
                gpl, d_buf_l = self.local_expert_bwd_f(
                    attn_side["layers"][l], buf_l, d_ol)
                gpa, dx = self.attn_route_bwd_f(
                    attn_side["layers"][l], x[(l, j)], positions, dh,
                    d_buf_r, d_buf_l, dw)
                gpa = jax.tree.map(jnp.add, gpa, gpl)
                grads_a["layers"][l] = jax.tree.map(
                    jnp.add, grads_a["layers"][l], gpa)
                g_x[(l, j)] = dx
                tr.end(track)

        for j in range(R):
            with tr.span(track, f"embed^B mb{j}", microbatch=j):
                ge = self.embed_bwd_f(attn_side["embed"], toks[j],
                                      positions, g_x[(0, j)])
                grads_a["embed"] = jax.tree.map(jnp.add, grads_a["embed"],
                                                ge)

        loss = sum(losses) / R
        scale = 1.0 / R
        grads_a = jax.tree.map(lambda g: g * scale, grads_a)
        grads_e = [jax.tree.map(lambda g: g * scale, g) for g in grads_e]
        return loss, grads_a, grads_e
