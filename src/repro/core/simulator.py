"""Discrete-event simulator of heterogeneous MoE training schedules.

This is the paper's own methodology made explicit: HeterMoE ships a
simulator "to estimate the training throughput under different ZP group
setups" (§6.4.1 fn.2). Ours simulates the zebra schedule (and the EP /
DistEP / EP-Ideal / heterogeneity-aware-PP baselines) from per-task
durations supplied by the analytical profiler, and is what the fig7..fig12
benchmarks run.

Semantics: tasks execute on four FIFO streams (attention compute, expert
compute, two link directions). A task starts when its stream predecessor
AND its data dependencies are done. Iteration time = max end time. This is
exactly the constraint system of §4.1 (eq. for t(A_{i,j}^F)).
"""

from __future__ import annotations

import dataclasses
import math
from collections import defaultdict, deque
from typing import Dict, Optional

from repro.core import schedule as S
from repro.core.asym_ea import AsymEAPlan, apply_offload_to_times
from repro.core.profiler import LayerTimes

BWD_RATIO = 2.0  # backward ~ 2x forward (paper §4.2)


@dataclasses.dataclass(frozen=True)
class CommTimes:
    """Per-microbatch all-to-all durations (one direction)."""

    dispatch: float
    combine: float


def exposed_comm(t_comm: float, t_hide: float, n_chunks: int) -> float:
    """Exposed (critical-path) time of an all-to-all split into n_chunks
    and double-buffered against compute of total duration t_hide.

    The first chunk's wire time is always exposed (nothing to hide it
    under); each later chunk transfers while the previous chunk computes,
    so only the excess of per-chunk wire time over per-chunk compute time
    stays exposed. n_chunks == 1 is the serialized baseline (full t_comm
    exposed) — the pre-overlap cost model."""
    q = max(int(n_chunks), 1)
    if q == 1:
        return t_comm
    per = t_comm / q
    return per + (q - 1) * max(0.0, per - t_hide / q)


@dataclasses.dataclass
class SimResult:
    iter_time: float
    attn_busy: float
    exp_busy: float
    attn_util: float
    exp_util: float
    starts: Dict
    # Task end times (same keys as starts). Optional so older pickled /
    # hand-built results keep working; obs.zebra.sim_to_trace needs it to
    # lay the schedule out as spans on a simulated timeline.
    ends: Dict = dataclasses.field(default_factory=dict)

    @property
    def attn_bubble(self) -> float:
        return 1.0 - self.attn_util


def task_duration(task, times: LayerTimes, comm: CommTimes, L: int,
                  offload, n_experts: int, N: int, M: int,
                  head_time: float, n_chunks: int = 1) -> float:
    kind, phase, l, _ = task
    scale = BWD_RATIO if phase == "B" else 1.0
    o_l = offload[l] if 0 <= l < L else 0
    if kind == "A":
        return times.t_attn * scale
    if kind == "E":
        t_exp, _ = apply_offload_to_times(times, o_l, n_experts, N, M)
        return t_exp * scale
    if kind == "X":
        _, t_extra = apply_offload_to_times(times, o_l, n_experts, N, M)
        return t_extra * scale
    if kind in ("D", "C"):
        # Volume is phase-independent (activations fwd, cotangents bwd);
        # with chunked dispatch only the exposed residue sits on the link
        # stream — the rest hides under the matching expert compute (whose
        # duration scales with BWD_RATIO in the backward).
        frac = 1.0 - o_l * N / n_experts  # offloaded tokens stay local-ish
        t_exp, _ = apply_offload_to_times(times, o_l, n_experts, N, M)
        vol = (comm.dispatch if kind == "D" else comm.combine) * frac
        return exposed_comm(vol, t_exp * scale, n_chunks)
    if kind == "H":
        return head_time
    raise ValueError(task)


def simulate(sched: S.ZebraSchedule, times: LayerTimes, comm: CommTimes,
             n_experts: int, N: int, M: int,
             head_time: float = 0.0) -> SimResult:
    """List-schedule the task system; Kahn topological order over
    (dependency edges + stream-FIFO edges)."""
    L, offload = sched.L, sched.offload
    preds: Dict = defaultdict(list)
    succs: Dict = defaultdict(list)
    indeg: Dict = defaultdict(int)
    tasks = sched.all_tasks()
    tset = set(tasks)

    def add_edge(a, b):
        preds[b].append(a)
        succs[a].append(b)
        indeg[b] += 1

    for stream_tasks in sched.streams.values():
        for a, b in zip(stream_tasks, stream_tasks[1:]):
            add_edge(a, b)
    for t in tasks:
        for d in S.dependencies(t, L, offload):
            if d in tset:
                add_edge(d, t)

    end: Dict = {}
    start: Dict = {}
    q = deque([t for t in tasks if indeg[t] == 0])
    done = 0
    while q:
        t = q.popleft()
        done += 1
        st = max((end[p] for p in preds[t]), default=0.0)
        dur = task_duration(t, times, comm, L, offload, n_experts, N, M,
                            head_time, n_chunks=sched.n_chunks)
        start[t] = st
        end[t] = st + dur
        for s_ in succs[t]:
            indeg[s_] -= 1
            if indeg[s_] == 0:
                q.append(s_)
    if done != len(tasks):
        raise ValueError("schedule has a dependency cycle")

    total = max(end.values())
    attn_busy = sum(end[t] - start[t] for t in sched.streams["attn_comp"])
    exp_busy = sum(end[t] - start[t] for t in sched.streams["exp_comp"])
    return SimResult(
        iter_time=total,
        attn_busy=attn_busy,
        exp_busy=exp_busy,
        attn_util=attn_busy / total if total else 0.0,
        exp_util=exp_busy / total if total else 0.0,
        starts=start,
        ends=end,
    )


# ---------------------------------------------------------------------------
# System-level throughput models (paper baselines)
# ---------------------------------------------------------------------------

def comm_times(cfg, global_batch: int, seq_len: int, R: int,
               link_bw: float, M: int, N: int) -> CommTimes:
    """All-to-all volume per microbatch: every routed token copy crosses the
    bipartite cut once per direction (paper: no extra communication vs EP)."""
    from repro.core.profiler import a2a_time
    mb_tokens = global_batch * seq_len // R
    t = a2a_time(cfg, mb_tokens, link_bw, M, N)
    return CommTimes(dispatch=t, combine=t)


def simulate_hetermoe(cfg, times: LayerTimes, comm: CommTimes, R: int,
                      M: int, N: int, plan: Optional[AsymEAPlan] = None,
                      head_time: float = 0.0, n_chunks: int = 1) -> SimResult:
    offload = plan.offload if plan is not None else tuple([0] * cfg.n_layers)
    sched = S.canonical_schedule(cfg.n_layers, R, offload, n_chunks=n_chunks)
    return simulate(sched, times, comm, cfg.n_experts, N, M, head_time)


def simulate_distep(cfg, times: LayerTimes, comm: CommTimes, M: int,
                    N: int, head_time: float = 0.0) -> SimResult:
    """Naive disaggregation: no microbatch pipeline (R=1), no overlap.
    `times`/`comm` must be profiled at R=1 (whole batch per step)."""
    sched = S.canonical_schedule(cfg.n_layers, 1, None)
    return simulate(sched, times, comm, cfg.n_experts, N, M, head_time)


def distep_iter_time(cfg, zp, global_batch: int, seq_len: int,
                     link_bw: float) -> SimResult:
    """DistEP baseline with its own R=1 profile."""
    from repro.core import profiler as P
    times = P.profile_layer(cfg, zp, global_batch, seq_len, 1)
    comm = comm_times(cfg, global_batch, seq_len, 1, link_bw, zp.M, zp.N)
    return simulate_distep(cfg, times, comm, zp.M, zp.N)


def ep_iter_time(cfg, zp, global_batch: int, seq_len: int,
                 link_bw: float) -> float:
    """Vanilla EP over the heterogeneous cluster: every GPU computes
    attention + its expert shard; the slowest class paces every stage."""
    from repro.core import profiler as P
    G = zp.M + zp.N
    tokens_per_gpu = global_batch * seq_len // G
    copies_per_gpu = tokens_per_gpu * max(cfg.top_k, 1)
    t_attn = max(
        P.attention_block_time(cfg, tokens_per_gpu, seq_len, zp.attn_class),
        P.attention_block_time(cfg, tokens_per_gpu, seq_len, zp.exp_class))
    t_exp = max(
        P.expert_ffn_time(cfg, copies_per_gpu, zp.attn_class),
        P.expert_ffn_time(cfg, copies_per_gpu, zp.exp_class))
    byts = tokens_per_gpu * max(cfg.top_k, 1) * cfg.d_model * 2
    t_comm = 2 * byts / min(zp.attn_class.link_bw, zp.exp_class.link_bw)
    return cfg.n_layers * (1 + BWD_RATIO) * (t_attn + t_exp + t_comm)


def homogeneous_ep_iter_time(cfg, dev, n_gpus: int, global_batch: int,
                             seq_len: int) -> float:
    """EP on a homogeneous sub-cluster (basis of EP-Ideal and Fig. 11)."""
    from repro.core import profiler as P
    tokens_per_gpu = global_batch * seq_len // n_gpus
    copies_per_gpu = tokens_per_gpu * max(cfg.top_k, 1)
    t_attn = P.attention_block_time(cfg, tokens_per_gpu, seq_len, dev)
    t_exp = P.expert_ffn_time(cfg, copies_per_gpu, dev)
    byts = tokens_per_gpu * max(cfg.top_k, 1) * cfg.d_model * 2
    t_comm = 2 * byts / dev.link_bw if n_gpus > 1 else 0.0
    # Tutel/Lina-style overlap on homogeneous EP: comm hides under compute
    # where possible.
    t_layer = t_attn + max(t_exp, t_comm)
    return cfg.n_layers * (1 + BWD_RATIO) * t_layer


def ep_ideal_throughput(cfg, zp, global_batch: int, seq_len: int) -> float:
    """Paper's EP (Ideal): run each class separately, sum throughputs
    (perfect balance, zero cross-class comm overhead). tokens/sec."""
    th = 0.0
    for dev, count in ((zp.attn_class, zp.M), (zp.exp_class, zp.N)):
        if count == 0:
            continue
        t = homogeneous_ep_iter_time(cfg, dev, count, global_batch, seq_len)
        th += global_batch * seq_len / t
    return th


# ---------------------------------------------------------------------------
# Serving-mode simulation (DESIGN.md §10)
# ---------------------------------------------------------------------------
#
# The serving counterpart of the training schedule simulator: a
# deterministic replay of a request trace through either deployment shape.
#
#   * unified (colocated=True): the continuous-batching engine run
#     data-parallel lockstep over the WHOLE mixed group — each tick spends
#     one prefill chunk (when a prompt is mid-flight) plus one decode step,
#     both paced by the slowest class present, and decode of live slots
#     stalls behind every prefill chunk (exactly the engine's tick loop).
#   * disagg (colocated=False): prefill streams drain the queue in
#     continuous time on the prefill group's clock; decode ticks
#     independently on the decode group's clock; a finished prefill pays
#     the page-handoff wire time before it can claim a decode slot.
#     Migration is FIFO head-of-line, like the controller.
#
# Being a function of the trace and the analytic profile only, its outputs
# gate CI (BENCH_serve.json `disagg`) the way gate.speedup does for zebra.

@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One trace entry for the serving simulator."""

    arrival: float  # seconds
    prompt: int     # prompt tokens
    gen: int        # tokens to generate


@dataclasses.dataclass
class ServeSimResult:
    makespan: float
    goodput: float     # generated tokens of finished requests per second
    ttft_mean: float
    ttft_p50: float
    n_finished: int


def zipf_poisson_trace(seed: int, n: int, rate: float, prompt: int,
                       gen: int, n_experts: int, zipf_s: float = 1.2):
    """Skewed serving workload for EP-placement planning (DESIGN.md §11):
    Poisson arrivals with fixed prompt/gen lengths, plus a Zipf routing
    histogram over a seed-shuffled expert order (rank-r expert gets mass
    1/(r+1)^s) — the distribution the placement planner consumes. Returns
    ``(requests, hist)`` with ``hist`` a normalized n_experts-tuple. Pure
    python so the simulator stays dependency-free."""
    import random
    rng = random.Random(seed)
    reqs, t = [], 0.0
    for _ in range(n):
        t += rng.expovariate(rate)
        reqs.append(ServeRequest(arrival=t, prompt=prompt, gen=gen))
    order = list(range(n_experts))
    rng.shuffle(order)
    w = [0.0] * n_experts
    for r, e in enumerate(order):
        w[e] = 1.0 / (r + 1) ** zipf_s
    tot = sum(w)
    return reqs, tuple(x / tot for x in w)


def production_trace(seed: int, n: int, *, base_rate: float,
                     diurnal_amp: float = 0.8, period_s: float = 600.0,
                     prompt_med: int = 512, prompt_sigma: float = 0.9,
                     gen_med: int = 64, gen_sigma: float = 0.8,
                     interactive_frac_amp: float = 0.45,
                     prompt_cap: int = 16384, gen_cap: int = 2048):
    """Production-shaped serving load (DESIGN.md §12): heavy-tailed
    lognormal prompt/output lengths under a diurnal arrival-rate swing.

    Arrivals are an inhomogeneous Poisson process thinned from rate
    ``base_rate * (1 + diurnal_amp * sin(2*pi*t/period_s))`` — traffic from
    a user population breathes with the clock. The REQUEST MIX breathes
    with it too: each request is "interactive" (short prompt, long
    generation — chat traffic, decode-bound) with probability
    ``0.5 + interactive_frac_amp * sin(...)`` at its arrival phase, else
    "batch" (long prompt, short generation — summarization/extraction,
    prefill-bound). The bottleneck ROLE therefore shifts over the day,
    which is exactly the gap an elastic fleet closes over any static
    prefill:decode split. Lengths are lognormal (median ``*_med``, shape
    ``*_sigma``: p99/p50 ~ e^{2.3 sigma}), capped so one request cannot
    exceed a pool. Pure python + deterministic under ``seed``."""
    import random
    rng = random.Random(seed)
    two_pi = 2.0 * math.pi

    def lognorm(med, sigma, cap):
        return max(1, min(int(med * math.exp(sigma * rng.gauss(0, 1))), cap))

    reqs, t = [], 0.0
    peak = base_rate * (1.0 + abs(diurnal_amp))
    while len(reqs) < n:
        t += rng.expovariate(peak)  # thinning: propose at the peak rate
        phase = math.sin(two_pi * t / period_s)
        rate_t = base_rate * (1.0 + diurnal_amp * phase)
        if rng.random() * peak > max(rate_t, 0.0):
            continue
        if rng.random() < 0.5 + interactive_frac_amp * phase:
            prompt = lognorm(prompt_med // 4, prompt_sigma, prompt_cap)
            gen = lognorm(gen_med * 2, gen_sigma, gen_cap)
        else:
            prompt = lognorm(prompt_med * 2, prompt_sigma, prompt_cap)
            gen = lognorm(max(gen_med // 4, 1), gen_sigma, gen_cap)
        reqs.append(ServeRequest(arrival=t, prompt=prompt, gen=gen))
    return reqs


@dataclasses.dataclass(frozen=True)
class TenantRequest:
    """One entry of a token-level multi-tenant trace (DESIGN.md §14):
    unlike :class:`ServeRequest` it carries actual token ids, because the
    prefix cache is keyed on them."""

    arrival: float       # engine ticks
    tenant: int
    prompt: tuple        # token ids (tenant shared prefix + unique tail)
    gen: int             # tokens to generate


def multi_tenant_trace(seed: int, n: int, *, n_tenants: int, rate: float,
                       prompt_len: int, gen: int, vocab: int,
                       shared_len: Optional[int] = None,
                       rates=None):
    """Shared-prefix multi-tenant serving workload (DESIGN.md §14).

    Every tenant owns a seeded ``shared_len``-token system prefix
    (default: half the prompt budget); each of its requests prepends that
    prefix to a unique random tail, so same-tenant requests share a long
    cacheable prefix while cross-tenant requests share nothing. Arrivals
    merge independent per-tenant Poisson streams: ``rates`` gives each
    tenant its own arrival rate (requests per engine tick — a skewed
    vector models one bursty tenant flooding the rest, the fairness
    scenario), defaulting to an even split of ``rate``. Generation
    budgets mix in [gen/2, gen]. Pure python + deterministic under
    ``seed``; returns ``n`` :class:`TenantRequest` sorted by arrival."""
    import random
    rng = random.Random(seed)
    shared_len = prompt_len // 2 if shared_len is None else shared_len
    assert 0 <= shared_len < prompt_len, \
        f"shared_len {shared_len} must leave room for a unique tail"
    assert n_tenants >= 1
    if rates is None:
        rates = [rate / n_tenants] * n_tenants
    assert len(rates) == n_tenants and all(r > 0 for r in rates)
    prefixes = [tuple(rng.randrange(vocab) for _ in range(shared_len))
                for _ in range(n_tenants)]
    t_next = [rng.expovariate(r) for r in rates]
    reqs = []
    while len(reqs) < n:
        tid = min(range(n_tenants), key=lambda i: t_next[i])
        t = t_next[tid]
        t_next[tid] += rng.expovariate(rates[tid])
        tail = rng.randint(1, max(1, prompt_len - shared_len))
        prompt = prefixes[tid] + tuple(
            rng.randrange(vocab) for _ in range(tail))
        g = rng.randint(max(1, gen // 2), gen)
        reqs.append(TenantRequest(arrival=t, tenant=tid, prompt=prompt,
                                  gen=g))
    return reqs


def _percentile(xs, q):
    s = sorted(xs)
    return s[min(len(s) - 1, int(round(q * (len(s) - 1))))] if s else 0.0


def simulate_serve_trace(reqs, *, prefill_chunk: int, t_prefill_chunk: float,
                         t_decode_step: float, decode_slots: int,
                         n_prefill_streams: int = 1, t_handoff: float = 0.0,
                         colocated: bool = False,
                         max_ticks: int = 10_000_000) -> ServeSimResult:
    """Replay ``reqs`` (ServeRequest list) through one deployment shape.

    For the unified engine pass the slowest-class times and
    ``colocated=True`` (streams and handoff are ignored: one engine, one
    prefill stream, zero-copy admission). For disagg pass each group's own
    clock plus the per-request page-handoff time."""
    order = sorted(range(len(reqs)), key=lambda i: (reqs[i].arrival, i))
    chunks = {i: -(-reqs[i].prompt // prefill_chunk) for i in order}
    ttft: Dict[int, float] = {}
    finish: Dict[int, float] = {}

    if colocated:
        import collections
        queue = collections.deque(order)
        t = 0.0
        free = decode_slots
        mid = None  # (idx, chunks_left)
        active: Dict[int, int] = {}
        for _ in range(max_ticks):
            if mid is None and queue and reqs[queue[0]].arrival <= t \
                    and free > 0:
                idx = queue.popleft()
                free -= 1
                mid = [idx, chunks[idx]]
            dt = 0.0
            if mid is not None:
                dt += t_prefill_chunk
                mid[1] -= 1
                if mid[1] == 0:
                    idx = mid[0]
                    ttft[idx] = t + dt - reqs[idx].arrival
                    if reqs[idx].gen <= 1:
                        finish[idx] = t + dt
                        free += 1
                    else:
                        active[idx] = reqs[idx].gen - 1
                    mid = None
            if active:
                dt += t_decode_step
                for idx in list(active):
                    active[idx] -= 1
                    if active[idx] == 0:
                        finish[idx] = t + dt
                        free += 1
                        del active[idx]
            if dt == 0.0:
                if not queue:
                    break
                t = max(t, reqs[queue[0]].arrival)
            else:
                t += dt
    else:
        # Prefill group: FIFO over the streams, continuous time.
        stream_free = [0.0] * max(n_prefill_streams, 1)
        ready: Dict[int, float] = {}
        for i in order:
            s = min(range(len(stream_free)), key=lambda j: stream_free[j])
            start = max(reqs[i].arrival, stream_free[s])
            done = start + chunks[i] * t_prefill_chunk
            stream_free[s] = done
            ready[i] = done + t_handoff
        # Decode group: independent tick clock, FIFO head-of-line admits.
        import collections
        pending = collections.deque(order)
        t = 0.0
        free = decode_slots
        active: Dict[int, int] = {}
        for _ in range(max_ticks):
            while pending and ready[pending[0]] <= t and free > 0:
                idx = pending.popleft()
                free -= 1
                ttft[idx] = t - reqs[idx].arrival
                if reqs[idx].gen <= 1:
                    finish[idx] = t
                    free += 1
                else:
                    active[idx] = reqs[idx].gen - 1
            if not active:
                if not pending:
                    break
                t = max(t, ready[pending[0]])
                continue
            t += t_decode_step
            for idx in list(active):
                active[idx] -= 1
                if active[idx] == 0:
                    finish[idx] = t
                    free += 1
                    del active[idx]

    if len(finish) != len(reqs):
        # Never returns a truncated replay: the outputs feed the CI-gated
        # disagg.goodput_ratio_sim, which must not pass (or fail) on a
        # partial trace.
        raise RuntimeError(
            f"serve trace did not complete within {max_ticks} ticks "
            f"({len(finish)}/{len(reqs)} finished)")
    done_tok = sum(reqs[i].gen for i in finish)
    t0 = min((r.arrival for r in reqs), default=0.0)
    makespan = max(finish.values(), default=0.0) - t0
    tt = list(ttft.values())
    return ServeSimResult(
        makespan=makespan,
        goodput=done_tok / makespan if makespan > 0 else 0.0,
        ttft_mean=sum(tt) / len(tt) if tt else 0.0,
        ttft_p50=_percentile(tt, 0.5),
        n_finished=len(finish))


def pp_iter_time(cfg, zp, global_batch: int, seq_len: int,
                 n_microbatches: int = 8) -> float:
    """Heterogeneity-aware pipeline parallelism (Metis/FlashFlex style):
    layers split across one attention-class stage and one expert-class
    stage to balance per-stage time, memory permitting; 1F1B timing."""
    from repro.core import profiler as P
    tokens = global_batch * seq_len
    mb_tokens = tokens // n_microbatches

    def stage_time_per_layer(dev):
        t_a = P.attention_block_time(cfg, mb_tokens, seq_len, dev)
        t_e = P.expert_ffn_time(cfg, mb_tokens * max(cfg.top_k, 1), dev)
        return t_a + t_e

    ta = stage_time_per_layer(zp.attn_class)
    te = stage_time_per_layer(zp.exp_class)
    # Optimal fractional split of L layers: attention class takes x layers
    # s.t. x*ta == (L-x)*te  ->  x = L*te/(ta+te); memory bound: the
    # expert-class stage must fit its layers.
    L = cfg.n_layers
    x = L * te / (ta + te)
    mem_per_layer = (cfg.n_experts * 3 * cfg.d_model * cfg.d_ff_expert * 12
                     + mb_tokens * cfg.d_model * 2 * 4)
    max_layers_exp = max(int(zp.exp_class.mem_bytes * zp.N * 0.9
                             // max(mem_per_layer, 1)), 1)
    layers_exp = min(L - x, max_layers_exp)
    layers_attn = L - layers_exp
    stage = max(layers_attn * ta / max(zp.M, 1) * 1.0,
                layers_exp * te / max(zp.N, 1) * 1.0)
    # 1F1B: (R + S - 1) * stage, fwd+bwd
    return (n_microbatches + 2 - 1) * stage * (1 + BWD_RATIO)


# -- chaos fault-schedule matrix (DESIGN.md §13) ----------------------------
#
# The STANDARD seeded fault schedules every chaos consumer shares: the
# acceptance tests (tests/test_chaos.py) drive the real fleet through each
# one, the CI chaos-smoke job replays them through launch/serve.py --chaos,
# and bench_serve's chaos section prices the "standard" entry against the
# fault-free run (chaos.goodput_degraded_ratio). One source of truth so a
# schedule can never silently diverge between the gate and the tests.
#
# Assumed topology (the chaos acceptance config): groups g0,g1 = prefill,
# g2,g3 = decode — two groups per role so any single-group fault is
# survivable.

def chaos_matrix():
    """``[(name, spec, seed)]`` — the standard fault-schedule matrix.

    Covers every hook point: chunk drop (probabilistic and
    retry-exhausting), corruption, link stall, heartbeat flap long enough
    to zombify-and-rejoin, and a mid-tick crash at each crash site. Specs
    follow the ``ft.chaos`` grammar; each entry carries its own seed so
    replays are independent."""
    return [
        # Probabilistic chunk loss: retries absorb it, no aborts.
        ("drop", "drop%0.6*4", 101),
        # Bit-flipped chunks: caught by the checksum, retried.
        ("corrupt", "corrupt*3", 202),
        # Delivered-but-unacked chunks: idempotent replay.
        ("stall", "stall*2", 303),
        # 4-deep drop bursts exhaust the retry budget (max_retries=3):
        # transfers abort and roll back into re-prefill.
        ("abort_reprefill", "drop@2*12", 404),
        # Heartbeat flap on decode g3, longer than the grace window:
        # zombify (fence + quarantine) then rejoin at gen+1.
        ("zombie_flap", "hb_loss@6:g3~8", 505),
        # Mid-tick crashes, one per hook point.
        ("crash_post_prefill", "crash_post_prefill@4:g0", 606),
        ("crash_mid_export", "crash_mid_export@3:g0", 707),
        ("crash_mid_import", "crash_mid_import@3:g2", 808),
        # The bench/CI "standard" schedule: a mild mix of everything.
        ("standard", "drop%0.5*2;corrupt*1;stall*1;hb_loss@6:g3~8", 909),
    ]
