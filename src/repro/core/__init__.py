"""HeterMoE core: zebra parallelism, Asym-EA, planner, simulator.

  asym_ea    — Algorithm 1 (gather-and-squeeze) + alpha/beta memory bounds
  schedule   — Theorem 1 task ordering + dependency model
  simulator  — discrete-event simulator (paper §6.4.1 fn.2) + baselines
  hardware   — device-class models calibrated to the paper's Fig. 2
  profiler   — analytical stand-in for the §5 profiler
  planner    — ZP-group planning / elastic replanning
  zebra_spmd — single-mesh production engine (scan-pipelined overlap)
  zebra_mpmd — two-mesh paper-faithful disaggregation engine
"""

from repro.core import (asym_ea, hardware, planner, profiler, schedule,
                        simulator)

__all__ = ["asym_ea", "hardware", "planner", "profiler", "schedule",
           "simulator"]
