"""Asymmetric expert assignment — Algorithm 1 of the paper (+ alpha/beta).

Decides, per layer, how many experts each expert GPU offloads back to the
attention GPUs: "gather" per-layer bubbles on the attention GPUs across
consecutive layers until at least one chunk (n1 experts per attention GPU /
n2 per expert GPU) can be "squeezed" out.

Units: all o_l are experts offloaded FROM EACH expert GPU (paper output
spec); n_min / n_max bound sum(O) in the same units.

Note on line 4: the paper prints T_squeeze = (T_E^Exp N/n) n1 +
(T_E^Attn N/n) n2, but its own prose defines N*T_E^Exp/n as the time saved
per expert *offloaded by an expert GPU* (n2 per chunk) and N*T_E^Attn/n as
the time added per expert *acquired by an attention GPU* (n1 per chunk). We
implement the prose (n2 with the Exp term, n1 with the Attn term); the two
readings coincide whenever M == N (all of the paper's Asym-EA-active
evaluation ratios are powers of two where both give identical schedules for
M=N, and the divisibility rule makes the difference a constant factor
otherwise).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

from repro.core.profiler import LayerTimes


@dataclasses.dataclass(frozen=True)
class AsymEAPlan:
    offload: tuple  # o_l per layer: experts offloaded per expert GPU
    n1: int  # experts each attention GPU acquires per chunk
    n2: int  # experts each expert GPU offloads per chunk
    t_gather: float
    t_squeeze: float
    alpha: float
    beta: float

    @property
    def total_offload(self) -> int:
        return sum(self.offload)

    def experts_on_attention(self, layer: int, N: int) -> int:
        """Total experts resident on the attention group for `layer`."""
        return self.offload[layer] * N


def divisibility_ok(M: int, N: int) -> bool:
    """Asym-EA requires M | N or N | M (paper §4.2)."""
    return M % N == 0 or N % M == 0


def asym_ea_offload(
    n: int,
    L: int,
    M: int,
    N: int,
    t_attn: float,
    t_exp_attn: float,
    t_exp: float,
    n_min: int = 0,
    n_max: Optional[int] = None,
    t_comm_exposed: float = 0.0,
) -> AsymEAPlan:
    """Algorithm 1. Times are per-microbatch forward durations.

    n: experts per layer; L: layers; M/N: attention/expert GPUs per ZP group.
    t_attn = T_A^Attn, t_exp_attn = T_E^Attn (one expert FFN on an attention
    GPU), t_exp = T_E^Exp.
    n_min/n_max: bounds on sum(O) in per-expert-GPU units.

    t_comm_exposed: the EXPOSED (not-overlapped) dispatch+combine all-to-all
    residue per microbatch (simulator.exposed_comm). It sits on the expert
    hop's critical path exactly like expert compute, so it joins t_exp in
    the per-layer bubble the attention GPUs gather. With serialized
    dispatch (n_chunks=1) this is the full wire time; with chunked
    double-buffered dispatch most of it hides under expert compute and
    MUST NOT be double-counted here — the planner passes the residue only
    (DESIGN.md §8).
    """
    if not divisibility_ok(M, N):
        raise ValueError(f"Asym-EA needs M|N or N|M, got M={M}, N={N}")
    n1 = max(1, N // M)                      # line 1
    n2 = n1 * M // N                          # line 2
    if n_max is None:
        n_max = n  # at most everything
    n_max = min(n_max, L * (n // N))          # cannot offload more than held

    t_gather = t_exp + t_comm_exposed - t_attn  # line 3 (+ exposed a2a)
    # line 4 (prose form; see module docstring):
    t_squeeze = (t_exp * N / n) * n2 + (t_exp_attn * N / n) * n1

    # Degenerate: no bubbles to squeeze and no memory pressure.
    if t_gather <= 0 and n_min <= 0:
        return AsymEAPlan(tuple([0] * L), n1, n2, t_gather, t_squeeze,
                          1.0, 1.0)
    if t_gather <= 0:
        # Memory-forced offload with no perf bubbles: spread n_min evenly.
        chunks = math.ceil(n_min / n2)
        per = chunks // L
        extra = chunks % L
        O = [(per + (1 if l < extra else 0)) * n2 for l in range(L)]
        return AsymEAPlan(tuple(O), n1, n2, t_gather, t_squeeze, 1.0,
                          float("inf"))

    # alpha/beta memory coefficients (paper, "Addressing memory limitations")
    gatherable = L * t_gather
    alpha = min(((n_max // n2) * t_squeeze) / gatherable, 1.0)
    beta = max((math.ceil(n_min / n2) * t_squeeze) / gatherable, 1.0)

    t_bubble = 0.0                            # line 5
    O: List[int] = []
    per_gpu = n // N  # an expert GPU cannot offload more than it holds
    for _ in range(L):                        # line 6
        t_bubble += alpha * beta * t_gather   # line 7 (modified)
        o_l = 0
        if t_bubble >= t_squeeze:             # line 8
            o_l = int(t_bubble // t_squeeze)  # line 9
            o_l = min(o_l, per_gpu // n2)     # physical per-layer cap
            t_bubble -= o_l * t_squeeze       # line 10
            o_l *= n2                         # line 11
        O.append(o_l)
    # Enforce hard bounds exactly (alpha/beta steer; rounding can overshoot).
    O = _clamp_total(O, n_min, n_max, n2, L)
    return AsymEAPlan(tuple(O), n1, n2, t_gather, t_squeeze, alpha, beta)


def _clamp_total(O: List[int], n_min: int, n_max: int, n2: int,
                 L: int) -> List[int]:
    total = sum(O)
    if total > n_max:
        excess = total - (n_max // n2) * n2
        for l in range(L - 1, -1, -1):
            if excess <= 0:
                break
            take = min(O[l], ((excess + n2 - 1) // n2) * n2)
            O[l] -= take
            excess -= take
    total = sum(O)
    if total < n_min:
        deficit = math.ceil((n_min - total) / n2) * n2
        l = 0
        while deficit > 0:
            O[l % L] += n2
            deficit -= n2
            l += 1
    return O


# ---------------------------------------------------------------------------
# Serving-mode extension: expert placement across a decode group (§11)
# ---------------------------------------------------------------------------

def round_robin_placement(n_experts: int, ep_size: int) -> tuple:
    """Uniform baseline placement: expert e -> shard e % ep_size. Returns
    a tuple of per-shard expert-id tuples with equal cardinality."""
    if ep_size < 1 or n_experts % ep_size:
        raise ValueError(f"ep_size {ep_size} must divide "
                         f"n_experts {n_experts}")
    return tuple(tuple(range(j, n_experts, ep_size))
                 for j in range(ep_size))


def placement_speeds(shard_classes, *, flops_per_byte: float = 0.0) -> tuple:
    """Per-shard service rates for ``asym_ea_place`` from device classes.

    Decode expert service is a roofline: weight reads stream at
    ``hbm_bw``, but the grouped GEMM over the m rows routed to an expert
    only sustains ``peak_flops * gemm_eff``. At arithmetic intensity
    ``flops_per_byte`` (≈ rows per activated expert in the bf16 decode
    regime: 2*m flops per 2 weight bytes), the effective byte rate is
    ``min(hbm_bw, peak_flops * gemm_eff / flops_per_byte)`` — so a
    compute-weak class (low ``gemm_eff * peak_flops``) falls off the
    bandwidth roofline first and should receive fewer hot experts.
    ``flops_per_byte=0`` degenerates to pure HBM bandwidth (the PR 6
    memory-bound assumption, kept as the default)."""
    speeds = []
    for c in shard_classes:
        bw = c.hbm_bw
        if flops_per_byte > 0.0:
            bw = min(bw, c.peak_flops * c.gemm_eff / flops_per_byte)
        speeds.append(bw)
    return tuple(speeds)


def asym_ea_place(load, speeds, cap: int) -> tuple:
    """Heterogeneity-aware expert placement: greedy LPT with fixed shard
    cardinality — the serving-mode analogue of Algorithm 1's offload
    sweep. ``load[e]`` is expert e's cost mass (for decode: its expected
    weight-read activation at the target batch), ``speeds[j]`` shard j's
    relative service rate (HBM bandwidth for the weight-read-bound decode
    regime), ``cap`` the exact experts per shard (EP layout needs equal
    shards). Experts are assigned heaviest-first to the feasible shard
    minimizing its resulting finish time (load + l) / speed, which lands
    hot experts on the strong class and cold ones on the weak class."""
    if len(load) != cap * len(speeds):
        raise ValueError(f"{len(load)} experts != {len(speeds)} shards "
                         f"x cap {cap}")
    if any(s <= 0 for s in speeds):
        raise ValueError("speeds must be positive")
    order = sorted(range(len(load)), key=lambda e: (-load[e], e))
    bins = [[] for _ in speeds]
    mass = [0.0] * len(speeds)
    for e in order:
        best, best_t = None, None
        for j, s in enumerate(speeds):
            if len(bins[j]) >= cap:
                continue
            t = (mass[j] + load[e]) / s
            if best_t is None or t < best_t:
                best, best_t = j, t
        bins[best].append(e)
        mass[best] += load[e]
    return tuple(tuple(sorted(b)) for b in bins)


def apply_offload_to_times(times: LayerTimes, offload_l: int, n: int, N: int,
                           M: int) -> tuple:
    """Per-layer durations after offloading o_l experts per expert GPU.

    Returns (t_exp_new, t_attn_extra): expert-GPU time for one microbatch
    and the extra per-microbatch expert work added to each attention GPU.
    """
    t_exp_new = times.t_exp * (1.0 - offload_l * N / n)
    acquired_per_attn = offload_l * N / M
    t_attn_extra = acquired_per_attn * (times.t_exp_attn * N / n)
    return max(t_exp_new, 0.0), t_attn_extra
