from repro.sharding.rules import (ShardingRules, rules_for, specs_for,
                                  shardings_for, batch_spec, constraint)

__all__ = ["ShardingRules", "rules_for", "specs_for", "shardings_for",
           "batch_spec", "constraint"]
