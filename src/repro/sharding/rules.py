"""Logical-axis -> mesh-axis sharding rules.

Params carry logical axis names (see repro.pytree.Param); these rules map
them onto the production mesh axes ("pod", "data", "model"). Weight rules
and activation rules are separate: weights can be 2D-sharded (FSDP-style,
gathered at use) regardless of how the computation itself is parallelized.

Variants:
  * dense / ssm / hybrid / audio / vlm — "fsdp" (default): token batch over
    ALL mesh axes, weights 2D-sharded over (data x model), activations pure
    data-parallel. No TP -> no head-divisibility padding, no per-layer
    psums; per-layer weight all-gathers ride ICI. "tp" variant keeps
    Megatron-style tensor parallelism over "model" for comparison (§Perf).
  * moe — "ep" (paper-faithful): experts along "model" (the paper's EP/ZP
    substrate), batch over (data x model), attention data-parallel with
    FSDP weights. "hybrid": TP attention + EP experts, batch over data only
    (enables zebra microbatching at full-pod scale, see zebra_spmd).

"pod" is pure data parallelism (DCN-friendly gradient reduction); experts
deliberately stay within a pod so dispatch/combine all-to-alls ride ICI,
mirroring the paper's assumption that ZP-group links are fast.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.pytree import axes_map

# Weights: 2D FSDP sharding for every big matrix.
_W_FSDP = {
    "vocab": "model", "embed": "data",
    "q_heads": "model", "kv_heads": "model",
    "mlp": "model", "mlp_out": "data",
    "expert": "model", "layers": None,
}
# Activations: pure data parallel.
_A_DP = {"q_heads": None, "kv_heads": None, "mlp": None, "vocab": None,
         "seq": None}
# Activations: Megatron-style TP over "model" (+ sequence-parallel layer
# boundaries on the same axis).
_A_TP = {"q_heads": "model", "kv_heads": "model", "mlp": "model",
         "vocab": "model", "seq": "model"}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Weight + activation logical-axis maps and batch axes."""

    rules: Mapping[str, object]          # weight axes
    act_rules: Mapping[str, object]      # activation axes
    batch_axes: tuple                    # token batch dim mesh axes

    def mesh_axes(self, logical: Optional[str]):
        if logical is None:
            return None
        return self.rules.get(logical, None)

    def act_axes(self, logical: Optional[str]):
        if logical is None:
            return None
        if logical == "batch":
            return self.batch_axes or None  # () -> replicated (e.g. B=1)
        return self.act_rules.get(logical, None)

    def spec(self, axes: Sequence[Optional[str]]) -> P:
        return P(*[self.mesh_axes(a) for a in axes])


def rules_for(cfg: ModelConfig, mesh: Mesh,
              variant: str = "default") -> ShardingRules:
    names = mesh.axis_names
    has_pod = "pod" in names
    data_axes = ("pod", "data") if has_pod else ("data",)
    all_axes = data_axes + ("model",)

    if variant == "serve":
        # Inference: batch rarely covers the whole pod, so "model" carries
        # TP/SP for activations; weights stay 2D-FSDP-sharded.
        w = dict(_W_FSDP)
        if cfg.is_moe:
            w["mlp"] = None
        return ShardingRules(rules=w, act_rules=dict(_A_TP),
                             batch_axes=data_axes)

    if cfg.is_moe:
        # expert dim takes "model"; expert matrices keep "embed"->data only
        # (a dim may not repeat a mesh axis within one spec).
        w = dict(_W_FSDP, mlp=None)
        if variant in ("default", "ep"):
            # Paper-faithful EP: batch spans the expert axis; attention DP.
            return ShardingRules(rules=w, act_rules=dict(_A_DP),
                                 batch_axes=all_axes)
        # hybrid: TP attention + EP experts; batch over data only.
        w = dict(w, embed=None)
        return ShardingRules(rules=w, act_rules=dict(_A_TP),
                             batch_axes=data_axes)

    if variant == "tp":
        w = dict(_W_FSDP, embed=None, mlp_out=None)
        return ShardingRules(rules=w, act_rules=dict(_A_TP),
                             batch_axes=data_axes)
    # default: FSDP
    return ShardingRules(rules=dict(_W_FSDP), act_rules=dict(_A_DP),
                         batch_axes=all_axes)


def specs_for(axes_tree, rules: ShardingRules):
    """Axes tree (tuples of logical names) -> PartitionSpec tree."""
    return axes_map(rules.spec, axes_tree)


def _fit_axis(dim: int, ax, mesh: Mesh):
    """Longest prefix of mesh axes whose product divides `dim` (jit arg
    shardings must divide exactly; odd vocabularies etc. fall back to fewer
    axes or replication)."""
    if ax is None:
        return None
    axs = ax if isinstance(ax, tuple) else (ax,)
    keep = []
    prod = 1
    for a in axs:
        if dim % (prod * mesh.shape[a]) == 0:
            keep.append(a)
            prod *= mesh.shape[a]
        else:
            break
    if not keep:
        return None
    return tuple(keep) if len(keep) > 1 else keep[0]


def fit_spec(shape, mesh: Mesh, parts) -> P:
    """Drop non-dividing mesh axes from a proposed spec, per dim."""
    fitted = [_fit_axis(d, a, mesh) for d, a in zip(shape, parts)]
    return P(*fitted)


def fitted_shardings(shapes_tree, axes_tree, rules: ShardingRules,
                     mesh: Mesh):
    """NamedSharding tree for jit in_shardings: logical axes -> mesh axes,
    with per-dim divisibility fitting against the actual shapes."""
    flat_s, treedef = jax.tree.flatten(shapes_tree)
    flat_a = jax.tree.leaves(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple)
        and all(e is None or isinstance(e, str) for e in x))
    out = []
    for s, a in zip(flat_s, flat_a):
        parts = [rules.mesh_axes(x) for x in a]
        out.append(NamedSharding(mesh, fit_spec(s.shape, mesh, parts)))
    return jax.tree.unflatten(treedef, out)


def shardings_for(axes_tree, rules: ShardingRules, mesh: Mesh):
    return axes_map(lambda a: NamedSharding(mesh, rules.spec(a)), axes_tree)


def ep_ffn_specs(ep_axis: str, offload: bool = False) -> dict:
    """shard_map in_specs for a zebra EP MoE ffn param dict.

    Router replicated; the [E_remote, ...] expert stacks sharded over the
    EP axis. With Asym-EA offload, the local (attention-side) expert
    slices ride along under the ``*_loc`` keys REPLICATED across the EP
    axis: every shard computes its own tokens' local-expert rows (no
    all-to-all for those tokens), so the weights must be present
    everywhere — the same placement the MPMD engine realizes by keeping
    offloaded experts on the attention mesh."""
    specs = {"router": P(None, None)}
    for k in ("wi_gate", "wi_up", "wo"):
        specs[k] = P(ep_axis, None, None)
        if offload:
            specs[k + "_loc"] = P(None, None, None)
    return specs


def slot_vector_spec(batch: int, mesh: Mesh, rules: ShardingRules) -> P:
    """Spec for per-slot serving vectors [B] (positions, active mask,
    request ids, sampling parameters). They ride the same batch axes as
    the token batch — divisibility-fitted — so the decode step's per-row
    cache scatter stays local to the shard owning the row instead of
    degrading to a replicated update."""
    if not rules.batch_axes:
        return P(None)
    return P(_fit_axis(batch, tuple(rules.batch_axes), mesh))


def page_table_spec(batch: int, mesh: Mesh, rules: ShardingRules) -> P:
    """Spec for per-slot page tables [B, max_pages] (paged KV serving,
    DESIGN.md §9). The slot dim rides the token-batch axes (like
    ``slot_vector_spec``) so each shard holds its own slots' tables; the
    page dim is replicated — tables are tiny int32 rows, and every model
    shard needs the full row to address its page-dim-sharded pool slice."""
    if not rules.batch_axes:
        return P(None, None)
    return P(_fit_axis(batch, tuple(rules.batch_axes), mesh), None)


def paged_pool_spec(n_pages: int, mesh: Mesh, rules: ShardingRules,
                    ndim: int = 4) -> P:
    """Spec for the physical KV pools [n_pages, page_size, KH, hd] (and the
    [n_pages, page_size] position pool with ndim=2). The PAGE dim shards
    over "model" — the paged analogue of the dense cache sharding its
    sequence dim there (kv-head counts rarely divide the TP axis; page
    counts are chosen to) — so pool HBM scales down with TP size and the
    per-page decode gather stays shard-local for owned pages."""
    del rules
    return P(_fit_axis(n_pages, "model", mesh), *([None] * (ndim - 1)))


def transfer_payload_spec(ndim: int) -> P:
    """Spec for a KV-handoff page payload ``[n, page_size, ...]``
    (disaggregated serving, DESIGN.md §10): fully replicated. The gathered
    pages are about to cross the group boundary, so pinning them to the
    source pool's page-dim sharding would force a resharding mid-transfer;
    chunks are a handful of pages, and the destination scatter re-lands
    them into the decode pool's own ``paged_pool_spec`` sharding."""
    return P(*([None] * ndim))


def batch_spec(rules: ShardingRules, ndim: int, *, seq_axis=None) -> P:
    """Spec for token-shaped arrays [batch, seq, ...]."""
    parts = [rules.batch_axes] + [None] * (ndim - 1)
    if seq_axis is not None and ndim >= 2:
        parts[1] = seq_axis
    return P(*parts)


def constraint(x, spec: P):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def make_constrainer(rules: ShardingRules, mesh: Mesh):
    """Activation-sharding constrainer injected into RunConfig.

    Pins activation shardings — without this, GSPMD falls back to
    replication when a dim isn't evenly divisible (e.g. 24 heads over
    model=16) and S^2-sized attention intermediates get replicated across
    the TP axis.
    """
    def constrain(x, axes):
        # NB: unlike jit in_shardings, constraints tolerate non-dividing
        # dims (GSPMD pads) — 56 heads over model=16 stays sharded. Only
        # dims SMALLER than the axis product are dropped (degenerate).
        parts = []
        for dim, a in zip(x.shape, axes):
            ax = rules.act_axes(a)
            if ax is not None:
                axs = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a_ in axs:
                    n *= mesh.shape[a_]
                if dim < n:
                    ax = _fit_axis(dim, ax, mesh)
            parts.append(ax)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*parts)))
    return constrain
