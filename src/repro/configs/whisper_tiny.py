"""whisper-tiny — enc-dec audio backbone; conv frontend stubbed to
precomputed frame embeddings per the brief. [arXiv:2212.04356]"""

from repro.models.config import LayerSpec, ModelConfig
from repro.models.registry import register


@register("whisper-tiny")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,          # decoder layers
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        pattern=(LayerSpec(mixer="attn", ffn="dense", cross_attn=True),),
        n_encoder_layers=4,
        encoder_seq=1500,    # stub frontend: 30 s of 10 ms mel frames / 2
        norm="layernorm",
        mlp_act="gelu",
        rope_theta=0.0,      # no rope
        learned_pos=True,    # learned absolute positions
        max_seq_len=32768,   # stretched for the assigned decode_32k cell
    )
