"""Architecture configs: the 10 assigned archs + the paper's Mixtral set.

Importing this package registers every config with the model registry.
"""

from repro.configs import (dbrx_132b, llama3_2_3b, llama3_2_vision_90b,
                           mamba2_2_7b, mixtral_paper, qwen3_32b,
                           qwen3_moe_30b_a3b, recurrentgemma_9b,
                           starcoder2_15b, whisper_tiny, yi_34b)
from repro.configs.inputs import input_specs, make_batch

ASSIGNED = [
    "mamba2-2.7b", "yi-34b", "llama3.2-3b", "starcoder2-15b", "qwen3-32b",
    "recurrentgemma-9b", "whisper-tiny", "llama-3.2-vision-90b",
    "dbrx-132b", "qwen3-moe-30b-a3b",
]

PAPER_MODELS = ["mixtral-w1", "mixtral-w2", "mixtral-d1", "mixtral-d2",
                "mixtral-d3"]

__all__ = ["ASSIGNED", "PAPER_MODELS", "input_specs", "make_batch"]
