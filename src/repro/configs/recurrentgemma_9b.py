"""recurrentgemma-9b — RG-LRU + local attention, 1:2. [arXiv:2402.19427]"""

from repro.models.config import LayerSpec, ModelConfig
from repro.models.registry import register


@register("recurrentgemma-9b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,         # 12 x (rglru, rglru, local_attn) + 2 rglru tail
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,        # MQA
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        pattern=(
            LayerSpec(mixer="rglru", ffn="dense"),
            LayerSpec(mixer="rglru", ffn="dense"),
            LayerSpec(mixer="local_attn", ffn="dense"),
        ),
        window=2048,
        lru_width=4096,
        conv_width=4,
        emb_scale=True,
        tie_embeddings=True,
        rope_theta=1e4,
    )
