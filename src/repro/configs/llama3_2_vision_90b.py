"""llama-3.2-vision-90b — dense backbone with interleaved cross-attention
image layers (every 5th); vision tower stubbed to precomputed patch
embeddings per the brief. [hf:meta-llama/Llama-3.2-90B-Vision]"""

from repro.models.config import LayerSpec, ModelConfig
from repro.models.registry import register


@register("llama-3.2-vision-90b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        n_layers=100,        # 20 x (4 self-attn + 1 cross-attn block)
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        pattern=(
            LayerSpec(mixer="attn", ffn="dense"),
            LayerSpec(mixer="attn", ffn="dense"),
            LayerSpec(mixer="attn", ffn="dense"),
            LayerSpec(mixer="attn", ffn="dense"),
            LayerSpec(mixer="none", ffn="dense", cross_attn=True),
        ),
        vision_seq=1601,     # (560/14)^2 + cls, one tile
        vision_dim=1280,
        rope_theta=5e5,
    )
