"""qwen3-32b — dense GQA with qk_norm. [hf:Qwen/Qwen3-32B]"""

from repro.models.config import LayerSpec, ModelConfig
from repro.models.registry import register


@register("qwen3-32b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,        # explicit (qwen3 decouples from d_model/n_heads)
        d_ff=25600,
        vocab_size=151936,
        pattern=(LayerSpec(mixer="attn", ffn="dense"),),
        qk_norm=True,
        rope_theta=1e6,
    )
