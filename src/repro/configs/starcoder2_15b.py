"""starcoder2-15b — GQA + RoPE code model. [arXiv:2402.19173; hf]"""

from repro.models.config import LayerSpec, ModelConfig
from repro.models.registry import register


@register("starcoder2-15b")
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        d_ff=24576,
        vocab_size=49152,
        pattern=(LayerSpec(mixer="attn", ffn="dense"),),
        rope_theta=1e5,
        norm="layernorm",
        mlp_act="gelu",
    )
