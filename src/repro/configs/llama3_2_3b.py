"""llama3.2-3b — small llama3. [hf:meta-llama/Llama-3.2-3B]"""

from repro.models.config import LayerSpec, ModelConfig
from repro.models.registry import register


@register("llama3.2-3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        pattern=(LayerSpec(mixer="attn", ffn="dense"),),
        rope_theta=5e5,
        tie_embeddings=True,
    )
