"""qwen3-moe-30b-a3b — 128 experts top-8, fine-grained. [hf:Qwen/Qwen3-30B-A3B]"""

from repro.models.config import LayerSpec, ModelConfig
from repro.models.registry import register


@register("qwen3-moe-30b-a3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,
        d_ff_expert=768,
        vocab_size=151936,
        pattern=(LayerSpec(mixer="attn", ffn="moe"),),
        n_experts=128,
        top_k=8,
        qk_norm=True,
        rope_theta=1e6,
        capacity_factor=1.25,
    )
