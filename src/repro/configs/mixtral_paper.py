"""The paper's own evaluation models (Table 2): Mixtral-architecture MoEs.

| Model      | #Layers | Hidden | #Experts | #Params |
|------------|---------|--------|----------|---------|
| Mixtral-W1 | 4       | 2048   | 12       | 2.2B    |
| Mixtral-W2 | 4       | 2048   | 24       | 4.3B    |
| Mixtral-D1 | 8       | 1024   | 24       | 2.1B    |
| Mixtral-D2 | 6       | 1024   | 18       | 1.2B    |
| Mixtral-D3 | 8       | 1024   | 40       | 3.5B    |

Top-2 gating (paper §6.1), Mixtral ratios: d_ff = 3.5 d, heads = d/128,
kv = heads/4, vocab 32000.
"""

from repro.models.config import LayerSpec, ModelConfig
from repro.models.registry import register


def _mixtral(name, n_layers, d_model, n_experts) -> ModelConfig:
    return ModelConfig(
        name=name,
        family="moe",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=d_model // 128,
        n_kv_heads=max(d_model // 512, 1),
        d_ff=int(3.5 * d_model),
        d_ff_expert=int(3.5 * d_model),
        vocab_size=32000,
        pattern=(LayerSpec(mixer="attn", ffn="moe"),),
        n_experts=n_experts,
        top_k=2,
        rope_theta=1e6,
    )


@register("mixtral-w1")
def config_w1() -> ModelConfig:
    return _mixtral("mixtral-w1", 4, 2048, 12)


@register("mixtral-w2")
def config_w2() -> ModelConfig:
    return _mixtral("mixtral-w2", 4, 2048, 24)


@register("mixtral-d1")
def config_d1() -> ModelConfig:
    return _mixtral("mixtral-d1", 8, 1024, 24)


@register("mixtral-d2")
def config_d2() -> ModelConfig:
    return _mixtral("mixtral-d2", 6, 1024, 18)


@register("mixtral-d3")
def config_d3() -> ModelConfig:
    return _mixtral("mixtral-d3", 8, 1024, 40)
