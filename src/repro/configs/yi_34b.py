"""yi-34b — llama-arch dense GQA. [arXiv:2403.04652; hf]"""

from repro.models.config import LayerSpec, ModelConfig
from repro.models.registry import register


@register("yi-34b")
def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b",
        family="dense",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20480,
        vocab_size=64000,
        pattern=(LayerSpec(mixer="attn", ffn="dense"),),
        rope_theta=5e6,
    )
