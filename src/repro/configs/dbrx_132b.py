"""dbrx-132b — MoE, 16 experts top-4 fine-grained. [hf:databricks/dbrx-base]"""

from repro.models.config import LayerSpec, ModelConfig
from repro.models.registry import register


@register("dbrx-132b")
def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        d_ff_expert=10752,
        vocab_size=100352,
        pattern=(LayerSpec(mixer="attn", ffn="moe"),),
        n_experts=16,
        top_k=4,
        rope_theta=5e5,
        capacity_factor=1.25,
    )
