"""mamba2-2.7b — SSD (state-space duality), attention-free. [arXiv:2405.21060]"""

from repro.models.config import LayerSpec, ModelConfig
from repro.models.registry import register


@register("mamba2-2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        n_layers=64,
        d_model=2560,
        n_heads=80,          # din / ssm_head_dim = 5120 / 64
        n_kv_heads=80,
        head_dim=64,
        d_ff=0,              # no separate MLP: the SSD block is the layer
        vocab_size=50280,
        pattern=(LayerSpec(mixer="ssd", ffn="none"),),
        ssm_state=128,
        ssm_heads=80,
        ssm_head_dim=64,
        ssm_chunk=256,
        ssm_expand=2,
        conv_width=4,
        tie_embeddings=True,
        rope_theta=0.0,      # no positional encoding (recurrence carries it)
    )
