"""ShapeDtypeStruct stand-ins + concrete batches for every (arch x shape).

``input_specs`` is the dry-run contract: weak-type-correct, shardable, no
device allocation. Modality frontends are stubbed per the brief —
whisper receives precomputed mel-frame embeddings, the VLM receives
precomputed patch embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, SHAPES, ShapeConfig


def _shape(shape_or_name) -> ShapeConfig:
    if isinstance(shape_or_name, str):
        return SHAPES[shape_or_name]
    return shape_or_name


def input_specs(cfg: ModelConfig, shape_or_name, compute_dtype=jnp.bfloat16):
    """Dict of jax.ShapeDtypeStruct for one input-shape cell."""
    sc = _shape(shape_or_name)
    B = sc.global_batch
    S = 1 if sc.kind == "decode" else sc.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if sc.kind == "train":
        specs["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.is_encdec:
        specs["encoder_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), compute_dtype)
    if cfg.vision_seq > 0:
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_seq, cfg.vision_dim or cfg.d_model), compute_dtype)
    return specs


def make_batch(key, cfg: ModelConfig, shape_or_name, compute_dtype=jnp.bfloat16):
    """Concrete random batch with the same structure as input_specs."""
    sc = _shape(shape_or_name)
    specs = input_specs(cfg, sc, compute_dtype)
    out = {}
    for name, spec in specs.items():
        key = jax.random.fold_in(key, hash(name) % (2 ** 31))
        if jnp.issubdtype(spec.dtype, jnp.integer):
            out[name] = jax.random.randint(key, spec.shape, 0,
                                           cfg.vocab_size, spec.dtype)
        else:
            out[name] = jax.random.normal(key, spec.shape, spec.dtype)
    return out
