"""Elastic training controller: failure -> replan -> reshard -> resume.

The control loop a 1000-node deployment runs around the train step:

  1. StragglerDetector flags a degraded expert group           (soft)
     -> planner.replan with the measured slow_factor: Asym-EA moves expert
        chunks onto the attention group; no restart, no data loss.
  2. HeartbeatMonitor declares hosts dead                       (hard)
     -> shrink the ZP group (M' = M - lost_attn, N' = N - lost_exp),
        planner.replan validates divisibility (expert count padding if
        needed), CheckpointManager.restore re-shards the latest snapshot
        onto the new mesh (placement comes from logical axes, never device
        ids), DataLoader resumes from the recorded step.

Both paths are exercised end-to-end (CPU-scale) in tests/test_ft.py.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from repro.core import planner as planner_mod
from repro.core.planner import ZebraPlan
from repro.ft.monitor import HeartbeatMonitor, StragglerDetector
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ElasticEvent:
    kind: str  # "straggler-replan" | "shrink" | "none"
    detail: str
    plan: Optional[ZebraPlan] = None


class ElasticController:
    def __init__(self, cfg: ModelConfig, plan: ZebraPlan, global_batch: int,
                 seq_len: int, attn_hosts: List[str], exp_hosts: List[str],
                 heartbeat: Optional[HeartbeatMonitor] = None,
                 detector: Optional[StragglerDetector] = None):
        self.cfg = cfg
        self.plan = plan
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.attn_hosts = list(attn_hosts)
        self.exp_hosts = list(exp_hosts)
        self.heartbeat = heartbeat or HeartbeatMonitor(
            attn_hosts + exp_hosts)
        self.detector = detector or StragglerDetector(["attn", "exp"])

    def record_step(self, attn_time: float, exp_time: float):
        self.detector.record("attn", attn_time)
        self.detector.record("exp", exp_time)

    def tick(self) -> ElasticEvent:
        """One control-loop iteration; returns the action taken."""
        dead = set(self.heartbeat.dead_hosts())
        if dead:
            lost_a = sum(1 for h in self.attn_hosts if h in dead)
            lost_e = sum(1 for h in self.exp_hosts if h in dead)
            self.attn_hosts = [h for h in self.attn_hosts if h not in dead]
            self.exp_hosts = [h for h in self.exp_hosts if h not in dead]
            self.plan = planner_mod.replan(
                self.cfg, self.plan, self.global_batch, self.seq_len,
                lost_attn=lost_a, lost_exp=lost_e)
            return ElasticEvent(
                "shrink",
                f"lost {lost_a} attention / {lost_e} expert hosts; "
                f"new ZP group M={self.plan.zp.M} N={self.plan.zp.N}, "
                f"offload={sum(self.plan.offload)}",
                self.plan)

        slow = self.detector.stragglers()
        if "exp" in slow:
            factor = self.detector.slow_factor("exp")
            self.plan = planner_mod.replan(
                self.cfg, self.plan, self.global_batch, self.seq_len,
                slow_factor=factor)
            return ElasticEvent(
                "straggler-replan",
                f"expert group {factor:.2f}x slow; Asym-EA offload now "
                f"{sum(self.plan.offload)} experts/GPU total",
                self.plan)
        return ElasticEvent("none", "healthy")
