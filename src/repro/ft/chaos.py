"""Deterministic fault injection for the serving fleet (DESIGN.md §13).

A chaos run is fully determined by ``(seed, spec)``: the spec names WHICH
faults can fire (site, target, arming tick, probability, budget) and the
seed drives the only randomness (per-opportunity coin flips), so any
failure observed once replays identically — the injector's event log is
the proof, and ``log_signature()`` is the one-line fingerprint CI can
compare across runs.

Spec grammar (entries joined by ``;``)::

    SITE[@TICK][:TARGET][%PROB][*COUNT][~DURATION]

* ``SITE`` — one of the named hook points below;
* ``@TICK`` — armed from that controller tick on (default: immediately);
* ``:TARGET`` — a group name (``g3``) or ``*`` (default) for any target.
  Link-fault sites (drop/corrupt/stall) are matched against the
  RECEIVING group's name;
* ``%PROB`` — per-opportunity firing probability in (0, 1] (default 1);
* ``*COUNT`` — total firing budget (default 1);
* ``~DURATION`` — window length in ticks, ``hb_loss`` only (default 1).

Sites (the hook points the serving stack consults):

===================== ====================================================
``drop``              transfer chunk lost on the wire (receiver timeout)
``corrupt``           transfer chunk arrives bit-flipped (checksum catch)
``stall``             link stall after delivery: the ack is lost and the
                      sender must replay the chunk (idempotent re-apply)
``hb_loss``           heartbeats suppressed for ``~DURATION`` ticks while
                      the group keeps computing — the zombie/flap window
``crash_start``       group crashes at the start of a tick
``crash_post_prefill`` group crashes right after its prefill step
``crash_mid_export``  source group crashes between transfer chunks
``crash_mid_import``  destination group crashes between transfer chunks
===================== ====================================================

Malformed specs raise ``ValueError`` at parse time — the driver turns
that into a non-zero exit, never a silently-ignored fault plan.
"""

from __future__ import annotations

import dataclasses
import hashlib
import random
import re
from typing import List, Optional, Tuple

LINK_SITES = ("drop", "corrupt", "stall")
CRASH_SITES = ("crash_start", "crash_post_prefill", "crash_mid_export",
               "crash_mid_import")
WINDOW_SITES = ("hb_loss",)
SITES = LINK_SITES + CRASH_SITES + WINDOW_SITES

_ENTRY = re.compile(
    r"^(?P<site>[a-z_]+)"
    r"(?:@(?P<tick>\d+))?"
    r"(?::(?P<target>\w+|\*))?"
    r"(?:%(?P<prob>[0-9.]+))?"
    r"(?:\*(?P<count>\d+))?"
    r"(?:~(?P<duration>\d+))?$")


class GroupCrashed(Exception):
    """A chaos crash fired mid-transfer. ``role`` says which end died
    ('src' | 'dst'); ``name`` is the group name the spec targeted."""

    def __init__(self, role: str, name: str):
        super().__init__(f"{role} group {name} crashed mid-transfer")
        self.role = role
        self.name = name


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One parsed spec entry."""

    site: str
    tick: Optional[int] = None   # armed at tick >= this (None: always)
    target: str = "*"
    prob: float = 1.0
    count: int = 1
    duration: int = 1            # window sites only

    def matches(self, site: str, target: str) -> bool:
        return self.site == site \
            and (self.target == "*" or self.target == target)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fired fault — the replayable event-log record."""

    tick: int
    site: str
    target: str
    seq: int   # firing order, global across sites

    def as_tuple(self) -> Tuple[int, str, str, int]:
        return (self.tick, self.site, self.target, self.seq)


class FaultPlan:
    """An ordered list of :class:`FaultSpec` parsed from a spec string."""

    def __init__(self, specs: List[FaultSpec]):
        self.specs = list(specs)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        if not spec or not spec.strip():
            raise ValueError("empty chaos spec")
        specs = []
        for raw in spec.split(";"):
            raw = raw.strip()
            if not raw:
                continue
            m = _ENTRY.match(raw)
            if m is None:
                raise ValueError(f"malformed chaos entry {raw!r} "
                                 f"(want SITE[@TICK][:TARGET][%PROB]"
                                 f"[*COUNT][~DURATION])")
            site = m.group("site")
            if site not in SITES:
                raise ValueError(f"unknown chaos site {site!r}; "
                                 f"known: {', '.join(SITES)}")
            tick = int(m.group("tick")) if m.group("tick") else None
            target = m.group("target") or "*"
            try:
                prob = float(m.group("prob")) if m.group("prob") else 1.0
            except ValueError:
                raise ValueError(f"bad probability in {raw!r}") from None
            count = int(m.group("count")) if m.group("count") else 1
            duration = int(m.group("duration")) \
                if m.group("duration") else 1
            if not 0.0 < prob <= 1.0:
                raise ValueError(f"probability must be in (0, 1], "
                                 f"got {prob} in {raw!r}")
            if count < 1:
                raise ValueError(f"count must be >= 1 in {raw!r}")
            if duration < 1:
                raise ValueError(f"duration must be >= 1 in {raw!r}")
            if m.group("duration") and site not in WINDOW_SITES:
                raise ValueError(f"~DURATION only applies to window "
                                 f"sites {WINDOW_SITES}, not {site!r}")
            if site in WINDOW_SITES and tick is None:
                raise ValueError(f"{site} needs an explicit @TICK "
                                 f"(the window start) in {raw!r}")
            if site in CRASH_SITES + WINDOW_SITES and target == "*":
                raise ValueError(f"{site} needs an explicit :TARGET "
                                 f"group in {raw!r}")
            specs.append(FaultSpec(site=site, tick=tick, target=target,
                                   prob=prob, count=count,
                                   duration=duration))
        if not specs:
            raise ValueError("empty chaos spec")
        return cls(specs)


class FaultInjector:
    """Seeded runtime half of the chaos layer.

    The serving stack calls ``begin_tick`` once per controller tick, then
    ``fire(site, target)`` at every hook point (consumes one opportunity;
    True means the fault happens NOW) and ``active(site, target)`` for
    window sites like heartbeat loss. All randomness comes from one
    seeded RNG consumed in call order, so the same ``(seed, spec)``
    against the same deterministic workload replays to an identical
    event log.
    """

    def __init__(self, plan: FaultPlan, seed: int = 0):
        self.plan = plan
        self.seed = seed
        self.rng = random.Random(seed)
        self.tick = 0
        self.events: List[FaultEvent] = []
        self._remaining = [s.count for s in plan.specs]
        self._windows_logged: set = set()

    def begin_tick(self, tick: int) -> None:
        self.tick = tick

    def _armed(self, spec: FaultSpec) -> bool:
        return spec.tick is None or self.tick >= spec.tick

    def fire(self, site: str, target: str = "*") -> bool:
        """Consume one fault opportunity at hook ``site`` for ``target``.
        Window sites never fire point-wise (use ``active``)."""
        for i, spec in enumerate(self.plan.specs):
            if spec.site in WINDOW_SITES or self._remaining[i] <= 0 \
                    or not spec.matches(site, target) \
                    or not self._armed(spec):
                continue
            if spec.prob < 1.0 and self.rng.random() >= spec.prob:
                continue
            self._remaining[i] -= 1
            self.events.append(FaultEvent(self.tick, site, target,
                                          len(self.events)))
            self._trace(site, target)
            return True
        return False

    def _trace(self, site: str, target: str) -> None:
        """Mirror a fired fault as an instant on the "chaos" meta track
        (obs §15) so the Perfetto timeline shows every injection."""
        from repro.obs import trace as obs_trace
        tr = obs_trace.TRACER
        if tr.enabled:
            tr.declare_track("chaos", pid="fleet", kind="meta")
            tr.instant("chaos", site, target=target, seq=len(self.events))

    def active(self, site: str, target: str = "*") -> bool:
        """Whether a window fault (``hb_loss``) covers the current tick
        for ``target``. The window opening is logged once."""
        for spec in self.plan.specs:
            if spec.site not in WINDOW_SITES \
                    or not spec.matches(site, target):
                continue
            if spec.tick <= self.tick < spec.tick + spec.duration:
                key = (id(spec), target)
                if key not in self._windows_logged:
                    self._windows_logged.add(key)
                    self.events.append(FaultEvent(self.tick, site, target,
                                                  len(self.events)))
                    self._trace(site, target)
                return True
        return False

    # -- replay proof --------------------------------------------------------

    def log(self) -> List[Tuple[int, str, str, int]]:
        return [e.as_tuple() for e in self.events]

    def log_signature(self) -> str:
        """Stable fingerprint of the event log: equal signatures mean the
        same faults fired at the same ticks in the same order."""
        blob = ";".join(f"{t}:{s}:{g}:{q}" for t, s, g, q in self.log())
        return hashlib.sha256(blob.encode()).hexdigest()[:16]
