from repro.ft.chaos import (FaultEvent, FaultInjector, FaultPlan, FaultSpec,
                            GroupCrashed)
from repro.ft.elastic import ElasticController, ElasticEvent
from repro.ft.monitor import (HeartbeatConfig, HeartbeatMonitor,
                              StragglerDetector)

__all__ = ["ElasticController", "ElasticEvent", "HeartbeatConfig",
           "HeartbeatMonitor", "StragglerDetector", "FaultEvent",
           "FaultInjector", "FaultPlan", "FaultSpec", "GroupCrashed"]
