"""Fault tolerance: heartbeats, straggler detection, elastic response.

Designed for 1000+ node fleets: per-host heartbeat tracking with grace
windows; per-step timing ring buffers with robust (median/MAD) outlier
detection; and a response policy that prefers *re-balancing over eviction* —
a straggling expert group is first handled by Asym-EA replanning (shift
expert work onto the healthy attention group: the same mechanism that
absorbs generation gaps absorbs degradation), and only persistent failures
trigger elastic shrink + checkpoint restore.
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class HeartbeatConfig:
    interval_s: float = 10.0
    grace_multiplier: float = 3.0


class HeartbeatMonitor:
    """Host-level liveness. Hosts call beat(); the coordinator calls
    dead_hosts() each scheduling tick."""

    def __init__(self, hosts: List[str], cfg: HeartbeatConfig = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg or HeartbeatConfig()
        self.clock = clock
        now = clock()
        self.last_seen: Dict[str, float] = {h: now for h in hosts}

    def beat(self, host: str):
        self.last_seen[host] = self.clock()

    def add(self, host: str):
        """Group join (fleet elasticity): starts with a fresh grace window."""
        self.last_seen[host] = self.clock()

    def remove(self, host: str):
        """Group leave / declared-dead eviction: stop tracking it so
        dead_hosts() converges after the coordinator has reacted."""
        self.last_seen.pop(host, None)

    def dead_hosts(self) -> List[str]:
        cutoff = self.clock() - self.cfg.interval_s * \
            self.cfg.grace_multiplier
        return [h for h, t in self.last_seen.items() if t < cutoff]


class StragglerDetector:
    """Per-group step-time statistics with median/MAD z-scores.

    A group whose recent step times exceed median + z_thresh * 1.4826*MAD
    for `patience` consecutive windows is flagged."""

    def __init__(self, groups: List[str], window: int = 20,
                 z_thresh: float = 4.0, patience: int = 3):
        self.window = window
        self.z = z_thresh
        self.patience = patience
        self.times: Dict[str, deque] = {g: deque(maxlen=window)
                                        for g in groups}
        self.strikes: Dict[str, int] = {g: 0 for g in groups}

    def record(self, group: str, step_time: float):
        self.times[group].append(step_time)

    def add(self, group: str):
        self.times.setdefault(group, deque(maxlen=self.window))
        self.strikes.setdefault(group, 0)

    def remove(self, group: str):
        self.times.pop(group, None)
        self.strikes.pop(group, None)

    def _stats(self):
        all_recent = [t for d in self.times.values() for t in d]
        if len(all_recent) < 4:
            return None
        s = sorted(all_recent)
        med = s[len(s) // 2]
        mad = sorted(abs(x - med) for x in s)[len(s) // 2]
        return med, max(mad, 1e-9)

    def stragglers(self) -> List[str]:
        st = self._stats()
        if st is None:
            return []
        med, mad = st
        out = []
        for g, d in self.times.items():
            if not d:
                continue
            recent = sum(list(d)[-3:]) / min(len(d), 3)
            zscore = (recent - med) / (1.4826 * mad)
            if zscore > self.z:
                self.strikes[g] += 1
            else:
                self.strikes[g] = 0
            if self.strikes[g] >= self.patience:
                out.append(g)
        return out

    def slow_factor(self, group: str) -> float:
        st = self._stats()
        if st is None or not self.times[group]:
            return 1.0
        med, _ = st
        recent = sum(list(self.times[group])[-3:]) / \
            min(len(self.times[group]), 3)
        return max(recent / med, 1.0)
