"""AdamW in pure JAX with ZeRO-1-style sharded optimizer states.

Master weights and moments are f32; compute casts to bf16 happen inside the
model (mixed precision per the paper's §6.1 setup). Optimizer states are
sharded like their params, and for params replicated on some mesh axis the
largest dim is additionally sharded over "data" (ZeRO-1): states are only
ever touched by elementwise updates, so any layout works, and the update's
all-gather overlaps with the next step's forward under XLA's scheduler.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.pytree import axes_map


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    end_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_schedule(cfg: OptimizerConfig, step):
    """Linear warmup + cosine decay to end_lr_frac * peak."""
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.peak_lr * (cfg.end_lr_frac + (1 - cfg.end_lr_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, master_weights: bool = False):
    """master_weights: keep an f32 master copy in the optimizer state so
    params themselves can be stored bf16 (halves parameter HBM and FSDP
    all-gather traffic; the f32 master lives ZeRO-sharded)."""
    st = {
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }
    if master_weights:
        st["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return st


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptimizerConfig, params, grads, state):
    """Returns (new_params, new_state, metrics). If the state carries
    master weights, updates apply to the f32 master and params are the
    bf16 cast."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    masters = state.get("master")

    def upd(p, g, mu, nu, master):
        base = master if master is not None else p.astype(jnp.float32)
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * base
        new_master = base - lr * delta
        return new_master.astype(p.dtype), mu, nu, new_master

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    flat_ma = jax.tree.leaves(masters) if masters is not None \
        else [None] * len(flat_p)
    out = [upd(p, g, m, n, ma) for p, g, m, n, ma
           in zip(flat_p, flat_g, flat_mu, flat_nu, flat_ma)]
    new_state = {
        "mu": jax.tree.unflatten(tdef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(tdef, [o[2] for o in out]),
        "step": step,
    }
    if masters is not None:
        new_state["master"] = jax.tree.unflatten(tdef, [o[3] for o in out])
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_state_axes(param_axes, zero1_axis: Optional[str] = "zero",
                   master_weights: bool = False):
    """Logical axes for optimizer states: same as params, but fully
    replicated tensors get their first dim tagged with `zero1_axis` (mapped
    to 'data' in the sharding rules) — ZeRO-1 partitioning."""
    def moment_axes(a):
        if zero1_axis and all(x is None for x in a) and len(a) >= 1:
            return (zero1_axis,) + tuple(a[1:])
        return a
    st = {
        "mu": axes_map(moment_axes, param_axes),
        "nu": axes_map(moment_axes, param_axes),
        "step": (),
    }
    if master_weights:
        st["master"] = axes_map(moment_axes, param_axes)
    return st
