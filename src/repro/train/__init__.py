from repro.train import loss, optimizer, step

__all__ = ["loss", "optimizer", "step"]
