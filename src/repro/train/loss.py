"""Losses: causal-LM cross entropy (+ z-loss) and MoE aux combination."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, targets, z_loss_coef: float = 1e-4,
                  mask=None):
    """logits: [..., V] (f32 recommended); targets: [...] int32.

    Returns (loss, metrics). z-loss regularizes logsumexp drift (large-scale
    training stabilizer).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    zl = jnp.square(lse)
    if mask is not None:
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        nll = jnp.sum(nll * mask) / denom
        zl = jnp.sum(zl * mask) / denom
    else:
        nll = jnp.mean(nll)
        zl = jnp.mean(zl)
    loss = nll + z_loss_coef * zl
    return loss, {"nll": nll, "z_loss": zl}


def total_loss(logits, targets, aux, z_loss_coef: float = 1e-4, mask=None):
    """LM loss + MoE auxiliary losses (already coefficient-scaled)."""
    loss, metrics = cross_entropy(logits, targets, z_loss_coef, mask)
    loss = loss + aux.get("moe_aux_loss", 0.0) + aux.get("moe_z_loss", 0.0)
    metrics.update({k: v for k, v in aux.items()})
    metrics["loss"] = loss
    return loss, metrics


def chunked_xent_from_hidden(hidden, table, targets, *, chunk: int = 512,
                             z_loss_coef: float = 1e-4, accum_dtype=jnp.float32,
                             unroll: bool = False, constrain=None):
    """Cross entropy streamed over sequence chunks, never materializing the
    full [B, S, V] f32 logits (a several-GB temp at 128k vocabularies).

    hidden: [B, S, d]; table: [V, d] (lm head or tied embedding).
    Backward recomputes each chunk's logits (jax.checkpoint).
    """
    B, S, d = hidden.shape
    c = min(chunk, S)
    pad = (-S) % c
    valid = jnp.ones((B, S), bool)
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    n = (S + pad) // c
    hs = jnp.moveaxis(hidden.reshape(B, n, c, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, n, c), 1, 0)
    vs = jnp.moveaxis(valid.reshape(B, n, c), 1, 0)
    if constrain is not None:
        # keep the chunk stream batch-sharded (the reshape otherwise lets
        # GSPMD fall back to a data-only layout through the scan carries)
        hs = constrain(hs, (None, "batch", None, None))
        ts = constrain(ts, (None, "batch", None))
        vs = constrain(vs, (None, "batch", None))

    @jax.checkpoint
    def block(h, t, v):
        logits = jnp.einsum("bsd,vd->bsv", h, table,
                            preferred_element_type=accum_dtype)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return (jnp.sum(jnp.where(v, lse - gold, 0.0)),
                jnp.sum(jnp.where(v, jnp.square(lse), 0.0)))

    if n == 1:
        nll, zl = block(hs[0], ts[0], vs[0])
    elif unroll:
        parts = [block(hs[i], ts[i], vs[i]) for i in range(n)]
        nll = sum(p[0] for p in parts)
        zl = sum(p[1] for p in parts)
    else:
        def step(carry, xt):
            a, b = block(*xt)
            return (carry[0] + a, carry[1] + b), None

        (nll, zl), _ = jax.lax.scan(
            step, (jnp.zeros((), accum_dtype), jnp.zeros((), accum_dtype)),
            (hs, ts, vs))
    denom = B * S
    nll = nll / denom
    zl = zl / denom
    return nll + z_loss_coef * zl, {"nll": nll, "z_loss": zl}
