"""Gradient compression with error feedback (DCN/pod-axis reducer).

For cross-pod data parallelism the gradient all-reduce rides DCN, which is
an order of magnitude slower than ICI. Standard mitigation: quantize the
per-pod gradient contribution to int8 with a per-tensor scale before the
reduction and keep the quantization residual in an error-feedback buffer
(added back the next step) so the compression bias vanishes over time
(1-bit Adam / PowerSGD lineage).

`compressed_psum` is the shard_map-compatible reducer used by the elastic
controller's cross-pod path; `compress`/`decompress`/`apply_error_feedback`
are the building blocks, unit-tested for convergence parity in
tests/test_train_ckpt_ft.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(g, *, bits: int = 8):
    """Per-tensor symmetric int quantization. Returns (q, scale)."""
    assert bits in (8,), "int8 is the supported wire format"
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress(q, scale):
    return q.astype(jnp.float32) * scale


def apply_error_feedback(g, err):
    """Add residual from the previous step; returns (g_corrected, fn) where
    fn(decompressed) produces the new residual."""
    g_corr = g.astype(jnp.float32) + err

    def new_err(g_hat):
        return g_corr - g_hat

    return g_corr, new_err


def compress_tree(grads, err_state):
    """Compress a gradient tree with error feedback.

    Returns (q_tree, scale_tree, new_err_fn) — new_err_fn must be called
    with the *decompressed* tree actually applied (post-reduction mean) to
    compute the stored residual."""
    corrected = jax.tree.map(
        lambda g, e: apply_error_feedback(g, e)[0], grads, err_state)
    qs = jax.tree.map(lambda g: compress(g)[0], corrected)
    scales = jax.tree.map(lambda g: compress(g)[1], corrected)

    def new_err(applied):
        return jax.tree.map(lambda c, a: c - a, corrected, applied)

    return qs, scales, new_err


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, err_state, axis_name: str):
    """Error-feedback int8 psum over `axis_name` (inside shard_map).

    Each participant quantizes its corrected contribution; the reduction
    sums dequantized tensors (wire bytes: 1/4 of f32, 1/2 of bf16).
    Returns (mean_grads, new_err_state).
    """
    qs, scales, new_err = compress_tree(grads, err_state)
    local_hat = jax.tree.map(decompress, qs, scales)
    summed = jax.tree.map(lambda x: jax.lax.psum(x, axis_name), local_hat)
    n = jax.lax.psum(1, axis_name)
    mean = jax.tree.map(lambda x: x / n, summed)
    return mean, new_err(local_hat)
