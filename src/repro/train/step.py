"""Jitted training / serving step builders with full sharding plumbing.

`TrainProgram` is the single object the launcher, the dry-run, and the tests
share: abstract param/opt shapes, NamedShardings derived from logical axes,
and the jitted step functions.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import zebra_spmd
from repro.models import stack
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.modules import RunConfig
from repro.pytree import split_params, tree_map_with_path_names
from repro.sharding.rules import ShardingRules, rules_for, specs_for
from repro.train import optimizer as opt
from repro.train.loss import total_loss


def fit_batch_axes(batch: int, mesh: Mesh, axes: tuple) -> tuple:
    """Largest prefix of `axes` whose product divides `batch`."""
    out = []
    prod = 1
    for a in axes:
        prod *= mesh.shape[a]
        if batch % prod == 0:
            out.append(a)
        else:
            break
    return tuple(out)


@dataclasses.dataclass
class TrainProgram:
    cfg: ModelConfig
    run: RunConfig
    mesh: Mesh
    rules: ShardingRules
    opt_cfg: opt.OptimizerConfig
    zcfg: Optional[zebra_spmd.ZebraConfig]
    param_shapes: object
    param_shardings: object
    opt_shardings: object
    batch_shardings: object
    train_step: Callable  # (params, opt_state, batch) -> (params, opt, metrics)
    loss_fn: Callable

    def init_params(self, seed: int = 0):
        """Materialize sharded params on the mesh."""
        init = functools.partial(self._init_values, seed)
        with self.mesh:
            return jax.jit(init, out_shardings=self.param_shardings)()

    def _init_values(self, seed):
        from repro.pytree import cast_tree
        vals = split_params(
            stack.init_model(jax.random.PRNGKey(seed), self.cfg))[0]
        return cast_tree(vals, self.run.policy.param_dtype)

    @property
    def master_weights(self) -> bool:
        import jax.numpy as jnp
        return jnp.dtype(self.run.policy.param_dtype) != jnp.float32

    def init_opt(self, params):
        with self.mesh:
            return jax.jit(
                functools.partial(opt.init_opt_state,
                                  master_weights=self.master_weights),
                out_shardings=self.opt_shardings)(params)


def _zero1_rules(rules: ShardingRules) -> ShardingRules:
    r = dict(rules.rules)
    r["zero"] = "data"
    return dataclasses.replace(rules, rules=r)


def make_train_program(cfg: ModelConfig, mesh: Mesh, run: RunConfig,
                       shape: ShapeConfig,
                       opt_cfg: Optional[opt.OptimizerConfig] = None,
                       zcfg: Optional[zebra_spmd.ZebraConfig] = None,
                       donate: bool = True,
                       constrain_grads: bool = False,
                       accum_steps: int = 1) -> TrainProgram:
    opt_cfg = opt_cfg or opt.OptimizerConfig()
    if cfg.is_moe:
        variant = "hybrid" if (zcfg is not None
                               and zcfg.mode == "replicated") else "ep"
    else:
        variant = "default"
    rules = rules_for(cfg, mesh, variant=variant)
    if zcfg is not None:
        zb = fit_batch_axes(shape.global_batch, mesh, rules.batch_axes)
        nsh = 1
        for a in zb:
            nsh *= mesh.shape[a]
        R = zcfg.num_microbatches
        B = shape.global_batch
        while R > 1 and (B % R or (B // R) % nsh):
            R -= 1  # microbatches must keep the batch shardable
        zcfg = dataclasses.replace(zcfg, batch_axes=zb, num_microbatches=R)
        if cfg.is_moe and zcfg.mode == "alltoall":
            # Chunked-dispatch knobs: the remote expert count must divide
            # over the EP axis after Asym-EA offload; shrink the offload
            # until it does rather than failing inside the engine.
            n_ep = mesh.shape[zcfg.ep_axis]
            off = max(min(zcfg.offload_experts, cfg.n_experts - n_ep), 0)
            while off and (cfg.n_experts - off) % n_ep:
                off -= 1
            zcfg = dataclasses.replace(zcfg, offload_experts=off,
                                       n_chunks=max(int(zcfg.n_chunks), 1))

    # Abstract shapes + shardings ------------------------------------------------
    from repro.pytree import cast_tree
    from repro.sharding.rules import fitted_shardings
    pshapes, paxes = abstract_params(cfg)
    pshapes = jax.eval_shape(lambda t: cast_tree(t, run.policy.param_dtype),
                             pshapes)
    psh = fitted_shardings(pshapes, paxes, rules, mesh)
    master = jnp.dtype(run.policy.param_dtype) != jnp.float32
    oshapes = jax.eval_shape(
        lambda t: opt.init_opt_state(t, master_weights=master), pshapes)
    o_axes = opt.opt_state_axes(paxes, master_weights=master)
    osh = fitted_shardings(oshapes, o_axes, _zero1_rules(rules), mesh)

    baxes = fit_batch_axes(shape.global_batch, mesh, rules.batch_axes)
    bsh = NamedSharding(mesh, P(baxes))

    from repro.sharding.rules import make_constrainer
    act_rules = dataclasses.replace(rules, batch_axes=baxes)
    run = dataclasses.replace(run, constrain=make_constrainer(act_rules, mesh))

    override = None
    if zcfg is not None and cfg.is_moe:
        override = zebra_spmd.make_layer_override(mesh, cfg, run, zcfg)

    def loss_fn(params, batch):
        hidden, _, aux = stack.apply_model(
            params, cfg, run, batch["tokens"],
            encoder_embeds=batch.get("encoder_embeds"),
            vision_embeds=batch.get("vision_embeds"),
            layer_override=override, return_hidden=True)
        table = params.get("lm_head", params["embed"]["table"])
        from repro.train.loss import chunked_xent_from_hidden
        loss, metrics = chunked_xent_from_hidden(
            hidden, table.astype(run.policy.compute_dtype),
            batch["targets"], unroll=cfg.unroll, constrain=run.constrain)
        loss = loss + aux.get("moe_aux_loss", 0.0) + aux.get("moe_z_loss", 0.0)
        metrics = dict(metrics, **aux, loss=loss)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if accum_steps > 1:
            # Gradient accumulation: scan over batch slices, mean grads.
            B = shape.global_batch
            assert B % accum_steps == 0

            def slice_batch(b, i):
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // accum_steps),
                        x.shape[0] // accum_steps, axis=0), b)

            def accum_body(carry, i):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, slice_batch(batch, i))
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), m

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, l_sum), ms = jax.lax.scan(
                accum_body, (zero_g, jnp.zeros((), jnp.float32)),
                jnp.arange(accum_steps))
            grads = jax.tree.map(lambda g: g / accum_steps, g_sum)
            loss = l_sum / accum_steps
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), ms)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        if constrain_grads:
            # Pin gradient shardings to the param layout BEFORE the
            # optimizer: turns XLA's full-size gradient all-reduce into
            # reduce-scatter (+ sharded elementwise update).
            grads = jax.lax.with_sharding_constraint(grads, psh)
        params, opt_state, om = opt.adamw_update(opt_cfg, params, grads,
                                                 opt_state)
        metrics.update(om)
        return params, opt_state, metrics

    from repro.configs.inputs import input_specs
    front_sh = NamedSharding(mesh, P(baxes, None, None))
    batch_shardings = {
        k: (bsh if k in ("tokens", "targets") else front_sh)
        for k in input_specs(cfg, shape)
    }

    jit_step = jax.jit(
        train_step,
        in_shardings=(psh, osh, batch_shardings),
        out_shardings=(psh, osh, None),
        donate_argnums=(0, 1) if donate else (),
    )

    return TrainProgram(cfg=cfg, run=run, mesh=mesh, rules=rules,
                        opt_cfg=opt_cfg, zcfg=zcfg, param_shapes=pshapes,
                        param_shardings=psh, opt_shardings=osh,
                        batch_shardings=batch_shardings,
                        train_step=jit_step, loss_fn=loss_fn)


def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct value tree, logical-axes tree) without allocating.

    Axes are static Python data produced during tracing, so they are
    captured through a side channel while eval_shape abstracts the values.
    """
    box = {}

    def split_build():
        vals, axes = split_params(
            stack.init_model(jax.random.PRNGKey(0), cfg))
        box["axes"] = axes
        return vals

    vals = jax.eval_shape(split_build)
    return vals, box["axes"]
