"""Small pytree / dtype utilities shared across the framework.

Params are plain nested dicts of jnp arrays. During init we build trees of
`Param(value, axes)` so the value tree and the logical-sharding-axes tree are
produced by a single code path (no drift between init and partition specs).
The two trees are split apart before entering jit boundaries.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Param:
    """A parameter leaf paired with its logical sharding axes.

    ``axes`` is a tuple of logical axis names (or None), one per dim, e.g.
    ``('embed', 'q_heads')``.  ``sharding/rules.py`` maps logical names to
    mesh axes.
    """

    value: Any  # jnp array or ShapeDtypeStruct
    axes: tuple


def is_param(x) -> bool:
    return isinstance(x, Param)


def is_axes(x) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)


def split_params(tree):
    """Split a tree of Param into (value_tree, axes_tree)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def merge_params(values, axes):
    """Inverse of split_params (flatten-order based; same dict structure)."""
    flat_v, treedef = jax.tree.flatten(values)
    flat_a = jax.tree.leaves(axes, is_leaf=is_axes)
    assert len(flat_v) == len(flat_a), (len(flat_v), len(flat_a))
    return jax.tree.unflatten(treedef, [Param(v, a) for v, a in zip(flat_v, flat_a)])


def axes_map(fn: Callable, axes_tree):
    """Map over an axes tree whose leaves are tuples of axis names."""
    return jax.tree.map(fn, axes_tree, is_leaf=is_axes)


def prepend_axis(axes_tree, name=None):
    """Prepend a leading logical axis (e.g. stacked-layer dim) to every leaf."""
    return axes_map(lambda a: (name,) + tuple(a), axes_tree)


def tree_size_bytes(tree) -> int:
    return sum(
        x.size * x.dtype.itemsize
        for x in jax.tree.leaves(tree)
        if hasattr(x, "size")
    )


def tree_param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def cast_tree(tree, dtype):
    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(_cast, tree)


def tree_map_with_path_names(fn: Callable, tree, *rest, is_leaf=None):
    """tree.map with '/'-joined string path as first arg."""
    def _name(path):
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return "/".join(parts)
    return jax.tree_util.tree_map_with_path(
        lambda p, *x: fn(_name(p), *x), tree, *rest, is_leaf=is_leaf)


# ---------------------------------------------------------------------------
# Initializers (pure JAX, no flax).
# ---------------------------------------------------------------------------

def trunc_normal_init(key, shape, dtype, stddev: float):
    # 2-sigma truncation, variance-corrected like flax's truncated_normal.
    unscaled = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return (unscaled * stddev / 0.87962566103423978).astype(dtype)


def fan_in_init(key, shape, dtype, fan_in: int | None = None, scale: float = 1.0):
    """LeCun-normal-style init: stddev = scale / sqrt(fan_in)."""
    if fan_in is None:
        fan_in = shape[0] if len(shape) >= 1 else 1
    return trunc_normal_init(key, shape, dtype, scale / math.sqrt(max(fan_in, 1)))


def zeros_init(key, shape, dtype, **_):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype, **_):
    del key
    return jnp.ones(shape, dtype)
