"""repro: HeterMoE (zebra parallelism + Asym-EA) reproduced as a JAX framework.

Public surface:
    repro.configs   — architecture configs (10 assigned archs + paper's Mixtral set)
    repro.models    — pure-JAX model zoo
    repro.core      — zebra parallelism, Asym-EA, planner, simulator
    repro.train     — training loop, optimizer, mixed precision
    repro.serve     — KV-cache serving
    repro.launch    — mesh / dryrun / train / serve entry points
"""

__version__ = "0.1.0"
