"""Serving metrics: queue depth, time-to-first-token, inter-token latency,
throughput (DESIGN.md §7).

Wall-clock times come from a injectable ``clock`` (default
``time.perf_counter``); engine ticks are recorded alongside so tests can
assert scheduling behaviour (interleaving, slot recycling) without
depending on timing.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


@dataclasses.dataclass
class RequestTrace:
    rid: int
    prompt_len: int = 0
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    first_token_tick: Optional[int] = None
    finish_tick: Optional[int] = None
    n_generated: int = 0
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def itl(self) -> List[float]:
        """Inter-token latencies (gaps between consecutive tokens)."""
        ts = self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]


def _pctl(xs: List[float], q: float) -> float:
    if not xs:
        return float("nan")
    s = sorted(xs)
    i = min(len(s) - 1, int(round(q * (len(s) - 1))))
    return s[i]


class ServeMetrics:
    """Aggregates per-request traces + per-tick engine state."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self.requests: Dict[int, RequestTrace] = {}
        self.queue_depths: List[int] = []
        self.active_counts: List[int] = []
        self._t0: Optional[float] = None

    # -- event hooks (called by the engine) ---------------------------------

    def on_submit(self, rid: int, prompt_len: int) -> None:
        now = self.clock()
        if self._t0 is None:
            self._t0 = now
        self.requests[rid] = RequestTrace(rid=rid, prompt_len=prompt_len,
                                          submit_time=now)

    def on_token(self, rid: int, tick: int) -> None:
        now = self.clock()
        tr = self.requests[rid]
        if tr.first_token_time is None:
            tr.first_token_time = now
            tr.first_token_tick = tick
        tr.token_times.append(now)
        tr.n_generated += 1

    def on_finish(self, rid: int, tick: int) -> None:
        tr = self.requests[rid]
        tr.finish_time = self.clock()
        tr.finish_tick = tick

    def on_tick(self, queue_depth: int, n_active: int) -> None:
        self.queue_depths.append(queue_depth)
        self.active_counts.append(n_active)

    # -- aggregates ---------------------------------------------------------

    def summary(self) -> dict:
        done = [t for t in self.requests.values() if t.finish_time is not None]
        ttfts = [t.ttft for t in done if t.ttft is not None]
        itls = [g for t in done for g in t.itl]
        n_tok = sum(t.n_generated for t in done)
        wall = (max(t.finish_time for t in done) - self._t0) \
            if done and self._t0 is not None else float("nan")
        return {
            "n_requests": len(done),
            "n_generated_tokens": n_tok,
            "wall_s": round(wall, 4) if wall == wall else wall,
            "tokens_per_s": round(n_tok / wall, 2) if wall and wall == wall
            and wall > 0 else float("nan"),
            "ttft_s": {"mean": _mean(ttfts), "p50": _pctl(ttfts, 0.5),
                       "max": max(ttfts) if ttfts else float("nan")},
            "itl_s": {"mean": _mean(itls), "p50": _pctl(itls, 0.5),
                      "p95": _pctl(itls, 0.95)},
            "queue_depth": {"mean": _mean(self.queue_depths),
                            "max": max(self.queue_depths, default=0)},
            "max_concurrent_active": max(self.active_counts, default=0),
        }


def _mean(xs: List[float]) -> float:
    return sum(xs) / len(xs) if xs else float("nan")
