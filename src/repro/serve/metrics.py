"""Serving metrics: queue depth, time-to-first-token, inter-token latency,
throughput (DESIGN.md §7).

Wall-clock times come from a injectable ``clock`` (default
``time.perf_counter``); engine ticks are recorded alongside so tests can
assert scheduling behaviour (interleaving, slot recycling) without
depending on timing.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RequestTrace:
    rid: int
    prompt_len: int = 0
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    first_token_tick: Optional[int] = None
    finish_tick: Optional[int] = None
    n_generated: int = 0
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def itl(self) -> List[float]:
        """Inter-token latencies (gaps between consecutive tokens)."""
        ts = self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]


def percentile(xs: List[float], q: float) -> float:
    """Exact host-side percentile with linear interpolation (the SLO gate
    arithmetic — numpy-free so the fleet simulator can import it without
    device deps). ``q`` in [0, 1]; nan on empty input."""
    if not xs:
        return float("nan")
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"q must be in [0, 1], got {q}")
    s = sorted(xs)
    pos = q * (len(s) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(s) - 1)
    frac = pos - lo
    return s[lo] * (1.0 - frac) + s[hi] * frac


def percentiles(xs: List[float], qs=(0.5, 0.95, 0.99)) -> Dict[str, float]:
    """{"p50": ..., "p95": ..., "p99": ...} over one sorted pass. Every
    percentile in this module goes through :func:`percentile` — the one
    exact-rank implementation (a nearest-rank `_pctl` twin used to live
    here; keep it dead)."""
    return {f"p{int(q * 100)}": percentile(xs, q) for q in qs}


@dataclasses.dataclass
class RobustnessCounters:
    """Failure-path accounting (DESIGN.md §13) — every fault the serving
    stack absorbed rather than surfaced, reported in bench summaries."""

    transfer_retries: int = 0         # chunk re-attempts after any fault
    checksum_failures: int = 0        # corrupted chunks caught + retried
    transfer_aborts: int = 0          # transfers rolled back to re-prefill
    shed_requests: int = 0            # SLO-infeasible arrivals shed
    fenced_stale_completions: int = 0  # zombie tokens rejected by epoch
    fenced_stale_tickets: int = 0     # zombie tickets dropped at admission
    zombie_rejoins: int = 0           # falsely-dead groups re-admitted

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ServeMetrics:
    """Aggregates per-request traces + per-tick engine state."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self.requests: Dict[int, RequestTrace] = {}
        self.queue_depths: List[int] = []
        self.active_counts: List[int] = []
        self.robust = RobustnessCounters()
        self._t0: Optional[float] = None

    # -- event hooks (called by the engine) ---------------------------------

    def on_submit(self, rid: int, prompt_len: int) -> None:
        now = self.clock()
        if self._t0 is None:
            self._t0 = now
        self.requests[rid] = RequestTrace(rid=rid, prompt_len=prompt_len,
                                          submit_time=now)

    def on_token(self, rid: int, tick: int) -> None:
        now = self.clock()
        tr = self.requests[rid]
        if tr.first_token_time is None:
            tr.first_token_time = now
            tr.first_token_tick = tick
        tr.token_times.append(now)
        tr.n_generated += 1

    def on_finish(self, rid: int, tick: int) -> None:
        tr = self.requests[rid]
        tr.finish_time = self.clock()
        tr.finish_tick = tick

    def on_tick(self, queue_depth: int, n_active: int) -> None:
        self.queue_depths.append(queue_depth)
        self.active_counts.append(n_active)

    # -- aggregates ---------------------------------------------------------

    def summary(self) -> dict:
        done = [t for t in self.requests.values() if t.finish_time is not None]
        ttfts = [t.ttft for t in done if t.ttft is not None]
        itls = [g for t in done for g in t.itl]
        n_tok = sum(t.n_generated for t in done)
        wall = (max(t.finish_time for t in done) - self._t0) \
            if done and self._t0 is not None else float("nan")
        return {
            "n_requests": len(done),
            "n_generated_tokens": n_tok,
            "wall_s": round(wall, 4) if wall == wall else wall,
            "tokens_per_s": round(n_tok / wall, 2) if wall and wall == wall
            and wall > 0 else float("nan"),
            "ttft_s": {"mean": _mean(ttfts), **percentiles(ttfts),
                       "max": max(ttfts) if ttfts else float("nan")},
            "itl_s": {"mean": _mean(itls), **percentiles(itls)},
            "queue_depth": {"mean": _mean(self.queue_depths),
                            "max": max(self.queue_depths, default=0)},
            "max_concurrent_active": max(self.active_counts, default=0),
            "robustness": self.robust.as_dict(),
        }


def _mean(xs: List[float]) -> float:
    return sum(xs) / len(xs) if xs else float("nan")


class RoutingEMA:
    """Per-layer EMA of observed MoE routing histograms (DESIGN.md §11).

    The EP decode engine feeds it one ``[n_layers, n_experts]`` count
    matrix per decode step (dead-slot copies already masked out inside the
    step). Each layer keeps an exponential moving average of its NORMALIZED
    histogram — normalizing per update keeps the EMA a distribution, so
    drift is comparable across load levels — and ``merged()`` is the
    layer-mean distribution the placement planner consumes.
    """

    def __init__(self, n_experts: int, decay: float = 0.9):
        assert 0.0 <= decay < 1.0
        self.n_experts = n_experts
        self.decay = decay
        self.hist: Dict[int, np.ndarray] = {}  # layer -> EMA distribution
        self.n_updates = 0

    def update(self, counts) -> None:
        """counts: [n_layers, n_experts] (or [n_experts] for one layer)."""
        counts = np.atleast_2d(np.asarray(counts, np.float64))
        assert counts.shape[-1] == self.n_experts, counts.shape
        for layer, row in enumerate(counts):
            tot = row.sum()
            if tot <= 0:
                continue
            p = row / tot
            old = self.hist.get(layer)
            self.hist[layer] = p if old is None \
                else self.decay * old + (1.0 - self.decay) * p
        self.n_updates += 1

    def layer(self, layer: int) -> Optional[np.ndarray]:
        return self.hist.get(layer)

    def merged(self) -> np.ndarray:
        """Layer-mean routing distribution [n_experts] (uniform if no
        updates yet — a cold planner sees no skew rather than garbage)."""
        if not self.hist:
            return np.full((self.n_experts,), 1.0 / self.n_experts)
        m = np.mean(list(self.hist.values()), axis=0)
        tot = m.sum()
        return m / tot if tot > 0 else np.full_like(m, 1.0 / len(m))

    def drift(self, reference) -> float:
        """Total-variation distance between ``merged()`` and a reference
        distribution — the online re-balance trigger."""
        ref = np.asarray(reference, np.float64)
        ref = ref / max(ref.sum(), 1e-12)
        return 0.5 * float(np.abs(self.merged() - ref).sum())
