"""Expert-parallel decode with heterogeneity-aware placement (DESIGN.md §11).

The replicated serving engines keep every expert's weights on every decode
device — exactly the per-device HBM that paged KV (§9) and disaggregation
(§10) were built to conserve. This module shards the expert stacks across
the decode group and routes decode tokens through the same chunked
all-to-all machinery the zebra training engines use (§8), so per-device
expert weight residency drops by ``ep_size``× while the decode step stays
greedy token-exact vs the replicated engine.

Placement is data, not layout: experts are stored in PACKED order (shard
j's experts occupy slots ``[j*E_loc, (j+1)*E_loc)`` of the expert axis) and
an ``eslot`` int32 map — injected next to each MoE ffn's weights — carries
expert-id -> slot. Re-placing experts (hot -> strong device class, cold ->
weak, per the observed routing histogram) is then a host-side permutation
of the weight stacks + a new ``eslot``: page tables, KV pools and slot
state never move, which is what makes the online re-balance token-exact
mid-trace.

Routing histograms come back from the decode step itself: the EP MoE hop
counts routed copies per GLOBAL expert id (dead slots masked out) and the
stack surfaces them per layer via ``aux_extras`` / ``layer_aux``; the
engine feeds them to :class:`~repro.serve.metrics.RoutingEMA` and triggers
``rebalance`` when the distribution drifts.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.core.asym_ea import asym_ea_place, round_robin_placement
from repro.core.zebra_spmd import _pack, _round_up, _unpack
from repro.models import modules
from repro.models.config import ModelConfig
from repro.models.modules import RunConfig
from repro.serve.engine import ContinuousBatchingEngine, ContinuousProgram
from repro.serve.metrics import RoutingEMA
from repro.serve.scheduler import Scheduler


@dataclasses.dataclass(frozen=True)
class EPDecodeConfig:
    """Expert-parallel decode configuration (DESIGN.md §11).

    ep_size must equal the mesh's ``ep_axis`` extent and divide the expert
    count — validation REJECTS a non-dividing ep_size (no silent
    truncation; the launch driver surfaces the ValueError as a non-zero
    exit). ``placement`` is the initial expert -> shard assignment
    (defaults to round-robin); ``rebalance_every`` > 0 checks the routing
    EMA's drift every that many decode steps and re-places experts when
    total-variation drift exceeds ``drift_threshold``.
    """

    ep_size: int
    ep_axis: str = "model"
    n_chunks: int = 1           # chunked a2a dispatch (zebra §8 semantics)
    placement: Optional[tuple] = None
    rebalance_every: int = 0    # decode steps between drift checks; 0 = off
    drift_threshold: float = 0.1
    ema_decay: float = 0.9


def validate_ep_config(cfg: ModelConfig, mesh: Mesh,
                       ep: EPDecodeConfig) -> None:
    """Reject-don't-truncate sanitization (cf. train/step.py's zcfg
    clamping — serving has no safe fallback, a wrong shard count silently
    changes which weights each device holds)."""
    if not cfg.is_moe:
        raise ValueError("EP decode needs a MoE model (n_experts == 0)")
    if ep.ep_size < 1:
        raise ValueError(f"ep_size must be >= 1, got {ep.ep_size}")
    if cfg.n_experts % ep.ep_size:
        raise ValueError(
            f"ep_size {ep.ep_size} does not divide n_experts "
            f"{cfg.n_experts}; refusing to truncate the expert shard")
    if ep.ep_axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {ep.ep_axis!r}")
    if mesh.shape[ep.ep_axis] != ep.ep_size:
        raise ValueError(
            f"ep_size {ep.ep_size} != mesh axis {ep.ep_axis!r} size "
            f"{mesh.shape[ep.ep_axis]}")
    if ep.n_chunks < 1:
        raise ValueError(f"n_chunks must be >= 1, got {ep.n_chunks}")
    if ep.placement is not None:
        placement_to_perm(ep.placement, cfg.n_experts, ep.ep_size)


# ---------------------------------------------------------------------------
# Placement as data: packed permutation + expert -> slot map
# ---------------------------------------------------------------------------

def placement_to_perm(placement, n_experts: int, ep_size: int) -> tuple:
    """Validate a placement (tuple of per-shard expert-id tuples) and
    return the packed slot -> expert permutation."""
    if len(placement) != ep_size:
        raise ValueError(f"placement has {len(placement)} shards, "
                         f"expected {ep_size}")
    cap = n_experts // ep_size
    perm = []
    for j, shard in enumerate(placement):
        if len(shard) != cap:
            raise ValueError(f"shard {j} holds {len(shard)} experts, "
                             f"expected {cap} (equal cardinality)")
        perm.extend(int(e) for e in shard)
    if sorted(perm) != list(range(n_experts)):
        raise ValueError("placement is not a permutation of expert ids")
    return tuple(perm)


def eslot_of(placement, n_experts: int) -> np.ndarray:
    """Inverse permutation: expert id -> packed slot index [E] int32."""
    perm = [int(e) for shard in placement for e in shard]
    eslot = np.zeros((n_experts,), np.int32)
    eslot[np.asarray(perm)] = np.arange(n_experts, dtype=np.int32)
    return eslot


def place_params(params, cfg: ModelConfig, placement):
    """Permute every MoE ffn's expert stacks into packed placement order
    and inject the ``eslot`` map. Routers are NOT permuted — routing stays
    in global expert ids; only the storage order changes. Stacked block
    leaves ([L, E, ...]) permute axis 1 and get a broadcast [L, E] eslot
    (the scan slices it per layer); tail leaves permute axis 0."""
    perm = placement_to_perm(placement, cfg.n_experts, len(placement))
    perm_j = jnp.asarray(perm, jnp.int32)
    eslot = jnp.asarray(eslot_of(placement, cfg.n_experts))

    def walk(node):
        if isinstance(node, dict):
            if "router" in node and "wi_gate" in node:
                out = dict(node)
                stacked = jnp.ndim(node["wi_gate"]) == 4
                ax = 1 if stacked else 0
                for k in ("wi_gate", "wi_up", "wo"):
                    out[k] = jnp.take(node[k], perm_j, axis=ax)
                es = eslot
                if stacked:
                    es = jnp.broadcast_to(
                        es[None], (node["wi_gate"].shape[0],
                                   cfg.n_experts))
                out["eslot"] = es
                return out
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(params)


def ep_param_shardings(psh, pshapes, mesh: Mesh, ep: EPDecodeConfig):
    """Patch the serve param shardings: expert stacks pinned to the EP
    axis (the HBM win — each device stores E/ep_size experts) and the
    ``eslot`` map added replicated, matching ``place_params`` output."""
    ax = ep.ep_axis

    def walk(sh, shp):
        if isinstance(sh, dict):
            if "router" in sh and "wi_gate" in sh:
                out = dict(sh)
                nd = len(shp["wi_gate"].shape)
                lead = (None,) * (nd - 3)
                for k in ("wi_gate", "wi_up", "wo"):
                    out[k] = NamedSharding(mesh, P(*lead, ax, None, None))
                out["eslot"] = NamedSharding(
                    mesh, P(*((None,) * (nd - 2))))
                return out
            return {k: walk(sh[k], shp[k]) for k in sh}
        return sh

    return walk(psh, pshapes)


# ---------------------------------------------------------------------------
# The EP decode expert hop (shard_map)
# ---------------------------------------------------------------------------

def make_ep_moe_decode(mesh: Mesh, cfg: ModelConfig, run: RunConfig,
                       ep: EPDecodeConfig) -> Callable:
    """Returns ``moe_fn(ffn_params, x2d [T,d], mask [T]) -> (y2d, aux)``.

    Decode batches are tiny, so unlike the training zebra hop the token
    batch stays REPLICATED across the EP axis (divisibility-safe for any
    slot count / prefill chunk): every shard routes the full batch, then
    takes its own ceil(T/ep_size) token stripe, capacity-packs it against
    the PLACEMENT slot order (``eslot[idx]``), and exchanges capacity
    chunks with ``lax.all_to_all`` exactly like zebra's alltoall mode.
    The per-shard grouped FFN auto-routes to the group-dense small-M path
    (ops.moe_ffn_packed_multi, small_m=None) — the crossover is evaluated
    at the per-shard group count E/ep_size by construction. Stripe results
    are all-gathered back to the replicated layout.

    aux carries ``ep_counts`` [E]: routed copies per GLOBAL expert id with
    ``mask`` (the live-slot mask) applied — the RoutingEMA's input.
    """
    E = cfg.n_experts
    k = cfg.top_k
    ax = ep.ep_axis
    n_ep = ep.ep_size
    E_loc = E // n_ep
    Q = max(int(ep.n_chunks), 1)
    cd = run.policy.compute_dtype
    from repro.kernels import ops as kops
    from repro.sharding.rules import ep_ffn_specs
    uk = True if run.use_gmm_kernel else None

    ffn_specs = dict(ep_ffn_specs(ax), eslot=P(None))
    in_specs = (ffn_specs, P(None, None), P(None))
    out_specs = (P(None, None),
                 {"moe_aux_loss": P(), "moe_z_loss": P(),
                  "ep_counts": P(None)})

    def fn(ffn, x, mask):
        T, d = x.shape
        weights, idx, aux = modules.moe_route(ffn["router"], cfg,
                                              run.policy, x)
        # Routed-copy histogram in GLOBAL ids, dead slots masked out.
        # x is replicated over the EP axis, so counts (and the router aux
        # losses) are identical on every shard — no psum needed.
        counts = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
            jnp.repeat(mask.astype(jnp.float32), k))
        aux = dict(aux, ep_counts=counts)
        # Placement remap: route in expert ids, dispatch in slot ids.
        slot_idx = jnp.take(ffn["eslot"].astype(jnp.int32), idx)
        my = jax.lax.axis_index(ax)
        Tp = -(-T // n_ep)
        pad = n_ep * Tp - T
        if pad:
            # Pad rows are zero -> zero FFN output -> inert in the combine.
            x = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)])
            slot_idx = jnp.concatenate(
                [slot_idx, jnp.zeros((pad, k), slot_idx.dtype)])
            weights = jnp.concatenate(
                [weights, jnp.zeros((pad, k), weights.dtype)])
        x_s = jax.lax.dynamic_slice_in_dim(x, my * Tp, Tp, axis=0)
        i_s = jax.lax.dynamic_slice_in_dim(slot_idx, my * Tp, Tp, axis=0)
        w_s = jax.lax.dynamic_slice_in_dim(weights, my * Tp, Tp, axis=0)
        # Dropless: top-k experts are distinct per token, so one expert
        # receives at most Tp copies from this stripe -> C >= Tp suffices.
        C, Cq = kops.chunk_capacity(max(_round_up(Tp, 8), 8), Q)
        buf, meta = _pack(x_s, i_s, E, C)       # [E, C, d], slot order
        rem = buf.reshape(n_ep, E_loc, C, d)
        recv = [jax.lax.all_to_all(
                    jax.lax.dynamic_slice_in_dim(rem, q * Cq, Cq, axis=2),
                    ax, split_axis=0, concat_axis=0, tiled=False)
                for q in range(Q)]
        outs = []
        for q in range(Q):
            r = jnp.swapaxes(recv[q], 0, 1).reshape(E_loc, n_ep * Cq, d)
            # small_m=None: auto-route on the PER-SHARD group count E_loc
            # (decode M is tiny -> group-dense, DESIGN.md §5.5).
            o = kops.moe_ffn_packed_multi(
                [r], [ffn["wi_gate"].astype(cd)],
                [ffn["wi_up"].astype(cd)], [ffn["wo"].astype(cd)],
                small_m=None, use_kernel=uk)[0]
            o = jnp.swapaxes(o.reshape(E_loc, n_ep, Cq, d), 0, 1)
            outs.append(jax.lax.all_to_all(o, ax, split_axis=0,
                                           concat_axis=0, tiled=False))
        back = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=2)
        y_s = _unpack(back.reshape(E, C, d), meta, w_s, Tp)
        y = jax.lax.all_gather(y_s, ax, axis=0, tiled=True)[:T]
        return y, aux

    def moe_fn(ffn_params, x2d, mask):
        fp = {k_: ffn_params[k_]
              for k_ in ("router", "wi_gate", "wi_up", "wo", "eslot")}
        sm = _shard_map(fn, mesh, in_specs, out_specs)
        return sm(fp, x2d, mask)

    return moe_fn


def moe_override_for(moe_fn: Callable, active=None) -> Callable:
    """Adapt the EP moe_fn to the stack's ``moe_override`` contract.

    ``active`` is the decode step's live-slot mask [B] (traced — the
    override is built per decode call); None means every row is live
    (prefill), so the histogram counts prefill tokens at full weight there
    — but prefill never registers ``ep_counts`` in its aux accumulator,
    so only decode feeds the EMA."""
    def override(ffn_params, u):
        B, S, d = u.shape
        if active is None:
            m = jnp.ones((B * S,), jnp.float32)
        else:
            m = jnp.repeat(active.astype(jnp.float32), S)
        y2, aux = moe_fn(ffn_params, u.reshape(-1, d), m)
        return y2.reshape(u.shape).astype(u.dtype), aux
    return override


# ---------------------------------------------------------------------------
# Per-device HBM accounting (admission inputs, DESIGN.md §11.3)
# ---------------------------------------------------------------------------

def expert_weight_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """Exact expert-stack residency (wi_gate + wi_up + wo over every MoE
    layer) from the abstract param tree."""
    from repro.train.step import abstract_params
    shapes, _ = abstract_params(cfg)
    total = 0

    def walk(node):
        nonlocal total
        if isinstance(node, dict):
            if "router" in node and "wi_gate" in node:
                for k in ("wi_gate", "wi_up", "wo"):
                    total += int(np.prod(node[k].shape))
            else:
                for v in node.values():
                    walk(v)

    walk(shapes)
    return total * dtype_bytes


def model_weight_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    from repro.train.step import abstract_params
    shapes, _ = abstract_params(cfg)
    return sum(int(np.prod(l.shape))
               for l in jax.tree.leaves(shapes)) * dtype_bytes


def ep_hbm_budget(cfg: ModelConfig, *, hbm_bytes: int, ep_size: int,
                  page_size: int, dtype_bytes: int = 2) -> dict:
    """Admission vs per-device HBM: what EP sharding frees and how many
    decode pool pages fit in it. The scheduler's pool (`BlockAllocator`
    geometry) should be sized from ``pool_pages_ep`` — replicated expert
    weights were previously charged against the same budget."""
    from repro.core import profiler as prof
    experts = expert_weight_bytes(cfg, dtype_bytes)
    dense = model_weight_bytes(cfg, dtype_bytes) - experts
    shard = -(-experts // max(ep_size, 1))
    page = max(prof.kv_page_bytes(cfg, page_size), 1)

    def pages(resident):
        return max(int((hbm_bytes - resident) // page), 0)

    return {
        "expert_bytes_total": experts,
        "expert_bytes_per_device": shard,
        "hbm_reduction": experts / max(shard, 1),
        "pool_pages_replicated": pages(dense + experts),
        "pool_pages_ep": pages(dense + shard),
    }


# ---------------------------------------------------------------------------
# EP continuous-batching engine: placement lifecycle + online re-balance
# ---------------------------------------------------------------------------

def balanced_placement(hist, ep_size: int, speeds=None) -> tuple:
    """Histogram-aware placement via the serving Asym-EA extension:
    greedy LPT over per-expert load with fixed shard cardinality. Equal
    ``speeds`` (the engine-internal default — it has no device classes)
    load-balances; the planner passes per-shard HBM bandwidths to get the
    hot-on-strong / cold-on-weak heterogeneity-aware assignment."""
    E = len(hist)
    if E % ep_size:
        raise ValueError(f"{ep_size} shards do not divide {E} experts")
    sp = list(speeds) if speeds is not None else [1.0] * ep_size
    return asym_ea_place([float(h) for h in hist], sp, E // ep_size)


class EPContinuousBatchingEngine(ContinuousBatchingEngine):
    """Continuous batching over EP-sharded expert weights (DESIGN.md §11).

    Takes UNPLACED (replicated-layout) params: placement happens here —
    permute + inject ``eslot`` + device_put under the program's EP param
    shardings. Every decode step returns the routed-copy histogram, which
    feeds a :class:`RoutingEMA`; when ``rebalance_every`` is set and the
    EMA drifts past ``drift_threshold`` (total variation vs the histogram
    the current placement was computed from), experts are re-placed via
    ``placer`` (a callable hist -> placement; defaults to load-balanced
    :func:`balanced_placement`). Re-balance swaps ONLY ``self.params`` —
    KV pools, page tables and slot state are untouched, so generation
    continues token-exact across the reshuffle.
    """

    def __init__(self, program: ContinuousProgram, params,
                 scheduler: Scheduler, *, placement=None,
                 placer: Callable = None, **kw):
        ep = program.ep
        assert ep is not None, "program was built without ep=EPDecodeConfig"
        self.epcfg = ep
        self._base_params = params
        self.placer = placer
        self.ema = RoutingEMA(program.cfg.n_experts, decay=ep.ema_decay)
        self.n_rebalances = 0
        self._steps_since_check = 0
        pl = placement if placement is not None else ep.placement
        if pl is None:
            pl = round_robin_placement(program.cfg.n_experts, ep.ep_size)
        self.placement = tuple(tuple(int(e) for e in s) for s in pl)
        E = program.cfg.n_experts
        self._placement_hist = np.full((E,), 1.0 / E)
        self._program = program  # _place runs before super().__init__
        placed = self._place(self.placement)
        super().__init__(program, placed, scheduler, **kw)

    def _place(self, placement):
        placed = place_params(self._base_params, self._program.cfg,
                              placement)
        with self._program.mesh:
            return jax.device_put(placed, self._program.param_shardings)

    def _on_ep_counts(self, counts) -> None:
        self.ema.update(np.asarray(counts))
        ep = self.epcfg
        if ep.rebalance_every <= 0:
            return
        self._steps_since_check += 1
        if self._steps_since_check < ep.rebalance_every:
            return
        self._steps_since_check = 0
        if self.ema.drift(self._placement_hist) <= ep.drift_threshold:
            return
        hist = self.ema.merged()
        new = self.placer(hist) if self.placer \
            else balanced_placement(hist, ep.ep_size)
        self.rebalance(new)

    def rebalance(self, placement) -> bool:
        """Re-place experts mid-trace. Only the param tree moves; decode
        state survives, so live requests continue token-exact."""
        placement = tuple(tuple(int(e) for e in s) for s in placement)
        self._placement_hist = self.ema.merged()
        if placement == self.placement:
            return False
        self.params = self._place(placement)
        self.placement = placement
        self.n_rebalances += 1
        return True
