"""Unified deployment configuration + factory (DESIGN.md §14).

Every serving deployment shape this repo grew — lockstep batch, unified
continuous batching (dense or paged, optionally EP-sharded), the
disaggregated prefill/decode pair, the elastic multi-group fleet with
chaos injection — used to be wired by hand at each call site (the launch
driver, the bench harness, the tests), each with its own kwarg spelling.
:class:`ServeConfig` is the one declarative description of a deployment
and :func:`build_deployment` the one construction path: it validates the
config as a whole (EVERY violation reported in one
:class:`ServeConfigError`, one non-zero exit — not the first of a
cascade), then builds exactly the engine the config describes.

Config -> engine mapping::

    encdec / vision arch          -> BatchedServer (lockstep fallback)
    fleet.enabled                 -> FleetController      (make_fleet)
    disagg.enabled                -> DisaggController     (make_disagg)
    ep.ep_size > 0 (MoE arch)     -> EPContinuousBatchingEngine
    otherwise                     -> ContinuousBatchingEngine
    paged.enabled                 -> + BlockAllocator (paged KV, §9)
    prefix.enabled                -> + PrefixIndex (COW prefix cache, §14)

The nested dataclasses are frozen and JSON-trivial on purpose: a
ServeConfig is a value, not a builder — it can be printed into a bench
artifact or compared in a test without touching any device state.

Migration from the old flag/kwarg spellings is table-driven in
DESIGN.md §14.5.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.serve.sampling import SamplingParams


class ServeConfigError(ValueError):
    """An invalid ServeConfig. The message lists EVERY violation
    (semicolon-joined), so one failed launch reports the whole set."""


def parse_group_spec(spec: str, default_cls: str) -> list:
    """``--prefill-groups``/``--decode-groups`` value: either an integer
    count (that many groups of the role's default class) or a
    comma-separated device-class list (one group per entry)."""
    items = [x.strip() for x in (spec or "").split(",") if x.strip()]
    if len(items) == 1 and items[0].isdigit():
        return [default_cls] * int(items[0])
    return items


def parse_kills(specs) -> list:
    """``--kill-group`` occurrences -> [(tick, gid)], parsed by the ONE
    fault-spec grammar (``ft.chaos.FaultPlan``): the legacy ``GID@TICK``
    shorthand is sugar for a ``crash_start@TICK:gGID`` chaos entry, and
    the full entry form is accepted verbatim — so a kill spec and a
    ``--chaos`` schedule can never drift apart in syntax."""
    from repro.ft.chaos import FaultPlan
    kills = []
    for spec in specs or ():
        raw = spec.strip()
        head = raw.split("@", 1)[0]
        if "@" in raw and head.isdigit():
            gid, tick = raw.split("@", 1)
            raw = f"crash_start@{tick}:g{gid}"
        try:
            plan = FaultPlan.parse(raw)
        except ValueError:
            raise ValueError(
                f"--kill-group wants GID@TICK (or a chaos-grammar "
                f"crash_start@TICK:gGID entry), got {spec!r}") from None
        (entry,) = plan.specs
        tgt = entry.target or ""
        if entry.site != "crash_start" or entry.tick is None \
                or not (tgt.startswith("g") and tgt[1:].isdigit()):
            raise ValueError(
                f"--kill-group wants GID@TICK (or a chaos-grammar "
                f"crash_start@TICK:gGID entry), got {spec!r}")
        kills.append((entry.tick, int(tgt[1:])))
    return kills


@dataclasses.dataclass(frozen=True)
class PagedCfg:
    """Paged-KV geometry (DESIGN.md §9). ``enabled`` switches the unified
    engine to paged mode; disagg/fleet deployments are paged inherently
    and read only the geometry fields."""

    enabled: bool = False
    page_size: int = 16
    pool_pages: Optional[int] = None          # decode/unified pool
    prefill_pool_pages: Optional[int] = None  # disagg/fleet prefill pool


@dataclasses.dataclass(frozen=True)
class PrefixCacheCfg:
    """Prefix-cached COW paged KV (DESIGN.md §14). Requires a paged
    deployment (unified ``paged`` or ``disagg``). ``fair`` switches
    admission to per-tenant deficit round-robin."""

    enabled: bool = False
    capacity_pages: Optional[int] = None  # LRU bound on pinned pages
    fair: bool = False


@dataclasses.dataclass(frozen=True)
class DisaggCfg:
    """Disaggregated prefill/decode deployment (DESIGN.md §10)."""

    enabled: bool = False
    transfer_chunk_pages: int = 4
    link_bw: Optional[float] = None
    latency_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class EPCfg:
    """Expert-parallel decode (DESIGN.md §11). ``ep_size`` == 0 is off;
    ``placement`` is ``uniform`` (static round-robin) or ``planned``
    (online heterogeneity-aware re-placement from the routing EMA)."""

    ep_size: int = 0
    placement: str = "uniform"


@dataclasses.dataclass(frozen=True)
class FleetCfg:
    """Elastic multi-group fleet (DESIGN.md §12). ``kills`` are
    (tick, gid) crash injections — see :func:`parse_kills`."""

    enabled: bool = False
    prefill_groups: Tuple[str, ...] = ("a40",)
    decode_groups: Tuple[str, ...] = ("v100",)
    elastic: bool = False
    kills: Tuple[Tuple[int, int], ...] = ()
    slo_ttft: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class ChaosCfg:
    """Seeded fault schedule (DESIGN.md §13, fleet mode only)."""

    spec: Optional[str] = None
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """One declarative description of a serving deployment."""

    slots: int = 4
    max_len: int = 72
    prefill_chunk: int = 16
    token_budget: Optional[int] = None  # prefill tokens/tick (None: chunk)
    seed: int = 0
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    paged: PagedCfg = PagedCfg()
    prefix: PrefixCacheCfg = PrefixCacheCfg()
    disagg: DisaggCfg = DisaggCfg()
    ep: EPCfg = EPCfg()
    fleet: FleetCfg = FleetCfg()
    chaos: ChaosCfg = ChaosCfg()

    # -- derived ------------------------------------------------------------

    @property
    def sampling(self) -> SamplingParams:
        return SamplingParams(temperature=self.temperature,
                              top_k=self.top_k, top_p=self.top_p)

    @property
    def any_paged(self) -> bool:
        """Whether any page machinery exists (unified paged, disagg or
        fleet — the latter two are paged inherently)."""
        return self.paged.enabled or self.disagg.enabled or self.fleet.enabled

    def ep_decode_config(self):
        """The runtime ``EPDecodeConfig`` this config describes (None when
        EP is off)."""
        if not self.ep.ep_size:
            return None
        from repro.serve.ep_decode import EPDecodeConfig
        planned = self.ep.placement == "planned"
        return EPDecodeConfig(ep_size=self.ep.ep_size, n_chunks=2,
                              rebalance_every=8 if planned else 0,
                              drift_threshold=0.05)

    # -- construction from CLI args -----------------------------------------

    @classmethod
    def from_args(cls, args) -> "ServeConfig":
        """Build from the launch driver's argparse namespace. Parse-level
        problems (malformed kill specs, bad group lists) surface as
        :class:`ServeConfigError` so the driver has ONE error path."""
        try:
            pre = tuple(parse_group_spec(
                getattr(args, "prefill_groups", "a40"), "a40"))
            dec = tuple(parse_group_spec(
                getattr(args, "decode_groups", "v100"), "v100"))
            kills = tuple(parse_kills(getattr(args, "kill_group", None)))
        except ValueError as e:
            raise ServeConfigError(str(e)) from None
        return cls(
            slots=args.slots,
            max_len=args.prompt_len + args.gen,
            prefill_chunk=args.prefill_chunk,
            token_budget=args.prefill_budget,
            seed=args.seed,
            temperature=args.temperature,
            top_k=args.top_k,
            top_p=args.top_p,
            paged=PagedCfg(
                enabled=bool(getattr(args, "paged", False)),
                page_size=getattr(args, "page_size", 16),
                pool_pages=getattr(args, "pool_pages", None),
                prefill_pool_pages=getattr(args, "prefill_pool_pages",
                                           None)),
            prefix=PrefixCacheCfg(
                enabled=bool(getattr(args, "prefix_cache", False)),
                capacity_pages=getattr(args, "prefix_capacity", None),
                fair=bool(getattr(args, "fair", False))),
            disagg=DisaggCfg(enabled=bool(getattr(args, "disagg", False))),
            ep=EPCfg(ep_size=getattr(args, "ep_size", 0) or 0,
                     placement=getattr(args, "ep_placement", "uniform")),
            fleet=FleetCfg(
                enabled=bool(getattr(args, "fleet", False)),
                prefill_groups=pre, decode_groups=dec,
                elastic=bool(getattr(args, "fleet_elastic", False)),
                kills=kills,
                slo_ttft=getattr(args, "slo_ttft", None)),
            chaos=ChaosCfg(spec=getattr(args, "chaos", None),
                           seed=getattr(args, "chaos_seed", 0)))

    # -- validation ---------------------------------------------------------

    def validate(self, model_cfg=None, mesh=None) -> None:
        """Reject-don't-truncate validation of the WHOLE config.

        Collects every violation and raises a single
        :class:`ServeConfigError` — the launch driver turns that into one
        clear non-zero exit instead of a cascade of partial failures.
        ``model_cfg``/``mesh`` switch on the arch- and topology-dependent
        checks (EP divisibility, recurrent-arch prefix rejection)."""
        errs: List[str] = []
        if self.slots < 1:
            errs.append(f"slots must be >= 1, got {self.slots}")
        if self.max_len < 2:
            errs.append(f"max_len must be >= 2, got {self.max_len}")
        if self.prefill_chunk < 1:
            errs.append(
                f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        if self.token_budget is not None and self.token_budget < 1:
            errs.append(
                f"token_budget must be >= 1, got {self.token_budget}")
        if self.any_paged:
            if self.paged.page_size < 1:
                errs.append(f"page_size must be >= 1, "
                            f"got {self.paged.page_size}")
            for name, v in (("pool_pages", self.paged.pool_pages),
                            ("prefill_pool_pages",
                             self.paged.prefill_pool_pages)):
                if v is not None and v < 1:
                    errs.append(f"{name} must be >= 1, got {v}")
        if self.fleet.enabled and self.disagg.enabled:
            errs.append("--fleet and --disagg are mutually exclusive "
                        "deployment shapes")
        if self.prefix.enabled and not (self.paged.enabled
                                        or self.disagg.enabled):
            errs.append("--prefix-cache needs a paged deployment "
                        "(--paged or --disagg)")
        if self.prefix.enabled and self.fleet.enabled:
            errs.append("--prefix-cache is not supported with --fleet "
                        "(per-group pools do not share a prefix index)")
        if self.prefix.capacity_pages is not None \
                and self.prefix.capacity_pages < 1:
            errs.append(f"prefix capacity_pages must be >= 1, "
                        f"got {self.prefix.capacity_pages}")
        if self.chaos.spec and not self.fleet.enabled:
            errs.append("--chaos requires --fleet (the chaos hook points "
                        "live in the fleet controller)")
        if self.fleet.kills and not self.fleet.enabled:
            errs.append("--kill-group requires --fleet")
        if self.fleet.slo_ttft is not None and not self.fleet.enabled:
            errs.append("--slo-ttft requires --fleet")
        if self.fleet.enabled:
            if not self.fleet.prefill_groups or not self.fleet.decode_groups:
                errs.append("fleet needs >= 1 prefill and >= 1 decode group")
            from repro.core.hardware import CLASSES
            unknown = [c for c in (*self.fleet.prefill_groups,
                                   *self.fleet.decode_groups)
                       if c not in CLASSES]
            if unknown:
                errs.append(f"unknown device class(es) {unknown}; "
                            f"known: {sorted(CLASSES)}")
        if self.chaos.spec:
            from repro.ft.chaos import FaultPlan
            try:
                FaultPlan.parse(self.chaos.spec)
            except ValueError as e:
                errs.append(f"bad --chaos spec: {e}")
        if self.ep.ep_size:
            if self.fleet.enabled:
                errs.append("--ep-size is not supported with --fleet")
            if self.ep.placement not in ("uniform", "planned"):
                errs.append(f"ep placement must be 'uniform' or 'planned', "
                            f"got {self.ep.placement!r}")
            if model_cfg is not None:
                if not model_cfg.is_moe:
                    errs.append(f"--ep-size needs a MoE arch; "
                                f"{model_cfg.name} is dense")
                elif mesh is not None:
                    from repro.serve.ep_decode import validate_ep_config
                    try:
                        validate_ep_config(model_cfg, mesh,
                                           self.ep_decode_config())
                    except ValueError as e:
                        errs.append(f"bad EP config: {e}")
        if self.prefix.enabled and model_cfg is not None:
            rec = sorted({s.mixer for s in model_cfg.layer_layout()
                          if s.mixer in ("rglru", "ssd")})
            if rec:
                errs.append(
                    f"--prefix-cache needs per-position KV only; "
                    f"{model_cfg.name} carries recurrent mixers {rec} "
                    f"whose state depends on every earlier token, so "
                    f"skipping a cached prefix would corrupt it")
        if errs:
            raise ServeConfigError("; ".join(errs))


def build_deployment(cfg, mesh, run, serve_cfg: ServeConfig, *,
                     params=None, metrics=None, on_token=None,
                     record_logits: bool = False):
    """THE construction path from a :class:`ServeConfig` to a live engine.

    Validates first (so an invalid config can never half-construct), then
    builds the deployment the config describes — see the module docstring
    for the mapping. ``params`` defaults to a fresh seeded init placed the
    way each deployment wants it (jit-init under the program's shardings
    for the plain unified engine; replicated for EP/disagg/fleet, which
    place params themselves). All engines expose ``run(trace)``
    (FleetController additionally takes ``kills=``) and ``rejected``.
    """
    import jax

    from repro.models import stack
    from repro.pytree import split_params

    serve_cfg.validate(model_cfg=cfg, mesh=mesh)
    sc = serve_cfg
    key = jax.random.PRNGKey(0)

    def replicated_params():
        return params if params is not None \
            else split_params(stack.init_model(key, cfg))[0]

    if cfg.is_encdec or cfg.vision_seq > 0:
        # Lockstep fallback: enc-dec / vision archs need per-request front
        # embeddings the continuous engine does not carry.
        from repro.models.config import ShapeConfig
        from repro.serve.engine import BatchedServer, make_serve_program
        shape = ShapeConfig("cli", "decode", sc.max_len, sc.slots)
        program = make_serve_program(cfg, mesh, run, shape,
                                     max_len=sc.max_len)
        if params is None:
            with mesh:
                p = jax.jit(
                    lambda: split_params(stack.init_model(key, cfg))[0],
                    out_shardings=program.param_shardings)()
        else:
            p = params
        return BatchedServer(program, p, sc.slots, sc.max_len)

    if sc.fleet.enabled:
        from repro.serve.fleet import make_fleet
        chaos = None
        if sc.chaos.spec:
            from repro.ft.chaos import FaultInjector, FaultPlan
            chaos = FaultInjector(FaultPlan.parse(sc.chaos.spec),
                                  seed=sc.chaos.seed)
        return make_fleet(
            cfg, mesh, run, replicated_params(),
            prefill_classes=list(sc.fleet.prefill_groups),
            decode_classes=list(sc.fleet.decode_groups),
            decode_slots=sc.slots, max_len=sc.max_len,
            page_size=sc.paged.page_size,
            decode_pages=sc.paged.pool_pages,
            prefill_pages=sc.paged.prefill_pool_pages,
            prefill_chunk=sc.prefill_chunk, token_budget=sc.token_budget,
            seed=sc.seed, metrics=metrics, on_token=on_token,
            elastic=sc.fleet.elastic, chaos=chaos,
            slo_ttft=sc.fleet.slo_ttft)

    if sc.disagg.enabled:
        from repro.serve.disagg import make_disagg
        return make_disagg(
            cfg, mesh, run, replicated_params(), decode_slots=sc.slots,
            max_len=sc.max_len, page_size=sc.paged.page_size,
            decode_pages=sc.paged.pool_pages,
            prefill_pages=sc.paged.prefill_pool_pages,
            prefill_chunk=sc.prefill_chunk, token_budget=sc.token_budget,
            seed=sc.seed,
            transfer_chunk_pages=sc.disagg.transfer_chunk_pages,
            link_bw=sc.disagg.link_bw, latency_s=sc.disagg.latency_s,
            metrics=metrics, on_token=on_token,
            record_logits=record_logits, ep=sc.ep_decode_config(),
            prefix=sc.prefix)

    from repro.serve.engine import (ContinuousBatchingEngine,
                                    make_continuous_program)
    from repro.serve.kv_blocks import BlockAllocator
    from repro.serve.scheduler import Scheduler

    program = make_continuous_program(cfg, mesh, run, serve_cfg=sc,
                                      ep=sc.ep_decode_config())
    allocator = prefix_index = None
    if sc.paged.enabled:
        allocator = BlockAllocator(program.n_pages, program.page_size,
                                   program.max_pages)
        if sc.prefix.enabled:
            from repro.serve.prefix_index import PrefixIndex
            prefix_index = PrefixIndex(
                allocator, capacity_pages=sc.prefix.capacity_pages)
    sched = Scheduler(sc.slots, sc.max_len, prefill_chunk=sc.prefill_chunk,
                      token_budget=sc.token_budget, allocator=allocator,
                      prefix_index=prefix_index, fair=sc.prefix.fair)
    if program.ep is not None:
        # The EP engine places (permutes + shards) replicated params
        # itself.
        from repro.serve.ep_decode import EPContinuousBatchingEngine
        return EPContinuousBatchingEngine(
            program, replicated_params(), sched, metrics=metrics,
            on_token=on_token, record_logits=record_logits)
    if params is None:
        with mesh:
            params = jax.jit(
                lambda: split_params(stack.init_model(key, cfg))[0],
                out_shardings=program.param_shardings)()
    return ContinuousBatchingEngine(program, params, sched,
                                    metrics=metrics, on_token=on_token,
                                    record_logits=record_logits)
