"""Token-id prefix index over paged KV — the prefix-cache brain
(DESIGN.md §14).

A radix tree over PAGES: each node is one physical page holding the KV
lines of one ``page_size``-token run, keyed by the token ids of that run
under its parent chain (so the path from the root to a node spells a
prompt prefix, page by page). Interior nodes are always FULL pages; leaf
nodes may be PARTIAL (``n_valid < page_size`` lines written — a finished
request's tail). Lookups walk the tree greedily and may stop mid-page on
a partial match — the divergence point where the engine COW-forks.

Pages referenced by the index are PINNED in the :class:`BlockAllocator`
(one extra refcount), which is what lets them outlive the request that
wrote them. Eviction is leaf-first LRU and only ever UNPINS — the
allocator frees a page when its refcount reaches 0, so a cached page
that some live request still shares survives eviction untouched (the
index merely forgets it). The index registers itself as the allocator's
``reclaim`` hook: an allocation shortfall evicts cold entries before the
allocator refuses, so prefix pins can never wedge admission or
preemption progress.

Soundness leans on the structural-position invariant (§9.2): a page
mounted at the same logical table slot reads as the same positions for
every sharer, so sharing page runs that start at slot 0 is exact by
construction. Registration happens at two points (engine-driven):
prompt full pages at prefill completion, the whole sequence including
the partial tail at request completion (multi-turn replay hits).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serve.kv_blocks import BlockAllocator


@dataclasses.dataclass
class _Node:
    nid: int
    parent: Optional["_Node"]
    tokens: Tuple[int, ...]  # the token run this page holds (n_valid ids)
    page: int
    n_valid: int  # lines written; == page_size for interior/full nodes
    n_children: int = 0


def _common_prefix(a: Sequence[int], b: Sequence[int]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class PrefixIndex:
    """Prefix -> page-run index with leaf-first LRU eviction.

    ``capacity_pages`` bounds how many pages the index may pin at once
    (None: unbounded — the allocator's reclaim hook is the only bound).
    """

    def __init__(self, allocator: BlockAllocator, *,
                 capacity_pages: Optional[int] = None):
        self.alloc = allocator
        self.page_size = allocator.page_size
        self.capacity_pages = capacity_pages
        self._lru: "OrderedDict[int, _Node]" = OrderedDict()  # cold -> hot
        self._full: Dict[Tuple[int, Tuple[int, ...]], _Node] = {}
        self._children: Dict[int, List[_Node]] = {}  # parent nid -> nodes
        self._next = 1
        self.hits = 0
        self.misses = 0
        self.tokens_served = 0
        self.n_inserted = 0
        self.n_evicted = 0
        allocator.reclaim = self.evict

    # -- introspection ------------------------------------------------------

    @property
    def n_pages(self) -> int:
        return len(self._lru)

    def check(self) -> None:
        """Index-side conservation: every node's page carries at least one
        allocator pin, pin totals match node counts per page, and child
        counters agree with the tree."""
        per_page: Dict[int, int] = {}
        kids: Dict[int, int] = {}
        for node in self._lru.values():
            per_page[node.page] = per_page.get(node.page, 0) + 1
            if node.parent is not None:
                kids[node.parent.nid] = kids.get(node.parent.nid, 0) + 1
        assert per_page == dict(self.alloc.pins), \
            f"index pins {per_page} != allocator pins {self.alloc.pins}"
        for node in self._lru.values():
            assert node.n_children == kids.get(node.nid, 0), \
                f"node {node.nid} child count drift"

    # -- lookup -------------------------------------------------------------

    def lookup(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens``: ``(page_run, n_cached)``.

        ``page_run`` are the physical pages covering lines
        ``[0, n_cached)`` when mounted at table slots ``0..len(run)-1``;
        the last page may be valid only up to ``n_cached % page_size``
        lines (mid-page divergence — the sharer must COW-fork it before
        writing). Touches the LRU along the matched path."""
        toks = tuple(tokens)
        ps = self.page_size
        pages: List[int] = []
        path: List[_Node] = []
        n = 0
        parent_id = 0
        while n + ps <= len(toks):
            node = self._full.get((parent_id, toks[n:n + ps]))
            if node is None:
                break
            pages.append(node.page)
            path.append(node)
            n += ps
            parent_id = node.nid
        # Divergence tail: the child (full or partial) sharing the longest
        # common token prefix with what remains still donates those lines.
        rest = toks[n:]
        if rest:
            best, best_m = None, 0
            for cand in self._children.get(parent_id, ()):
                m = min(_common_prefix(cand.tokens, rest), cand.n_valid)
                if m > best_m:
                    best, best_m = cand, m
            if best is not None:
                pages.append(best.page)
                path.append(best)
                n += best_m
        for node in path:
            self._lru.move_to_end(node.nid)
        if n > 0:
            self.hits += 1
            self.tokens_served += n
        elif toks:
            self.misses += 1
        return pages, n

    # -- registration -------------------------------------------------------

    def insert(self, tokens: Sequence[int], pages: Sequence[int],
               n_valid: Optional[int] = None) -> int:
        """Register the page run of a request: ``pages`` are its table in
        slot order, holding the KV lines of ``tokens[:n_valid]``. Full
        pages become interior nodes; a trailing remainder becomes a
        partial leaf. Nodes already present are touched, not duplicated
        (first writer wins — the resident page is as good as ours).
        Returns the number of NEW pages pinned."""
        toks = tuple(tokens)
        n_valid = len(toks) if n_valid is None else min(n_valid, len(toks))
        ps = self.page_size
        parent: Optional[_Node] = None
        parent_id = 0
        added = 0
        n_full = n_valid // ps
        for i in range(n_full):
            run = toks[i * ps:(i + 1) * ps]
            node = self._full.get((parent_id, run))
            if node is None:
                if i >= len(pages):
                    break
                node = self._new_node(parent, run, pages[i], ps)
                self._full[(parent_id, run)] = node
                added += 1
            else:
                self._lru.move_to_end(node.nid)
            parent, parent_id = node, node.nid
        rem = n_valid - n_full * ps
        if rem > 0 and n_full < len(pages):
            run = toks[n_full * ps:n_valid]
            # Dedupe against an existing child already covering this run.
            exists = any(
                min(_common_prefix(c.tokens, run), c.n_valid) >= rem
                for c in self._children.get(parent_id, ()))
            if not exists:
                self._new_node(parent, run, pages[n_full], rem)
                added += 1
        self.n_inserted += added
        if self.capacity_pages is not None:
            while len(self._lru) > self.capacity_pages:
                if not self._evict_one():
                    break
        return added

    def _new_node(self, parent: Optional[_Node], tokens: Tuple[int, ...],
                  page: int, n_valid: int) -> _Node:
        node = _Node(self._next, parent, tokens, page, n_valid)
        self._next += 1
        self._lru[node.nid] = node
        self._children.setdefault(
            0 if parent is None else parent.nid, []).append(node)
        if parent is not None:
            parent.n_children += 1
        self.alloc.pin(page)
        return node

    # -- eviction -----------------------------------------------------------

    def _evict_one(self) -> bool:
        """Unpin the coldest LEAF (interior nodes would strand their
        subtree's pins). Returns False when nothing is evictable."""
        victim = None
        for node in self._lru.values():  # iterates cold -> hot
            if node.n_children == 0:
                victim = node
                break
        if victim is None:
            return False
        del self._lru[victim.nid]
        pid = 0 if victim.parent is None else victim.parent.nid
        self._children[pid].remove(victim)
        if not self._children[pid]:
            del self._children[pid]
        if victim.parent is not None:
            victim.parent.n_children -= 1
        if victim.n_valid == self.page_size:
            del self._full[(pid, victim.tokens)]
        self.alloc.unpin(victim.page)
        self.n_evicted += 1
        return True

    def evict(self, need: int = 1) -> int:
        """Allocator reclaim hook: evict cold entries until ``need`` pages
        landed on the free list (an unpin only frees a page nobody else
        shares) or nothing evictable remains. Returns pages freed."""
        before = self.alloc.n_free
        while self.alloc.n_free - before < need:
            if not self._evict_one():
                break
        return self.alloc.n_free - before

    def flush(self) -> int:
        """Drop every entry (unpinning all pages). Returns entries
        removed."""
        n = 0
        while self._evict_one():
            n += 1
        assert not self._lru, "flush left non-leaf cycles"
        return n
