"""Disaggregated prefill/decode serving controller (DESIGN.md §10).

The router + two-level scheduler over one :class:`PrefillWorker` and one
:class:`DecodeWorker`:

  level 1 (prefill admission): requests enter the PREFILL queue and are
      admitted by the prefill pool's page budget (PrefillScheduler);
  level 2 (decode admission): finished prefills park as migration
      tickets and move to decode FIFO, gated by a free decode slot AND
      enough decode-pool pages for the full prompt — the KV crosses as
      pages through the transfer engine, the table rewrite makes it
      addressable, and the source pages recycle.

One controller ``tick`` mirrors the unified engine's: prefill chunks up
to the token budget, then migrations, then decode page growth (pool OOM
preempts newest back to RE-PREFILL — the victim's pages free on both
sides and it replays prompt+generated through the prefill worker;
key(rid, n) sampling keeps the continuation token-exact), then one
batched decode step. Because per-request logits depend only on the
request's own tokens (attention is per-row, the serve MoE path is
dropless) and sampling keys are schedule-independent, the disagg
deployment is greedy/sampled TOKEN-EXACT against the unified
ContinuousBatchingEngine on any trace — pinned by
tests/test_serve_disagg.py.

Head-of-line migration: tickets migrate strictly FIFO (a stuck head does
not let younger tickets overtake), matching the unified engine's FIFO
admission so queue metrics stay comparable.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax

from repro.models.config import ModelConfig
from repro.models.modules import RunConfig
from repro.obs import trace as obs_trace
from repro.serve.engine import make_continuous_program
from repro.serve.kv_blocks import BlockAllocator
from repro.serve.kv_transfer import KVTransferEngine, TransferAbortedError
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import (DecodeScheduler, PrefillScheduler,
                                   Request)
from repro.serve.disagg.workers import (DecodeWorker, MigrationTicket,
                                        PrefillWorker)


class DisaggController:
    """Drives the role-split workers through a shared tick clock."""

    def __init__(self, prefill: PrefillWorker, decode: DecodeWorker,
                 transfer: KVTransferEngine, *,
                 metrics: Optional[ServeMetrics] = None):
        self.prefill = prefill
        self.decode = decode
        self.transfer = transfer
        self.metrics = metrics or decode.metrics
        self.decode.metrics = self.metrics
        self.pending: List[MigrationTicket] = []  # finished, unmigrated
        self.rejected: List[int] = []
        self.tick_count = 0
        self.owns_clock = True  # standalone: this controller advances the
        #                         tracer (fleet takes it over per group)
        self.n_full_hits = 0  # prefix-cache full hits routed straight
        #                       to decode (zero KV transfer, §14)

    def set_tracks(self, prefill_track: str, decode_track: str) -> None:
        """Rename the two role tracks (fleet groups use g{gid}:prefill /
        g{gid}:decode) and cede the tick clock to the caller."""
        self.prefill.track = prefill_track
        self.prefill.sched.track = prefill_track
        self.decode.track = decode_track
        self.decode.sched.track = decode_track
        self.owns_clock = False

    # -- submission ---------------------------------------------------------

    @property
    def results(self) -> Dict[int, List[int]]:
        return self.decode.sched.results

    @property
    def logits(self):
        return self.decode.logits

    def submit(self, req: Request) -> None:
        """Admit to the prefill queue. Validates against BOTH pools: the
        prefill pool must hold the worst-case re-prefill (prompt +
        generated on a late preemption) and the decode pool the full
        sequence — otherwise preemption could never clear room."""
        total = len(req.prompt) + req.max_new_tokens
        if not self.decode.allocator.fits_pool(total):
            self.prefill.sched.n_rejected += 1
            raise ValueError(
                f"request {req.rid}: needs more pages than the decode "
                f"pool holds")
        self.prefill.sched.submit(req)  # validates + prefill-pool fit
        self.metrics.on_submit(req.rid, len(req.prompt))
        obs_trace.TRACER.flow(self.prefill.track, "queued", req.rid,
                              prompt=len(req.prompt))

    # -- one controller tick ------------------------------------------------

    def tick(self) -> None:
        tr = obs_trace.TRACER
        if self.owns_clock:
            tr.advance(self.tick_count)
        self._admit_full_hits()
        self.pending.extend(self.prefill.step())
        while self.pending:
            # FIFO, head-of-line: a stuck head keeps its place in line.
            try:
                if not self.decode.try_admit(self.pending[0], self.prefill,
                                             self.transfer,
                                             self.tick_count):
                    break
            except TransferAbortedError:
                # Transfer exhausted its retries: the decode side already
                # rolled back (lease + slot). Roll back the source export
                # and send the request down the existing re-prefill path —
                # key(rid, n) sampling keeps its continuation token-exact.
                t = self.pending.pop(0)
                rid = t.request.rid
                self.prefill.allocator.abort_export(rid)
                self.prefill.allocator.free(rid)
                self.metrics.robust.transfer_aborts += 1
                self.prefill.sched.requeue_front(
                    t.request, list(t.tokens[len(t.request.prompt):]))
                continue
            self.pending.pop(0)
        for request, generated in self.decode.ensure_pages():
            self.prefill.sched.requeue_front(request, generated)
        if self.decode.any_active():
            self.decode.decode_once(self.tick_count)
        st = self.transfer.stats
        self.metrics.robust.transfer_retries = st.n_retries
        self.metrics.robust.checksum_failures = st.n_checksum_failures
        self.metrics.on_tick(self.queue_depth, self.decode.sched.n_active)
        if tr.enabled:
            # Per-role idle attribution (§15): a role track that opened no
            # span this tick gets exactly one idle bucket.
            if not tr.busy_this_tick(self.prefill.track):
                bucket = "pool-OOM" \
                    if self.prefill.sched.wait_reason == "pages" \
                    else "queue-starved"
                tr.mark_idle(self.prefill.track, bucket)
            if not tr.busy_this_tick(self.decode.track):
                bucket = "transfer-wait" if self.pending \
                    else "queue-starved"
                tr.mark_idle(self.decode.track, bucket)
            tr.count(self.prefill.track, "queue_depth", self.queue_depth)
        self.tick_count += 1

    def _admit_full_hits(self) -> None:
        """Route prefix-cache FULL hits straight to decode (§14): a queued
        request whose prompt (minus the always-prefilled last token) is
        entirely resident in the DECODE pool's prefix index skips the
        prefill worker AND the KV transfer — the decode worker mounts the
        shared pages and runs the 1-token completion itself. Scans the
        whole queue (a full hit behind a cold head should not wait for the
        head's prefill), admitting in FIFO order among the hits;
        non-hits keep their positions."""
        sched = self.prefill.sched
        if self.decode.sched.prefix_index is None or not sched.queue:
            return
        i = 0
        while i < len(sched.queue):
            if not self.decode.sched.has_free():
                return
            entry = sched.queue[i]
            if self.decode.try_admit_cached(
                    entry.request, entry.tokens, len(entry.resume),
                    self.tick_count):
                del sched.queue[i]
                self.n_full_hits += 1
                obs_trace.TRACER.instant(self.decode.track, "full-hit",
                                         rid=entry.request.rid)
            else:
                i += 1

    @property
    def queue_depth(self) -> int:
        return self.prefill.sched.depth + len(self.pending)

    def has_work(self) -> bool:
        return self.prefill.sched.has_work() or bool(self.pending) \
            or bool(self.decode.sched.running)

    # -- trace driver -------------------------------------------------------

    def run(self, requests: List[Request], max_ticks: int = 100_000):
        """Drive a trace to completion (same contract as the unified
        engine's ``run``: arrivals in engine ticks, inadmissible requests
        are recorded in ``rejected`` and skipped)."""
        pending = sorted(requests, key=lambda r: r.arrival)
        while True:
            while pending and pending[0].arrival <= self.tick_count:
                req = pending.pop(0)
                try:
                    self.submit(req)
                except ValueError:
                    self.rejected.append(req.rid)
            if not pending and not self.has_work() \
                    and not self.decode.any_active():
                return self.results
            self.tick()
            if self.tick_count > max_ticks:
                raise RuntimeError(f"serve trace exceeded {max_ticks} ticks")


def make_disagg(cfg: ModelConfig, mesh, run: RunConfig, params, *,
                decode_slots: int, max_len: int, page_size: int,
                prefill_pages: Optional[int] = None,
                decode_pages: Optional[int] = None,
                prefill_chunk: int = 16,
                token_budget: Optional[int] = None, seed: int = 0,
                transfer_chunk_pages: int = 4,
                link_bw: Optional[float] = None, latency_s: float = 0.0,
                metrics: Optional[ServeMetrics] = None,
                on_token: Optional[Callable] = None,
                record_logits: bool = False, ep=None,
                ep_placement=None, prefix=None) -> DisaggController:
    """Wire up the full disaggregated deployment over one mesh.

    Both workers get their own paged program + pool + allocator (the
    prefill pool defaults to TWO max-length sequences — the mid-flight
    batch-1 prompt plus parked-ticket headroom; the decode pool defaults
    to full reservation capacity). The
    role split is logical on this container; the inter-group link lives
    in the transfer engine's cost model.

    ``ep`` (a ``serve.ep_decode.EPDecodeConfig``) shards the decode
    group's expert weights over the EP axis (DESIGN.md §11): BOTH
    programs are built with EP (the prefill worker shares the mesh here,
    so its expert hop must use the sharded weights too), params are
    placed under ``ep_placement`` (default round-robin), and the decode
    worker's routed-copy histograms feed a RoutingEMA exposed at
    ``controller.decode.routing_ema``.

    ``prefix`` (a ``serve.config.PrefixCacheCfg``) attaches a
    :class:`~repro.serve.prefix_index.PrefixIndex` to the DECODE pool
    only (DESIGN.md §14): decode-side registration feeds it, full hits
    bypass prefill and the transfer entirely
    (``DisaggController._admit_full_hits``), and its ``fair`` flag
    switches the prefill queue to per-tenant deficit round-robin. The
    prefill pool never shares pages — its exports require refcount 1.
    """
    max_pages = -(-max_len // page_size)
    prefill_pages = prefill_pages if prefill_pages is not None \
        else 2 * max_pages
    pre_prog = make_continuous_program(
        cfg, mesh, run, n_slots=1, max_len=max_len, seed=seed,
        page_size=page_size, n_pages=max(prefill_pages, max_pages), ep=ep)
    dec_prog = make_continuous_program(
        cfg, mesh, run, n_slots=decode_slots, max_len=max_len, seed=seed,
        page_size=page_size, n_pages=decode_pages, ep=ep)
    if ep is not None:
        from repro.core.asym_ea import round_robin_placement
        from repro.serve.ep_decode import place_params
        pl = ep_placement if ep_placement is not None else ep.placement
        if pl is None:
            pl = round_robin_placement(cfg.n_experts, ep.ep_size)
        params = place_params(params, cfg, pl)
    with mesh:
        pre_params = jax.device_put(params, pre_prog.param_shardings)
        dec_params = jax.device_put(params, dec_prog.param_shardings)
    caching = prefix is not None and getattr(prefix, "enabled", False)
    pre_sched = PrefillScheduler(
        max_len, prefill_chunk=prefill_chunk, token_budget=token_budget,
        allocator=BlockAllocator(pre_prog.n_pages, page_size,
                                 pre_prog.max_pages),
        fair=caching and prefix.fair)
    dec_alloc = BlockAllocator(dec_prog.n_pages, page_size,
                               dec_prog.max_pages)
    prefix_index = None
    if caching:
        from repro.serve.prefix_index import PrefixIndex
        prefix_index = PrefixIndex(dec_alloc,
                                   capacity_pages=prefix.capacity_pages)
    dec_sched = DecodeScheduler(decode_slots, allocator=dec_alloc,
                                prefix_index=prefix_index)
    prefill = PrefillWorker(pre_prog, pre_params, pre_sched)
    decode = DecodeWorker(dec_prog, dec_params, dec_sched, metrics=metrics,
                          on_token=on_token, record_logits=record_logits)
    if ep is not None:
        from repro.serve.metrics import RoutingEMA
        decode.routing_ema = RoutingEMA(cfg.n_experts, decay=ep.ema_decay)
    transfer = KVTransferEngine(chunk_pages=transfer_chunk_pages,
                                link_bw=link_bw, latency_s=latency_s)
    return DisaggController(prefill, decode, transfer, metrics=metrics)
