from repro.serve.disagg.controller import DisaggController, make_disagg
from repro.serve.disagg.workers import (DecodeWorker, MigrationTicket,
                                        PrefillWorker)

__all__ = ["DisaggController", "make_disagg", "PrefillWorker",
           "DecodeWorker", "MigrationTicket"]
