"""Role-split serving workers (DESIGN.md §10).

The HeterMoE zebra insight applied to serving: prefill is attention-heavy
and compute-bound — it belongs on the attention-strong (newer) device
group — while decode is expert/GEMM-heavy and memory-bound — it stays
efficient on the older expert group. Each worker owns its OWN paged pool
and allocator; a request's KV crosses the group boundary exactly once, as
pages (serve/kv_transfer.py), when its prefill finishes.

* :class:`PrefillWorker` — batch-1 chunked prefill into the prefill
  pool, driven by a :class:`PrefillScheduler` whose page-budget admission
  is against that pool. A finished prompt parks as a
  :class:`MigrationTicket`: its pages leave the live table for the
  allocator's EXPORTED state (owned by the pending transfer, reachable by
  no engine) and the batch-1 recurrent carry + final-position logits ride
  along host-side. The single prefill stream is immediately free for the
  next request — migration backpressure shows up as pool pressure, not
  stream pressure.
* :class:`DecodeWorker` — the decode half of the continuous-batching
  engine (per-slot positions, page tables, sampled decode) minus any
  prefill path. Admission = import pages into the decode pool + ship the
  payload + insert the recurrent carry + page-table rewrite; pool OOM
  preempts newest and hands the victim BACK for re-prefill (the
  controller requeues it at the prefill queue front; key(rid, n) sampling
  makes the resume token-exact, §7.4).

Both workers are driven by :class:`~repro.serve.disagg.controller.
DisaggController`; on this container the two "groups" share one process
and the link cost is simulated in the transfer engine.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

import jax

from repro.models import stack
from repro.obs import trace as obs_trace
from repro.serve.engine import ContinuousProgram
from repro.serve.kv_transfer import KVTransferEngine
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import (DecodeScheduler, PrefillScheduler,
                                   Request)


@dataclasses.dataclass
class MigrationTicket:
    """A finished prefill awaiting migration to the decode group.

    Owns the request's prefill-pool pages (allocator EXPORTED state) until
    the transfer lands; ships only page ids + the tiny batch-1 recurrent
    carry + the final-position logits — never a contiguous KV cache."""

    request: Request
    tokens: List[int]        # prompt + replayed resume tokens
    n_done: int              # tokens generated before this prefill (resume)
    src_pages: List[int]     # prefill-pool page ids, logical order
    prec: object             # batch-1 recurrent carry (device tree)
    last_logits: object      # [1, V] f32 final-position logits


class PrefillWorker:
    """Chunked paged prefill on the attention-strong group."""

    def __init__(self, program: ContinuousProgram, params,
                 sched: PrefillScheduler):
        assert program.paged, "disagg workers require paged programs"
        assert sched.allocator is not None, \
            "prefill scheduler needs the prefill pool's allocator"
        self.p = program
        self.params = params
        self.sched = sched
        self.track = "prefill"  # tracer track (§15); fleet renames per group
        sched.track = self.track
        with program.mesh:
            # The detached prefill state (stack.init_paged_prefill_state):
            # pools sized by the PREFILL group's HBM budget, batch-1
            # recurrent skeleton — no decode-engine slot geometry anywhere.
            self.state = jax.jit(
                lambda: stack.init_paged_prefill_state(
                    program.cfg, program.n_pages, program.page_size,
                    program.run.policy.compute_dtype),
                out_shardings=program.state_shardings)()
        self.prec = None  # batch-1 recurrent carry of the mid-flight prompt

    @property
    def allocator(self):
        return self.sched.allocator

    def step(self) -> List[MigrationTicket]:
        """Spend up to ``token_budget`` prefill tokens on the FIFO queue;
        returns tickets for prompts now fully cached in the prefill pool.
        The batch-1 stream is the landing site (slot hooks are trivial);
        page admission against the prefill allocator is the real gate."""
        tickets = []
        tr = obs_trace.TRACER
        budget = self.sched.token_budget
        while budget > 0:
            chunk = self.sched.plan(budget, lambda: True, lambda: 0)
            if chunk is None:
                break
            req = chunk.request
            with tr.span(self.track, "prefill", rid=req.rid,
                         start=chunk.start, length=chunk.length):
                if chunk.first:
                    tr.flow(self.track, "prefill", req.rid)
                toks = np.asarray(
                    chunk.tokens[chunk.start:chunk.start + chunk.length],
                    np.int32)[None, :]
                if chunk.start == 0:  # fresh (or resumed) -> fresh carry
                    with self.p.mesh:
                        self.prec = self.p.init_prec()
                ptrow = jnp.asarray(self.allocator.table(
                    req.rid, self.p.max_pages))[None, :]
                with self.p.mesh:
                    self.state, self.prec, logits = self.p.prefill_step(
                        self.params, self.state, self.prec, toks,
                        jnp.asarray(chunk.start, jnp.int32), ptrow)
            budget -= chunk.length
            if self.sched.finish_chunk(chunk):
                ticket = MigrationTicket(
                    request=req, tokens=list(chunk.tokens),
                    n_done=chunk.n_done,
                    src_pages=self.allocator.export_pages(req.rid),
                    prec=self.prec, last_logits=logits)
                tickets.append(ticket)
                tr.instant(self.track, "ticket", rid=req.rid,
                           pages=len(ticket.src_pages))
                self.prec = None
        return tickets


class DecodeWorker:
    """Continuous-batching decode on the expert group."""

    def __init__(self, program: ContinuousProgram, params,
                 sched: DecodeScheduler, *,
                 metrics: Optional[ServeMetrics] = None,
                 on_token: Optional[Callable] = None,
                 record_logits: bool = False):
        assert program.paged, "disagg workers require paged programs"
        assert sched.allocator is not None, \
            "decode scheduler needs the decode pool's allocator"
        assert sched.allocator.page_size == program.page_size \
            and sched.allocator.n_pages == program.n_pages \
            and sched.allocator.max_pages_per_seq >= program.max_pages, \
            "allocator geometry disagrees with the program"
        self.p = program
        self.params = params
        self.sched = sched
        self.track = "decode"  # tracer track (§15); fleet renames per group
        sched.track = self.track
        self.metrics = metrics or ServeMetrics()
        self.on_token = on_token
        self.record_logits = record_logits
        self.logits: Dict[int, List[np.ndarray]] = {}
        B = program.n_slots
        with program.mesh:
            self.state = program.init_state()
        # Host mirrors of the per-slot decode inputs (same layout as the
        # unified ContinuousBatchingEngine).
        self._tok = np.zeros((B,), np.int32)
        self._pos = np.full((B,), -1, np.int32)
        self._active = np.zeros((B,), bool)
        self._rid = np.zeros((B,), np.int32)
        self._ngen = np.zeros((B,), np.int32)
        self._temp = np.zeros((B,), np.float32)
        self._topk = np.zeros((B,), np.int32)
        self._topp = np.ones((B,), np.float32)
        self._ptab = np.full((B, program.max_pages), -1, np.int32)
        self.page_peak = 0
        # EP decode (DESIGN.md §11): the controller attaches a RoutingEMA
        # when the program carries an EPDecodeConfig.
        self.routing_ema = None

    @property
    def allocator(self):
        return self.sched.allocator

    # -- migration (the inbound half of the handoff) ------------------------

    def try_admit(self, ticket: MigrationTicket,
                  src_worker: PrefillWorker,
                  transfer: KVTransferEngine, tick: int, *,
                  src_name: str = "*", dst_name: str = "*") -> bool:
        """Land a migration ticket: lease pages in the decode pool, ship
        the KV pages, commit the lease, insert the recurrent carry, rewrite
        the page table, and sample the request's next token from the
        shipped logits. False (nothing changed) when no free slot or not
        enough pages. Transactional (DESIGN.md §13): the destination pages
        stay under an in-flight lease until the transfer lands, so a
        failed/aborted transfer rolls back here — lease returned, slot
        released, source pages still EXPORTED for the caller's
        ``abort_export`` — and the exception propagates."""
        req = ticket.request
        if not self.sched.has_free():
            return False
        dst = self.allocator.begin_import(req.rid, len(ticket.tokens))
        if dst is None:
            return False
        slot = self.sched.claim_slot()
        try:
            with self.p.mesh, obs_trace.TRACER.span(
                    self.track, "admit", rid=req.rid, pages=len(dst)):
                self.state = transfer.transfer(
                    src_worker.state, self.state, ticket.src_pages, dst,
                    dst_n_pages=self.p.n_pages,
                    src_name=src_name, dst_name=dst_name, rid=req.rid)
        except Exception as e:
            # The transfer's scatter donates our state: if any chunk
            # landed before the fault, the old reference is dead and the
            # live tree rides on the exception. The partial writes only
            # touched pages under the lease we're about to abort.
            live = getattr(e, "dst_state", None)
            if live is not None:
                self.state = live
            self.allocator.abort_import(req.rid)
            self.sched.release_slot(slot)
            raise
        self.allocator.commit_import(req.rid)
        with self.p.mesh:
            src_worker.allocator.release_exported(req.rid)
            self.state = self.p.insert_step(self.state, ticket.prec,
                                            jnp.asarray(slot, jnp.int32))
            sp = req.sampling
            first = self.p.sample_step(
                ticket.last_logits, np.asarray([req.rid], np.int32),
                np.asarray([ticket.n_done], np.int32),
                np.asarray([sp.temperature], np.float32),
                np.asarray([sp.top_k], np.int32),
                np.asarray([sp.top_p], np.float32))
        self._ptab[slot] = self.allocator.table(req.rid, self.p.max_pages)
        first = int(np.asarray(first)[0])
        if self.record_logits:
            row = np.asarray(ticket.last_logits)[0]
            if ticket.n_done == 0:
                self.logits[req.rid] = [row]
            else:
                self.logits[req.rid].append(row)
        self.metrics.on_token(req.rid, tick)
        finished = self.sched.activate(req, slot, ticket.tokens,
                                       ticket.n_done, first)
        if self.on_token:
            self.on_token(req.rid, first, finished)
        if finished:
            self.metrics.on_finish(req.rid, tick)
            self._ptab[slot] = -1
            return True
        self._tok[slot] = first
        self._pos[slot] = len(ticket.tokens)
        self._active[slot] = True
        self._rid[slot] = req.rid
        self._ngen[slot] = ticket.n_done + 1
        self._temp[slot] = sp.temperature
        self._topk[slot] = sp.top_k
        self._topp[slot] = sp.top_p
        return True

    # -- prefix-cache full hit (DESIGN.md §14) ------------------------------

    def try_admit_cached(self, req: Request, tokens: List[int],
                         n_done: int, tick: int) -> bool:
        """Admit a request whose prompt is a FULL prefix-cache hit straight
        into a decode slot — zero KV transfer: the decode pool already
        holds every line but the last, so a 1-token prefill at offset
        ``len(tokens) - 1`` on THIS program (into a COW-forked tail page if
        the cached one is shared) completes the KV and yields the same
        final-position logits the prefill worker would have shipped —
        token-exact by the key(rid, n) sampling contract. Opportunistic:
        False (nothing changed) when there is no hit, no slot, or no
        pages — the request stays queued for the ordinary prefill path."""
        index = self.sched.prefix_index
        if index is None or not self.sched.has_free() or len(tokens) < 2:
            return False
        pages, n_cached = index.lookup(tokens)
        if n_cached < len(tokens) - 1:
            return False
        alloc = self.allocator
        if not alloc.share_pages(req.rid, len(tokens), pages):
            return False
        last = len(tokens) - 1
        pslot = last // alloc.page_size
        table = alloc.tables[req.rid]
        if alloc.is_shared(table[pslot]):
            try:
                old, new = alloc.cow_fork(req.rid, pslot)
            except MemoryError:
                alloc.free(req.rid)  # fall back to the prefill path
                return False
            with self.p.mesh:
                self.state = self.p.fork_step(
                    self.state, jnp.asarray([old], jnp.int32),
                    jnp.asarray([new], jnp.int32))
        slot = self.sched.claim_slot()
        sp = req.sampling
        ptrow = jnp.asarray(alloc.table(req.rid, self.p.max_pages))[None, :]
        toks = np.asarray([tokens[last]], np.int32)[None, :]
        with self.p.mesh, obs_trace.TRACER.span(
                self.track, "cached-admit", rid=req.rid, cached=n_cached):
            prec = self.p.init_prec()
            self.state, prec, logits = self.p.prefill_step(
                self.params, self.state, prec, toks,
                jnp.asarray(last, jnp.int32), ptrow)
            first = self.p.sample_step(
                logits, np.asarray([req.rid], np.int32),
                np.asarray([n_done], np.int32),
                np.asarray([sp.temperature], np.float32),
                np.asarray([sp.top_k], np.int32),
                np.asarray([sp.top_p], np.float32))
            self.state = self.p.insert_step(self.state, prec,
                                            jnp.asarray(slot, jnp.int32))
        self._ptab[slot] = alloc.table(req.rid, self.p.max_pages)
        first = int(np.asarray(first)[0])
        if self.record_logits:
            row = np.asarray(logits)[0]
            if n_done == 0:
                self.logits[req.rid] = [row]
            else:
                self.logits[req.rid].append(row)
        self.metrics.on_token(req.rid, tick)
        finished = self.sched.activate(req, slot, tokens, n_done, first)
        if self.on_token:
            self.on_token(req.rid, first, finished)
        if finished:
            self.metrics.on_finish(req.rid, tick)
            self._ptab[slot] = -1
            return True
        self._tok[slot] = first
        self._pos[slot] = len(tokens)
        self._active[slot] = True
        self._rid[slot] = req.rid
        self._ngen[slot] = n_done + 1
        self._temp[slot] = sp.temperature
        self._topk[slot] = sp.top_k
        self._topp[slot] = sp.top_p
        return True

    # -- decode tick --------------------------------------------------------

    def ensure_pages(self) -> List[tuple]:
        """Claim a decode-pool page for every live slot whose next write
        position crossed its allocated frontier; on pool OOM preempt the
        newest running request. Returns the preempted (request, generated)
        pairs — the controller requeues them for re-prefill."""
        alloc = self.allocator
        preempted = []
        order = sorted((int(s) for s in np.nonzero(self._active)[0]),
                       key=lambda s: self.sched.running[s].seq)
        for slot in order:
            if not self._active[slot]:
                continue  # evicted by an earlier slot's OOM relief
            rid = int(self._rid[slot])
            while not alloc.covers(rid, int(self._pos[slot])):
                if alloc.extend(rid):
                    self._ptab[slot] = alloc.table(rid, self.p.max_pages)
                    continue
                out = self.sched.pop_newest()
                assert out is not None, "OOM with nothing to preempt"
                victim, request, generated = out
                self._clear_slot(victim)
                preempted.append((request, generated))
                if victim == slot:
                    break  # this slot itself was evicted; it will resume
            if self._active[slot]:
                self._cow_guard(slot, rid, preempted)
        return preempted

    def _cow_guard(self, slot: int, rid: int, preempted: List[tuple]) -> None:
        """Fork the page this slot is about to write if it is still shared
        (decode half of fork-on-divergence, §14). Pool OOM preempts the
        newest running request for the copy target, appending to the
        caller's ``preempted`` list."""
        alloc = self.allocator
        table = alloc.tables.get(rid)
        pslot = int(self._pos[slot]) // alloc.page_size
        if not table or pslot >= len(table) \
                or not alloc.is_shared(table[pslot]):
            return
        while True:
            try:
                old, new = alloc.cow_fork(rid, pslot)
                break
            except MemoryError:
                out = self.sched.pop_newest()
                assert out is not None, "COW OOM with nothing to preempt"
                victim, request, generated = out
                self._clear_slot(victim)
                preempted.append((request, generated))
                if victim == slot:
                    return  # the writer itself was evicted; it resumes
        with self.p.mesh:
            self.state = self.p.fork_step(
                self.state, jnp.asarray([old], jnp.int32),
                jnp.asarray([new], jnp.int32))
        self._ptab[slot] = alloc.table(rid, self.p.max_pages)

    def decode_once(self, tick: int) -> None:
        """One batched decode step over all live slots."""
        with self.p.mesh, obs_trace.TRACER.span(
                self.track, "decode", n_active=int(self._active.sum())):
            out = self.p.decode_step(
                self.params, self.state, self._tok[:, None], self._pos,
                self._ptab, self._active, self._rid, self._ngen,
                self._temp, self._topk, self._topp)
        if self.p.ep is not None:
            self.state, nxt, logits, counts = out
            self._on_ep_counts(counts)
        else:
            self.state, nxt, logits = out
        nxt = np.asarray(nxt)
        if self.record_logits:
            logits = np.asarray(logits)
        for slot in np.nonzero(self._active)[0]:
            slot = int(slot)
            tok = int(nxt[slot])
            rid = int(self._rid[slot])
            if self.record_logits:
                self.logits[rid].append(logits[slot])
            self.metrics.on_token(rid, tick)
            finished = self.sched.note_token(slot, tok)
            if self.on_token:
                self.on_token(rid, tok, finished)
            if finished:
                self.metrics.on_finish(rid, tick)
                self._clear_slot(slot)
            else:
                self._tok[slot] = tok
                self._pos[slot] += 1
                self._ngen[slot] += 1
        self.page_peak = max(self.page_peak, self.allocator.pages_in_use)

    def _on_ep_counts(self, counts) -> None:
        """Routing-histogram hook (EP decode program, DESIGN.md §11):
        the controller attaches a RoutingEMA here when EP is enabled."""
        if self.routing_ema is not None:
            self.routing_ema.update(np.asarray(counts))

    def _clear_slot(self, slot: int) -> None:
        self._active[slot] = False
        self._pos[slot] = -1
        self._tok[slot] = 0
        self._ngen[slot] = 0
        self._temp[slot] = 0.0
        self._topk[slot] = 0
        self._topp[slot] = 1.0
        self._ptab[slot] = -1

    def any_active(self) -> bool:
        return bool(self._active.any())
