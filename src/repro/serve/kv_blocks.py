"""Paged KV-cache block allocator (DESIGN.md §9, §14).

vLLM-style block-granular cache management, host-side only (mirrors the
scheduler: the allocator decides WHICH physical pages a request owns; the
engine's jitted steps consume the decision as `[B, max_pages]` page-table
arrays). The device-side pool is `[n_pages, page_size, ...]` per attention
layer; a page id indexes the same physical slot in every layer's pool.

Contracts:

* every in-use physical page carries a REFCOUNT (DESIGN.md §14): one per
  live-table occurrence, one per in-transit export, one per in-flight
  import lease, one per prefix-index PIN. ``check()`` asserts exact
  refcount conservation — the PR 4 "owned by at most one request"
  invariant is the refcount-1 special case and still holds verbatim for
  any run that never shares;
* freeing is a **page-table reset** — a page returns to the free list
  when its LAST reference drops, and the request's table entry is
  dropped with no device traffic. Stale KV lines in recycled pages are
  unreachable because the paged attention paths compute key positions
  structurally from the page-table slot (line ``j`` of table slot ``p``
  is position ``p * page_size + j``) and mask everything beyond the
  owner's causal frontier (DESIGN.md §9.2). The same structural-position
  argument is what makes SHARING sound: a page mounted at the same
  logical slot of two tables reads identically for both owners;
* ``share_pages`` builds a table whose leading slots alias
  already-resident pages (prefix-cache hit) and only draws fresh pages
  for the tail; ``cow_fork`` replaces one shared slot with a private
  copy-target page *before* the owner's first write into it
  (copy-on-write: writers never mutate a page with refcount > 1 — the
  engine copies the page's device lines old -> new after forking);
* allocation is all-or-nothing: ``allocate``/``share_pages``/``extend``
  either hand over every requested page or change nothing. When the
  free list runs short the allocator first consults the optional
  ``reclaim`` hook (the prefix index's LRU eviction), which may unpin
  cold cached pages back onto the free list;
* ownership transfer (disaggregated serving, DESIGN.md §10) is a
  three-state machine per request: live -> exported (pages owned by the
  in-flight KV transfer, reachable by neither side's engines) ->
  released (back on the free list once the destination pool holds the
  data). Only EXCLUSIVELY owned pages (refcount 1) may be exported —
  shared pages stay put, which is why prefix-hit requests skip the
  transfer entirely;
* the DESTINATION half of a handoff holds its claimed pages under an
  in-flight LEASE (``begin_import`` -> ``commit_import`` /
  ``abort_import``, DESIGN.md §13): leased pages are off the free list
  but not yet in any live table, so a transfer that dies mid-flight can
  neither leak a page (abort returns the whole lease) nor double-own one
  (``check()`` counts leases too). ``import_pages`` is the one-shot
  begin+commit wrapper for transfers with no failure path.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` cache lines."""
    return -(-max(n_tokens, 0) // page_size)


class BlockAllocator:
    """Free-list allocator over ``n_pages`` fixed-size physical pages."""

    def __init__(self, n_pages: int, page_size: int, max_pages_per_seq: int):
        assert n_pages >= 1 and page_size >= 1 and max_pages_per_seq >= 1
        self.n_pages = n_pages
        self.page_size = page_size
        self.max_pages_per_seq = max_pages_per_seq
        self._free: List[int] = list(range(n_pages - 1, -1, -1))  # pop -> 0
        self.tables: Dict[int, List[int]] = {}  # rid -> owned page ids
        self.exported: Dict[int, List[int]] = {}  # rid -> in-transit pages
        self.leases: Dict[int, List[int]] = {}  # rid -> inbound in-flight
        self.ref: Dict[int, int] = {}  # page -> total refcount (in-use only)
        self.pins: Dict[int, int] = {}  # page -> prefix-index pin count
        # Optional LRU-eviction hook (the prefix index): called with the
        # page shortfall when the free list cannot cover a request, may
        # return pages to the free list by unpinning cold cache entries.
        self.reclaim: Optional[Callable[[int], int]] = None
        self.n_fresh_allocs = 0  # pages drawn from the free list (bench)
        self.n_shared_allocs = 0  # table slots served by sharing (bench)
        self.n_cow_forks = 0  # cow_fork count (bench / tests)

    # -- capacity -----------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return pages_for(n_tokens, self.page_size)

    def fits_pool(self, n_tokens: int) -> bool:
        """Whether a request of ``n_tokens`` total lines can EVER be served
        (worst-case page need within the whole pool and the per-seq table).
        Checked at submit so preemption can always make progress down to a
        single live request — prefix-index pins do not break this because
        ``reclaim`` can evict every pin whose page is not also live."""
        need = self.pages_for(n_tokens)
        return need <= min(self.n_pages, self.max_pages_per_seq)

    # -- refcount internals -------------------------------------------------

    def _incref(self, page: int) -> None:
        self.ref[page] = self.ref.get(page, 0) + 1

    def _decref(self, page: int) -> None:
        n = self.ref[page] - 1
        if n:
            self.ref[page] = n
        else:
            del self.ref[page]
            self._free.append(page)

    def _take_free(self, need: int) -> Optional[List[int]]:
        """Pop ``need`` fresh pages, consulting the ``reclaim`` hook on
        shortfall. All-or-nothing: None when the pool cannot cover it."""
        if need > len(self._free) and self.reclaim is not None:
            self.reclaim(need - len(self._free))
        if need > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(need)]
        for p in pages:
            self._incref(p)
        self.n_fresh_allocs += len(pages)
        return pages

    def is_shared(self, page: int) -> bool:
        """True when writes to ``page`` must COW-fork first (refcount > 1:
        some other table, export, lease, or index pin also holds it)."""
        return self.ref.get(page, 0) > 1

    # -- allocation ---------------------------------------------------------

    def allocate(self, rid: int, n_tokens: int) -> bool:
        """Fresh table for ``rid`` covering ``n_tokens`` lines.

        All-or-nothing: returns False (and allocates nothing) when the free
        list cannot cover the request. ``rid`` must not already own pages.
        """
        return self.share_pages(rid, n_tokens, ())

    def share_pages(self, rid: int, n_tokens: int,
                    shared: "List[int] | tuple") -> bool:
        """Table for ``rid`` covering ``n_tokens`` lines whose leading
        slots ALIAS the already-resident ``shared`` pages (prefix-cache
        hit, DESIGN.md §14); only the tail draws fresh pages. Shared pages
        are increfed, never copied — a writer COW-forks before touching
        one. All-or-nothing like ``allocate``."""
        assert rid not in self.tables, f"rid {rid} already owns pages"
        need = self.pages_for(n_tokens)
        shared = list(shared)[:need]
        if need > self.max_pages_per_seq:
            return False
        for p in shared:
            assert p in self.ref, f"shared page {p} is not resident"
        # Hold our reference BEFORE drawing fresh pages: the reclaim hook
        # may evict index pins mid-draw, and these pages must survive it.
        for p in shared:
            self._incref(p)
        fresh = self._take_free(need - len(shared))
        if fresh is None:
            for p in shared:
                self._decref(p)
            return False
        self.n_shared_allocs += len(shared)
        self.tables[rid] = shared + fresh
        return True

    def extend(self, rid: int, n_new: int = 1) -> bool:
        """Append ``n_new`` pages to ``rid``'s table (decode growth)."""
        table = self.tables[rid]
        if len(table) + n_new > self.max_pages_per_seq:
            return False
        fresh = self._take_free(n_new)
        if fresh is None:
            return False
        table.extend(fresh)
        return True

    def cow_fork(self, rid: int, slot: int) -> "tuple[int, int]":
        """Replace the SHARED page at table slot ``slot`` of ``rid`` with a
        private fresh page (fork-on-write, DESIGN.md §14). Host-side only:
        the caller must copy the device lines ``old -> new`` (the engine's
        ``fork_step``) before any write lands. Returns ``(old, new)``.
        Raises MemoryError when no page can be reclaimed for the copy."""
        table = self.tables[rid]
        old = table[slot]
        assert self.is_shared(old), \
            f"cow_fork on exclusively-owned page {old} (slot {slot})"
        fresh = self._take_free(1)
        if fresh is None:
            raise MemoryError("cow_fork: pool exhausted")
        table[slot] = fresh[0]
        self._decref(old)
        self.n_cow_forks += 1
        return old, fresh[0]

    def free(self, rid: int) -> None:
        """Drop ``rid``'s table: each page loses one reference and returns
        to the free list only when nobody else (table/export/lease/pin)
        still holds it (copy-free recycle: the page-table reset IS the
        recycle)."""
        for p in self.tables.pop(rid, ()):
            self._decref(p)

    # -- prefix-index pins (DESIGN.md §14) ----------------------------------

    def pin(self, page: int) -> None:
        """Add a prefix-index reference to a resident page: the page
        survives its owner's ``free`` so future requests can share it."""
        assert page in self.ref, f"pin of non-resident page {page}"
        self.pins[page] = self.pins.get(page, 0) + 1
        self._incref(page)

    def unpin(self, page: int) -> None:
        """Drop one index reference (LRU eviction); the page is freed when
        this was the last reference of any kind."""
        n = self.pins[page] - 1
        if n:
            self.pins[page] = n
        else:
            del self.pins[page]
        self._decref(page)

    # -- ownership transfer (disaggregated handoff, DESIGN.md §10) ----------

    def export_pages(self, rid: int) -> List[int]:
        """Detach ``rid``'s pages from the live table for an outbound KV
        transfer. The pages leave the table but do NOT return to the free
        list: they are owned by the in-flight transfer (readable source
        data, unreachable by any engine-side page table) until
        ``release_exported`` lands them back. Only exclusively-owned
        pages may travel — a shared page's other owners would be left
        pointing at a recycled slot. Returns the page ids in logical
        (page-slot) order."""
        assert rid not in self.exported, f"rid {rid} already exporting"
        pages = self.tables[rid]
        for p in pages:
            assert self.ref[p] == 1, \
                f"export of shared page {p} (ref {self.ref[p]})"
        del self.tables[rid]
        self.exported[rid] = pages
        return list(pages)

    def release_exported(self, rid: int) -> None:
        """Finish an export: the destination pool holds the data, so the
        source pages recycle to the free list (a list move — no device
        traffic, like ``free``)."""
        for p in self.exported.pop(rid):
            self._decref(p)

    def abort_export(self, rid: int) -> None:
        """Undo ``export_pages`` (failed transfer): the pages return to the
        live table untouched — the source pool still holds valid KV."""
        assert rid not in self.tables, f"rid {rid} re-allocated mid-export"
        self.tables[rid] = self.exported.pop(rid)

    def begin_import(self, rid: int, n_tokens: int) -> Optional[List[int]]:
        """Destination half of the handoff, transactional (DESIGN.md §13):
        claim pages covering ``n_tokens`` lines under an in-flight LEASE.
        Leased pages are off the free list but in no live table — the
        transfer engine scatters into them while they are unreachable by
        any engine-side page table. ``commit_import`` lands them in the
        live table; ``abort_import`` (transfer failed / destination
        crashed mid-flight) returns the whole lease to the free list, so
        a dead transfer can neither leak nor double-own a page.
        All-or-nothing like ``allocate``; returns the leased page ids in
        logical order, or None when the pool cannot cover the request."""
        assert rid not in self.tables, f"rid {rid} already owns pages"
        assert rid not in self.leases, f"rid {rid} already importing"
        need = self.pages_for(n_tokens)
        if need > self.max_pages_per_seq:
            return None
        pages = self._take_free(need)
        if pages is None:
            return None
        self.leases[rid] = pages
        return list(pages)

    def commit_import(self, rid: int) -> None:
        """Transfer landed: promote the lease to the live table."""
        assert rid not in self.tables, f"rid {rid} re-allocated mid-import"
        self.tables[rid] = self.leases.pop(rid)

    def abort_import(self, rid: int) -> None:
        """Transfer failed: the leased pages hold garbage no table points
        at — return them to the free list untouched."""
        for p in self.leases.pop(rid):
            self._decref(p)

    def import_pages(self, rid: int, n_tokens: int) -> Optional[List[int]]:
        """One-shot begin+commit import for transfers with no failure
        path (returns the page ids now in ``rid``'s live table)."""
        if self.begin_import(rid, n_tokens) is None:
            return None
        self.commit_import(rid)
        return list(self.tables[rid])

    # -- introspection ------------------------------------------------------

    def covers(self, rid: int, line: int) -> bool:
        """Whether cache line ``line`` falls inside ``rid``'s owned pages."""
        return line < len(self.tables.get(rid, ())) * self.page_size

    def n_lines(self, rid: int) -> int:
        return len(self.tables.get(rid, ())) * self.page_size

    def table(self, rid: int, pad_to: int | None = None) -> np.ndarray:
        """``rid``'s page table as int32, -1-padded to ``pad_to`` slots."""
        pages = self.tables.get(rid, [])
        pad_to = self.max_pages_per_seq if pad_to is None else pad_to
        out = np.full((pad_to,), -1, np.int32)
        out[:len(pages)] = pages
        return out

    def check(self) -> None:
        """Assert refcount conservation (DESIGN.md §14): every page's
        refcount equals its occurrences across live tables, in-transit
        exports, in-flight import leases, and index pins; pages with no
        references sit on the free list exactly once; nothing leaks and
        nothing is double-owned. For runs that never share this reduces
        to the PR 4 exactly-once invariant."""
        want: Dict[int, int] = {}
        for pages in self.tables.values():
            for p in pages:
                want[p] = want.get(p, 0) + 1
        for pages in self.exported.values():
            for p in pages:
                want[p] = want.get(p, 0) + 1
        for pages in self.leases.values():
            for p in pages:
                want[p] = want.get(p, 0) + 1
        for p, n in self.pins.items():
            want[p] = want.get(p, 0) + n
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "page owned twice (free)"
        assert len(self._free) + len(self.ref) == self.n_pages, \
            f"page leak: {len(self._free) + len(self.ref)} tracked " \
            f"of {self.n_pages}"
        for p, n in self.ref.items():
            assert p not in free_set, f"page {p} both free and owned twice"
            assert want.get(p, 0) == n, \
                f"page {p} refcount {n} != {want.get(p, 0)} referenced " \
                f"(leak or double-own)"
        for p in want:
            assert p in self.ref, f"page {p} referenced but leak-untracked"
