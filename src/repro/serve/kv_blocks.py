"""Paged KV-cache block allocator (DESIGN.md §9).

vLLM-style block-granular cache management, host-side only (mirrors the
scheduler: the allocator decides WHICH physical pages a request owns; the
engine's jitted steps consume the decision as `[B, max_pages]` page-table
arrays). The device-side pool is `[n_pages, page_size, ...]` per attention
layer; a page id indexes the same physical slot in every layer's pool.

Contracts:

* a physical page is owned by AT MOST one live request at a time
  (``check()`` asserts it; tests drive it every engine tick);
* freeing is a **page-table reset** — pages return to the free list and
  the request's table entry is dropped, with no device traffic. Stale KV
  lines in recycled pages are unreachable because the paged attention
  paths compute key positions structurally from the page-table slot
  (line ``j`` of table slot ``p`` is position ``p * page_size + j``) and
  mask everything beyond the owner's causal frontier (DESIGN.md §9.2);
* allocation is all-or-nothing: ``allocate``/``extend`` either hand over
  every requested page or change nothing (no partial grabs to unwind);
* ownership transfer (disaggregated serving, DESIGN.md §10) is a
  three-state machine per request: live -> exported (pages owned by the
  in-flight KV transfer, reachable by neither side's engines) ->
  released (back on the free list once the destination pool holds the
  data). ``check()`` counts exported pages, so exactly-once ownership is
  asserted ACROSS the handoff, not just within one pool;
* the DESTINATION half of a handoff holds its claimed pages under an
  in-flight LEASE (``begin_import`` -> ``commit_import`` /
  ``abort_import``, DESIGN.md §13): leased pages are off the free list
  but not yet in any live table, so a transfer that dies mid-flight can
  neither leak a page (abort returns the whole lease) nor double-own one
  (``check()`` counts leases too). ``import_pages`` is the one-shot
  begin+commit wrapper for transfers with no failure path.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` cache lines."""
    return -(-max(n_tokens, 0) // page_size)


class BlockAllocator:
    """Free-list allocator over ``n_pages`` fixed-size physical pages."""

    def __init__(self, n_pages: int, page_size: int, max_pages_per_seq: int):
        assert n_pages >= 1 and page_size >= 1 and max_pages_per_seq >= 1
        self.n_pages = n_pages
        self.page_size = page_size
        self.max_pages_per_seq = max_pages_per_seq
        self._free: List[int] = list(range(n_pages - 1, -1, -1))  # pop -> 0
        self.tables: Dict[int, List[int]] = {}  # rid -> owned page ids
        self.exported: Dict[int, List[int]] = {}  # rid -> in-transit pages
        self.leases: Dict[int, List[int]] = {}  # rid -> inbound in-flight

    # -- capacity -----------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return pages_for(n_tokens, self.page_size)

    def fits_pool(self, n_tokens: int) -> bool:
        """Whether a request of ``n_tokens`` total lines can EVER be served
        (worst-case page need within the whole pool and the per-seq table).
        Checked at submit so preemption can always make progress down to a
        single live request."""
        need = self.pages_for(n_tokens)
        return need <= min(self.n_pages, self.max_pages_per_seq)

    # -- allocation ---------------------------------------------------------

    def allocate(self, rid: int, n_tokens: int) -> bool:
        """Fresh table for ``rid`` covering ``n_tokens`` lines.

        All-or-nothing: returns False (and allocates nothing) when the free
        list cannot cover the request. ``rid`` must not already own pages.
        """
        assert rid not in self.tables, f"rid {rid} already owns pages"
        need = self.pages_for(n_tokens)
        if need > len(self._free) or need > self.max_pages_per_seq:
            return False
        self.tables[rid] = [self._free.pop() for _ in range(need)]
        return True

    def extend(self, rid: int, n_new: int = 1) -> bool:
        """Append ``n_new`` pages to ``rid``'s table (decode growth)."""
        table = self.tables[rid]
        if n_new > len(self._free) \
                or len(table) + n_new > self.max_pages_per_seq:
            return False
        table.extend(self._free.pop() for _ in range(n_new))
        return True

    def free(self, rid: int) -> None:
        """Return every page of ``rid`` to the free list (copy-free recycle:
        the page-table reset IS the recycle)."""
        self._free.extend(self.tables.pop(rid, ()))

    # -- ownership transfer (disaggregated handoff, DESIGN.md §10) ----------

    def export_pages(self, rid: int) -> List[int]:
        """Detach ``rid``'s pages from the live table for an outbound KV
        transfer. The pages leave the table but do NOT return to the free
        list: they are owned by the in-flight transfer (readable source
        data, unreachable by any engine-side page table) until
        ``release_exported`` lands them back. Returns the page ids in
        logical (page-slot) order."""
        assert rid not in self.exported, f"rid {rid} already exporting"
        pages = self.tables.pop(rid)
        self.exported[rid] = pages
        return list(pages)

    def release_exported(self, rid: int) -> None:
        """Finish an export: the destination pool holds the data, so the
        source pages recycle to the free list (a list move — no device
        traffic, like ``free``)."""
        self._free.extend(self.exported.pop(rid))

    def abort_export(self, rid: int) -> None:
        """Undo ``export_pages`` (failed transfer): the pages return to the
        live table untouched — the source pool still holds valid KV."""
        assert rid not in self.tables, f"rid {rid} re-allocated mid-export"
        self.tables[rid] = self.exported.pop(rid)

    def begin_import(self, rid: int, n_tokens: int) -> Optional[List[int]]:
        """Destination half of the handoff, transactional (DESIGN.md §13):
        claim pages covering ``n_tokens`` lines under an in-flight LEASE.
        Leased pages are off the free list but in no live table — the
        transfer engine scatters into them while they are unreachable by
        any engine-side page table. ``commit_import`` lands them in the
        live table; ``abort_import`` (transfer failed / destination
        crashed mid-flight) returns the whole lease to the free list, so
        a dead transfer can neither leak nor double-own a page.
        All-or-nothing like ``allocate``; returns the leased page ids in
        logical order, or None when the pool cannot cover the request."""
        assert rid not in self.tables, f"rid {rid} already owns pages"
        assert rid not in self.leases, f"rid {rid} already importing"
        need = self.pages_for(n_tokens)
        if need > len(self._free) or need > self.max_pages_per_seq:
            return None
        self.leases[rid] = [self._free.pop() for _ in range(need)]
        return list(self.leases[rid])

    def commit_import(self, rid: int) -> None:
        """Transfer landed: promote the lease to the live table."""
        assert rid not in self.tables, f"rid {rid} re-allocated mid-import"
        self.tables[rid] = self.leases.pop(rid)

    def abort_import(self, rid: int) -> None:
        """Transfer failed: the leased pages hold garbage no table points
        at — return them to the free list untouched."""
        self._free.extend(self.leases.pop(rid))

    def import_pages(self, rid: int, n_tokens: int) -> Optional[List[int]]:
        """One-shot begin+commit import for transfers with no failure
        path (returns the page ids now in ``rid``'s live table)."""
        if self.begin_import(rid, n_tokens) is None:
            return None
        self.commit_import(rid)
        return list(self.tables[rid])

    # -- introspection ------------------------------------------------------

    def covers(self, rid: int, line: int) -> bool:
        """Whether cache line ``line`` falls inside ``rid``'s owned pages."""
        return line < len(self.tables.get(rid, ())) * self.page_size

    def n_lines(self, rid: int) -> int:
        return len(self.tables.get(rid, ())) * self.page_size

    def table(self, rid: int, pad_to: int | None = None) -> np.ndarray:
        """``rid``'s page table as int32, -1-padded to ``pad_to`` slots."""
        pages = self.tables.get(rid, [])
        pad_to = self.max_pages_per_seq if pad_to is None else pad_to
        out = np.full((pad_to,), -1, np.int32)
        out[:len(pages)] = pages
        return out

    def check(self) -> None:
        """Assert the no-sharing invariant: every physical page appears
        exactly once across the free list, all live tables, all
        in-transit exports, and all in-flight import leases."""
        seen = list(self._free)
        for rid, pages in self.tables.items():
            seen.extend(pages)
        for rid, pages in self.exported.items():
            seen.extend(pages)
        for rid, pages in self.leases.items():
            seen.extend(pages)
        assert len(seen) == self.n_pages, \
            f"page leak: {len(seen)} tracked of {self.n_pages}"
        assert len(set(seen)) == self.n_pages, "page owned twice"
