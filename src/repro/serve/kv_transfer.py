"""Page-granular KV transfer between paged pools (DESIGN.md §10).

The disaggregated handoff ships a finished prefill's KV from the prefill
group's pool to the decode group's pool by moving ONLY the request's
allocated physical pages: the source page ids come straight out of the
exporting allocator's table, the payload keeps the ``[n, page_size, ...]``
page layout end to end (a page-dim gather, never a contiguous
``[tokens, ...]`` cache), and the destination scatter lands the pages at
the importing allocator's ids — the request's logical cache is
reconstituted purely by the TABLE rewrite, in the virtual domain.

Transfers stream in §8-style fixed-size page chunks so a long prompt's
KV pipelines across the link instead of serializing behind one bulk copy
(and so the jitted gather/scatter pair compiles exactly once: the final
chunk is padded — source padding re-reads page 0 harmlessly, destination
padding uses the out-of-bounds sentinel and is dropped by the scatter).

On this container both pools share one process, so the "link" is a cost
model: :class:`TransferStats` accrues the simulated wire time
(per-chunk latency + bytes/bandwidth) that the serving simulator and
bench report; the data path itself is the real gather/scatter.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import stack
from repro.sharding.rules import constraint, transfer_payload_spec


@dataclasses.dataclass
class TransferStats:
    """Accrued transfer-engine accounting (one engine, many transfers)."""

    n_transfers: int = 0
    n_pages: int = 0          # real pages shipped (padding excluded)
    n_chunks: int = 0
    bytes: int = 0            # real payload bytes (padding excluded)
    sim_seconds: float = 0.0  # simulated link occupancy
    # The DISTINCT leaf shapes that crossed the link, for the structural
    # pages-only guarantee: tests assert each one is page-granular
    # [k, page_size, ...] and that no contiguous [tokens, ...] cache ever
    # materialized on the transfer path. Deduplicated so a long-lived
    # engine doesn't grow a per-chunk-per-leaf log without bound.
    shipped_shapes: List[tuple] = dataclasses.field(default_factory=list)

    def note_shapes(self, shapes) -> None:
        for s in shapes:
            if s not in self.shipped_shapes:
                self.shipped_shapes.append(s)


class KVTransferEngine:
    """Ships a request's KV pages between two paged decode-state trees."""

    def __init__(self, *, chunk_pages: int = 4,
                 link_bw: Optional[float] = None, latency_s: float = 0.0):
        assert chunk_pages >= 1
        self.chunk_pages = chunk_pages
        self.link_bw = link_bw
        self.latency_s = latency_s
        self.stats = TransferStats()

        def gather(state, ids):
            payload = stack.gather_kv_pages(state, ids)
            # Replicate the in-flight pages (transfer_payload_spec): they
            # are leaving the source group's pool sharding anyway.
            return jax.tree.map(
                lambda v: constraint(v, transfer_payload_spec(v.ndim)),
                payload)

        self._gather = jax.jit(gather)
        self._scatter = jax.jit(stack.scatter_kv_pages, donate_argnums=(0,))

    def _page_bytes(self, payload, n_pages_in_payload: int) -> int:
        """Payload bytes of ONE page across every layer's pools."""
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(payload)) \
            // max(n_pages_in_payload, 1)

    def transfer(self, src_state, dst_state, src_ids: List[int],
                 dst_ids: List[int], *, dst_n_pages: int):
        """Move pages ``src_ids`` of ``src_state``'s pools into pages
        ``dst_ids`` of ``dst_state``'s pools, chunk by chunk. Returns the
        updated destination state; the source state is read-only (its
        pages recycle via the exporting allocator, not here)."""
        assert len(src_ids) == len(dst_ids) and src_ids, \
            "transfer needs matching non-empty page-id lists"
        n = len(src_ids)
        cp = self.chunk_pages
        for lo in range(0, n, cp):
            src_chunk = list(src_ids[lo:lo + cp])
            dst_chunk = list(dst_ids[lo:lo + cp])
            real = len(src_chunk)
            # Fixed chunk shape: pad the tail (src: re-read page 0 — the
            # dropped dst sentinel makes the duplicate write a no-op).
            src_chunk += [0] * (cp - real)
            dst_chunk += [dst_n_pages] * (cp - real)
            payload = self._gather(src_state,
                                   jnp.asarray(src_chunk, jnp.int32))
            dst_state = self._scatter(dst_state, payload,
                                      jnp.asarray(dst_chunk, jnp.int32))
            page_b = self._page_bytes(payload, cp)
            self.stats.n_chunks += 1
            self.stats.n_pages += real
            self.stats.bytes += real * page_b
            if self.link_bw:
                self.stats.sim_seconds += self.latency_s \
                    + real * page_b / self.link_bw
            self.stats.note_shapes(
                tuple(leaf.shape) for leaf in jax.tree.leaves(payload))
        self.stats.n_transfers += 1
        return dst_state
