"""Page-granular KV transfer between paged pools (DESIGN.md §10, §13).

The disaggregated handoff ships a finished prefill's KV from the prefill
group's pool to the decode group's pool by moving ONLY the request's
allocated physical pages: the source page ids come straight out of the
exporting allocator's table, the payload keeps the ``[n, page_size, ...]``
page layout end to end (a page-dim gather, never a contiguous
``[tokens, ...]`` cache), and the destination scatter lands the pages at
the importing allocator's ids — the request's logical cache is
reconstituted purely by the TABLE rewrite, in the virtual domain.

Transfers stream in §8-style fixed-size page chunks so a long prompt's
KV pipelines across the link instead of serializing behind one bulk copy
(and so the jitted gather/scatter pair compiles exactly once: the final
chunk is padded — source padding re-reads page 0 harmlessly, destination
padding uses the out-of-bounds sentinel and is dropped by the scatter).

The transfer is TRANSACTIONAL per chunk (DESIGN.md §13): every chunk is
checksummed at the source and verified at the destination, a dropped or
corrupted chunk is retried with bounded exponential backoff, and a
delivered-but-unacknowledged chunk (link stall) is simply replayed — the
page-granular scatter is idempotent, so at-least-once delivery is safe.
When a chunk exhausts its retry budget the whole transfer aborts with
:class:`TransferAbortedError` and NOTHING has changed ownership: the
source pages are still in the exporting allocator's EXPORTED state
(rolled back via ``abort_export``) and the destination pages are still
under their import LEASE (rolled back via ``abort_import``). Faults come
from an optional :class:`~repro.ft.chaos.FaultInjector` consulted at the
named hook points (drop / corrupt / stall per chunk, matched against the
receiving group's name; crash_mid_export / crash_mid_import between
chunks raise :class:`~repro.ft.chaos.GroupCrashed`).

On this container both pools share one process, so the "link" is a cost
model: :class:`TransferStats` accrues the simulated wire time
(per-chunk latency + bytes/bandwidth, plus timeout and backoff charges
on the retry path) that the serving simulator and bench report; the data
path itself is the real gather/scatter.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.chaos import FaultInjector, GroupCrashed
from repro.models import stack
from repro.obs import trace as obs_trace
from repro.sharding.rules import constraint, transfer_payload_spec


class TransferAbortedError(RuntimeError):
    """A chunk exhausted its retry budget; the transfer rolled back —
    neither pool's ownership changed (source still EXPORTED, destination
    lease still open for the caller to abort)."""


def _tree_crc(payload) -> int:
    """Host-side CRC32 over every leaf of a payload tree — the per-chunk
    checksum both ends of the link compute."""
    crc = 0
    for leaf in jax.tree.leaves(payload):
        crc = zlib.crc32(np.asarray(leaf).tobytes(), crc)
    return crc


def _flip_bits(payload):
    """Simulated wire corruption: flip the first byte of the first leaf
    (shape/dtype preserved, so only the checksum can tell)."""
    leaves, treedef = jax.tree.flatten(payload)
    v = np.asarray(leaves[0]).copy()
    v.view(np.uint8).reshape(-1)[:1] ^= 0xFF
    return jax.tree.unflatten(treedef, [jnp.asarray(v)] + leaves[1:])


@dataclasses.dataclass
class TransferStats:
    """Accrued transfer-engine accounting (one engine, many transfers)."""

    n_transfers: int = 0
    n_pages: int = 0          # real pages shipped (padding excluded)
    n_chunks: int = 0
    bytes: int = 0            # real payload bytes (padding excluded)
    sim_seconds: float = 0.0  # simulated link occupancy
    # -- robustness (DESIGN.md §13) --
    n_retries: int = 0            # chunk re-attempts after any fault
    n_timeouts: int = 0           # chunks lost on the wire / acks lost
    n_checksum_failures: int = 0  # corrupted chunks caught at the receiver
    n_replayed_chunks: int = 0    # delivered chunks re-applied (lost ack)
    n_aborts: int = 0             # transfers that exhausted their retries
    # The DISTINCT leaf shapes that crossed the link, for the structural
    # pages-only guarantee: tests assert each one is page-granular
    # [k, page_size, ...] and that no contiguous [tokens, ...] cache ever
    # materialized on the transfer path. Deduplicated so a long-lived
    # engine doesn't grow a per-chunk-per-leaf log without bound.
    shipped_shapes: List[tuple] = dataclasses.field(default_factory=list)

    def note_shapes(self, shapes) -> None:
        for s in shapes:
            if s not in self.shipped_shapes:
                self.shipped_shapes.append(s)


class KVTransferEngine:
    """Ships a request's KV pages between two paged decode-state trees."""

    def __init__(self, *, chunk_pages: int = 4,
                 link_bw: Optional[float] = None, latency_s: float = 0.0,
                 max_retries: int = 3, timeout_s: float = 0.05,
                 backoff_s: float = 0.01, verify_checksums: bool = True,
                 chaos: Optional[FaultInjector] = None):
        assert chunk_pages >= 1 and max_retries >= 0
        self.chunk_pages = chunk_pages
        self.link_bw = link_bw
        self.latency_s = latency_s
        self.max_retries = max_retries
        self.timeout_s = timeout_s
        self.backoff_s = backoff_s
        self.verify_checksums = verify_checksums
        self.chaos = chaos
        self.stats = TransferStats()

        def gather(state, ids):
            payload = stack.gather_kv_pages(state, ids)
            # Replicate the in-flight pages (transfer_payload_spec): they
            # are leaving the source group's pool sharding anyway.
            return jax.tree.map(
                lambda v: constraint(v, transfer_payload_spec(v.ndim)),
                payload)

        self._gather = jax.jit(gather)
        self._scatter = jax.jit(stack.scatter_kv_pages, donate_argnums=(0,))

    def _page_bytes(self, payload, n_pages_in_payload: int) -> int:
        """Payload bytes of ONE page across every layer's pools."""
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(payload)) \
            // max(n_pages_in_payload, 1)

    def transfer(self, src_state, dst_state, src_ids: List[int],
                 dst_ids: List[int], *, dst_n_pages: int,
                 src_name: str = "*", dst_name: str = "*",
                 rid: Optional[int] = None):
        """Move pages ``src_ids`` of ``src_state``'s pools into pages
        ``dst_ids`` of ``dst_state``'s pools, chunk by chunk. Returns the
        updated destination state; the source state is read-only (its
        pages recycle via the exporting allocator, not here).

        Raises :class:`TransferAbortedError` when a chunk exhausts its
        retry budget, and :class:`~repro.ft.chaos.GroupCrashed` when a
        chaos crash fires between chunks — in both cases the caller rolls
        ownership back (``abort_export`` / ``abort_import``). The scatter
        DONATES the destination state, so once any chunk has landed the
        caller's original reference is dead; both exceptions therefore
        carry the live partially-scattered tree as ``.dst_state`` and the
        caller MUST rebind to it before rolling back. The partial writes
        only touched pages under the import lease, which ``abort_import``
        returns to the free list — their contents are unreachable."""
        assert len(src_ids) == len(dst_ids) and src_ids, \
            "transfer needs matching non-empty page-id lists"
        chaos = self.chaos
        tr = obs_trace.TRACER
        track = f"xfer:{src_name}->{dst_name}"
        if tr.enabled:
            tr.declare_track(track, kind="meta")
            if rid is not None:
                tr.flow(track, "transfer", rid, pages=len(src_ids))
        n = len(src_ids)
        cp = self.chunk_pages
        for lo in range(0, n, cp):
            if chaos is not None:
                if chaos.fire("crash_mid_export", src_name):
                    tr.instant(track, "crash", side="src", rid=rid)
                    exc = GroupCrashed("src", src_name)
                    exc.dst_state = dst_state
                    raise exc
                if chaos.fire("crash_mid_import", dst_name):
                    tr.instant(track, "crash", side="dst", rid=rid)
                    exc = GroupCrashed("dst", dst_name)
                    exc.dst_state = dst_state
                    raise exc
            src_chunk = list(src_ids[lo:lo + cp])
            dst_chunk = list(dst_ids[lo:lo + cp])
            real = len(src_chunk)
            # Fixed chunk shape: pad the tail (src: re-read page 0 — the
            # dropped dst sentinel makes the duplicate write a no-op).
            src_chunk += [0] * (cp - real)
            dst_chunk += [dst_n_pages] * (cp - real)
            src_arr = jnp.asarray(src_chunk, jnp.int32)
            dst_arr = jnp.asarray(dst_chunk, jnp.int32)
            committed = False
            tr.begin(track, "chunk", idx=lo // cp, pages=real, rid=rid)
            for attempt in range(1 + self.max_retries):
                if attempt:
                    # Bounded exponential backoff before each retry,
                    # charged to the simulated link clock.
                    self.stats.n_retries += 1
                    self.stats.sim_seconds += \
                        self.backoff_s * (2 ** (attempt - 1))
                    tr.instant(track, "retry", idx=lo // cp,
                               attempt=attempt)
                payload = self._gather(src_state, src_arr)
                if chaos is not None and chaos.fire("drop", dst_name):
                    # Chunk lost on the wire: the receiver times out.
                    self.stats.n_timeouts += 1
                    self.stats.sim_seconds += self.timeout_s
                    tr.instant(track, "drop", idx=lo // cp)
                    continue
                crc = _tree_crc(payload) if self.verify_checksums else None
                if chaos is not None and chaos.fire("corrupt", dst_name):
                    payload = _flip_bits(payload)
                if crc is not None and _tree_crc(payload) != crc:
                    # Receiver-side checksum mismatch: discard, retry.
                    self.stats.n_checksum_failures += 1
                    tr.instant(track, "corrupt", idx=lo // cp)
                    continue
                dst_state = self._scatter(dst_state, payload, dst_arr)
                if chaos is not None and chaos.fire("stall", dst_name):
                    # Delivered but the ack is lost: the sender replays
                    # the chunk. The scatter writes the same pages to the
                    # same slots, so the at-least-once replay is safe —
                    # idempotence is the contract, exercised here.
                    self.stats.n_timeouts += 1
                    self.stats.n_replayed_chunks += 1
                    self.stats.sim_seconds += self.timeout_s
                    tr.instant(track, "replay", idx=lo // cp)
                    continue
                committed = True
                break
            tr.end(track, committed=committed)
            if not committed:
                self.stats.n_aborts += 1
                tr.instant(track, "abort", idx=lo // cp, rid=rid)
                exc = TransferAbortedError(
                    f"chunk {lo // cp} of {src_name}->{dst_name} "
                    f"exhausted {self.max_retries} retries")
                exc.dst_state = dst_state
                raise exc
            page_b = self._page_bytes(payload, cp)
            self.stats.n_chunks += 1
            self.stats.n_pages += real
            self.stats.bytes += real * page_b
            if self.link_bw:
                self.stats.sim_seconds += self.latency_s \
                    + real * page_b / self.link_bw
            self.stats.note_shapes(
                tuple(leaf.shape) for leaf in jax.tree.leaves(payload))
        self.stats.n_transfers += 1
        return dst_state
