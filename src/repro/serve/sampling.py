"""Batched token sampling for the serving engine (DESIGN.md §7.4).

One fused sampler covers greedy, temperature, top-k and nucleus (top-p)
sampling: every slot selects its own behaviour from per-slot parameter
vectors, so a batch mixing greedy and sampled requests still decodes in a
single compiled program.

Determinism contract: the PRNG key for request ``rid``'s ``n``-th
generated token is ``fold_in(fold_in(base_key, rid), n)`` — a function of
the request and token index ONLY. Sampling is therefore independent of
batch composition, slot assignment, and prefill chunking, which is what
makes the slot-recycling test (and replay debugging) possible: a request
produces the same tokens under any schedule.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs (0 / 1.0 = disabled)."""

    temperature: float = 0.0  # <= 0 -> greedy (argmax)
    top_k: int = 0            # 0 -> no top-k cut
    top_p: float = 1.0        # 1.0 -> no nucleus cut


GREEDY = SamplingParams()


def request_keys(base_key, rids, n_generated):
    """Per-slot PRNG keys: fold_in(fold_in(base, rid), n). [B] -> [B] keys."""
    def one(rid, n):
        return jax.random.fold_in(jax.random.fold_in(base_key, rid), n)
    return jax.vmap(one)(rids, n_generated)


def sample_tokens(logits, keys, temperature, top_k, top_p):
    """Sample one token per slot. All modes in one jit-able function.

    logits: [B, V] (any float dtype); keys: [B] PRNG keys (request_keys);
    temperature/top_p: [B] f32; top_k: [B] i32. Returns [B] int32.

    Filtering runs in the sorted domain (descending logits): top-k keeps
    rank < k; top-p keeps the smallest prefix whose mass reaches p (the
    head token always survives, so the result is never empty); the pick is
    a Gumbel-max over the surviving entries, mapped back through the sort
    permutation.
    """
    V = logits.shape[-1]

    def one(lg, key, t, k, p):
        lg = lg.astype(jnp.float32)
        greedy = t <= 0.0
        scaled = lg / jnp.maximum(t, 1e-6)
        order = jnp.argsort(-scaled)  # descending
        vals = scaled[order]
        rank = jnp.arange(V)
        keep = rank < jnp.where(k <= 0, V, k)
        probs = jax.nn.softmax(vals)
        cum = jnp.cumsum(probs)
        keep &= (cum - probs) < p  # mass BEFORE this entry still below p
        keep |= rank == 0          # head always survives
        vals = jnp.where(keep, vals, -jnp.inf)
        g = jax.random.gumbel(key, (V,), jnp.float32)
        pick = order[jnp.argmax(vals + g)]
        return jnp.where(greedy, jnp.argmax(lg), pick).astype(jnp.int32)

    return jax.vmap(one)(logits, keys, temperature, top_k, top_p)
