from repro.serve.engine import BatchedServer, ServeProgram, make_serve_program

__all__ = ["BatchedServer", "ServeProgram", "make_serve_program"]
