from repro.serve.engine import (BatchedServer, ContinuousBatchingEngine,
                                ContinuousProgram, ServeProgram,
                                make_continuous_program, make_serve_program)
from repro.serve.kv_blocks import BlockAllocator, pages_for
from repro.serve.config import (ChaosCfg, DisaggCfg, EPCfg, FleetCfg,
                                PagedCfg, PrefixCacheCfg, ServeConfig,
                                ServeConfigError, build_deployment)
from repro.serve.ep_decode import (EPContinuousBatchingEngine,
                                   EPDecodeConfig)
from repro.serve.kv_transfer import KVTransferEngine, TransferStats
from repro.serve.metrics import RoutingEMA, ServeMetrics
from repro.serve.prefix_index import PrefixIndex
from repro.serve.sampling import GREEDY, SamplingParams
from repro.serve.scheduler import (DecodeScheduler, PrefillScheduler,
                                   Request, Scheduler)

__all__ = ["BatchedServer", "ServeProgram", "make_serve_program",
           "ContinuousBatchingEngine", "ContinuousProgram",
           "make_continuous_program", "ServeMetrics", "SamplingParams",
           "GREEDY", "Request", "Scheduler", "PrefillScheduler",
           "DecodeScheduler", "BlockAllocator", "pages_for",
           "KVTransferEngine", "TransferStats", "EPDecodeConfig",
           "EPContinuousBatchingEngine", "RoutingEMA", "PrefixIndex",
           "ServeConfig", "ServeConfigError", "build_deployment",
           "PagedCfg", "PrefixCacheCfg", "DisaggCfg", "EPCfg", "FleetCfg",
           "ChaosCfg"]
