"""Serving: prefill / decode steps with sharded KV caches + batch engine."""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import stack
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.modules import RunConfig
from repro.sharding.rules import ShardingRules, rules_for
from repro.train.step import abstract_params, fit_batch_axes


def decode_state_specs(cfg: ModelConfig, mesh: Mesh, rules: ShardingRules,
                       batch: int, max_len: int, dtype=jnp.bfloat16):
    """PartitionSpecs for the decode-state tree (by leaf role).

    KV caches shard the *sequence* dim over "model" (flash-decoding style:
    kv-head counts rarely divide the TP axis, sequence always does at these
    lengths) plus batch over "data"; recurrent states shard their channel /
    head dims over "model"."""
    from repro.sharding.rules import fit_spec
    baxes = fit_batch_axes(batch, mesh, rules.batch_axes)
    b = baxes if baxes else None
    mdl = "model"

    def spec_for(name: str, leaf) -> P:
        stacked = leaf.ndim and leaf.shape[0] == cfg.n_pattern_repeats \
            and cfg.n_pattern_repeats > 1
        lead = (None,) if stacked else ()
        tail = name.rsplit("/", 1)[-1]
        body = {
            "k": (*lead, b, mdl, None, None),
            "v": (*lead, b, mdl, None, None),
            "pos": (*lead, b, mdl),
            "conv": (*lead, b, None, mdl),
            "lru": (*lead, b, mdl),
            "ssm": (*lead, b, mdl, None, None),
        }.get(tail)
        if body is None:
            body = (*lead, *([None] * (leaf.ndim - len(lead))))
        return fit_spec(leaf.shape, mesh, body)

    state_shapes = jax.eval_shape(
        lambda: stack.init_decode_state(cfg, batch, max_len, dtype))
    from repro.pytree import tree_map_with_path_names
    return state_shapes, tree_map_with_path_names(spec_for, state_shapes)


@dataclasses.dataclass
class ServeProgram:
    cfg: ModelConfig
    run: RunConfig
    mesh: Mesh
    prefill_step: Callable  # (params, tokens, state, **fronts) -> (state, logits)
    decode_step: Callable   # (params, state, tok, idx, **fronts) -> (state, tok)
    state_shapes: object
    state_shardings: object
    param_shardings: object
    batch_sharding: object


def make_serve_program(cfg: ModelConfig, mesh: Mesh, run: RunConfig,
                       shape: ShapeConfig,
                       max_len: Optional[int] = None) -> ServeProgram:
    rules = rules_for(cfg, mesh, variant="serve")
    max_len = max_len or shape.seq_len
    B = shape.global_batch
    from repro.sharding.rules import fitted_shardings
    pshapes, paxes = abstract_params(cfg)
    psh = fitted_shardings(pshapes, paxes, rules, mesh)
    state_shapes, sspecs = decode_state_specs(cfg, mesh, rules, B, max_len,
                                              run.policy.compute_dtype)
    ssh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                       is_leaf=lambda x: isinstance(x, P))
    baxes = fit_batch_axes(B, mesh, rules.batch_axes)
    bsh = NamedSharding(mesh, P(baxes if baxes else None))
    from repro.sharding.rules import make_constrainer
    act_rules = dataclasses.replace(rules, batch_axes=baxes)
    run = dataclasses.replace(run, constrain=make_constrainer(act_rules, mesh))

    front_sh = {}
    if cfg.is_encdec:
        front_sh["encoder_embeds"] = NamedSharding(
            mesh, P(baxes if baxes else None, None, None))
    if cfg.vision_seq > 0:
        front_sh["vision_embeds"] = NamedSharding(
            mesh, P(baxes if baxes else None, None, None))

    # MoE FFNs always go through the sharded EP path in serving (the gather
    # path would let GSPMD replicate expert weights across the pod).
    moe_override = None
    if cfg.is_moe:
        from repro.core.zebra_spmd import ZebraConfig, make_ep_moe
        zc = ZebraConfig(mode="replicated", batch_axes=baxes or ("data",),
                         capacity_factor=cfg.capacity_factor * 2)
        moe_fn = make_ep_moe(mesh, cfg, run, zc)

        def moe_override(ffn_params, u):
            y2, aux = moe_fn(ffn_params, u.reshape(-1, u.shape[-1]))
            return y2.reshape(u.shape).astype(u.dtype), aux

    def prefill(params, state, tokens, fronts):
        """Full-sequence prefill writing the KV caches; returns last logits.
        Only the final position is unembedded ([B,S,V] f32 logits would be
        tens of GB at 32k)."""
        from repro.models import modules
        hidden, state, _ = stack.apply_model(
            params, cfg, run, tokens, decode_state=state,
            cache_index=jnp.zeros((), jnp.int32), moe_override=moe_override,
            return_hidden=True, **fronts)
        last = modules.apply_unembedding(
            params["embed"], params.get("lm_head"), cfg, run.policy,
            hidden[:, -1])
        return state, last

    def decode(params, state, tok, cache_index, fronts):
        """One decode step: tok [B,1] -> greedy next token [B,1]."""
        logits, state, _ = stack.apply_model(
            params, cfg, run, tok, decode_state=state,
            cache_index=cache_index, moe_override=moe_override, **fronts)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return state, nxt[:, None]

    jit_prefill = jax.jit(prefill, in_shardings=(psh, ssh, bsh, front_sh),
                          out_shardings=(ssh, None), donate_argnums=(1,))
    jit_decode = jax.jit(decode, in_shardings=(psh, ssh, bsh, None, front_sh),
                         out_shardings=(ssh, None), donate_argnums=(1,))

    return ServeProgram(cfg=cfg, run=run, mesh=mesh,
                        prefill_step=jit_prefill, decode_step=jit_decode,
                        state_shapes=state_shapes, state_shardings=ssh,
                        param_shardings=psh, batch_sharding=bsh)


class BatchedServer:
    """Minimal continuous-batching loop over fixed slots (example driver)."""

    def __init__(self, program: ServeProgram, params, batch: int,
                 max_len: int):
        self.p = program
        self.params = params
        self.batch = batch
        self.max_len = max_len
        cfg, run = program.cfg, program.run
        with program.mesh:
            self.state = jax.jit(
                lambda: stack.init_decode_state(cfg, batch, max_len,
                                                run.policy.compute_dtype),
                out_shardings=program.state_shardings)()
        self.cache_index = jnp.zeros((), jnp.int32)
        self.tokens = jnp.zeros((batch, 1), jnp.int32)

    def submit_prefill(self, tokens, fronts=None):
        with self.p.mesh:
            self.state, last = self.p.prefill_step(self.params, self.state,
                                                   tokens, fronts or {})
        self.cache_index = jnp.asarray(tokens.shape[1], jnp.int32)
        self.tokens = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
        return self.tokens

    def step(self, fronts=None):
        with self.p.mesh:
            self.state, self.tokens = self.p.decode_step(
                self.params, self.state, self.tokens, self.cache_index,
                fronts or {})
        self.cache_index = self.cache_index + 1
        return self.tokens
