"""Serving engines over sharded KV decode states.

Two entry points:

* ``make_serve_program`` / ``BatchedServer`` — the lockstep demo path: one
  scalar ``cache_index`` shared by the whole batch, whole-batch prefill,
  greedy decode. Kept for A/B parity tests and the dry-run tooling.
* ``make_continuous_program`` / ``ContinuousBatchingEngine`` — the real
  serving path (DESIGN.md §7): per-slot position vector ``[B]`` + active
  mask, chunked prefill into a batch-1 cache that is *inserted* into a
  free slot without touching live ones, sampled decode (temperature /
  top-k / top-p per slot), slot recycling on EOS or length limit.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import stack
from repro.models.config import ModelConfig, ShapeConfig
from repro.obs import trace as obs_trace
from repro.models.modules import RunConfig
from repro.serve import sampling
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import PrefillChunk, Request, Scheduler
from repro.sharding.rules import (ShardingRules, rules_for,
                                  slot_vector_spec)
from repro.train.step import abstract_params, fit_batch_axes


def _state_spec_for(cfg: ModelConfig, mesh: Mesh, b, kv_bodies):
    """Shared decode-state leaf-spec mapper.

    Recurrent leaves (conv/lru/ssm) have ONE mapping — batch over "data",
    channel/head dims over "model" — used by both the dense and the paged
    state trees; only the attention-cache leaves (k/v/pos) differ, so the
    caller passes their bodies via ``kv_bodies(tail)`` (per-slot dense
    caches vs shared paged pools). ``b`` is the fitted batch-axis tuple.
    """
    from repro.sharding.rules import fit_spec
    mdl = "model"

    def spec_for(name: str, leaf) -> P:
        stacked = leaf.ndim and leaf.shape[0] == cfg.n_pattern_repeats \
            and cfg.n_pattern_repeats > 1
        lead = (None,) if stacked else ()
        tail = name.rsplit("/", 1)[-1]
        if tail in ("k", "v", "pos"):
            body = (*lead, *kv_bodies(tail, leaf.ndim - len(lead)))
        else:
            body = {
                "conv": (*lead, b, None, mdl),
                "lru": (*lead, b, mdl),
                "ssm": (*lead, b, mdl, None, None),
            }.get(tail, (*lead, *([None] * (leaf.ndim - len(lead)))))
        return fit_spec(leaf.shape, mesh, body)

    return spec_for


def decode_state_specs(cfg: ModelConfig, mesh: Mesh, rules: ShardingRules,
                       batch: int, max_len: int, dtype=jnp.bfloat16):
    """PartitionSpecs for the decode-state tree (by leaf role).

    KV caches shard the *sequence* dim over "model" (flash-decoding style:
    kv-head counts rarely divide the TP axis, sequence always does at these
    lengths) plus batch over "data"; recurrent states shard their channel /
    head dims over "model"."""
    baxes = fit_batch_axes(batch, mesh, rules.batch_axes)
    b = baxes if baxes else None
    mdl = "model"
    kv = {"k": (b, mdl, None, None), "v": (b, mdl, None, None),
          "pos": (b, mdl)}
    spec_for = _state_spec_for(cfg, mesh, b, lambda tail, nd: kv[tail])

    state_shapes = jax.eval_shape(
        lambda: stack.init_decode_state(cfg, batch, max_len, dtype))
    from repro.pytree import tree_map_with_path_names
    return state_shapes, tree_map_with_path_names(spec_for, state_shapes)


@dataclasses.dataclass
class ServeProgram:
    cfg: ModelConfig
    run: RunConfig
    mesh: Mesh
    prefill_step: Callable  # (params, tokens, state, **fronts) -> (state, logits)
    decode_step: Callable   # (params, state, tok, idx, **fronts) -> (state, tok)
    state_shapes: object
    state_shardings: object
    param_shardings: object
    batch_sharding: object


def make_serve_program(cfg: ModelConfig, mesh: Mesh, run: RunConfig,
                       shape: ShapeConfig,
                       max_len: Optional[int] = None) -> ServeProgram:
    rules = rules_for(cfg, mesh, variant="serve")
    max_len = max_len or shape.seq_len
    B = shape.global_batch
    from repro.sharding.rules import fitted_shardings
    pshapes, paxes = abstract_params(cfg)
    psh = fitted_shardings(pshapes, paxes, rules, mesh)
    state_shapes, sspecs = decode_state_specs(cfg, mesh, rules, B, max_len,
                                              run.policy.compute_dtype)
    ssh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                       is_leaf=lambda x: isinstance(x, P))
    baxes = fit_batch_axes(B, mesh, rules.batch_axes)
    bsh = NamedSharding(mesh, P(baxes if baxes else None))
    from repro.sharding.rules import make_constrainer
    act_rules = dataclasses.replace(rules, batch_axes=baxes)
    run = dataclasses.replace(run, constrain=make_constrainer(act_rules, mesh))

    front_sh = {}
    if cfg.is_encdec:
        front_sh["encoder_embeds"] = NamedSharding(
            mesh, P(baxes if baxes else None, None, None))
    if cfg.vision_seq > 0:
        front_sh["vision_embeds"] = NamedSharding(
            mesh, P(baxes if baxes else None, None, None))

    # MoE FFNs always go through the sharded EP path in serving (the gather
    # path would let GSPMD replicate expert weights across the pod).
    moe_override = None
    if cfg.is_moe:
        from repro.core.zebra_spmd import ZebraConfig, make_ep_moe
        zc = ZebraConfig(mode="replicated", batch_axes=baxes or ("data",),
                         capacity_factor=cfg.capacity_factor * 2)
        moe_fn = make_ep_moe(mesh, cfg, run, zc)

        def moe_override(ffn_params, u):
            y2, aux = moe_fn(ffn_params, u.reshape(-1, u.shape[-1]))
            return y2.reshape(u.shape).astype(u.dtype), aux

    def prefill(params, state, tokens, fronts):
        """Full-sequence prefill writing the KV caches; returns last logits.
        Only the final position is unembedded ([B,S,V] f32 logits would be
        tens of GB at 32k)."""
        from repro.models import modules
        hidden, state, _ = stack.apply_model(
            params, cfg, run, tokens, decode_state=state,
            cache_index=jnp.zeros((), jnp.int32), moe_override=moe_override,
            return_hidden=True, **fronts)
        last = modules.apply_unembedding(
            params["embed"], params.get("lm_head"), cfg, run.policy,
            hidden[:, -1])
        return state, last

    def decode(params, state, tok, cache_index, fronts):
        """One decode step: tok [B,1] -> greedy next token [B,1]."""
        logits, state, _ = stack.apply_model(
            params, cfg, run, tok, decode_state=state,
            cache_index=cache_index, moe_override=moe_override, **fronts)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return state, nxt[:, None]

    jit_prefill = jax.jit(prefill, in_shardings=(psh, ssh, bsh, front_sh),
                          out_shardings=(ssh, None), donate_argnums=(1,))
    jit_decode = jax.jit(decode, in_shardings=(psh, ssh, bsh, None, front_sh),
                         out_shardings=(ssh, None), donate_argnums=(1,))

    return ServeProgram(cfg=cfg, run=run, mesh=mesh,
                        prefill_step=jit_prefill, decode_step=jit_decode,
                        state_shapes=state_shapes, state_shardings=ssh,
                        param_shardings=psh, batch_sharding=bsh)


class BatchedServer:
    """Minimal continuous-batching loop over fixed slots (example driver)."""

    def __init__(self, program: ServeProgram, params, batch: int,
                 max_len: int):
        self.p = program
        self.params = params
        self.batch = batch
        self.max_len = max_len
        cfg, run = program.cfg, program.run
        with program.mesh:
            self.state = jax.jit(
                lambda: stack.init_decode_state(cfg, batch, max_len,
                                                run.policy.compute_dtype),
                out_shardings=program.state_shardings)()
        self.cache_index = jnp.zeros((), jnp.int32)
        self.tokens = jnp.zeros((batch, 1), jnp.int32)

    def submit_prefill(self, tokens, fronts=None):
        with self.p.mesh:
            self.state, last = self.p.prefill_step(self.params, self.state,
                                                   tokens, fronts or {})
        self.cache_index = jnp.asarray(tokens.shape[1], jnp.int32)
        self.tokens = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
        return self.tokens

    def step(self, fronts=None):
        with self.p.mesh:
            self.state, self.tokens = self.p.decode_step(
                self.params, self.state, self.tokens, self.cache_index,
                fronts or {})
        self.cache_index = self.cache_index + 1
        return self.tokens


# ---------------------------------------------------------------------------
# Continuous batching (DESIGN.md §7)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ContinuousProgram:
    """Compiled pieces of the continuous-batching engine.

    Two builds share this container (DESIGN.md §7 / §9):

    * dense (``paged=False``): per-slot contiguous KV reservations; prefill
      runs on a separate batch-1 state inserted wholesale on admission.
    * paged (``paged=True``): KV lives in shared physical pools addressed
      through per-slot page tables; prefill writes its pages DIRECTLY into
      the pool (pages are disjoint from live slots'), so the insert step
      copies only the batch-1 recurrent carry and slot recycling is a
      host-side page-table reset. Step signatures:
        prefill_step(params, state, prec, tokens[1,c], offset, ptrow[1,MP])
            -> (state, prec, last_logits)
        insert_step(state, prec, slot) -> state
        decode_step(params, state, tok, pos, ptabs[B,MP], active, rids,
                    ngen, temp, topk, topp) -> (state, next, last_logits)
    """

    cfg: ModelConfig
    run: RunConfig
    mesh: Mesh
    n_slots: int
    max_len: int
    prefill_step: Callable   # (params, pstate, tokens[1,c], offset) ->
    #                          (pstate, last_logits [1,V] f32)
    insert_step: Callable    # (state, pstate, slot) -> state
    decode_step: Callable    # (params, state, tok[B,1], pos[B], active[B],
    #                          rids[B], ngen[B], temp[B], topk[B], topp[B])
    #                          -> (state, next[B], last_logits [B,V] f32)
    sample_step: Callable    # (logits[N,V], rids, ngen, temp, topk, topp)
    init_state: Callable     # () -> batched decode state (B = n_slots)
    init_pstate: Callable    # () -> batch-1 prefill decode state
    param_shardings: object
    state_shardings: object
    paged: bool = False
    page_size: int = 0
    n_pages: int = 0
    max_pages: int = 0       # page-table slots per request
    init_prec: Callable = None  # () -> batch-1 prefill recurrent carry
    fork_step: Callable = None  # (state, src[1], dst[1]) -> state (COW §14)
    # EP decode (DESIGN.md §11): when set, expert weights are sharded over
    # ep.ep_axis, params must be placed (serve/ep_decode.place_params) and
    # decode_step returns a 4th output — the per-layer routed-copy
    # histogram [n_rows, n_experts] feeding the placement EMA.
    ep: object = None


def paged_state_specs(cfg: ModelConfig, mesh: Mesh, rules: ShardingRules,
                      batch: int, n_pages: int, page_size: int,
                      dtype=jnp.bfloat16):
    """PartitionSpecs for the PAGED decode-state tree (DESIGN.md §9).

    KV pools shard their page dim over "model" (`paged_pool_spec` — the
    paged analogue of the dense cache sharding its sequence dim there);
    per-slot recurrent states keep the dense layout (batch over "data",
    channels over "model") via the shared `_state_spec_for` mapper."""
    from repro.sharding.rules import paged_pool_spec
    baxes = fit_batch_axes(batch, mesh, rules.batch_axes)
    b = baxes if baxes else None
    spec_for = _state_spec_for(
        cfg, mesh, b,
        lambda tail, nd: paged_pool_spec(n_pages, mesh, rules, ndim=nd))

    state_shapes = jax.eval_shape(
        lambda: stack.init_paged_decode_state(cfg, batch, n_pages,
                                              page_size, dtype))
    from repro.pytree import tree_map_with_path_names
    return state_shapes, tree_map_with_path_names(spec_for, state_shapes)


def make_continuous_program(cfg: ModelConfig, mesh: Mesh, run: RunConfig, *,
                            serve_cfg=None,
                            n_slots: int | None = None,
                            max_len: int | None = None, seed: int = 0,
                            page_size: int | None = None,
                            n_pages: int | None = None,
                            ep=None) -> ContinuousProgram:
    """Build the jit'd steps of the continuous-batching engine.

    ``serve_cfg`` (a :class:`repro.serve.config.ServeConfig`) is the
    preferred input — slots, max_len, seed and the paged geometry all come
    from it; the bare ``n_slots``/``max_len``/``page_size``/``n_pages``
    kwargs remain as the legacy spelling for existing call sites.

    ``page_size`` switches on the paged-KV build (DESIGN.md §9): KV moves
    into shared ``[n_pages, page_size, ...]`` pools addressed through
    per-slot page tables, prefill writes its allocated pages directly into
    the pool, and admission copies only the recurrent carry. ``n_pages``
    defaults to full reservation capacity (n_slots x pages-per-sequence);
    benchmarks pass smaller pools to measure paging's slot lift at fixed
    HBM (bench_serve.py --paged).

    Decode carries a per-slot position vector ``pos [B]`` (the next cache
    line of each slot; -1 for dead slots, whose cache writes are dropped
    and whose query positions mask out every key) instead of the lockstep
    scalar ``cache_index``. Prefill runs at batch 1 — chunked, attending
    over its own cache — and the finished cache is inserted into a free
    slot by a batch-axis ``dynamic_update_slice`` over every decode-state
    leaf, so live slots are never touched.

    MoE FFNs take the dropless gather path (``apply_moe`` -> single-pack
    ``ops.moe_ffn``): no capacity, so dead-slot tokens can never displace
    live tokens, and decode shapes auto-route to the group-dense small-M
    fallback (DESIGN.md §5.5). With ``ep`` (an
    ``serve.ep_decode.EPDecodeConfig``) expert weights are instead sharded
    over the EP axis and the MoE hop runs the chunked all-to-all dispatch
    (DESIGN.md §11); ``decode_step`` then returns a 4th output, the
    per-layer routed-copy histogram.
    """
    if serve_cfg is not None:
        n_slots = serve_cfg.slots
        max_len = serve_cfg.max_len
        seed = serve_cfg.seed
        if serve_cfg.paged.enabled:
            page_size = serve_cfg.paged.page_size
            n_pages = serve_cfg.paged.pool_pages
    assert n_slots is not None and max_len is not None, \
        "pass serve_cfg or the legacy n_slots/max_len kwargs"
    assert not cfg.is_encdec and cfg.vision_seq == 0, \
        "continuous batching supports decoder-only LMs"
    if page_size is not None:
        return _make_paged_program(cfg, mesh, run, n_slots=n_slots,
                                   max_len=max_len, seed=seed,
                                   page_size=page_size, n_pages=n_pages,
                                   ep=ep)
    rules = rules_for(cfg, mesh, variant="serve")
    B = n_slots
    from repro.sharding.rules import fitted_shardings, make_constrainer
    pshapes, paxes = abstract_params(cfg)
    psh = fitted_shardings(pshapes, paxes, rules, mesh)
    dtype = run.policy.compute_dtype

    ep_moe = None
    if ep is not None:
        from repro.serve import ep_decode as epd
        epd.validate_ep_config(cfg, mesh, ep)
        psh = epd.ep_param_shardings(psh, pshapes, mesh, ep)
        ep_moe = epd.make_ep_moe_decode(mesh, cfg, run, ep)
        ep_extras = (("ep_counts", (cfg.n_experts,)),)
        ep_prefill_ov = epd.moe_override_for(ep_moe)
        ep_decode_ov = epd.moe_override_for

    _, sspecs = decode_state_specs(cfg, mesh, rules, B, max_len, dtype)
    ssh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                       is_leaf=lambda x: isinstance(x, P))
    _, pspecs = decode_state_specs(cfg, mesh, rules, 1, max_len, dtype)
    pssh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))

    baxes = fit_batch_axes(B, mesh, rules.batch_axes)
    run_b = dataclasses.replace(run, constrain=make_constrainer(
        dataclasses.replace(rules, batch_axes=baxes), mesh))
    run_p = dataclasses.replace(run, constrain=make_constrainer(
        dataclasses.replace(rules, batch_axes=()), mesh))
    vec_sh = NamedSharding(mesh, slot_vector_spec(B, mesh, rules))
    tok_sh = NamedSharding(mesh, P(baxes if baxes else None, None))
    base_key = jax.random.PRNGKey(seed)

    from repro.models import modules

    def prefill(params, pstate, tokens, offset):
        """One prompt chunk at batch 1: writes cache lines
        [offset, offset+c), attends over the whole cache (earlier chunks
        included), returns f32 logits of the chunk's last position."""
        hidden, pstate, _ = stack.apply_model(
            params, cfg, run_p, tokens, decode_state=pstate,
            cache_index=offset, attend_to_cache=True, return_hidden=True,
            moe_override=ep_prefill_ov if ep_moe is not None else None)
        last = modules.apply_unembedding(
            params["embed"], params.get("lm_head"), cfg, run.policy,
            hidden[:, -1])
        return pstate, last.astype(jnp.float32)

    def insert(state, pstate, slot):
        """Overwrite slot ``slot`` of every decode-state leaf with the
        batch-1 prefilled state (batch axis: 1 for scan-stacked block
        leaves, 0 for tail leaves). A full overwrite — KV, cache
        positions, recurrent states — so recycled slots cannot leak."""
        def ins(axis):
            return lambda d, s: jax.lax.dynamic_update_slice_in_dim(
                d, s.astype(d.dtype), slot, axis=axis)
        new = {"blocks": None, "tails":
               jax.tree.map(ins(0), state["tails"], pstate["tails"])}
        if state["blocks"] is not None:
            new["blocks"] = jax.tree.map(ins(1), state["blocks"],
                                         pstate["blocks"])
        return new

    def decode(params, state, tok, pos, active, rids, ngen, temp, topk,
               topp):
        """One decode step for every slot; dead slots (pos < 0) write no
        cache lines and emit token 0. Under EP the per-layer routed-copy
        histogram rides along as a 4th output."""
        if ep_moe is not None:
            logits, state, aux = stack.apply_model(
                params, cfg, run_b, tok, decode_state=state,
                cache_index=pos, moe_override=ep_decode_ov(ep_moe, active),
                aux_extras=ep_extras, layer_aux=True)
        else:
            logits, state, _ = stack.apply_model(
                params, cfg, run_b, tok, decode_state=state,
                cache_index=pos)
        last = logits[:, -1].astype(jnp.float32)
        keys = sampling.request_keys(base_key, rids, ngen)
        nxt = sampling.sample_tokens(last, keys, temp, topk, topp)
        if ep_moe is not None:
            return (state, jnp.where(active, nxt, 0), last,
                    aux["per_layer"]["ep_counts"])
        return state, jnp.where(active, nxt, 0), last

    def sample(logits, rids, ngen, temp, topk, topp):
        keys = sampling.request_keys(base_key, rids, ngen)
        return sampling.sample_tokens(logits.astype(jnp.float32), keys,
                                      temp, topk, topp)

    jit_prefill = jax.jit(prefill, in_shardings=(psh, pssh, None, None),
                          out_shardings=(pssh, None), donate_argnums=(1,))
    jit_insert = jax.jit(insert, in_shardings=(ssh, pssh, None),
                         out_shardings=ssh, donate_argnums=(0,))
    dec_out = (ssh, None, None) if ep_moe is None else (ssh, None, None,
                                                        None)
    jit_decode = jax.jit(
        decode,
        in_shardings=(psh, ssh, tok_sh) + (vec_sh,) * 7,
        out_shardings=dec_out, donate_argnums=(1,))

    return ContinuousProgram(
        cfg=cfg, run=run, mesh=mesh, n_slots=B, max_len=max_len,
        prefill_step=jit_prefill, insert_step=jit_insert,
        decode_step=jit_decode, sample_step=jax.jit(sample),
        init_state=jax.jit(
            lambda: stack.init_decode_state(cfg, B, max_len, dtype),
            out_shardings=ssh),
        init_pstate=jax.jit(
            lambda: stack.init_decode_state(cfg, 1, max_len, dtype),
            out_shardings=pssh),
        param_shardings=psh, state_shardings=ssh, ep=ep)


def _make_paged_program(cfg: ModelConfig, mesh: Mesh, run: RunConfig, *,
                        n_slots: int, max_len: int, seed: int,
                        page_size: int, n_pages: int | None,
                        ep=None) -> ContinuousProgram:
    """Paged-KV build of the continuous program (DESIGN.md §9.4).

    KV never moves at admission or recycling: prefill scatters straight
    into the request's allocated pool pages (disjoint from every live
    slot's), the insert step copies only the batch-1 recurrent carry into
    the slot row, and freeing is the allocator's page-table reset. Decode
    carries ``pos [B]`` plus page tables ``[B, max_pages]``.
    """
    rules = rules_for(cfg, mesh, variant="serve")
    B = n_slots
    from repro.sharding.rules import (fitted_shardings, make_constrainer,
                                      page_table_spec)
    pshapes, paxes = abstract_params(cfg)
    psh = fitted_shardings(pshapes, paxes, rules, mesh)
    dtype = run.policy.compute_dtype

    ep_moe = None
    if ep is not None:
        from repro.serve import ep_decode as epd
        epd.validate_ep_config(cfg, mesh, ep)
        psh = epd.ep_param_shardings(psh, pshapes, mesh, ep)
        ep_moe = epd.make_ep_moe_decode(mesh, cfg, run, ep)
        ep_extras = (("ep_counts", (cfg.n_experts,)),)
        ep_prefill_ov = epd.moe_override_for(ep_moe)
        ep_decode_ov = epd.moe_override_for

    max_pages = -(-max_len // page_size)
    n_pages = n_pages if n_pages is not None else B * max_pages
    assert n_pages >= max_pages, "pool smaller than one sequence"

    _, sspecs = paged_state_specs(cfg, mesh, rules, B, n_pages, page_size,
                                  dtype)
    ssh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                       is_leaf=lambda x: isinstance(x, P))
    # Prefill recurrent carry: the non-KV part of a batch-1 dense state
    # (recurrent shapes are max_len-independent).
    _, pspecs = decode_state_specs(cfg, mesh, rules, 1, 1, dtype)
    prec_specs = stack.split_kv_state(pspecs)[1]
    prec_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), prec_specs,
                           is_leaf=lambda x: isinstance(x, P))

    baxes = fit_batch_axes(B, mesh, rules.batch_axes)
    run_b = dataclasses.replace(run, constrain=make_constrainer(
        dataclasses.replace(rules, batch_axes=baxes), mesh))
    run_p = dataclasses.replace(run, constrain=make_constrainer(
        dataclasses.replace(rules, batch_axes=()), mesh))
    vec_sh = NamedSharding(mesh, slot_vector_spec(B, mesh, rules))
    ptab_sh = NamedSharding(mesh, page_table_spec(B, mesh, rules))
    tok_sh = NamedSharding(mesh, P(baxes if baxes else None, None))
    base_key = jax.random.PRNGKey(seed)

    from repro.models import modules

    def prefill(params, state, prec, tokens, offset, ptrow):
        """One prompt chunk at batch 1, scattered through the request's
        page table straight into the shared pools; recurrent layers carry
        their batch-1 state in ``prec``."""
        kv_s, rec_s = stack.split_kv_state(state)
        merged = stack.merge_kv_state(kv_s, prec)
        hidden, new_merged, _ = stack.apply_model(
            params, cfg, run_p, tokens, decode_state=merged,
            cache_index=offset, attend_to_cache=True, return_hidden=True,
            page_table=ptrow,
            moe_override=ep_prefill_ov if ep_moe is not None else None)
        kv_n, prec_n = stack.split_kv_state(new_merged)
        last = modules.apply_unembedding(
            params["embed"], params.get("lm_head"), cfg, run.policy,
            hidden[:, -1])
        return (stack.merge_kv_state(kv_n, rec_s), prec_n,
                last.astype(jnp.float32))

    def insert(state, prec, slot):
        """Admission copies ONLY the recurrent carry into the slot row —
        the KV pages are already in the pool (written by prefill) and are
        exposed by the host updating the slot's page-table row."""
        kv_s, rec_s = stack.split_kv_state(state)

        def ins(axis):
            return lambda d, s: jax.lax.dynamic_update_slice_in_dim(
                d, s.astype(d.dtype), slot, axis=axis)
        new_rec = {"blocks": None, "tails":
                   jax.tree.map(ins(0), rec_s["tails"], prec["tails"])}
        if rec_s["blocks"] is not None:
            new_rec["blocks"] = jax.tree.map(ins(1), rec_s["blocks"],
                                             prec["blocks"])
        return stack.merge_kv_state(kv_s, new_rec)

    def decode(params, state, tok, pos, ptabs, active, rids, ngen, temp,
               topk, topp):
        if ep_moe is not None:
            logits, state, aux = stack.apply_model(
                params, cfg, run_b, tok, decode_state=state,
                cache_index=pos, page_table=ptabs,
                moe_override=ep_decode_ov(ep_moe, active),
                aux_extras=ep_extras, layer_aux=True)
        else:
            logits, state, _ = stack.apply_model(
                params, cfg, run_b, tok, decode_state=state,
                cache_index=pos, page_table=ptabs)
        last = logits[:, -1].astype(jnp.float32)
        keys = sampling.request_keys(base_key, rids, ngen)
        nxt = sampling.sample_tokens(last, keys, temp, topk, topp)
        if ep_moe is not None:
            return (state, jnp.where(active, nxt, 0), last,
                    aux["per_layer"]["ep_counts"])
        return state, jnp.where(active, nxt, 0), last

    def sample(logits, rids, ngen, temp, topk, topp):
        keys = sampling.request_keys(base_key, rids, ngen)
        return sampling.sample_tokens(logits.astype(jnp.float32), keys,
                                      temp, topk, topp)

    def fork(state, src, dst):
        """Copy-on-write page copy (DESIGN.md §14): duplicate physical
        page ``src`` into ``dst`` across every layer's K/V pool before a
        writer diverges from a shared prefix. One page of device traffic —
        the only KV copy anywhere in the paged engine."""
        return stack.scatter_kv_pages(
            state, stack.gather_kv_pages(state, src), dst)

    jit_fork = jax.jit(fork, in_shardings=(ssh, None, None),
                       out_shardings=ssh, donate_argnums=(0,))

    jit_prefill = jax.jit(prefill,
                          in_shardings=(psh, ssh, prec_sh, None, None, None),
                          out_shardings=(ssh, prec_sh, None),
                          donate_argnums=(1, 2))
    jit_insert = jax.jit(insert, in_shardings=(ssh, prec_sh, None),
                         out_shardings=ssh, donate_argnums=(0,))
    dec_out = (ssh, None, None) if ep_moe is None else (ssh, None, None,
                                                        None)
    jit_decode = jax.jit(
        decode,
        in_shardings=(psh, ssh, tok_sh, vec_sh, ptab_sh) + (vec_sh,) * 6,
        out_shardings=dec_out, donate_argnums=(1,))

    return ContinuousProgram(
        cfg=cfg, run=run, mesh=mesh, n_slots=B, max_len=max_len,
        prefill_step=jit_prefill, insert_step=jit_insert,
        decode_step=jit_decode, sample_step=jax.jit(sample),
        init_state=jax.jit(
            lambda: stack.init_paged_decode_state(cfg, B, n_pages,
                                                  page_size, dtype),
            out_shardings=ssh),
        init_pstate=None,
        param_shardings=psh, state_shardings=ssh,
        paged=True, page_size=page_size, n_pages=n_pages,
        max_pages=max_pages, ep=ep, fork_step=jit_fork,
        init_prec=jax.jit(
            lambda: stack.split_kv_state(
                stack.init_decode_state(cfg, 1, 1, dtype))[1],
            out_shardings=prec_sh))


class ContinuousBatchingEngine:
    """Continuous-batching serving loop (DESIGN.md §7).

    One ``tick`` = up to ``scheduler.token_budget`` chunked-prefill tokens
    (admitting at most one request at a time into a freed slot) followed
    by ONE batched decode step over all live slots. Requests finish and
    free their slot on EOS or length limit while other slots keep
    decoding; generated tokens land in ``results[rid]``.

    With a paged program (DESIGN.md §9.4) the scheduler must carry a
    ``BlockAllocator``; the engine mirrors each slot's page table, claims
    a page whenever a slot's next write position crosses a page boundary,
    and relieves pool OOM by preempting the newest running request
    (``scheduler.preempt_newest``) before the decode step runs.
    """

    def __init__(self, program: ContinuousProgram, params,
                 scheduler: Scheduler, *, metrics: ServeMetrics = None,
                 on_token: Callable = None, record_logits: bool = False):
        self.p = program
        self.params = params
        self.sched = scheduler
        self.metrics = metrics or ServeMetrics()
        self.on_token = on_token  # callable(rid, token, finished)
        self.record_logits = record_logits
        self.logits: Dict[int, List[np.ndarray]] = {}  # rid -> [V] rows
        self.rejected: List[int] = []  # rids refused admission
        self.tick_count = 0
        self.track = "serve"  # tracer track (fleet/disagg override per role)
        self.owns_clock = True  # standalone: this engine advances the tracer
        scheduler.set_track(self.track)
        B = program.n_slots
        with program.mesh:
            self.state = program.init_state()
        self.pstate = None
        self.prec = None  # paged mode: batch-1 prefill recurrent carry
        # Host mirrors of the per-slot decode inputs.
        self._tok = np.zeros((B,), np.int32)
        self._pos = np.full((B,), -1, np.int32)
        self._active = np.zeros((B,), bool)
        self._rid = np.zeros((B,), np.int32)
        self._ngen = np.zeros((B,), np.int32)
        self._temp = np.zeros((B,), np.float32)
        self._topk = np.zeros((B,), np.int32)
        self._topp = np.ones((B,), np.float32)
        if program.paged:
            alloc = scheduler.allocator
            assert alloc is not None, "paged program needs an allocator"
            assert alloc.page_size == program.page_size \
                and alloc.n_pages == program.n_pages \
                and alloc.max_pages_per_seq >= program.max_pages, \
                "allocator geometry disagrees with the program"
            self._ptab = np.full((B, program.max_pages), -1, np.int32)
            # page-pool occupancy stats (simulated-HBM benchmark inputs)
            self.page_peak = 0
            self._page_ticks: List[tuple] = []  # (pages_in_use, n_active)

    @property
    def results(self) -> Dict[int, List[int]]:
        return self.sched.results

    def set_track(self, track: str) -> None:
        """Point this engine's trace events at ``track`` (fleet groups use
        g{gid}, disagg roles use prefill/decode). Controllers that call
        this own the tick clock, so the engine stops advancing it."""
        self.track = track
        self.owns_clock = False
        self.sched.set_track(track)

    def submit(self, req: Request) -> None:
        self.sched.submit(req)
        self.metrics.on_submit(req.rid, len(req.prompt))
        obs_trace.TRACER.flow(self.track, "queued", req.rid,
                              prompt=len(req.prompt))

    # -- one engine tick ----------------------------------------------------

    def tick(self) -> None:
        tr = obs_trace.TRACER
        if self.owns_clock:
            tr.advance(self.tick_count)
        worked = False
        budget = self.sched.token_budget
        while budget > 0:
            chunk = self.sched.plan_prefill(budget)
            if chunk is None:
                break
            with tr.span(self.track, "prefill", rid=chunk.request.rid,
                         start=chunk.start, length=chunk.length):
                if chunk.first:
                    tr.flow(self.track, "prefill", chunk.request.rid)
                self._run_prefill_chunk(chunk)
            worked = True
            budget -= chunk.length
        if self.p.paged:
            self._ensure_pages()
        if self._active.any():
            with tr.span(self.track, "decode",
                         n_active=int(self._active.sum())):
                self._decode_once()
            worked = True
        if tr.enabled:
            tr.count(self.track, "queue_depth", self.sched.queue_depth)
            if not worked:
                bucket = "pool-OOM" \
                    if self.sched.prefill.wait_reason == "pages" \
                    else "queue-starved"
                tr.mark_idle(self.track, bucket)
        self.metrics.on_tick(self.sched.queue_depth, self.sched.n_active)
        if self.p.paged:
            in_use = self.sched.allocator.pages_in_use
            self.page_peak = max(self.page_peak, in_use)
            self._page_ticks.append((in_use, self.sched.n_active))
        self.tick_count += 1

    def _run_prefill_chunk(self, chunk: PrefillChunk) -> None:
        req = chunk.request
        toks = np.asarray(
            chunk.tokens[chunk.start:chunk.start + chunk.length],
            np.int32)[None, :]
        if self.p.paged:
            if chunk.first:  # fresh (or resumed) -> fresh rec carry;
                # a prefix hit starts at chunk.skipped, not 0 (§14)
                with self.p.mesh:
                    self.prec = self.p.init_prec()
            # Fork-on-divergence: this chunk writes lines
            # [start, start+length) — any SHARED page in that range must
            # be COW-forked before the scatter lands (a resumed mid-page
            # prefill into a cached partial tail is the canonical case).
            self._cow_guard(req.rid, chunk.start, chunk.length)
            ptrow = jnp.asarray(self.sched.allocator.table(
                req.rid, self.p.max_pages))[None, :]
            with self.p.mesh:
                self.state, self.prec, logits = self.p.prefill_step(
                    self.params, self.state, self.prec, toks,
                    jnp.asarray(chunk.start, jnp.int32), ptrow)
        else:
            if chunk.start == 0:  # fresh request -> fresh prefill cache
                with self.p.mesh:
                    self.pstate = self.p.init_pstate()
            with self.p.mesh:
                self.pstate, logits = self.p.prefill_step(
                    self.params, self.pstate, toks,
                    jnp.asarray(chunk.start, jnp.int32))
        if self.sched.finish_prefill_chunk(chunk):
            self._admit(chunk, logits)

    def _admit(self, chunk: PrefillChunk, last_logits) -> None:
        """Sample the next token from the prefill logits and insert the
        prefilled state into the freed slot. For a preemption resume
        (``chunk.n_done > 0``) the re-prefill replayed prompt + generated
        tokens, so the sample index continues at ``n_done`` — key(rid, n)
        makes the continuation token-exact (§7.4)."""
        req, slot = chunk.request, chunk.slot
        sp = req.sampling
        with self.p.mesh:
            first = self.p.sample_step(
                last_logits, np.asarray([req.rid], np.int32),
                np.asarray([chunk.n_done], np.int32),
                np.asarray([sp.temperature], np.float32),
                np.asarray([sp.top_k], np.int32),
                np.asarray([sp.top_p], np.float32))
            if self.p.paged:
                self.state = self.p.insert_step(self.state, self.prec,
                                                jnp.asarray(slot, jnp.int32))
                self.prec = None
                self._ptab[slot] = self.sched.allocator.table(
                    req.rid, self.p.max_pages)
            else:
                self.state = self.p.insert_step(self.state, self.pstate,
                                                jnp.asarray(slot, jnp.int32))
                self.pstate = None
        first = int(np.asarray(first)[0])
        if self.record_logits:
            if chunk.n_done == 0:
                self.logits[req.rid] = [np.asarray(last_logits)[0]]
            else:
                self.logits[req.rid].append(np.asarray(last_logits)[0])
        self.metrics.on_token(req.rid, self.tick_count)
        finished = self.sched.activate(chunk, first)
        if self.on_token:
            self.on_token(req.rid, first, finished)
        if finished:
            self.metrics.on_finish(req.rid, self.tick_count)
            if self.p.paged:
                self._ptab[slot] = -1
            return
        self._tok[slot] = first
        self._pos[slot] = len(chunk.tokens)
        self._active[slot] = True
        self._rid[slot] = req.rid
        self._ngen[slot] = chunk.n_done + 1
        self._temp[slot] = sp.temperature
        self._topk[slot] = sp.top_k
        self._topp[slot] = sp.top_p

    def _cow_guard(self, rid: int, line_start: int, n_lines: int,
                   slot: Optional[int] = None) -> None:
        """COW-fork every SHARED page of ``rid`` that the upcoming write
        to lines [line_start, line_start + n_lines) would touch
        (DESIGN.md §14): a fresh page replaces the shared one in the
        table and ``fork_step`` copies its device lines, so no writer
        ever mutates a page with refcount > 1. On pool exhaustion the
        newest running request is preempted for the copy target."""
        alloc = self.sched.allocator
        ps = alloc.page_size
        table = alloc.tables.get(rid)
        if not table or n_lines <= 0:
            return
        lo = line_start // ps
        hi = min((line_start + n_lines - 1) // ps, len(table) - 1)
        for pslot in range(lo, hi + 1):
            if not alloc.is_shared(table[pslot]):
                continue
            while True:
                try:
                    old, new = alloc.cow_fork(rid, pslot)
                    break
                except MemoryError:
                    victim = self.sched.preempt_newest()
                    assert victim is not None, \
                        "COW OOM with nothing to preempt"
                    self._clear_slot(victim)
                    if slot is not None and victim == slot:
                        return  # the writer itself was evicted; it resumes
            with self.p.mesh:
                self.state = self.p.fork_step(
                    self.state, jnp.asarray([old], jnp.int32),
                    jnp.asarray([new], jnp.int32))
            if slot is not None:
                self._ptab[slot] = alloc.table(rid, self.p.max_pages)

    def _ensure_pages(self) -> None:
        """Claim a pool page for every live slot whose next write position
        has crossed its allocated frontier; on pool OOM, preempt the newest
        running request (oldest slots are served first so eviction order is
        newest-first and the loop always converges — down to one live
        request, which submit() guaranteed fits the pool). With a prefix
        cache, a slot about to write into a still-shared page COW-forks it
        first (the decode half of fork-on-divergence, §14)."""
        alloc = self.sched.allocator
        order = sorted((int(s) for s in np.nonzero(self._active)[0]),
                       key=lambda s: self.sched.running[s].seq)
        for slot in order:
            if not self._active[slot]:
                continue  # evicted by an earlier slot's OOM relief
            rid = int(self._rid[slot])
            while not alloc.covers(rid, int(self._pos[slot])):
                if alloc.extend(rid):
                    self._ptab[slot] = alloc.table(rid, self.p.max_pages)
                    continue
                victim = self.sched.preempt_newest()
                assert victim is not None, "OOM with nothing to preempt"
                self._clear_slot(victim)
                if victim == slot:
                    break  # this slot itself was evicted; it will resume
            if self._active[slot]:
                self._cow_guard(rid, int(self._pos[slot]), 1, slot=slot)

    def _decode_once(self) -> None:
        with self.p.mesh:
            if self.p.paged:
                out = self.p.decode_step(
                    self.params, self.state, self._tok[:, None], self._pos,
                    self._ptab, self._active, self._rid, self._ngen,
                    self._temp, self._topk, self._topp)
            else:
                out = self.p.decode_step(
                    self.params, self.state, self._tok[:, None], self._pos,
                    self._active, self._rid, self._ngen, self._temp,
                    self._topk, self._topp)
        if self.p.ep is not None:
            self.state, nxt, logits, counts = out
            self._on_ep_counts(counts)
        else:
            self.state, nxt, logits = out
        nxt = np.asarray(nxt)
        if self.record_logits:
            logits = np.asarray(logits)
        for slot in np.nonzero(self._active)[0]:
            slot = int(slot)
            tok = int(nxt[slot])
            rid = int(self._rid[slot])
            if self.record_logits:
                self.logits[rid].append(logits[slot])
            self.metrics.on_token(rid, self.tick_count)
            finished = self.sched.note_token(slot, tok)
            if self.on_token:
                self.on_token(rid, tok, finished)
            if finished:
                self.metrics.on_finish(rid, self.tick_count)
                self._release(slot)
            else:
                self._tok[slot] = tok
                self._pos[slot] += 1
                self._ngen[slot] += 1

    def _on_ep_counts(self, counts) -> None:
        """Routing-histogram hook (EP decode): overridden by
        serve.ep_decode.EPContinuousBatchingEngine to feed the placement
        EMA; a plain engine driving an EP program just drops the counts."""

    def _release(self, slot: int) -> None:
        self._clear_slot(slot)

    def _clear_slot(self, slot: int) -> None:
        self._active[slot] = False
        self._pos[slot] = -1
        self._tok[slot] = 0
        self._ngen[slot] = 0
        self._temp[slot] = 0.0
        self._topk[slot] = 0
        self._topp[slot] = 1.0
        if self.p.paged:
            self._ptab[slot] = -1

    def page_occupancy(self) -> dict:
        """Simulated-HBM occupancy stats over the run (paged mode): peak
        pages in use and the time-averaged cache lines held per active
        slot — the quantities bench_serve.py --paged turns into the
        slots-at-fixed-HBM comparison against the reservation engine."""
        assert self.p.paged
        ticks = [t for t in self._page_ticks if t[1] > 0]
        lines = [p * self.p.page_size / a for p, a in ticks]
        alloc = self.sched.allocator
        return {
            "page_size": self.p.page_size,
            "n_pages": self.p.n_pages,
            "page_peak": self.page_peak,
            "mean_lines_per_active_slot":
                round(sum(lines) / len(lines), 2) if lines else 0.0,
            "n_preempted": self.sched.n_preempted,
            # prefix-cache accounting (§14; zeros when caching is off)
            "pages_allocated": alloc.n_fresh_allocs,
            "pages_shared": alloc.n_shared_allocs,
            "n_cow_forks": alloc.n_cow_forks,
            "prefix_hits": self.sched.prefill.n_prefix_hits,
            "tokens_skipped": self.sched.prefill.n_tokens_skipped,
        }

    # -- trace driver -------------------------------------------------------

    def run(self, requests: List[Request], max_ticks: int = 100_000):
        """Drive a trace to completion. ``Request.arrival`` is in engine
        ticks (the simulated clock); requests are submitted when the tick
        counter reaches their arrival time."""
        pending = sorted(requests, key=lambda r: r.arrival)
        while True:
            while pending and pending[0].arrival <= self.tick_count:
                req = pending.pop(0)
                try:
                    self.submit(req)
                except ValueError:
                    # inadmissible (oversized / empty) — reject this
                    # request, keep serving the rest
                    self.rejected.append(req.rid)
            if not pending and not self.sched.has_work() \
                    and not self._active.any():
                return self.results
            self.tick()
            if self.tick_count > max_ticks:
                raise RuntimeError(f"serve trace exceeded {max_ticks} ticks")
