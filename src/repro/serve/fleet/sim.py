"""Fleet-level trace replay: N prefill + M decode groups, elastic flips,
group kills (DESIGN.md §12).

The per-group model matches ``core.simulator.simulate_serve_trace``: a
prefill group is a sequential batch-1 stream (a request occupies it for
``ceil(len/chunk) * t_prefill_chunk``), a decode group steps all of its
active slots every ``t_decode_step``, and a finished prefill becomes a
ticket that is admissible ``t_handoff`` later. On top of that, this
simulator adds the three fleet mechanisms the real ``FleetController``
implements:

* **routing** — arrivals go to the prefill group with the least backlog
  per unit speed; tickets admit strictly FIFO (head-of-line, like the
  real controller's pending deque) to the decode group with the lowest
  occupancy-per-speed among those with a free slot;
* **elastic role flips** — every ``control_dt`` the policy may flip ONE
  idle group to the overloaded role (decode backlog → prefill group
  becomes a decode group, and back), paying ``flip_delay`` of
  unavailability; a flip never removes the last group of a role;
* **failure** — at each ``kills`` time a group vanishes; its in-flight
  requests re-enter the router ``detect_delay`` later (the heartbeat
  grace window) and RE-PREFILL their prompt plus every token already
  emitted, so recovery is priced as real token-exact replay. Emitted
  tokens are never un-emitted: the recovery gap lands in the request's
  max inter-token latency, which is exactly where an SLO feels it.

A request is **good** iff its TTFT ≤ ``slo_ttft`` and its worst ITL ≤
``slo_itl``; goodput-under-SLO counts only good requests' tokens. With
``slo_admission`` on, an ARRIVAL whose best achievable prefill ETA
already exceeds ``slo_ttft`` is SHED at the door (DESIGN.md §13) — an
explicit outcome instead of a guaranteed-late finish; recovery
re-entries are never shed (their tokens are already paid for). Pure
python, deterministic, host-only.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serve.metrics import percentile

_INF = float("inf")


@dataclasses.dataclass
class SimGroup:
    """One serving group as the fleet simulator sees it. Carries BOTH
    role clocks so an elastic flip is just ``role = other``."""

    gid: int
    cls: str                  # device-class name (display only)
    role: str                 # 'prefill' | 'decode'
    t_prefill_chunk: float
    t_decode_step: float
    decode_slots: int
    # -- runtime state (owned by the simulator) --
    alive: bool = True
    avail_at: float = 0.0     # role-flip latency: unusable before this
    queue: deque = dataclasses.field(default_factory=deque)   # prefill idx
    queued_chunks: int = 0    # incremental sum of chunks over `queue`
    current: Optional[int] = None                             # prefilling idx
    busy_until: float = _INF
    active: Dict[int, int] = dataclasses.field(default_factory=dict)
    next_tick: float = _INF
    draining: bool = False    # decode→prefill flip staged: admit() skips it
    flips: int = 0

    def idle(self) -> bool:
        if self.role == "prefill":
            return self.current is None and not self.queue
        return not self.active


@dataclasses.dataclass(frozen=True)
class FleetSimResult:
    makespan: float
    goodput: float            # finished tokens / makespan
    goodput_under_slo: float  # tokens of SLO-good finished reqs / makespan
    ttft_p99: float
    itl_p99: float            # p99 of per-request WORST inter-token gap
    n_requests: int
    n_finished: int
    n_good: int
    n_flips: int
    n_shed: int = 0           # SLO-infeasible arrivals shed at admission


@dataclasses.dataclass
class _Req:
    arrival: float
    prompt: int
    gen: int
    generated: int = 0
    ttft: Optional[float] = None
    last_tok: Optional[float] = None
    max_itl: float = 0.0
    done_at: Optional[float] = None

    def emit(self, t: float) -> None:
        if self.ttft is None:
            self.ttft = t - self.arrival
        elif self.last_tok is not None:
            self.max_itl = max(self.max_itl, t - self.last_tok)
        self.last_tok = t
        self.generated += 1
        if self.generated >= self.gen:
            self.done_at = t

    def replay_len(self, prefill_chunk: int) -> int:
        """Token-exact recovery re-prefills prompt + emitted tokens."""
        return -(-(self.prompt + self.generated) // prefill_chunk)


def simulate_fleet_trace(reqs, groups: Sequence[SimGroup], *,
                         prefill_chunk: int, t_handoff: float = 0.0,
                         elastic: bool = False, control_dt: float = 1.0,
                         flip_delay: float = 0.5,
                         wait_hi: float = 0.25, backlog_s_hi: float = 1.0,
                         kills: Sequence[Tuple[float, int]] = (),
                         detect_delay: float = 1.0,
                         slo_ttft: float = _INF, slo_itl: float = _INF,
                         slo_admission: bool = False,
                         max_events: int = 10_000_000) -> FleetSimResult:
    """Replay ``reqs`` (ServeRequest list) through a group fleet.

    ``groups`` are mutated (role, queues); pass fresh ones per run.
    ``kills`` is [(time, gid)]: the group dies at that time, its work
    re-enters the router ``detect_delay`` later. ``slo_admission``
    sheds arrivals whose best prefill ETA exceeds ``slo_ttft``.
    """
    groups = list(groups)
    by_gid = {g.gid: g for g in groups}
    if len(by_gid) != len(groups):
        raise ValueError("duplicate gid")
    R = [_Req(r.arrival, r.prompt, r.gen) for r in reqs]
    arrivals = sorted(range(len(R)), key=lambda i: (R[i].arrival, i))
    a_ptr = 0
    kill_list = sorted(kills)
    k_ptr = 0
    pending: deque = deque()           # (ready_time, idx) FIFO tickets
    delayed: List[Tuple[float, int]] = []  # recovery re-entries
    t = 0.0
    next_ctrl = control_dt if elastic else _INF
    n_flips = 0
    n_shed = 0

    def prefill_groups():
        return [g for g in groups if g.alive and g.role == "prefill"]

    def decode_groups():
        return [g for g in groups if g.alive and g.role == "decode"]

    def chunks_of(i: int) -> int:
        return R[i].replay_len(prefill_chunk)

    def backlog_s(g: SimGroup) -> float:
        n = g.queued_chunks
        if g.current is not None:
            n += 1  # at least the tail of the in-flight request
        return n * g.t_prefill_chunk

    def route_prefill(i: int, now: float) -> None:
        cands = [g for g in prefill_groups() if g.avail_at <= now]
        cands = cands or prefill_groups()
        if not cands:
            return  # no prefill capacity left; request is stranded
        g = min(cands, key=lambda g: (backlog_s(g)
                                      + chunks_of(i) * g.t_prefill_chunk,
                                      g.gid))
        g.queue.append(i)
        g.queued_chunks += chunks_of(i)
        start_prefill(g, max(now, g.avail_at))

    def start_prefill(g: SimGroup, now: float) -> None:
        if g.current is None and g.queue:
            i = g.queue.popleft()
            g.queued_chunks -= chunks_of(i)
            g.current = i
            g.busy_until = max(now, g.avail_at) + \
                chunks_of(i) * g.t_prefill_chunk

    def admit(now: float) -> None:
        # Strict FIFO head-of-line, like the controller's pending deque.
        while pending and pending[0][0] <= now:
            cands = [g for g in decode_groups()
                     if g.avail_at <= now and not g.draining
                     and len(g.active) < g.decode_slots]
            if not cands:
                return
            g = min(cands, key=lambda g: (len(g.active) * g.t_decode_step,
                                          g.gid))
            _, i = pending.popleft()
            R[i].emit(now)  # first token rides the handed-off logits
            left = R[i].gen - R[i].generated
            if left > 0:
                g.active[i] = left
                if g.next_tick == _INF:
                    g.next_tick = now + g.t_decode_step

    def kill(g: SimGroup, now: float) -> None:
        g.alive = False
        victims = list(g.queue) + \
            ([g.current] if g.current is not None else []) + \
            list(g.active)
        g.queue.clear()
        g.queued_chunks = 0
        g.current, g.busy_until = None, _INF
        g.active.clear()
        g.next_tick = _INF
        # Tickets handed off FROM a dead prefill group are gone with its
        # pool; they re-prefill too.
        for ready, i in list(pending):
            if R[i].done_at is None and i in victims:
                pending.remove((ready, i))
        for i in victims:
            if R[i].done_at is None:
                delayed.append((now + detect_delay, i))
        delayed.sort()

    def flip(g: SimGroup, to_role: str, now: float) -> None:
        nonlocal n_flips
        displaced = []
        if g.role == "prefill":
            displaced = list(g.queue) + \
                ([g.current] if g.current is not None else [])
            g.queue.clear()
            g.queued_chunks = 0
            g.current = None
        g.role = to_role
        g.avail_at = now + flip_delay
        g.busy_until = _INF
        g.next_tick = _INF
        g.draining = False
        g.flips += 1
        n_flips += 1
        for i in displaced:  # forced flips may displace queued prefills
            route_prefill(i, now)

    def control(now: float) -> None:
        # Pressure signals are WAIT-based, not instantaneous counts — a
        # momentary ticket spike that decode would drain in a step must
        # not cost a flip (flips pay flip_delay of lost service).
        dec = decode_groups()
        pre = prefill_groups()
        head_wait = (now - pending[0][0]) if pending and \
            pending[0][0] <= now else 0.0
        backlog = max((backlog_s(g) for g in pre), default=0.0)
        if head_wait > wait_hi and len(pre) > 1:
            # Decode is the bottleneck: tickets are stuck. Undo any staged
            # decode→prefill flip first, then add a decode group.
            for g in dec:
                g.draining = False
            idle = [g for g in pre if g.idle() and g.avail_at <= now]
            if idle:  # len(pre) > 1 already: never strand future arrivals
                flip(min(idle, key=lambda g: (g.t_decode_step, g.gid)),
                     "decode", now)
            return
        if backlog > backlog_s_hi and head_wait == 0.0 and len(dec) > 1:
            # Prefill is the bottleneck: add a prefill group. An idle
            # decode group flips now; otherwise stage a drain on the
            # least-loaded one (admissions skip it; it flips when empty).
            if not any(g.draining for g in dec):
                g = min(dec, key=lambda g: (len(g.active),
                                            g.t_prefill_chunk, g.gid))
                if g.active:
                    g.draining = True
                elif g.avail_at <= now:
                    flip(g, "prefill", now)
                    return
        elif backlog < 0.25 * backlog_s_hi:
            for g in dec:
                g.draining = False
        for g in list(dec):
            if g.draining and not g.active and g.avail_at <= now \
                    and len(decode_groups()) > 1:
                flip(g, "prefill", now)
                break

    for _ in range(max_events):
        # -- next event time --
        cand = []
        if a_ptr < len(arrivals):
            cand.append(R[arrivals[a_ptr]].arrival)
        if k_ptr < len(kill_list):
            cand.append(kill_list[k_ptr][0])
        if delayed:
            cand.append(delayed[0][0])
        cand += [g.busy_until for g in groups if g.current is not None]
        cand += [g.next_tick for g in groups if g.active]
        free = [g for g in decode_groups()
                if not g.draining and len(g.active) < g.decode_slots]
        if pending and free:
            cand.append(max(pending[0][0],
                            min(g.avail_at for g in free)))
        if elastic and (pending or any(not g.idle() for g in groups)):
            cand.append(next_ctrl)
        # stalled-but-flipping groups become usable at avail_at
        if pending or delayed or a_ptr < len(arrivals):
            cand += [g.avail_at for g in groups
                     if g.alive and g.avail_at > t]
        nxt = min((c for c in cand if c < _INF), default=_INF)
        if nxt == _INF:
            break
        t = max(t, nxt)

        # 1. failures first: death is detected at the tick boundary.
        while k_ptr < len(kill_list) and kill_list[k_ptr][0] <= t:
            gid = kill_list[k_ptr][1]
            if by_gid[gid].alive:
                kill(by_gid[gid], t)
            k_ptr += 1
            if elastic and not decode_groups():
                pre = [g for g in prefill_groups() if g.idle()] or \
                    prefill_groups()
                if len(prefill_groups()) > 1 and pre:
                    flip(min(pre, key=lambda g: g.gid), "decode", t)
        # 2. recovered work re-enters the router.
        while delayed and delayed[0][0] <= t:
            _, i = delayed.pop(0)
            route_prefill(i, t)
        # 3. arrivals (SLO admission sheds provably-late ones at the door:
        #    the best ETA over live prefill groups — queue drain + own
        #    chunks + any flip latency — already blows the TTFT budget).
        while a_ptr < len(arrivals) and R[arrivals[a_ptr]].arrival <= t:
            i = arrivals[a_ptr]
            a_ptr += 1
            if slo_admission and slo_ttft < _INF:
                etas = [backlog_s(g) + chunks_of(i) * g.t_prefill_chunk
                        + max(g.avail_at - t, 0.0)
                        for g in prefill_groups()]
                if etas and min(etas) > slo_ttft:
                    n_shed += 1
                    continue
            route_prefill(i, t)
        # 4. prefill completions -> tickets.
        for g in groups:
            while g.alive and g.role == "prefill" and \
                    g.current is not None and g.busy_until <= t:
                pending.append((g.busy_until + t_handoff, g.current))
                g.current, g.busy_until = None, _INF
                start_prefill(g, t)
        # 5. decode steps.
        for g in groups:
            while g.alive and g.role == "decode" and g.active and \
                    g.next_tick <= t:
                now = g.next_tick
                for i in list(g.active):
                    R[i].emit(now)
                    g.active[i] -= 1
                    if g.active[i] <= 0 or R[i].done_at is not None:
                        del g.active[i]
                g.next_tick = now + g.t_decode_step if g.active else _INF
        # 6. admissions at the new time.
        admit(t)
        for g in prefill_groups():
            start_prefill(g, t)
        # 7. elastic control.
        if elastic and next_ctrl <= t:
            control(t)
            while next_ctrl <= t:
                next_ctrl += control_dt
    else:
        raise RuntimeError("simulate_fleet_trace: max_events exceeded")

    done = [r for r in R if r.done_at is not None]
    good = [r for r in done
            if (r.ttft or 0.0) <= slo_ttft and r.max_itl <= slo_itl]
    makespan = max((r.done_at for r in done), default=0.0)
    tok = sum(r.generated for r in done)
    tok_good = sum(r.generated for r in good)
    return FleetSimResult(
        makespan=makespan,
        goodput=tok / makespan if makespan > 0 else 0.0,
        goodput_under_slo=tok_good / makespan if makespan > 0 else 0.0,
        ttft_p99=percentile([r.ttft for r in R if r.ttft is not None], 0.99),
        itl_p99=percentile([r.max_itl for r in done], 0.99),
        n_requests=len(R), n_finished=len(done), n_good=len(good),
        n_flips=n_flips, n_shed=n_shed)
