"""Fleet request/ticket routing (DESIGN.md §12).

The router is the fleet's placement policy, deliberately host-only and
duck-typed: it scores *group views* — anything exposing the small
protocol below — so the same policy runs over real
:class:`~repro.serve.fleet.controller.FleetGroup` objects and over plain
test stubs. Scores are estimated completion times, not queue lengths:
a queue of three requests on a fast class beats an empty queue on a
class three times slower.

Group protocol (prefill candidates)::

    g.gid, g.cls                  # id + device-class name
    g.queued_prefill_tokens()     # backlog ahead of a new arrival

Group protocol (decode candidates)::

    g.gid, g.cls
    g.n_active()                  # occupied decode slots
    g.can_accept_ticket(n_tokens) # free slot AND pool headroom

Speed priors are per-class scalars (tokens/s; any consistent unit).
``slow_factor`` is an optional callable (``StragglerDetector.slow_factor``
in the real controller): a degraded group's effective speed is divided by
it, steering load away from stragglers before they are evicted.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional


class FleetRouter:
    """Places arrivals on prefill groups and tickets on decode groups."""

    def __init__(self, prefill_speed: Optional[Dict[str, float]] = None,
                 decode_speed: Optional[Dict[str, float]] = None,
                 slow_factor: Optional[Callable[[str], float]] = None):
        self.prefill_speed = prefill_speed or {}
        self.decode_speed = decode_speed or {}
        self.slow_factor = slow_factor

    def _slow(self, name: str) -> float:
        return max(self.slow_factor(name), 1.0) if self.slow_factor else 1.0

    # -- scoring ------------------------------------------------------------

    def prefill_eta(self, g, n_tokens: int) -> float:
        """Estimated seconds until a new ``n_tokens`` prompt finishes
        prefilling on ``g`` (queue-ahead + own work, over class speed)."""
        speed = self.prefill_speed.get(g.cls, 1.0) / self._slow(g.name)
        return (g.queued_prefill_tokens() + n_tokens) / max(speed, 1e-12)

    def decode_eta(self, g) -> float:
        """Estimated per-token latency a ticket would see on ``g``:
        occupancy over class speed (a fuller, slower group serves each
        slot's token later)."""
        speed = self.decode_speed.get(g.cls, 1.0) / self._slow(g.name)
        return (g.n_active() + 1) / max(speed, 1e-12)

    # -- placement ----------------------------------------------------------

    def place_request(self, groups, n_tokens: int):
        """Least-ETA prefill group for a new prompt (None if no groups)."""
        cands = list(groups)
        if not cands:
            return None
        return min(cands, key=lambda g: (self.prefill_eta(g, n_tokens),
                                         g.gid))

    def place_ticket(self, groups, n_tokens: int):
        """Least-ETA decode group that can land an ``n_tokens`` ticket NOW
        (free slot + pool headroom). None when nothing can — the caller
        keeps the ticket at the head of its FIFO (head-of-line)."""
        cands = [g for g in groups if g.can_accept_ticket(n_tokens)]
        if not cands:
            return None
        return min(cands, key=lambda g: (self.decode_eta(g), g.gid))
