"""Elastic multi-group serving fleet (DESIGN.md §12).

Scales PR 5's one-prefill/one-decode disagg controller into a FLEET: N
prefill and M decode groups of mixed device classes, each a PR 5 worker
over its OWN paged pool, joined by three control-plane mechanisms:

* **routing** — arrivals land on the prefill group with the least
  estimated completion time and migration tickets on the least-loaded
  decode group that has a free slot AND pool headroom
  (:class:`~repro.serve.fleet.router.FleetRouter`); tickets stay strictly
  FIFO (head-of-line) so fleet metrics stay comparable to the
  single-group controller's;
* **elastic role reassignment** — when tickets back up behind decode
  (or decode groups die), an idle prefill group FLIPS into a decode
  group, and when prefill queues back up, a decode group drains and
  flips back. A flip swaps the group's worker object around the fleet's
  two shared compiled programs — no recompilation, fresh pool — and is
  only taken when the group's pool is empty (``pages_in_use == 0``
  covers live tables AND outstanding ticket exports), except the forced
  path that revives a fleet with zero decode groups, which displaces the
  flipped group's queued work and re-prefills its parked tickets;
* **failure recovery** — groups heartbeat into the dormant-until-now
  ``ft.monitor`` machinery on the tick clock. A killed group stops
  beating and stops computing; after the grace window
  ``HeartbeatMonitor`` declares it dead and every in-flight request it
  held (queued, mid-prefill, parked ticket, or mid-decode) re-enters the
  router and RE-PREFILLS token-exactly: resume tokens come from the
  fleet's results log (fed by streamed ``on_token`` callbacks — exactly
  what a control plane honestly still has after a crash), and the
  ``key(rid, n)`` sampler discipline makes the continuation bit-exact.
  Surviving pools are never touched, so ``BlockAllocator.check()`` holds
  throughout. ``StragglerDetector`` wall-times feed the router's
  ``slow_factor`` so degraded groups shed load before they die.

Because per-request logits depend only on the request's own tokens and
sampling keys are schedule-independent (§7.4), the whole fleet — across
routing, flips, preemptions, kills, and recovery — is TOKEN-EXACT
against the unified single-group engine on any trace.

Chaos hardening (DESIGN.md §13) layers three more mechanisms on top:

* **epoch fencing** — every group carries a ``generation`` that its
  token callbacks and migration tickets are stamped with. A group
  declared dead while actually still computing (heartbeat loss — a
  false positive) becomes a ZOMBIE: its epoch ``(gid, generation)`` is
  fenced, it is quarantined onto private results/metrics (so the fleet
  log cannot be corrupted), and every completion it keeps producing is
  rejected by the fence. When its heartbeats return it REJOINS at
  ``generation + 1`` with a fresh worker — the replacement and the
  zombie can never race because only the newest epoch passes the fence;
* **transactional handoff** — a migration whose transfer exhausts its
  retry budget rolls back cleanly (decode lease + slot inside
  ``try_admit``, source export here) and the request re-prefills
  token-exactly; a chaos crash mid-transfer kills the victim group and
  leaves the ticket head-of-line for the normal death path;
* **SLO-aware shedding** — with ``slo_ttft`` set, an arrival whose best
  achievable prefill ETA across the (possibly degraded) fleet already
  exceeds the SLO is SHED at submit: an explicit outcome the client can
  retry elsewhere, instead of a guaranteed-late finish. The run
  invariant becomes submitted ⊆ finished ∪ rejected ∪ shed.

All faults come from a seeded :class:`~repro.ft.chaos.FaultInjector`
consulted at named hook points, so every failure run replays exactly
from ``(seed, spec)``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.ft.chaos import FaultInjector, GroupCrashed
from repro.ft.monitor import (HeartbeatConfig, HeartbeatMonitor,
                              StragglerDetector)
from repro.obs import trace as obs_trace
from repro.serve.disagg.workers import (DecodeWorker, MigrationTicket,
                                        PrefillWorker)
from repro.serve.kv_transfer import KVTransferEngine, TransferAbortedError
from repro.serve.metrics import RequestTrace, ServeMetrics
from repro.serve.scheduler import Request
from repro.serve.fleet.router import FleetRouter

PREFILL, DECODE = "prefill", "decode"


class FleetGroup:
    """One serving group: a device class + a role + a PR 5 worker over
    its own pool. Implements the router's group-view protocol."""

    def __init__(self, gid: int, cls: str, role: str, worker):
        self.gid = gid
        self.cls = cls
        self.role = role
        self.worker = worker
        self.alive = True
        self.draining = False   # decode→prefill flip staged
        self.flips = 0
        self.generation = 0     # fencing epoch (bumps on zombie rejoin)

    @property
    def name(self) -> str:
        return f"g{self.gid}"

    # -- router protocol ----------------------------------------------------

    def queued_prefill_tokens(self) -> int:
        sched = self.worker.sched
        n = sum(len(e.tokens) for e in sched.queue)
        if sched._prefilling is not None:
            entry, _, start, _ = sched._prefilling
            n += len(entry.tokens) - start
        return n

    def n_active(self) -> int:
        return len(self.worker.sched.running)

    def can_accept_ticket(self, n_tokens: int) -> bool:
        if self.draining or not self.worker.sched.has_free():
            return False
        alloc = self.worker.allocator
        return alloc.pages_for(n_tokens) <= alloc.n_pages - \
            alloc.pages_in_use

    # -- flip eligibility ---------------------------------------------------

    def idle(self) -> bool:
        """No scheduled work and an empty pool — pages_in_use counts live
        tables AND exported (parked-ticket) pages, so a prefill group with
        un-migrated tickets is NOT idle."""
        w = self.worker
        if self.role == PREFILL:
            busy = w.sched.has_work()
        else:
            busy = bool(w.sched.running)
        return not busy and w.allocator.pages_in_use == 0


@dataclasses.dataclass
class _Pending:
    enq_tick: int
    src_gid: int
    gen: int                 # source group's generation at enqueue
    ticket: MigrationTicket


@dataclasses.dataclass(frozen=True)
class FleetEvent:
    tick: int
    kind: str     # 'flip' | 'dead' | 'recover' | 'rejoin' | 'shed'
    gid: int
    detail: str = ""


class FleetController:
    """Drives the group fleet through a shared tick clock."""

    def __init__(self, groups: Sequence[FleetGroup], router: FleetRouter,
                 transfer: KVTransferEngine, *,
                 make_prefill_worker: Callable[[], PrefillWorker],
                 make_decode_worker: Callable[[Dict, Callable],
                                              DecodeWorker],
                 metrics: Optional[ServeMetrics] = None,
                 elastic: bool = False, grace_ticks: int = 3,
                 wait_hi_ticks: int = 4, backlog_hi_chunks: int = 8,
                 on_token: Optional[Callable] = None,
                 chaos: Optional[FaultInjector] = None,
                 slo_ttft: Optional[float] = None):
        self.groups: List[FleetGroup] = list(groups)
        self.router = router
        self.transfer = transfer
        self.metrics = metrics or ServeMetrics()
        self.elastic = elastic
        self.wait_hi_ticks = wait_hi_ticks
        self.backlog_hi_chunks = backlog_hi_chunks
        self._make_prefill = make_prefill_worker
        self._make_decode = make_decode_worker
        self._user_on_token = on_token
        self.chaos = chaos
        self.slo_ttft = slo_ttft
        self.results: Dict[int, List[int]] = {}   # fleet results log
        self.finished: set = set()
        self.submitted: set = set()
        self.rejected: List[int] = []
        self.shed: List[int] = []                 # SLO-infeasible arrivals
        self.fenced: set = set()                  # dead (gid, generation)
        self.zombies: List[FleetGroup] = []       # quarantined false-deads
        self.pending: deque = deque()             # _Pending FIFO
        self.events: List[FleetEvent] = []
        self.n_flips = 0
        self.tick_count = 0
        self._dead_tracks: set = set()  # tracks of removed groups (§15)
        self.monitor = HeartbeatMonitor(
            [g.name for g in self.groups],
            HeartbeatConfig(interval_s=1.0, grace_multiplier=grace_ticks),
            clock=lambda: float(self.tick_count))
        self.detector = StragglerDetector([g.name for g in self.groups])
        if router.slow_factor is None:
            router.slow_factor = self.detector.slow_factor
        # Decode pools share one geometry (one compiled decode program),
        # so the submit-time bound survives flips and deaths.
        dec = [g for g in self.groups if g.role == DECODE]
        if not dec or not [g for g in self.groups if g.role == PREFILL]:
            raise ValueError("fleet needs >= 1 prefill and >= 1 decode "
                             "group")
        a = dec[0].worker.allocator
        self._decode_pool = (a.n_pages, a.page_size, a.max_pages_per_seq)
        # Decode schedulers share ONE results dict: the fleet control
        # plane's token log, which is what recovery resumes from.
        for g in self.groups:
            self._wire(g)

    def _wire(self, g: FleetGroup) -> None:
        # One tracer track per group (§15): both roles' spans land on
        # g{gid}, so a flip shows up as the span names changing on the
        # same track.
        g.worker.track = g.name
        g.worker.sched.track = g.name
        if g.role == DECODE:
            g.worker.sched.results = self.results
            g.worker.metrics = self.metrics
            # The fencing epoch is baked into the callback at wire time:
            # a zombie's stale worker keeps reporting under its OLD
            # (gid, gen) and is rejected, while the gen+1 replacement
            # passes — the two can never interleave in the results log.
            gid, gen = g.gid, g.generation
            g.worker.on_token = \
                lambda rid, tok, fin: self._on_token(gid, gen, rid, tok,
                                                     fin)

    def _fleet_instant(self, name: str, **args) -> None:
        """Control-plane instant on the "fleet" meta track (§15):
        excluded from idle attribution, visible in the viewer."""
        tr = obs_trace.TRACER
        if tr.enabled:
            tr.declare_track("fleet", pid="fleet", kind="meta")
            tr.instant("fleet", name, **args)

    def _event(self, kind: str, gid: int, detail: str = "") -> None:
        self.events.append(FleetEvent(self.tick_count, kind, gid, detail))
        self._fleet_instant(kind, gid=gid, detail=detail)

    def _on_token(self, gid: int, gen: int, rid: int, tok: int,
                  finished: bool) -> None:
        if (gid, gen) in self.fenced:
            self.metrics.robust.fenced_stale_completions += 1
            return
        if finished:
            self.finished.add(rid)
        if self._user_on_token:
            self._user_on_token(rid, tok, finished)

    # -- views --------------------------------------------------------------

    def prefill_groups(self) -> List[FleetGroup]:
        return [g for g in self.groups if g.alive and g.role == PREFILL]

    def decode_groups(self) -> List[FleetGroup]:
        return [g for g in self.groups if g.alive and g.role == DECODE]

    def group(self, gid: int) -> FleetGroup:
        for g in self.groups:
            if g.gid == gid:
                return g
        raise KeyError(f"no group {gid}")

    @property
    def queue_depth(self) -> int:
        return sum(g.worker.sched.depth for g in self.prefill_groups()) \
            + len(self.pending)

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        pre = self.prefill_groups()
        total = len(req.prompt) + req.max_new_tokens
        if not pre:
            raise ValueError(f"request {req.rid}: no live prefill group")
        n_pages, page_size, max_per_seq = self._decode_pool
        if -(-total // page_size) > min(n_pages, max_per_seq):
            raise ValueError(
                f"request {req.rid}: needs more pages than a decode "
                f"pool holds")
        if self.slo_ttft is not None:
            # SLO-aware shedding (DESIGN.md §13): price the arrival with
            # the router's class-speed ETAs. If even the BEST prefill
            # group cannot reach first token inside the SLO, the degraded
            # fleet provably cannot serve it — shed now, explicitly,
            # instead of finishing late. Shed requests count as submitted
            # (the invariant is submitted ⊆ finished ∪ rejected ∪ shed)
            # but never enter the latency metrics.
            eta = min(self.router.prefill_eta(g, len(req.prompt))
                      for g in pre)
            if eta > self.slo_ttft:
                self.submitted.add(req.rid)
                self.shed.append(req.rid)
                self.metrics.robust.shed_requests += 1
                self._event("shed", -1, f"rid {req.rid}")
                return
        g = self.router.place_request(pre, len(req.prompt))
        g.worker.sched.submit(req)  # validates + prefill-pool fit
        self.submitted.add(req.rid)
        self.metrics.on_submit(req.rid, len(req.prompt))
        self._fleet_instant("route", rid=req.rid, gid=g.gid)
        obs_trace.TRACER.flow(g.name, "queued", req.rid,
                              prompt=len(req.prompt))

    # -- failure injection + recovery ---------------------------------------

    def kill_group(self, gid: int) -> None:
        """Crash a group: it stops beating and stops computing. Its state
        is unreachable from now on; recovery happens only after the
        heartbeat grace window declares it dead. Killing a quarantined
        zombie really kills it — it never rejoins."""
        for z in self.zombies:
            if z.gid == gid:
                z.alive = False
                self.zombies.remove(z)
                return
        self.group(gid).alive = False

    def _requeue(self, request: Request, resume: List[int]) -> None:
        tgt = self.router.place_request(
            self.prefill_groups(), len(request.prompt) + len(resume))
        if tgt is None:
            raise RuntimeError("no live prefill group to recover into")
        tgt.worker.sched.requeue_front(request, resume)

    def _strip_group_work(self, g: FleetGroup,
                          abort_exports: bool) -> List[Tuple]:
        """Collect (request, resume) for every in-flight request ``g``
        holds. For a LIVE group being flipped, also release its pool
        state (abort ticket exports, free mid-prefill pages); for a dead
        group the pool is unreachable and left as-is."""
        victims: List[Tuple] = []
        w = g.worker
        if g.role == PREFILL:
            sched = w.sched
            if sched._prefilling is not None:
                entry, *_ = sched._prefilling
                victims.append((entry.request, list(entry.resume)))
                if abort_exports:
                    w.allocator.free(entry.request.rid)
                sched._prefilling = None
                w.prec = None
            for entry in sched.queue:
                victims.append((entry.request, list(entry.resume)))
            sched.queue.clear()
            still = deque()
            for item in self.pending:
                if item.src_gid != g.gid:
                    still.append(item)
                    continue
                t = item.ticket
                rid = t.request.rid
                victims.append(
                    (t.request, list(t.tokens[len(t.request.prompt):])))
                if abort_exports:
                    w.allocator.abort_export(rid)
                    w.allocator.free(rid)
            self.pending = still
        else:
            for slot in sorted(w.sched.running,
                               key=lambda s: w.sched.running[s].seq):
                run = w.sched.running[slot]
                rid = run.request.rid
                victims.append((run.request, list(self.results[rid])))
            if abort_exports:
                for slot in list(w.sched.running):
                    w.sched.pop_newest()
        return victims

    def _handle_deaths(self) -> None:
        for name in self.monitor.dead_hosts():
            g = next((g for g in self.groups if g.name == name), None)
            if g is None:
                continue
            self.monitor.remove(name)
            self.detector.remove(name)
            self.groups.remove(g)
            # Declared dead while still computing (suppressed heartbeats,
            # not a crash): a ZOMBIE — the detection was a false positive
            # and the group will keep producing completions. Fence its
            # epoch and quarantine it; it may rejoin at gen+1 later.
            zombie = g.alive
            self._event("dead", g.gid,
                        g.role + (" (zombie)" if zombie else ""))
            self._dead_tracks.add(g.name)
            victims = self._strip_group_work(g, abort_exports=False)
            if zombie:
                self._quarantine(g)
            # Revive a decode-less fleet before re-routing its victims.
            if self.elastic and not self.decode_groups():
                self._force_decode_flip()
            for request, resume in victims:
                self._requeue(request, resume)
            if victims:
                self._event("recover", g.gid,
                            f"{len(victims)} requests re-prefill")

    def _quarantine(self, g: FleetGroup) -> None:
        """Fence a falsely-dead group's epoch and detach it from every
        fleet-shared structure, so the zombie can keep computing without
        corrupting the results log the replacement is rebuilding."""
        self.fenced.add((g.gid, g.generation))
        w = g.worker
        tr = obs_trace.TRACER
        if tr.enabled:
            # The zombie keeps computing: move it to a meta track (no idle
            # attribution) so the replacement owns the real g{gid} track.
            ztrack = f"{g.name}:zombie"
            tr.declare_track(ztrack, pid="fleet", kind="meta")
            w.track = ztrack
            w.sched.track = ztrack
        if g.role == DECODE:
            # Private snapshot of the results log: the zombie's scheduler
            # keeps appending (its requests are still live inside it) but
            # the fleet log only hears from it via the fenced callback,
            # which rejects everything. Same for metrics: a private,
            # seeded ServeMetrics absorbs its on_token/on_finish calls.
            w.sched.results = {rid: list(toks)
                               for rid, toks in self.results.items()}
            m = ServeMetrics()
            for run in w.sched.running.values():
                m.requests[run.request.rid] = \
                    RequestTrace(rid=run.request.rid)
            w.metrics = m
        self.zombies.append(g)

    def _maybe_rejoin_zombies(self) -> None:
        """Re-admit quarantined groups whose heartbeats returned: bump
        the generation (the fence keeps rejecting the old epoch), build a
        fresh worker + pool, and rejoin with a fresh grace window."""
        if self.chaos is None:
            return
        for z in list(self.zombies):
            if self.chaos.active("hb_loss", z.name):
                continue
            self.zombies.remove(z)
            z.generation += 1
            z.draining = False
            z.worker = self._make_decode(self.results, None) \
                if z.role == DECODE else self._make_prefill()
            self._wire(z)
            self.groups.append(z)
            self._dead_tracks.discard(z.name)
            self.monitor.add(z.name)
            self.detector.add(z.name)
            self.metrics.robust.zombie_rejoins += 1
            self._event("rejoin", z.gid, f"gen {z.generation}")

    # -- elastic role flips -------------------------------------------------

    def _flip(self, g: FleetGroup, to_role: str) -> None:
        if to_role == DECODE:
            g.worker = self._make_decode(self.results, self._on_token)
        else:
            g.worker = self._make_prefill()
        g.role = to_role
        g.draining = False
        g.flips += 1
        self.n_flips += 1
        self._wire(g)
        self._event("flip", g.gid, f"-> {to_role}")

    def _force_decode_flip(self) -> None:
        """Zero decode groups left: conscript a prefill group, displacing
        its queued work and parked tickets onto the survivors."""
        pre = self.prefill_groups()
        if len(pre) < 2:
            return
        g = min(pre, key=lambda g: (g.queued_prefill_tokens(), g.gid))
        displaced = self._strip_group_work(g, abort_exports=True)
        self._flip(g, DECODE)
        for request, resume in displaced:
            self._requeue(request, resume)

    def _elastic_tick(self) -> None:
        pre, dec = self.prefill_groups(), self.decode_groups()
        head_wait = (self.tick_count - self.pending[0].enq_tick) \
            if self.pending else 0
        backlog = max((-(-g.queued_prefill_tokens()
                         // g.worker.sched.prefill_chunk)
                       for g in pre), default=0)
        if head_wait > self.wait_hi_ticks and len(pre) > 1:
            # Decode-bound: tickets are stuck. Cancel staged drains, then
            # flip an idle prefill group (fastest decode class first).
            for g in dec:
                g.draining = False
            idle = [g for g in pre if g.idle()]
            if idle:
                dspeed = self.router.decode_speed
                self._flip(min(idle, key=lambda g:
                               (-dspeed.get(g.cls, 1.0), g.gid)), DECODE)
            return
        if backlog > self.backlog_hi_chunks and head_wait == 0 \
                and len(dec) > 1:
            # Prefill-bound: flip an idle decode group now, else stage a
            # drain on the least-loaded one (router stops feeding it).
            if not any(g.draining for g in dec):
                pspeed = self.router.prefill_speed
                g = min(dec, key=lambda g: (g.n_active(),
                                            -pspeed.get(g.cls, 1.0),
                                            g.gid))
                if g.idle():
                    self._flip(g, PREFILL)
                    return
                g.draining = True
        elif backlog <= max(self.backlog_hi_chunks // 4, 1):
            for g in dec:
                g.draining = False
        for g in dec:
            if g.draining and g.idle() and len(self.decode_groups()) > 1:
                self._flip(g, PREFILL)
                break

    # -- one fleet tick -----------------------------------------------------

    def tick(self) -> None:
        chaos = self.chaos
        tr = obs_trace.TRACER
        tr.advance(self.tick_count)
        if tr.enabled:
            tr.declare_track("fleet", pid="fleet", kind="meta")
            for g in self.groups:
                tr.declare_track(g.name, pid="fleet")
        if chaos is not None:
            chaos.begin_tick(self.tick_count)
            for g in list(self.groups):
                if g.alive and chaos.fire("crash_start", g.name):
                    self.kill_group(g.gid)
        for g in self.groups:
            if g.alive and not (chaos is not None
                                and chaos.active("hb_loss", g.name)):
                self.monitor.beat(g.name)
        self._handle_deaths()
        self._maybe_rejoin_zombies()
        for g in self.prefill_groups():
            t0 = time.perf_counter()
            for ticket in g.worker.step():
                self.pending.append(_Pending(self.tick_count, g.gid,
                                             g.generation, ticket))
            self.detector.record(g.name, time.perf_counter() - t0)
            if chaos is not None \
                    and chaos.fire("crash_post_prefill", g.name):
                self.kill_group(g.gid)
        while self.pending:
            # FIFO, head-of-line: a stuck head keeps its place in line.
            item = self.pending[0]
            if (item.src_gid, item.gen) in self.fenced:
                # A fenced epoch's ticket: its request was already
                # re-routed when the group was declared dead — landing it
                # too would double-serve. Drop, count, move on.
                self.pending.popleft()
                self.metrics.robust.fenced_stale_tickets += 1
                continue
            src = next((g for g in self.groups
                        if g.gid == item.src_gid), None)
            if src is None or not src.alive:
                # Source crashed with the ticket parked: its pool is
                # unreachable, so the ticket cannot migrate. Hold the
                # line — the death path collects and re-prefills it once
                # the grace window expires.
                break
            tgt = self.router.place_ticket(self.decode_groups(),
                                           len(item.ticket.tokens))
            if tgt is None:
                break
            try:
                ok = tgt.worker.try_admit(item.ticket, src.worker,
                                          self.transfer, self.tick_count,
                                          src_name=src.name,
                                          dst_name=tgt.name)
            except TransferAbortedError:
                # Retries exhausted: the decode side already rolled back
                # (lease + slot). Roll back the source export and send
                # the request down the re-prefill path — key(rid, n)
                # sampling keeps its continuation token-exact.
                self.pending.popleft()
                t = item.ticket
                src.worker.allocator.abort_export(t.request.rid)
                src.worker.allocator.free(t.request.rid)
                self.metrics.robust.transfer_aborts += 1
                self._requeue(t.request,
                              list(t.tokens[len(t.request.prompt):]))
                continue
            except GroupCrashed as e:
                # One end died mid-transfer. The decode rollback already
                # ran; the ticket stays head-of-line and the normal
                # death machinery (grace window -> strip -> re-prefill)
                # recovers whatever the victim held.
                victim = src if e.role == "src" else tgt
                self.kill_group(victim.gid)
                break
            if not ok:
                break
            self.pending.popleft()
        for g in self.decode_groups():
            for request, generated in g.worker.ensure_pages():
                self._requeue(request, generated)
        for g in self.decode_groups():
            if g.worker.any_active():
                t0 = time.perf_counter()
                g.worker.decode_once(self.tick_count)
                self.detector.record(g.name, time.perf_counter() - t0)
        # Zombies keep computing against their private quarantine state —
        # that is exactly the race the fence exists to win. Their output
        # lands in the fenced callback and is counted, never recorded.
        for z in self.zombies:
            if z.role == DECODE:
                z.worker.ensure_pages()  # victims already re-routed
                if z.worker.any_active():
                    z.worker.decode_once(self.tick_count)
        if self.elastic:
            self._elastic_tick()
        st = self.transfer.stats
        self.metrics.robust.transfer_retries = st.n_retries
        self.metrics.robust.checksum_failures = st.n_checksum_failures
        self.metrics.on_tick(
            self.queue_depth,
            sum(g.worker.sched.n_active for g in self.decode_groups()))
        if tr.enabled:
            self._attribute_idle(tr, chaos)
        self.tick_count += 1

    def _attribute_idle(self, tr, chaos) -> None:
        """Classify this tick for every group track that did no work
        (§15). Exactly one bucket per idle group-tick; the report
        defaults unmarked ticks to queue-starved, so removed groups'
        trailing gaps are marked fault-stall here explicitly."""
        for g in self.groups:
            if tr.busy_this_tick(g.name):
                continue
            if not g.alive or (chaos is not None
                               and chaos.active("hb_loss", g.name)):
                bucket = "fault-stall"
            elif g.role == PREFILL:
                if any(p.src_gid == g.gid for p in self.pending):
                    # Pool (partly) parked behind un-migrated tickets.
                    bucket = "transfer-wait"
                elif g.worker.sched.wait_reason == "pages":
                    bucket = "pool-OOM"
                else:
                    bucket = "queue-starved"
            else:
                bucket = "drain" if g.draining else "queue-starved"
            tr.mark_idle(g.name, bucket)
        for name in self._dead_tracks:
            tr.mark_idle(name, "fault-stall")

    def has_work(self) -> bool:
        return any(g.worker.sched.has_work()
                   for g in self.prefill_groups()) \
            or bool(self.pending) \
            or any(g.worker.sched.running for g in self.decode_groups())

    # -- trace driver -------------------------------------------------------

    def run(self, requests: List[Request],
            kills: Sequence[Tuple[int, int]] = (),
            max_ticks: int = 100_000) -> Dict[int, List[int]]:
        """Drive a trace to completion. ``kills`` is [(tick, gid)] fault
        injection: the group crashes at the START of that tick (scripted
        — the seeded chaos layer injects everything else). The run is
        complete when every submitted request has finished, been
        rejected, or been shed — NOT when queues look empty, because a
        crashed group's requests are invisible until the heartbeat grace
        window expires."""
        arrivals = sorted(requests, key=lambda r: r.arrival)
        kill_q = sorted(kills)
        k = 0
        while True:
            while k < len(kill_q) and kill_q[k][0] <= self.tick_count:
                self.kill_group(kill_q[k][1])
                k += 1
            while arrivals and arrivals[0].arrival <= self.tick_count:
                req = arrivals.pop(0)
                try:
                    self.submit(req)
                except ValueError:
                    self.rejected.append(req.rid)
            if not arrivals and k >= len(kill_q) \
                    and self.submitted <= (self.finished
                                           | set(self.rejected)
                                           | set(self.shed)):
                return self.results
            self.tick()
            if self.tick_count > max_ticks:
                raise RuntimeError(
                    f"fleet trace exceeded {max_ticks} ticks "
                    f"({len(self.finished)}/{len(self.submitted)} done)")


def make_fleet(cfg, mesh, run, params, *, prefill_classes: Sequence[str],
               decode_classes: Sequence[str], decode_slots: int,
               max_len: int, page_size: int,
               prefill_pages: Optional[int] = None,
               decode_pages: Optional[int] = None, prefill_chunk: int = 16,
               token_budget: Optional[int] = None, seed: int = 0,
               transfer_chunk_pages: int = 4,
               link_bw: Optional[float] = None, latency_s: float = 0.0,
               metrics: Optional[ServeMetrics] = None,
               on_token: Optional[Callable] = None, elastic: bool = False,
               grace_ticks: int = 3, wait_hi_ticks: int = 4,
               backlog_hi_chunks: int = 8,
               chaos: Optional[FaultInjector] = None,
               slo_ttft: Optional[float] = None,
               transfer_max_retries: int = 3) -> FleetController:
    """Wire up a full fleet over one mesh (the multi-group analogue of
    ``make_disagg``). ``prefill_classes`` / ``decode_classes`` name the
    device class of each initial group (keys of ``hardware.CLASSES``) —
    one group per entry; the class sets the router's speed priors via the
    analytic serve profile (§10). ONE prefill program and ONE decode
    program are compiled and shared by every group (and every future
    flip — a role flip builds a fresh worker + pool around the already
    compiled program); each group still owns its own pool state and
    allocator.
    """
    import jax

    from repro.core import profiler as P
    from repro.core.hardware import CLASSES
    from repro.serve.engine import make_continuous_program
    from repro.serve.kv_blocks import BlockAllocator
    from repro.serve.scheduler import DecodeScheduler, PrefillScheduler

    names = list(prefill_classes) + list(decode_classes)
    if not prefill_classes or not decode_classes:
        raise ValueError("fleet needs >= 1 prefill and >= 1 decode group")
    unknown = [n for n in names if n not in CLASSES]
    if unknown:
        raise ValueError(f"unknown device class(es) {unknown}; "
                         f"known: {sorted(CLASSES)}")
    max_pages = -(-max_len // page_size)
    prefill_pages = prefill_pages if prefill_pages is not None \
        else 2 * max_pages
    pre_prog = make_continuous_program(
        cfg, mesh, run, n_slots=1, max_len=max_len, seed=seed,
        page_size=page_size, n_pages=max(prefill_pages, max_pages))
    dec_prog = make_continuous_program(
        cfg, mesh, run, n_slots=decode_slots, max_len=max_len, seed=seed,
        page_size=page_size, n_pages=decode_pages)
    with mesh:
        pre_params = jax.device_put(params, pre_prog.param_shardings)
        dec_params = jax.device_put(params, dec_prog.param_shardings)

    def make_prefill_worker() -> PrefillWorker:
        sched = PrefillScheduler(
            max_len, prefill_chunk=prefill_chunk, token_budget=token_budget,
            allocator=BlockAllocator(pre_prog.n_pages, page_size,
                                     pre_prog.max_pages))
        return PrefillWorker(pre_prog, pre_params, sched)

    def make_decode_worker(results, on_tok) -> DecodeWorker:
        sched = DecodeScheduler(
            decode_slots,
            allocator=BlockAllocator(dec_prog.n_pages, page_size,
                                     dec_prog.max_pages))
        sched.results = results
        return DecodeWorker(dec_prog, dec_params, sched, on_token=on_tok)

    shared = ServeMetrics() if metrics is None else metrics
    groups = []
    for gid, cls in enumerate(names):
        role = PREFILL if gid < len(prefill_classes) else DECODE
        worker = make_prefill_worker() if role == PREFILL \
            else make_decode_worker({}, None)
        groups.append(FleetGroup(gid, cls, role, worker))
    prefill_speed = {n: prefill_chunk
                     / P.prefill_chunk_time(cfg, prefill_chunk, max_len,
                                            CLASSES[n])
                     for n in set(names)}
    decode_speed = {n: decode_slots
                    / P.decode_step_time(cfg, decode_slots, max_len,
                                         CLASSES[n])
                    for n in set(names)}
    router = FleetRouter(prefill_speed=prefill_speed,
                         decode_speed=decode_speed)
    transfer = KVTransferEngine(chunk_pages=transfer_chunk_pages,
                                link_bw=link_bw, latency_s=latency_s,
                                max_retries=transfer_max_retries,
                                chaos=chaos)
    return FleetController(
        groups, router, transfer,
        make_prefill_worker=make_prefill_worker,
        make_decode_worker=make_decode_worker, metrics=shared,
        elastic=elastic, grace_ticks=grace_ticks,
        wait_hi_ticks=wait_hi_ticks, backlog_hi_chunks=backlog_hi_chunks,
        on_token=on_token, chaos=chaos, slo_ttft=slo_ttft)
