from repro.serve.fleet.controller import (FleetController, FleetEvent,
                                          FleetGroup, make_fleet)
from repro.serve.fleet.router import FleetRouter
from repro.serve.fleet.sim import (FleetSimResult, SimGroup,
                                   simulate_fleet_trace)

__all__ = ["FleetController", "FleetGroup", "FleetEvent", "FleetRouter",
           "make_fleet", "SimGroup", "FleetSimResult",
           "simulate_fleet_trace"]
