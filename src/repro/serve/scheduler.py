"""Continuous-batching request scheduler (DESIGN.md §7.1/§7.3).

Host-side bookkeeping only — no jax. The scheduler decides WHAT runs each
engine tick (which prefill chunk, which slots decode); the engine owns the
device arrays and executes the plan.

Slot lifecycle: queued -> prefilling (chunks of <= prefill_chunk tokens
into the batch-1 prefill cache) -> active (inserted into a free slot of
the batched decode state) -> finished (EOS or length limit) -> slot freed
and recycled. An insert overwrites EVERY decode-state leaf of the slot
(KV cache, cache positions, recurrent states), which is why recycling can
never leak state across requests.

Admission rules:
  * a request must fit its slot: len(prompt) + max_new_tokens <= max_len
    (checked at submit — oversized requests are rejected immediately);
  * at most ``token_budget`` prompt tokens are scheduled per tick, so a
    long prompt is spread over several ticks and decode of live slots
    never stalls for more than one chunk;
  * one request prefills at a time (its chunks are sequential — they
    share the single prefill cache); the queue is FIFO.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional

from repro.serve.sampling import GREEDY, SamplingParams


@dataclasses.dataclass
class Request:
    """One generation request."""

    rid: int
    prompt: List[int]
    max_new_tokens: int
    sampling: SamplingParams = GREEDY
    eos_token: Optional[int] = None
    arrival: float = 0.0  # trace time (engine ticks in the simulated clock)


@dataclasses.dataclass
class PrefillChunk:
    """One scheduled slice of a request's prompt."""

    request: Request
    slot: int
    start: int
    length: int

    @property
    def final(self) -> bool:
        return self.start + self.length >= len(self.request.prompt)


@dataclasses.dataclass
class _Running:
    request: Request
    n_generated: int = 0


class Scheduler:
    """Request queue + slot allocator over ``n_slots`` KV slots."""

    def __init__(self, n_slots: int, max_len: int, *,
                 prefill_chunk: int = 64, token_budget: Optional[int] = None):
        assert n_slots >= 1 and prefill_chunk >= 1
        self.n_slots = n_slots
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.token_budget = token_budget or prefill_chunk
        self.queue: Deque[Request] = collections.deque()
        self.free: List[int] = list(range(n_slots - 1, -1, -1))  # pop -> 0
        self.running: Dict[int, _Running] = {}  # slot -> live request
        self._prefilling = None  # (request, slot, next_start) | None
        self.results: Dict[int, List[int]] = {}  # rid -> generated tokens
        self.n_rejected = 0

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) < 1 or req.max_new_tokens < 1:
            self.n_rejected += 1
            raise ValueError(f"request {req.rid}: empty prompt or zero budget")
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            self.n_rejected += 1
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + "
                f"max_new {req.max_new_tokens} exceeds max_len {self.max_len}")
        self.queue.append(req)

    # -- prefill planning ---------------------------------------------------

    def plan_prefill(self, budget: int) -> Optional[PrefillChunk]:
        """Next prompt chunk to run, spending at most ``budget`` tokens.

        Admits the queue head into a free slot when nothing is mid-prefill.
        Returns None when there is no admissible work (empty queue, no free
        slot, or exhausted budget).
        """
        if budget <= 0:
            return None
        if self._prefilling is None:
            if not self.queue or not self.free:
                return None
            self._prefilling = (self.queue.popleft(), self.free.pop(), 0)
        req, slot, start = self._prefilling
        length = min(self.prefill_chunk, len(req.prompt) - start, budget)
        if length <= 0:
            return None
        return PrefillChunk(request=req, slot=slot, start=start,
                            length=length)

    def finish_prefill_chunk(self, chunk: PrefillChunk) -> bool:
        """Record a completed chunk; True when the whole prompt is cached."""
        req, slot, start = self._prefilling
        assert req is chunk.request and start == chunk.start
        if chunk.final:
            self._prefilling = None
            return True
        self._prefilling = (req, slot, start + chunk.length)
        return False

    # -- slot lifecycle -----------------------------------------------------

    def activate(self, chunk: PrefillChunk, first_token: int) -> bool:
        """Admit the fully-prefilled request into its slot with its first
        sampled token. Returns True if it finished immediately (EOS or
        max_new_tokens == 1) — the slot is then freed right away."""
        req = chunk.request
        self.results[req.rid] = [first_token]
        self.running[chunk.slot] = _Running(request=req, n_generated=1)
        return self._maybe_finish(chunk.slot, first_token)

    def note_token(self, slot: int, token: int) -> bool:
        """Record one decoded token for a live slot; True when finished."""
        run = self.running[slot]
        run.n_generated += 1
        self.results[run.request.rid].append(token)
        return self._maybe_finish(slot, token)

    def _maybe_finish(self, slot: int, token: int) -> bool:
        run = self.running[slot]
        req = run.request
        done = (req.eos_token is not None and token == req.eos_token) \
            or run.n_generated >= req.max_new_tokens
        if done:
            del self.running[slot]
            self.free.append(slot)
        return done

    # -- introspection ------------------------------------------------------

    def slot_request(self, slot: int) -> Request:
        return self.running[slot].request

    def slot_generated(self, slot: int) -> int:
        return self.running[slot].n_generated

    @property
    def queue_depth(self) -> int:
        return len(self.queue) + (1 if self._prefilling is not None else 0)

    @property
    def n_active(self) -> int:
        return len(self.running)

    def has_work(self) -> bool:
        return bool(self.queue) or self._prefilling is not None \
            or bool(self.running)
