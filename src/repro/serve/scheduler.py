"""Continuous-batching request scheduler (DESIGN.md §7.1/§7.3, §9.4).

Host-side bookkeeping only — no jax. The scheduler decides WHAT runs each
engine tick (which prefill chunk, which slots decode); the engine owns the
device arrays and executes the plan.

Slot lifecycle: queued -> prefilling (chunks of <= prefill_chunk tokens
into the batch-1 prefill cache) -> active (inserted into a free slot of
the batched decode state) -> finished (EOS or length limit) -> slot freed
and recycled. An insert overwrites EVERY decode-state leaf of the slot
(KV cache, cache positions, recurrent states), which is why recycling can
never leak state across requests.

Admission rules:
  * a request must fit its slot: len(prompt) + max_new_tokens <= max_len
    (checked at submit — oversized requests are rejected immediately);
  * at most ``token_budget`` prompt tokens are scheduled per tick, so a
    long prompt is spread over several ticks and decode of live slots
    never stalls for more than one chunk;
  * one request prefills at a time (its chunks are sequential — they
    share the single prefill cache); the queue is FIFO.

Paged mode (``allocator`` set, DESIGN.md §9.4) adds page-budget admission:
the queue head is admitted only when a free slot AND enough free pages for
its prompt exist (admission budgets PAGES, not slots x max_len — that is
the whole point of paging); decode growth claims pages one at a time, and
when the pool runs dry the NEWEST running request is preempted: its pages
return to the free list (a page-table reset, no device traffic) and it
re-queues at the queue FRONT with its generated tokens as resume state.
Re-prefilling prompt+generated reproduces its remaining tokens exactly
because sampling keys are ``key(rid, n)`` — schedule-independent (§7.4).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional

from repro.serve.kv_blocks import BlockAllocator
from repro.serve.sampling import GREEDY, SamplingParams


@dataclasses.dataclass
class Request:
    """One generation request."""

    rid: int
    prompt: List[int]
    max_new_tokens: int
    sampling: SamplingParams = GREEDY
    eos_token: Optional[int] = None
    arrival: float = 0.0  # trace time (engine ticks in the simulated clock)


@dataclasses.dataclass
class _QueueEntry:
    """A queued request plus its resume state (non-empty after preemption:
    the tokens it had already generated, replayed as prompt on re-prefill)."""

    request: Request
    resume: List[int] = dataclasses.field(default_factory=list)

    @property
    def tokens(self) -> List[int]:
        return self.request.prompt + self.resume


@dataclasses.dataclass
class PrefillChunk:
    """One scheduled slice of a request's (prompt + resume) token list."""

    request: Request
    slot: int
    start: int
    length: int
    tokens: List[int] = None  # full prompt (+ resumed generations)
    n_done: int = 0           # tokens already generated before this prefill

    def __post_init__(self):
        if self.tokens is None:
            self.tokens = self.request.prompt

    @property
    def final(self) -> bool:
        return self.start + self.length >= len(self.tokens)


@dataclasses.dataclass
class _Running:
    request: Request
    n_generated: int = 0
    seq: int = 0  # admission order (monotonic; newest = preemption victim)


class Scheduler:
    """Request queue + slot allocator over ``n_slots`` KV slots.

    ``allocator`` switches on paged admission (DESIGN.md §9.4): pages are
    claimed for the whole prompt at admission, extended one page at a time
    during decode by the engine, and released on finish/preempt.
    """

    def __init__(self, n_slots: int, max_len: int, *,
                 prefill_chunk: int = 64, token_budget: Optional[int] = None,
                 allocator: Optional[BlockAllocator] = None):
        assert n_slots >= 1 and prefill_chunk >= 1
        self.n_slots = n_slots
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.token_budget = token_budget or prefill_chunk
        self.allocator = allocator
        self.queue: Deque[_QueueEntry] = collections.deque()
        self.free: List[int] = list(range(n_slots - 1, -1, -1))  # pop -> 0
        self.running: Dict[int, _Running] = {}  # slot -> live request
        self._prefilling = None  # (entry, slot, next_start) | None
        self.results: Dict[int, List[int]] = {}  # rid -> generated tokens
        self.n_rejected = 0
        self.n_preempted = 0
        self._admit_seq = 0

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) < 1 or req.max_new_tokens < 1:
            self.n_rejected += 1
            raise ValueError(f"request {req.rid}: empty prompt or zero budget")
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            self.n_rejected += 1
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + "
                f"max_new {req.max_new_tokens} exceeds max_len {self.max_len}")
        if self.allocator is not None and not self.allocator.fits_pool(
                len(req.prompt) + req.max_new_tokens):
            # Worst-case page need exceeds the whole pool: preemption could
            # never clear room, so reject up front (keeps OOM-preemption
            # guaranteed to make progress down to one live request).
            self.n_rejected += 1
            raise ValueError(
                f"request {req.rid}: needs more pages than the pool holds")
        self.queue.append(_QueueEntry(req))

    # -- prefill planning ---------------------------------------------------

    def plan_prefill(self, budget: int) -> Optional[PrefillChunk]:
        """Next prompt chunk to run, spending at most ``budget`` tokens.

        Admits the queue head into a free slot when nothing is mid-prefill
        (in paged mode additionally claiming pages for its full prompt —
        all-or-nothing, so a half-admitted request never wedges the pool).
        Returns None when there is no admissible work (empty queue, no free
        slot, not enough free pages, or exhausted budget).
        """
        if budget <= 0:
            return None
        if self._prefilling is None:
            if not self.queue or not self.free:
                return None
            entry = self.queue[0]
            if self.allocator is not None and not self.allocator.allocate(
                    entry.request.rid, len(entry.tokens)):
                return None  # wait for pages (decode frees them on finish)
            self.queue.popleft()
            self._prefilling = (entry, self.free.pop(), 0)
        entry, slot, start = self._prefilling
        length = min(self.prefill_chunk, len(entry.tokens) - start, budget)
        if length <= 0:
            return None
        return PrefillChunk(request=entry.request, slot=slot, start=start,
                            length=length, tokens=entry.tokens,
                            n_done=len(entry.resume))

    def finish_prefill_chunk(self, chunk: PrefillChunk) -> bool:
        """Record a completed chunk; True when the whole prompt is cached."""
        entry, slot, start = self._prefilling
        assert entry.request is chunk.request and start == chunk.start
        if chunk.final:
            self._prefilling = None
            return True
        self._prefilling = (entry, slot, start + chunk.length)
        return False

    # -- slot lifecycle -----------------------------------------------------

    def activate(self, chunk: PrefillChunk, first_token: int) -> bool:
        """Admit the fully-prefilled request into its slot with its next
        sampled token (the FIRST token for fresh requests; token
        ``n_done`` when resuming after preemption — earlier tokens are
        already in ``results``). Returns True if it finished immediately —
        the slot is then freed right away."""
        req = chunk.request
        if chunk.n_done == 0:
            self.results[req.rid] = [first_token]
        else:
            assert self.results[req.rid] == list(chunk.tokens[
                len(req.prompt):]), "resume tokens diverged from results"
            self.results[req.rid].append(first_token)
        self._admit_seq += 1
        self.running[chunk.slot] = _Running(
            request=req, n_generated=chunk.n_done + 1, seq=self._admit_seq)
        return self._maybe_finish(chunk.slot, first_token)

    def note_token(self, slot: int, token: int) -> bool:
        """Record one decoded token for a live slot; True when finished."""
        run = self.running[slot]
        run.n_generated += 1
        self.results[run.request.rid].append(token)
        return self._maybe_finish(slot, token)

    def _maybe_finish(self, slot: int, token: int) -> bool:
        run = self.running[slot]
        req = run.request
        done = (req.eos_token is not None and token == req.eos_token) \
            or run.n_generated >= req.max_new_tokens
        if done:
            del self.running[slot]
            self.free.append(slot)
            if self.allocator is not None:
                self.allocator.free(req.rid)  # page-table reset = recycle
        return done

    def preempt_newest(self) -> Optional[int]:
        """Evict the most recently admitted running request (paged OOM
        relief, DESIGN.md §9.4): frees its slot and pages and re-queues it
        at the queue FRONT with its generated tokens as resume state.
        Returns the freed slot (engine clears its host mirrors), or None
        when nothing is running."""
        if not self.running:
            return None
        slot = max(self.running, key=lambda s: self.running[s].seq)
        run = self.running.pop(slot)
        self.free.append(slot)
        rid = run.request.rid
        if self.allocator is not None:
            self.allocator.free(rid)
        self.queue.appendleft(
            _QueueEntry(run.request, resume=list(self.results[rid])))
        self.n_preempted += 1
        return slot

    # -- introspection ------------------------------------------------------

    def slot_request(self, slot: int) -> Request:
        return self.running[slot].request

    def slot_generated(self, slot: int) -> int:
        return self.running[slot].n_generated

    @property
    def queue_depth(self) -> int:
        return len(self.queue) + (1 if self._prefilling is not None else 0)

    @property
    def n_active(self) -> int:
        return len(self.running)

    def has_work(self) -> bool:
        return bool(self.queue) or self._prefilling is not None \
            or bool(self.running)
