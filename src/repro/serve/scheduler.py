"""Continuous-batching request scheduling (DESIGN.md §7.1/§7.3, §9.4, §10).

Host-side bookkeeping only — no jax. Scheduling is split into two policies
so the unified engine and the disaggregated prefill/decode deployment
share one implementation:

* :class:`PrefillScheduler` — the prefill-side policy: FIFO queue, submit
  validation, chunk planning under a per-tick token budget, and
  page-budget admission against ITS pool's allocator. Where the admitted
  request lands (a decode slot in the unified engine, the single batch-1
  prefill stream in a disaggregated PrefillWorker) is the caller's
  business, injected through the ``has_slot`` / ``claim_slot`` hooks.
* :class:`DecodeScheduler` — the decode-side policy: slot lifecycle
  (activate -> note_token -> finish/recycle), per-request results, and
  newest-first preemption for pool-OOM relief. Freeing a finished or
  preempted request releases its pages in the DECODE-side allocator.
* :class:`Scheduler` — the unified engine's view: both policies over ONE
  pool and ONE slot set (prefill admission claims a decode slot up
  front). Its public surface is unchanged from the pre-split scheduler.

Slot lifecycle: queued -> prefilling (chunks of <= prefill_chunk tokens
into the batch-1 prefill cache) -> active (inserted into a free slot of
the batched decode state) -> finished (EOS or length limit) -> slot freed
and recycled. An insert overwrites EVERY decode-state leaf of the slot
(KV cache, cache positions, recurrent states), which is why recycling can
never leak state across requests.

Admission rules:
  * a request must fit its slot: len(prompt) + max_new_tokens <= max_len
    (checked at submit — oversized requests are rejected immediately);
  * at most ``token_budget`` prompt tokens are scheduled per tick, so a
    long prompt is spread over several ticks and decode of live slots
    never stalls for more than one chunk;
  * one request prefills at a time (its chunks are sequential — they
    share the single prefill cache); the queue is FIFO.

Paged mode (``allocator`` set, DESIGN.md §9.4) adds page-budget admission:
the queue head is admitted only when a slot AND enough free pages for its
prompt exist (admission budgets PAGES, not slots x max_len — that is the
whole point of paging); decode growth claims pages one at a time, and when
the pool runs dry the NEWEST running request is preempted: its pages
return to the free list (a page-table reset, no device traffic) and it
re-queues at the queue FRONT with its generated tokens as resume state.
Re-prefilling prompt+generated reproduces its remaining tokens exactly
because sampling keys are ``key(rid, n)`` — schedule-independent (§7.4).

Prefix caching (``prefix_index`` set, DESIGN.md §14) changes admission
from ``allocate`` to ``share_pages``: the longest cached prefix of the
token list mounts as shared leading table slots and prefill SKIPS those
lines entirely — the chunk stream starts at ``skipped`` (capped at
``len(tokens) - 1`` so at least one line always prefills and the first
sampled token keeps coming from prefill logits, schedule-independent as
ever). The decode side registers finished KV runs back into the index.

Fairness (``fair=True``, DESIGN.md §14): admission picks the next
request by per-tenant deficit round-robin (the tenant with the fewest
admissions so far goes first) instead of global FIFO, so one tenant's
burst cannot starve the pool; within a tenant order stays FIFO, and a
preempted request's front-requeue still resumes before anything else.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.obs import trace as obs_trace
from repro.serve.kv_blocks import BlockAllocator
from repro.serve.sampling import GREEDY, SamplingParams


@dataclasses.dataclass
class Request:
    """One generation request."""

    rid: int
    prompt: List[int]
    max_new_tokens: int
    sampling: SamplingParams = GREEDY
    eos_token: Optional[int] = None
    arrival: float = 0.0  # trace time (engine ticks in the simulated clock)
    tenant: int = 0  # fairness domain (multi-tenant admission, §14)


@dataclasses.dataclass
class _QueueEntry:
    """A queued request plus its resume state (non-empty after preemption:
    the tokens it had already generated, replayed as prompt on re-prefill)."""

    request: Request
    resume: List[int] = dataclasses.field(default_factory=list)

    @property
    def tokens(self) -> List[int]:
        return self.request.prompt + self.resume


@dataclasses.dataclass
class PrefillChunk:
    """One scheduled slice of a request's (prompt + resume) token list."""

    request: Request
    slot: int
    start: int
    length: int
    tokens: List[int] = None  # full prompt (+ resumed generations)
    n_done: int = 0           # tokens already generated before this prefill
    skipped: int = 0          # leading lines served by the prefix cache

    def __post_init__(self):
        if self.tokens is None:
            self.tokens = self.request.prompt

    @property
    def final(self) -> bool:
        return self.start + self.length >= len(self.tokens)

    @property
    def first(self) -> bool:
        """Whether this is the request's first chunk this prefill pass
        (``start`` sits at the cache-skip point, not at 0 — §14)."""
        return self.start == self.skipped


@dataclasses.dataclass
class _Running:
    request: Request
    n_generated: int = 0
    seq: int = 0  # admission order (monotonic; newest = preemption victim)


class PrefillScheduler:
    """Prefill-side policy: queue, chunking, page-budget admission."""

    def __init__(self, max_len: int, *, prefill_chunk: int = 64,
                 token_budget: Optional[int] = None,
                 allocator: Optional[BlockAllocator] = None,
                 prefix_index=None, fair: bool = False):
        assert prefill_chunk >= 1
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.token_budget = token_budget or prefill_chunk
        self.allocator = allocator
        self.prefix_index = prefix_index
        self.fair = fair
        self.queue: Deque[_QueueEntry] = collections.deque()
        self._prefilling = None  # (entry, slot, next_start, skipped) | None
        self.n_rejected = 0
        self.n_prefix_hits = 0
        self.n_tokens_skipped = 0
        self._admitted: Dict[int, int] = {}  # tenant -> admissions (fair)
        self.track = "serve"  # tracer track (§15); factories override
        # Why the last plan() returned None: "empty" (no queued work),
        # "no-slot" (landing site busy), "pages" (pool cannot back the
        # head), or None after a successful plan. Engines read this to
        # bucket idle ticks (pool-OOM vs queue-starved) without the
        # tracer ever influencing scheduling.
        self.wait_reason: Optional[str] = None

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) < 1 or req.max_new_tokens < 1:
            self.n_rejected += 1
            raise ValueError(f"request {req.rid}: empty prompt or zero budget")
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            self.n_rejected += 1
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + "
                f"max_new {req.max_new_tokens} exceeds max_len {self.max_len}")
        if self.allocator is not None and not self.allocator.fits_pool(
                len(req.prompt) + req.max_new_tokens):
            # Worst-case page need exceeds the whole pool: preemption could
            # never clear room, so reject up front (keeps OOM-preemption
            # guaranteed to make progress down to one live request).
            self.n_rejected += 1
            raise ValueError(
                f"request {req.rid}: needs more pages than the pool holds")
        self.queue.append(_QueueEntry(req))

    def requeue_front(self, request: Request, resume: List[int]) -> None:
        """Front-of-queue requeue after preemption: ``resume`` carries the
        tokens already generated, replayed as prompt on re-prefill."""
        self.queue.appendleft(_QueueEntry(request, resume=list(resume)))

    # -- prefill planning ---------------------------------------------------

    def plan(self, budget: int, has_slot: Callable[[], bool],
             claim_slot: Callable[[], int]) -> Optional[PrefillChunk]:
        """Next prompt chunk to run, spending at most ``budget`` tokens.

        Admits the queue head when nothing is mid-prefill: ``has_slot`` /
        ``claim_slot`` are the landing-site hooks (a decode slot in the
        unified engine, the batch-1 stream in a disagg PrefillWorker); in
        paged mode the head additionally claims pages for its full token
        list from THIS side's allocator — all-or-nothing, so a
        half-admitted request never wedges the pool. Returns None when
        there is no admissible work."""
        if budget <= 0:
            return None
        if self._prefilling is None:
            if not self.queue:
                self.wait_reason = "empty"
                return None
            if not has_slot():
                self.wait_reason = "no-slot"
                return None
            idx = self._select()
            entry = self.queue[idx]
            skipped, shared = 0, ()
            if self.allocator is not None:
                if self.prefix_index is not None:
                    shared, n_cached = self.prefix_index.lookup(entry.tokens)
                    # >= 1 line always prefills so the first sampled token
                    # keeps coming from prefill logits (§14).
                    n_cached = min(n_cached, len(entry.tokens) - 1)
                    if n_cached > 0:
                        skipped = n_cached
                    else:
                        shared = ()
                if not self.allocator.share_pages(
                        entry.request.rid, len(entry.tokens), shared):
                    self.wait_reason = "pages"
                    return None  # wait for pages (freed on finish/migration)
            del self.queue[idx]
            if skipped:
                self.n_prefix_hits += 1
                self.n_tokens_skipped += skipped
                obs_trace.TRACER.instant(
                    self.track, "prefix-skip", rid=entry.request.rid,
                    skipped=skipped)
            tenant = entry.request.tenant
            self._admitted[tenant] = self._admitted.get(tenant, 0) + 1
            self._prefilling = (entry, claim_slot(), skipped, skipped)
            obs_trace.TRACER.flow(
                self.track, "admitted", entry.request.rid,
                tokens=len(entry.tokens), skipped=skipped)
        self.wait_reason = None
        entry, slot, start, skipped = self._prefilling
        length = min(self.prefill_chunk, len(entry.tokens) - start, budget)
        if length <= 0:
            return None
        return PrefillChunk(request=entry.request, slot=slot, start=start,
                            length=length, tokens=entry.tokens,
                            n_done=len(entry.resume), skipped=skipped)

    def _select(self) -> int:
        """Queue index to admit next. FIFO by default; with ``fair`` the
        tenant with the fewest admissions so far goes first (deficit
        round-robin — a flooding tenant cannot starve the rest). A
        preempted request requeued at the front always resumes first."""
        if not self.fair or self.queue[0].resume:
            return 0
        tenants: List[int] = []
        for e in self.queue:
            if e.request.tenant not in tenants:
                tenants.append(e.request.tenant)
        pick = min(tenants, key=lambda t: self._admitted.get(t, 0))
        for i, e in enumerate(self.queue):
            if e.request.tenant == pick:
                return i
        raise AssertionError("unreachable: tenant vanished from queue")

    def finish_chunk(self, chunk: PrefillChunk) -> bool:
        """Record a completed chunk; True when the whole prompt is cached."""
        entry, slot, start, skipped = self._prefilling
        assert entry.request is chunk.request and start == chunk.start
        if chunk.final:
            self._prefilling = None
            return True
        self._prefilling = (entry, slot, start + chunk.length, skipped)
        return False

    # -- introspection ------------------------------------------------------

    @property
    def depth(self) -> int:
        return len(self.queue) + (1 if self._prefilling is not None else 0)

    def has_work(self) -> bool:
        return bool(self.queue) or self._prefilling is not None


class DecodeScheduler:
    """Decode-side policy: slot lifecycle, results, preemption."""

    def __init__(self, n_slots: int, *,
                 allocator: Optional[BlockAllocator] = None,
                 prefix_index=None):
        assert n_slots >= 1
        self.n_slots = n_slots
        self.allocator = allocator
        self.prefix_index = prefix_index
        self.free: List[int] = list(range(n_slots - 1, -1, -1))  # pop -> 0
        self.running: Dict[int, _Running] = {}  # slot -> live request
        self.results: Dict[int, List[int]] = {}  # rid -> generated tokens
        self.n_preempted = 0
        self._admit_seq = 0
        self.track = "serve"  # tracer track (§15); factories override

    # -- slots --------------------------------------------------------------

    def has_free(self) -> bool:
        return bool(self.free)

    def claim_slot(self) -> int:
        return self.free.pop()

    def release_slot(self, slot: int) -> None:
        """Return an UNUSED claimed slot (admission rolled back before
        ``activate`` — e.g. the KV transfer aborted, DESIGN.md §13)."""
        assert slot not in self.running, f"slot {slot} is live"
        self.free.append(slot)

    # -- lifecycle ----------------------------------------------------------

    def activate(self, request: Request, slot: int, tokens: List[int],
                 n_done: int, first_token: int) -> bool:
        """Admit a fully-prefilled request into ``slot`` with its next
        sampled token (the FIRST token for fresh requests; token
        ``n_done`` when resuming after preemption — earlier tokens are
        already in ``results``). ``tokens`` is the prompt + replayed
        resume list the prefill ran over. Returns True if it finished
        immediately — the slot is then freed right away."""
        if n_done == 0:
            self.results[request.rid] = [first_token]
        else:
            assert self.results[request.rid] == list(tokens[
                len(request.prompt):]), "resume tokens diverged from results"
            self.results[request.rid].append(first_token)
        if self.prefix_index is not None and self.allocator is not None:
            # Prompt KV is resident NOW: register the FULL pages so
            # concurrent same-prefix arrivals hit immediately. Full pages
            # are never written again (decode only appends past them);
            # the partial tail waits for finish-time registration.
            ps = self.allocator.page_size
            self.prefix_index.insert(
                tokens, self.allocator.tables.get(request.rid, []),
                n_valid=(len(tokens) // ps) * ps)
        self._admit_seq += 1
        self.running[slot] = _Running(
            request=request, n_generated=n_done + 1, seq=self._admit_seq)
        obs_trace.TRACER.flow(self.track, "decode", request.rid, slot=slot,
                              n_done=n_done)
        return self._maybe_finish(slot, first_token)

    def note_token(self, slot: int, token: int) -> bool:
        """Record one decoded token for a live slot; True when finished."""
        run = self.running[slot]
        run.n_generated += 1
        self.results[run.request.rid].append(token)
        return self._maybe_finish(slot, token)

    def _maybe_finish(self, slot: int, token: int) -> bool:
        run = self.running[slot]
        req = run.request
        done = (req.eos_token is not None and token == req.eos_token) \
            or run.n_generated >= req.max_new_tokens
        if done:
            del self.running[slot]
            self.free.append(slot)
            if self.allocator is not None:
                if self.prefix_index is not None:
                    # The last sampled token was never fed back, so lines
                    # [0, prompt + generated - 1) hold valid KV — register
                    # the whole run incl. the partial tail (multi-turn
                    # replays hit it), THEN free: pinned pages survive the
                    # page-table reset, unpinned ones recycle as before.
                    seq = list(req.prompt) + self.results[req.rid][:-1]
                    self.prefix_index.insert(
                        seq, self.allocator.tables.get(req.rid, []))
                self.allocator.free(req.rid)  # page-table reset = recycle
            obs_trace.TRACER.flow(self.track, "finished", req.rid,
                                  generated=run.n_generated)
        return done

    def pop_newest(self) -> Optional[Tuple[int, Request, List[int]]]:
        """Evict the most recently admitted running request (pool-OOM
        relief): frees its slot and its DECODE-side pages and returns
        (slot, request, generated-so-far) — the caller requeues it on the
        prefill side. None when nothing is running."""
        if not self.running:
            return None
        slot = max(self.running, key=lambda s: self.running[s].seq)
        run = self.running.pop(slot)
        self.free.append(slot)
        rid = run.request.rid
        if self.allocator is not None:
            self.allocator.free(rid)
        self.n_preempted += 1
        obs_trace.TRACER.instant(self.track, "preempt", rid=rid, slot=slot,
                                 generated=run.n_generated)
        return slot, run.request, list(self.results[rid])

    # -- introspection ------------------------------------------------------

    def slot_request(self, slot: int) -> Request:
        return self.running[slot].request

    def slot_generated(self, slot: int) -> int:
        return self.running[slot].n_generated

    @property
    def n_active(self) -> int:
        return len(self.running)


class Scheduler:
    """Unified-engine view: both policies over one pool + one slot set.

    ``allocator`` switches on paged admission (DESIGN.md §9.4): pages are
    claimed for the whole prompt at admission, extended one page at a time
    during decode by the engine, and released on finish/preempt. The same
    allocator backs both policies — prefill writes into the pages decode
    later reads, which is exactly what disaggregation splits apart.
    """

    def __init__(self, n_slots: int, max_len: int, *,
                 prefill_chunk: int = 64, token_budget: Optional[int] = None,
                 allocator: Optional[BlockAllocator] = None,
                 prefix_index=None, fair: bool = False):
        self.n_slots = n_slots
        self.max_len = max_len
        self.allocator = allocator
        self.prefix_index = prefix_index
        self.prefill = PrefillScheduler(max_len, prefill_chunk=prefill_chunk,
                                        token_budget=token_budget,
                                        allocator=allocator,
                                        prefix_index=prefix_index, fair=fair)
        self.decode = DecodeScheduler(n_slots, allocator=allocator,
                                      prefix_index=prefix_index)

    def set_track(self, track: str) -> None:
        """Route both policies' trace events to ``track`` (§15)."""
        self.prefill.track = track
        self.decode.track = track

    # -- delegated state (public surface unchanged by the policy split) -----

    @property
    def prefill_chunk(self) -> int:
        return self.prefill.prefill_chunk

    @property
    def token_budget(self) -> int:
        return self.prefill.token_budget

    @property
    def queue(self) -> Deque[_QueueEntry]:
        return self.prefill.queue

    @property
    def _prefilling(self):
        return self.prefill._prefilling

    @property
    def free(self) -> List[int]:
        return self.decode.free

    @property
    def running(self) -> Dict[int, _Running]:
        return self.decode.running

    @property
    def results(self) -> Dict[int, List[int]]:
        return self.decode.results

    @property
    def n_rejected(self) -> int:
        return self.prefill.n_rejected

    @property
    def n_preempted(self) -> int:
        return self.decode.n_preempted

    # -- lifecycle ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.prefill.submit(req)

    def plan_prefill(self, budget: int) -> Optional[PrefillChunk]:
        return self.prefill.plan(budget, self.decode.has_free,
                                 self.decode.claim_slot)

    def finish_prefill_chunk(self, chunk: PrefillChunk) -> bool:
        return self.prefill.finish_chunk(chunk)

    def activate(self, chunk: PrefillChunk, first_token: int) -> bool:
        return self.decode.activate(chunk.request, chunk.slot, chunk.tokens,
                                    chunk.n_done, first_token)

    def note_token(self, slot: int, token: int) -> bool:
        return self.decode.note_token(slot, token)

    def preempt_newest(self) -> Optional[int]:
        """Evict the newest running request (paged OOM relief, DESIGN.md
        §9.4) and requeue it at the queue FRONT with its generated tokens
        as resume state. Returns the freed slot (engine clears its host
        mirrors), or None when nothing is running."""
        out = self.decode.pop_newest()
        if out is None:
            return None
        slot, request, generated = out
        self.prefill.requeue_front(request, generated)
        return slot

    # -- introspection ------------------------------------------------------

    def slot_request(self, slot: int) -> Request:
        return self.decode.slot_request(slot)

    def slot_generated(self, slot: int) -> int:
        return self.decode.slot_generated(slot)

    @property
    def queue_depth(self) -> int:
        return self.prefill.depth

    @property
    def n_active(self) -> int:
        return self.decode.n_active

    def has_work(self) -> bool:
        return self.prefill.has_work() or bool(self.decode.running)
