"""Sharded, resumable checkpointing with atomic publish + async save.

Layout per step:
    <dir>/step_<k>/arrays.npz       flat {path: np.ndarray} (host shard)
    <dir>/step_<k>/MANIFEST.json    written LAST -> atomic publish
Manifest records tree structure, dtypes/shapes, logical axes, data-loader
state and content hashes; restore verifies hashes and re-shards onto
whatever mesh the restarted job has (elastic restart: the mesh may have
shrunk/grown — placement is re-derived from logical axes, not device ids).

Atomic publish (DESIGN.md §13): every file is written into ``<path>.tmp``
and fsync'd (file contents AND the tmp directory entry) BEFORE the
``rename`` publishes the step, and the parent directory is fsync'd after —
so a crash at any point mid-save leaves either the complete new step or
the untouched previous one, never a torn latest checkpoint.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.pytree import tree_map_with_path_names

MANIFEST = "MANIFEST.json"


def _fsync_path(path: str) -> None:
    """fsync a path by descriptor — directories included, so renames and
    new directory entries are durable, not just file bytes."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten_with_names(tree) -> Dict[str, Any]:
    out = {}
    tree_map_with_path_names(lambda n, x: out.__setitem__(n, x), tree)
    return out


def _unflatten_like(like, flat: Dict[str, Any]):
    return tree_map_with_path_names(lambda n, _: flat[n], like)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, params, opt_state=None, extra: dict = None,
             blocking: bool = True):
        """Snapshot to host memory synchronously, write asynchronously."""
        tree = {"params": params}
        if opt_state is not None:
            tree["opt"] = opt_state
        flat = _flatten_with_names(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        meta = {
            "step": step,
            "time": time.time(),
            "extra": extra or {},
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                           "sha256": hashlib.sha256(v.tobytes()).hexdigest()}
                       for k, v in host.items()},
        }
        self.wait()
        if blocking:
            self._write(step, host, meta)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()

    def _write(self, step: int, host: dict, meta: dict):
        path = os.path.join(self.dir, f"step_{step:08d}")
        tmp = path + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            np.savez(f, **{k.replace("/", "\x1f"): v
                           for k, v in host.items()})
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_path(tmp)      # the directory entries themselves
        shutil.rmtree(path, ignore_errors=True)
        os.rename(tmp, path)  # publish: atomic on POSIX
        _fsync_path(self.dir)  # make the rename durable
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, name, MANIFEST)):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like_params, like_opt=None, step: Optional[int] = None,
                shardings=None, opt_shardings=None, verify: bool = True):
        """Returns (step, params, opt_state, extra). `like_*` give the tree
        structure; `shardings` re-places arrays (elastic re-shard)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, MANIFEST)) as f:
            meta = json.load(f)
        npz = np.load(os.path.join(path, "arrays.npz"))
        flat = {k.replace("\x1f", "/"): npz[k] for k in npz.files}
        if verify:
            for k, v in flat.items():
                want = meta["arrays"][k]["sha256"]
                got = hashlib.sha256(v.tobytes()).hexdigest()
                if want != got:
                    raise IOError(f"checkpoint corruption at {k}")

        def place(prefix, like, sh):
            sub = {k[len(prefix) + 1:]: v for k, v in flat.items()
                   if k.startswith(prefix + "/")}
            tree = _unflatten_like(like, sub)
            if sh is not None:
                tree = jax.tree.map(
                    lambda x, s: jax.make_array_from_callback(
                        x.shape, s, lambda idx: x[idx]), tree, sh)
            else:
                tree = jax.tree.map(jnp.asarray, tree)
            return tree

        params = place("params", like_params, shardings)
        opt_state = None
        if like_opt is not None and any(k.startswith("opt/") for k in flat):
            opt_state = place("opt", like_opt, opt_shardings)
        return step, params, opt_state, meta.get("extra", {})
