"""Architecture registry: name -> ModelConfig + build helpers."""

from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.models import stack
from repro.models.config import ModelConfig, SHAPES, ShapeConfig
from repro.pytree import split_params, tree_param_count

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def names():
    _ensure_configs_loaded()
    return sorted(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    _ensure_configs_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def _ensure_configs_loaded():
    import repro.configs  # noqa: F401  (registers all archs on import)


def exact_param_count(cfg: ModelConfig) -> int:
    """Parameter count from the real init, via eval_shape (no allocation)."""
    shapes = jax.eval_shape(
        lambda: split_params(stack.init_model(jax.random.PRNGKey(0), cfg))[0])
    return tree_param_count(shapes)


def applicable_shapes(cfg: ModelConfig) -> list:
    """The assigned input-shape cells this arch runs (skip rules per brief)."""
    out = []
    for name, sc in SHAPES.items():
        if name == "long_500k" and not cfg.sub_quadratic:
            continue  # O(s^2) at 524k is not deployable for full attention
        out.append(sc)
    return out


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    import dataclasses
    n_layers = min(cfg.n_layers, 2 * len(cfg.pattern) + len(cfg.tail_specs))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=32,
        d_ff=256,
        d_ff_expert=0,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 8) if cfg.is_moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.is_moe else 0,
        ssm_state=32 if cfg.ssm_state else 0,
        ssm_heads=4 if cfg.ssm_state else 0,
        ssm_chunk=32,
        lru_width=0,
        window=min(cfg.window, 32) if cfg.window else 0,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 16) if cfg.encoder_seq else 0,
        vision_seq=min(cfg.vision_seq, 16) if cfg.vision_seq else 0,
        vision_dim=64 if cfg.vision_dim else 0,
        max_seq_len=4096,
    )
