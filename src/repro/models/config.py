"""Model configuration.

One `ModelConfig` describes any architecture in the zoo. The layer stack is a
repeating `pattern` of `LayerSpec`s (scanned with stacked params for compile
efficiency) plus an optional unrolled `tail`. This covers:

  * uniform decoder stacks           pattern=(attn+ffn,) x n
  * recurrentgemma 1:2 hybrid        pattern=(rglru, rglru, local_attn)
  * llama-3.2-vision cross-attn      pattern=(attn, attn, attn, attn, xattn)
  * mamba2                           pattern=(ssd,)
  * whisper enc/dec                  separate encoder stack + decoder stack
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

MixerKind = Literal["attn", "local_attn", "rglru", "ssd", "none"]
FFNKind = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One transformer-block position inside the repeating pattern."""

    mixer: MixerKind = "attn"
    ffn: FFNKind = "dense"
    cross_attn: bool = False  # adds a cross-attention sub-layer (enc-dec / VLM)
    causal: Optional[bool] = None  # None -> inherit ModelConfig.causal

    def tag(self) -> str:
        t = self.mixer
        if self.cross_attn:
            t += "+x"
        t += f"+{self.ffn}"
        return t


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # Layer layout ----------------------------------------------------------
    pattern: tuple = (LayerSpec(),)  # repeated floor(n_layers/len) times
    # remaining n_layers % len(pattern) layers reuse pattern prefix, unrolled

    # MoE --------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0  # per-expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3

    # Attention --------------------------------------------------------------
    qk_norm: bool = False
    rope_theta: float = 1e6
    learned_pos: bool = False  # learned absolute positions (whisper)
    window: int = 0  # sliding window for local_attn layers
    causal: bool = True
    attn_logit_softcap: float = 0.0

    # SSM (mamba2 SSD) ---------------------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0  # 0 -> n_heads
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    ssm_expand: int = 2

    # RG-LRU (recurrentgemma) --------------------------------------------------
    lru_width: int = 0  # 0 -> d_model

    # Enc-dec (whisper) ----------------------------------------------------------
    n_encoder_layers: int = 0
    encoder_seq: int = 0  # fixed encoder length (stubbed audio frontend frames)

    # VLM ------------------------------------------------------------------------
    vision_seq: int = 0  # number of precomputed image patch embeddings
    vision_dim: int = 0  # dim of stub patch embeddings (0 -> d_model)

    # Misc -------------------------------------------------------------------
    mlp_act: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    emb_scale: bool = False  # multiply embeddings by sqrt(d_model) (gemma-style)
    max_seq_len: int = 524_288
    unroll: bool = False  # python-loop the layer stack instead of lax.scan
    # (used by the dry-run's cost extrapolation: XLA HloCostAnalysis counts
    # while bodies once, so FLOPs are measured on unrolled 1/2-repeat
    # variants and extrapolated; production path stays scanned.)

    # ----------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.ssm_heads == 0:
            object.__setattr__(self, "ssm_heads", self.n_heads)
        if self.d_ff_expert == 0:
            object.__setattr__(self, "d_ff_expert", self.d_ff)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    # Layout helpers ---------------------------------------------------------
    @property
    def n_pattern_repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail_specs(self) -> tuple:
        r = self.n_layers % len(self.pattern)
        return self.pattern[:r]

    def layer_layout(self) -> list:
        """Full per-layer list of LayerSpec, length n_layers."""
        out = list(self.pattern) * self.n_pattern_repeats + list(self.tail_specs)
        assert len(out) == self.n_layers
        return out

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 1

    @property
    def attention_free(self) -> bool:
        return all(s.mixer in ("ssd", "none") and not s.cross_attn
                   for s in self.layer_layout())

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer does full global attention (long-context capable)."""
        return all(s.mixer in ("ssd", "local_attn", "rglru", "none")
                   for s in self.layer_layout()) and not self.is_encdec

    # Analytics ---------------------------------------------------------------
    def param_count(self) -> int:
        """Analytical parameter count (embedding + per-layer)."""
        d, hd = self.d_model, self.head_dim
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        for spec in self.layer_layout():
            total += self._mixer_params(spec) + self._ffn_params(spec)
            total += 2 * d  # two norms
            if spec.cross_attn:
                total += self._xattn_params() + d
        # encoder stack (whisper)
        for _ in range(self.n_encoder_layers):
            total += self._mixer_params(LayerSpec()) + self._ffn_params(
                LayerSpec(ffn="dense")) + 2 * self.d_model
        return total

    def _mixer_params(self, spec: LayerSpec) -> int:
        d, hd = self.d_model, self.head_dim
        if spec.mixer in ("attn", "local_attn"):
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            qknorm = 2 * hd if self.qk_norm else 0
            return q + kv + o + qknorm
        if spec.mixer == "rglru":
            w = self.lru_width
            # linear in/out + conv1d + RG-LRU gates (a-gate, i-gate) + Lambda
            return 2 * d * w + self.conv_width * w + 2 * w * w // 8 * 8 + w
        if spec.mixer == "ssd":
            din = self.ssm_expand * d
            nh, hs = self.ssm_heads, self.ssm_state
            # in_proj -> [z, x, B, C, dt]; conv over (x,B,C); out_proj
            zxbcdt = d * (2 * din + 2 * nh * hs // nh * nh + nh)
            zxbcdt = d * (2 * din + 2 * self.ssm_state + nh)  # grouped B,C (1 group)
            conv = self.conv_width * (din + 2 * self.ssm_state)
            out = din * d
            extra = 2 * nh + din  # A_log, D, norm
            return zxbcdt + conv + out + extra
        return 0

    def _xattn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + \
            self.n_heads * hd * d

    def _ffn_params(self, spec: LayerSpec) -> int:
        d = self.d_model
        if spec.ffn == "dense":
            mult = 3 if self.mlp_act == "swiglu" else 2
            return mult * d * self.d_ff
        if spec.ffn == "moe":
            mult = 3 if self.mlp_act == "swiglu" else 2
            return self.n_experts * mult * d * self.d_ff_expert + \
                d * self.n_experts  # router
        return 0

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        total = self.param_count()
        mult = 3 if self.mlp_act == "swiglu" else 2
        per_expert = mult * self.d_model * self.d_ff_expert
        n_moe_layers = sum(1 for s in self.layer_layout() if s.ffn == "moe")
        inactive = n_moe_layers * (self.n_experts - self.top_k) * per_expert
        return total - inactive

    def flops_per_token_train(self, seq_len: int) -> float:
        """Approx training FLOPs/token: 6*N_active + attention quadratic term."""
        flops = 6.0 * self.active_param_count()
        # attention: 2*s*d_head*n_heads per token per attn layer, x2 (qk^T, av),
        # x3 (fwd + 2x bwd)
        for spec in self.layer_layout():
            if spec.mixer == "attn":
                eff = seq_len if self.causal else seq_len
                flops += 3 * 2 * 2 * self.n_heads * self.head_dim * eff / 2
            elif spec.mixer == "local_attn":
                w = min(self.window or seq_len, seq_len)
                flops += 3 * 2 * 2 * self.n_heads * self.head_dim * w
        return flops


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
