"""Generic model assembly: embedding -> scanned block stack -> head.

The layer stack is `pattern` (a tuple of LayerSpecs) repeated
``n_pattern_repeats`` times via lax.scan over stacked params (keeps HLO size
O(len(pattern)) — essential for 100-layer dry-runs), plus an unrolled tail.
Covers decoder-only LMs, mamba2, recurrentgemma, whisper (enc-dec) and
llama-3.2-vision through one code path.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import modules
from repro.models.config import LayerSpec, ModelConfig
from repro.models.modules import Policy, RunConfig
from repro.pytree import Param, fan_in_init, merge_params, split_params

# aux-loss keys kept static so scan carries have a fixed tree structure
AUX_KEYS = ("moe_aux_loss", "moe_z_loss")


def _zero_aux(extras=()):
    """Zero aux accumulator. ``extras`` adds fixed-shape keys — e.g. the
    serve EP engine's per-expert routing counts — so the scan carry keeps a
    static tree while layers contribute vector-valued aux entries."""
    z = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
    for k, shape in extras:
        z[k] = jnp.zeros(shape, jnp.float32)
    return z


def _acc_aux(acc, aux):
    # iterate the ACCUMULATOR's keys: layers may emit extra aux entries
    # (they are dropped unless the caller registered them via extras)
    return {k: acc[k] + jnp.asarray(aux.get(k, 0.0), jnp.float32)
            for k in acc}


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block_stack(key, cfg: ModelConfig, pattern, n_repeats: int):
    """Params for `pattern` scanned n_repeats times: one stacked tree per
    pattern position, leading dim = n_repeats, logical axis 'layers'."""
    out = {}
    for pos, spec in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(key, pos), n_repeats)
        template = modules.init_layer(keys[0], cfg, spec)
        _, axes = split_params(template)

        def init_values(k, _spec=spec):
            return split_params(modules.init_layer(k, cfg, _spec))[0]

        values = jax.vmap(init_values)(keys)
        from repro.pytree import prepend_axis
        out[f"pos{pos}"] = merge_params(values, prepend_axis(axes, "layers"))
    return out


def init_model(key, cfg: ModelConfig):
    """Returns a tree of Param (use pytree.split_params before jit)."""
    ks = jax.random.split(key, 8)
    params = {"embed": modules.init_embedding(ks[0], cfg)}

    if cfg.n_pattern_repeats > 0:
        params["blocks"] = _init_block_stack(ks[1], cfg, cfg.pattern,
                                             cfg.n_pattern_repeats)
    for i, spec in enumerate(cfg.tail_specs):
        params[f"tail{i}"] = modules.init_layer(
            jax.random.fold_in(ks[2], i), cfg, spec)

    params["final_norm"] = modules.init_norm(cfg)
    if not cfg.tie_embeddings:
        params["lm_head"] = Param(
            fan_in_init(ks[3], (cfg.vocab_size, cfg.d_model), jnp.float32,
                        fan_in=cfg.d_model), ("vocab", "embed"))

    if cfg.is_encdec:
        enc_spec = LayerSpec(mixer="attn", ffn="dense", causal=False)
        params["encoder"] = {
            "blocks": _init_block_stack(ks[4], cfg, (enc_spec,),
                                        cfg.n_encoder_layers),
            "final_norm": modules.init_norm(cfg),
        }
    if cfg.vision_seq > 0:
        vdim = cfg.vision_dim or cfg.d_model
        params["vision_proj"] = Param(
            fan_in_init(ks[5], (vdim, cfg.d_model), jnp.float32, fan_in=vdim),
            (None, "embed"))
    return params


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def _apply_stack(blocks, tails, cfg: ModelConfig, run: RunConfig, pattern,
                 x, positions, states=None, tail_states=None,
                 encoder_out=None, encoder_positions=None, cache_index=None,
                 layer_override: Optional[Callable] = None,
                 moe_override: Optional[Callable] = None,
                 attend_to_cache: bool = False, page_table=None,
                 aux_extras=(), layer_aux: bool = False):
    """Run the scanned pattern stack + tail. Returns (x, new_states, aux).

    ``aux_extras`` registers extra fixed-shape aux keys (``(key, shape)``
    pairs) accumulated across layers alongside the aux losses. With
    ``layer_aux`` the returned aux dict additionally carries
    ``aux["per_layer"]``: each key stacked per layer row (scan steps first
    — pattern positions within a block summed — then one row per tail),
    collected through the scan's per-step outputs so the carry stays
    fixed-shape. The serve EP engine uses this for per-layer routing
    histograms."""
    aux = _zero_aux(aux_extras)
    decode = states is not None
    layer_rows = None

    def one_block(x, block_params, block_states):
        """Apply all pattern positions once. Returns (x, new_states, aux)."""
        new_states = {}
        a = _zero_aux(aux_extras)
        # sequence-parallel layer boundary (no-op unless act rule 'seq' set)
        x = run.constrain(x, ("batch", "seq", None))
        for pos, spec in enumerate(pattern):
            p = block_params[f"pos{pos}"]
            st = block_states.get(f"pos{pos}") if block_states else None
            if (layer_override is not None and spec.ffn == "moe"
                    and not decode):
                y, laux = layer_override(p, spec, x, positions)
                ns = None
            else:
                y, ns, laux = modules.apply_layer(
                    p, cfg, run, spec, x, positions, state=st,
                    encoder_out=encoder_out,
                    encoder_positions=encoder_positions,
                    cache_index=cache_index, moe_override=moe_override,
                    attend_to_cache=attend_to_cache, page_table=page_table)
            x = y
            a = _acc_aux(a, laux)
            if decode:
                new_states[f"pos{pos}"] = ns
        return x, new_states, a

    if blocks is not None:
        def scan_body(carry, xs):
            x, aux_acc = carry
            bp, bs = xs
            x, ns, a = one_block(x, bp, bs)
            ys = (ns, a) if layer_aux else ns
            return (x, _acc_aux(aux_acc, a)), ys

        if run.remat != "none" and not decode:
            policy = None
            if run.remat == "dots":
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            scan_body = jax.checkpoint(scan_body, policy=policy,
                                       prevent_cse=False)

        block_states = states.get("blocks") if decode else None
        if cfg.unroll:
            new_bs, rows = [], []
            carry = (x, aux)
            n_rep = cfg.n_pattern_repeats
            for i in range(n_rep):
                bp = jax.tree.map(lambda v: v[i], blocks)
                bs = (jax.tree.map(lambda v: v[i], block_states)
                      if block_states is not None else None)
                carry, ys = scan_body(carry, (bp, bs))
                if layer_aux:
                    ns, a_i = ys
                    rows.append(a_i)
                else:
                    ns = ys
                new_bs.append(ns)
            (x, aux) = carry
            if layer_aux:
                layer_rows = jax.tree.map(lambda *vs: jnp.stack(vs), *rows)
            new_block_states = (jax.tree.map(
                lambda *vs: jnp.stack(vs), *new_bs) if decode else None)
        else:
            (x, aux), ys = jax.lax.scan(
                scan_body, (x, aux), (blocks, block_states))
            if layer_aux:
                new_block_states, layer_rows = ys
            else:
                new_block_states = ys
    else:
        new_block_states = None

    new_tail_states = []
    for i, (spec, tp) in enumerate(tails):
        st = tail_states[i] if tail_states else None
        x, ns, a = one_block_single(tp, cfg, run, spec, x, positions, st,
                                    encoder_out, encoder_positions,
                                    cache_index, layer_override, decode,
                                    moe_override, attend_to_cache,
                                    page_table)
        aux = _acc_aux(aux, a)
        if layer_aux:
            row = jax.tree.map(lambda v: v[None],
                               _acc_aux(_zero_aux(aux_extras), a))
            layer_rows = row if layer_rows is None else jax.tree.map(
                lambda s, r: jnp.concatenate([s, r], axis=0),
                layer_rows, row)
        new_tail_states.append(ns)

    new_states = None
    if decode:
        new_states = {"blocks": new_block_states, "tails": new_tail_states}
    if layer_aux and layer_rows is not None:
        aux = dict(aux, per_layer=layer_rows)
    return x, new_states, aux


def one_block_single(p, cfg, run, spec, x, positions, st, encoder_out,
                     encoder_positions, cache_index, layer_override, decode,
                     moe_override=None, attend_to_cache=False,
                     page_table=None):
    if layer_override is not None and spec.ffn == "moe" and not decode:
        y, laux = layer_override(p, spec, x, positions)
        return y, None, laux
    return modules.apply_layer(p, cfg, run, spec, x, positions, state=st,
                               encoder_out=encoder_out,
                               encoder_positions=encoder_positions,
                               cache_index=cache_index,
                               moe_override=moe_override,
                               attend_to_cache=attend_to_cache,
                               page_table=page_table)


def apply_model(params, cfg: ModelConfig, run: RunConfig, tokens,
                positions=None, *, decode_state=None, cache_index=None,
                encoder_embeds=None, vision_embeds=None,
                layer_override: Optional[Callable] = None,
                moe_override: Optional[Callable] = None,
                return_hidden: bool = False,
                attend_to_cache: bool = False, page_table=None,
                aux_extras=(), layer_aux: bool = False):
    """Forward pass.

    tokens: [B, S] int32.
    positions: [B, S] (defaults to arange / cache_index).
    decode_state: state tree from init_decode_state (enables KV caching).
    cache_index: scalar next-cache-line index, or per-slot [B] vector
        (continuous batching — each sequence at its own position).
    attend_to_cache: S > 1 prefill attends over the existing cache instead
        of assuming it empty (chunked prefill, DESIGN.md §7).
    page_table: [B, max_pages] int32 — paged-KV mode (DESIGN.md §9); the
        decode_state must come from init_paged_decode_state. Shared by
        every attention layer (one table, per-layer physical pools).
    encoder_embeds: [B, T_enc, d] stub audio-frontend output (whisper).
    vision_embeds: [B, vision_seq, vision_dim] stub patch embeddings (VLM).
    aux_extras / layer_aux: extra fixed-shape aux keys accumulated across
        the stack, optionally also stacked per layer under
        ``aux["per_layer"]`` (see ``_apply_stack``; serve EP histograms).

    Returns (logits [B,S,vocab], new_decode_state, aux).
    """
    B, S = tokens.shape
    pol = run.policy
    if positions is None:
        if cache_index is not None:
            ci = jnp.asarray(cache_index, jnp.int32)
            base = ci[:, None] if ci.ndim == 1 else ci
            positions = jnp.broadcast_to(
                base + jnp.arange(S, dtype=jnp.int32), (B, S))
        else:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                         (B, S))

    # Cross-attention memory.
    encoder_out = None
    encoder_positions = None
    if cfg.is_encdec:
        assert encoder_embeds is not None, "whisper needs encoder_embeds"
        T_enc = encoder_embeds.shape[1]
        enc_pos = jnp.broadcast_to(jnp.arange(T_enc, dtype=jnp.int32),
                                   (B, T_enc))
        enc_x = encoder_embeds.astype(pol.compute_dtype)
        if "pos" in params["embed"]:
            pe = jnp.take(params["embed"]["pos"], enc_pos[0], axis=0)
            enc_x = enc_x + pe.astype(pol.compute_dtype)[None]
        enc = params["encoder"]
        enc_x, _, _ = _apply_stack(
            enc["blocks"], [], cfg, run,
            (LayerSpec(mixer="attn", ffn="dense", causal=False),),
            enc_x, enc_pos)
        encoder_out = modules.apply_norm(enc["final_norm"], enc_x, pol)
        encoder_positions = enc_pos
    elif cfg.vision_seq > 0:
        assert vision_embeds is not None, "VLM needs vision_embeds"
        encoder_out = (vision_embeds.astype(pol.compute_dtype)
                       @ params["vision_proj"].astype(pol.compute_dtype))
        Tv = encoder_out.shape[1]
        encoder_positions = jnp.broadcast_to(
            jnp.arange(Tv, dtype=jnp.int32), (B, Tv))

    x = modules.apply_embedding(params["embed"], cfg, pol, tokens,
                                positions, run=run)

    tails = [(spec, params[f"tail{i}"])
             for i, spec in enumerate(cfg.tail_specs)]
    tail_states = decode_state["tails"] if decode_state is not None else None
    x, new_state, aux = _apply_stack(
        params.get("blocks"), tails, cfg, run, cfg.pattern, x, positions,
        states=decode_state, tail_states=tail_states,
        encoder_out=encoder_out, encoder_positions=encoder_positions,
        cache_index=cache_index, layer_override=layer_override,
        moe_override=moe_override, attend_to_cache=attend_to_cache,
        page_table=page_table, aux_extras=aux_extras, layer_aux=layer_aux)

    x = modules.apply_norm(params["final_norm"], x, pol)
    if return_hidden:
        return x, new_state, aux
    head = params.get("lm_head")
    logits = modules.apply_unembedding(params["embed"], head, cfg, pol, x)
    logits = run.constrain(logits, ("batch", None, "vocab"))
    return logits, new_state, aux


# ---------------------------------------------------------------------------
# Decode state
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Stacked per-layer decode state matching the scan layout."""
    def stacked(spec):
        one = modules.init_layer_state(cfg, spec, batch, max_len, dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_pattern_repeats,) + x.shape),
            one)

    state = {}
    if cfg.n_pattern_repeats > 0:
        state["blocks"] = {f"pos{p}": stacked(spec)
                           for p, spec in enumerate(cfg.pattern)}
    else:
        state["blocks"] = None
    state["tails"] = [modules.init_layer_state(cfg, spec, batch, max_len,
                                               dtype)
                      for spec in cfg.tail_specs]
    return state


def init_paged_decode_state(cfg: ModelConfig, batch: int, n_pages: int,
                            page_size: int, dtype):
    """Paged decode state (DESIGN.md §9): per-layer KV pools of ``n_pages``
    shared physical pages (no batch dim) + per-slot recurrent states, in
    the same scan-stacked layout as :func:`init_decode_state`."""
    def stacked(spec):
        one = modules.init_paged_layer_state(cfg, spec, batch, n_pages,
                                             page_size, dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_pattern_repeats,) + x.shape),
            one)

    state = {}
    if cfg.n_pattern_repeats > 0:
        state["blocks"] = {f"pos{p}": stacked(spec)
                           for p, spec in enumerate(cfg.pattern)}
    else:
        state["blocks"] = None
    state["tails"] = [modules.init_paged_layer_state(cfg, spec, batch,
                                                     n_pages, page_size,
                                                     dtype)
                      for spec in cfg.tail_specs]
    return state


# -- paged-state tree surgery (engine helpers, DESIGN.md §9.4) --------------
#
# The paged engine splits a decode-state tree into its pooled-KV part
# (shared pages, written by prefill AND decode) and its per-slot recurrent
# part (batch-indexed, inserted on admission like the dense engine). The
# layer dicts are keyed "kv" / "rglru" / "ssd", so the split is a key
# partition applied layer-wise.

def map_layer_states(state, fn):
    """Apply ``fn`` to every per-layer state dict of a decode-state tree."""
    out = {"blocks": None, "tails": [fn(s) for s in state["tails"]]}
    if state["blocks"] is not None:
        out["blocks"] = {k: fn(v) for k, v in state["blocks"].items()}
    return out


def split_kv_state(state):
    """(kv_tree, rec_tree): pooled attention caches vs per-slot recurrent
    states. Both keep the full blocks/tails skeleton (layers without the
    respective part hold empty dicts) so jit signatures stay stable."""
    kv = map_layer_states(
        state, lambda d: {k: v for k, v in d.items() if k == "kv"})
    rec = map_layer_states(
        state, lambda d: {k: v for k, v in d.items() if k != "kv"})
    return kv, rec


def merge_kv_state(kv_tree, rec_tree):
    """Inverse of :func:`split_kv_state` (layer-wise dict union)."""
    out = {"blocks": None,
           "tails": [{**a, **b} for a, b in zip(kv_tree["tails"],
                                                rec_tree["tails"])]}
    if kv_tree["blocks"] is not None:
        out["blocks"] = {k: {**kv_tree["blocks"][k], **rec_tree["blocks"][k]}
                         for k in kv_tree["blocks"]}
    return out


# -- page-granular pool surgery (disaggregated handoff, DESIGN.md §10) ------
#
# The KV handoff between device groups ships a request's ALLOCATED physical
# pages and nothing else: gather pulls exactly the page ids named by the
# source page table out of every layer's pool (page-dim take — the payload
# keeps the [n, page_size, ...] page layout, never a contiguous
# [tokens, ...] cache), and scatter lands them at the destination pool's
# imported page ids. Block leaves carry the scan-stacked layer dim in front
# of the page dim, so the page axis is 1 there and 0 on tails.

def gather_kv_pages(state, page_ids):
    """Pull physical pages ``page_ids`` of every attention layer's pool out
    of a PAGED decode-state tree. Returns the kv skeleton with the page dim
    replaced by ``len(page_ids)`` — the transfer payload."""
    kv, _ = split_kv_state(state)

    def take(axis):
        return lambda v: jnp.take(v, page_ids, axis=axis)

    out = {"blocks": None,
           "tails": [jax.tree.map(take(0), d) for d in kv["tails"]]}
    if kv["blocks"] is not None:
        out["blocks"] = {k: jax.tree.map(take(1), v)
                         for k, v in kv["blocks"].items()}
    return out


def scatter_kv_pages(state, payload, page_ids):
    """Write a :func:`gather_kv_pages` payload into the pool pages
    ``page_ids`` of a PAGED decode-state tree (the import half of the
    handoff). Out-of-range ids (the transfer engine's chunk-padding
    sentinel) are dropped. Returns the full updated state tree; the
    per-slot recurrent part passes through untouched."""
    kv, rec = split_kv_state(state)

    def put(axis):
        def f(dst, src):
            if axis == 0:
                return dst.at[page_ids].set(src.astype(dst.dtype),
                                            mode="drop")
            return dst.at[:, page_ids].set(src.astype(dst.dtype),
                                           mode="drop")
        return f

    new = {"blocks": None,
           "tails": [jax.tree.map(put(0), d, p)
                     for d, p in zip(kv["tails"], payload["tails"])]}
    if kv["blocks"] is not None:
        new["blocks"] = {k: jax.tree.map(put(1), kv["blocks"][k],
                                         payload["blocks"][k])
                         for k in kv["blocks"]}
    return merge_kv_state(new, rec)


def init_paged_prefill_state(cfg: ModelConfig, n_pages: int, page_size: int,
                             dtype):
    """A PAGED prefill state that DETACHES from any serving engine
    (DESIGN.md §10): the per-layer pools plus a batch-1 recurrent carry,
    sized independently of decode-side slot counts. This is what a
    disaggregated PrefillWorker owns — its pool geometry is the prefill
    group's HBM budget, not the decode engine's."""
    return init_paged_decode_state(cfg, 1, n_pages, page_size, dtype)
