from repro.models.config import LayerSpec, ModelConfig
from repro.models import registry

__all__ = ["LayerSpec", "ModelConfig", "registry"]
