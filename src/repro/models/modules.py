"""Pure-JAX neural-net modules shared by every architecture in the zoo.

Each module is an (init, apply) pair. init returns a tree of
:class:`repro.pytree.Param` (value + logical sharding axes); apply is a pure
function over the value tree. Mixer kinds: full/local attention, RG-LRU
(recurrentgemma), SSD (mamba2). FFN kinds: dense (SwiGLU/GELU) and MoE.

The attention and MoE "parts" are exposed separately (``apply_mixer_part`` /
``apply_ffn_part``) so the zebra-parallelism engine can disaggregate and
pipeline them across device groups.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.config import LayerSpec, ModelConfig
from repro.pytree import (Param, fan_in_init, ones_init, zeros_init)

# ---------------------------------------------------------------------------
# Runtime policy
# ---------------------------------------------------------------------------

_BIG_NEG = -0.7 * float(jnp.finfo(jnp.float32).max)


@dataclasses.dataclass(frozen=True)
class Policy:
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    accum_dtype: Any = jnp.float32  # norms / softmax / router / losses


def _no_constraint(x, axes):
    del axes
    return x


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Runtime knobs orthogonal to the architecture."""

    policy: Policy = Policy()
    attn_impl: str = "ref"  # ref | chunked | flash (Pallas)
    # MoE execution path. "gather" (default): the single-pack fused
    # ops.moe_ffn pipeline — what every serve/train path runs. "dense":
    # the O(E) every-token-through-every-expert einsum, kept ONLY as the
    # exact test reference that parity suites compare against.
    moe_impl: str = "gather"
    # gather mode: True forces the Pallas grouped kernels (interpret mode
    # off-TPU — test vehicle); False lets kernels/ops pick the backend
    # default (Mosaic on TPU, XLA tile-gather fallback elsewhere).
    use_gmm_kernel: bool = False
    remat: str = "none"  # none | full | dots
    deterministic: bool = True
    chunk_q: int = 512  # query-chunk size of the chunked attention path
    # Embedding lookup strategy: "sharded" gathers against the vocab-sharded
    # f32 table (GSPMD masked-gather + f32 all-reduce over the vocab axis);
    # "replicated" all-gathers the table ONCE in bf16 (1-2 GB for 128k
    # vocabs) and gathers locally — cheaper in both HBM and ICI bytes.
    embed_mode: str = "sharded"
    # Activation-sharding constrainer (sharding.rules.make_constrainer);
    # identity outside a mesh context.
    constrain: Any = _no_constraint


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: int | None = None):
    dim = dim or cfg.d_model
    if cfg.norm == "layernorm":
        return {
            "scale": Param(jnp.ones((dim,), jnp.float32), ("embed",)),
            "bias": Param(jnp.zeros((dim,), jnp.float32), ("embed",)),
        }
    return {"scale": Param(jnp.ones((dim,), jnp.float32), ("embed",))}


def apply_norm(params, x, policy: Policy, eps: float = 1e-6):
    xf = x.astype(policy.accum_dtype)
    if "bias" in params:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(policy.accum_dtype) \
            + params["bias"].astype(policy.accum_dtype)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps)
        y = y * params["scale"].astype(policy.accum_dtype)
    return y.astype(policy.compute_dtype)


def rms_norm_headwise(scale, x, policy: Policy, eps: float = 1e-6):
    """Per-head RMSNorm over the trailing head_dim (qk_norm)."""
    xf = x.astype(policy.accum_dtype)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps) * scale.astype(policy.accum_dtype)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def apply_rope(x, positions, theta: float):
    """x: [..., S, n_heads, head_dim]; positions: [..., S] int32."""
    if theta <= 0:
        return x
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return rotated.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig):
    params = {
        "table": Param(
            fan_in_init(key, (cfg.vocab_size, cfg.d_model), jnp.float32,
                        fan_in=cfg.d_model),
            ("vocab", "embed"),
        )
    }
    if cfg.learned_pos:  # learned absolute positions (whisper)
        params["pos"] = Param(
            fan_in_init(jax.random.fold_in(key, 1),
                        (cfg.max_seq_len, cfg.d_model), jnp.float32,
                        fan_in=cfg.d_model),
            (None, "embed"),
        )
    return params


def apply_embedding(params, cfg: ModelConfig, policy: Policy, tokens,
                    positions=None, run: "RunConfig" = None):
    table = params["table"]
    if run is not None and run.embed_mode == "replicated":
        table = run.constrain(table.astype(policy.compute_dtype),
                              (None, None))
    x = jnp.take(table, tokens, axis=0).astype(policy.compute_dtype)
    if cfg.emb_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), policy.compute_dtype)
    if "pos" in params and positions is not None:
        pe = jnp.take(params["pos"], positions, axis=0)
        x = x + pe.astype(policy.compute_dtype)
    if run is not None:
        x = run.constrain(x, ("batch", None, None))
    return x


def apply_unembedding(params, head, cfg: ModelConfig, policy: Policy, x):
    """x: [..., d_model] -> logits [..., vocab] in accum dtype."""
    table = head if head is not None else params["table"]
    return jnp.einsum("...d,vd->...v", x, table.astype(policy.compute_dtype),
                      preferred_element_type=policy.accum_dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "wq": Param(fan_in_init(k1, (d, h * hd), jnp.float32, fan_in=d),
                    ("embed", "q_heads")),
        "wk": Param(fan_in_init(k2, (d, kh * hd), jnp.float32, fan_in=d),
                    ("embed", "kv_heads")),
        "wv": Param(fan_in_init(k3, (d, kh * hd), jnp.float32, fan_in=d),
                    ("embed", "kv_heads")),
        "wo": Param(fan_in_init(k4, (h * hd, d), jnp.float32, fan_in=h * hd),
                    ("q_heads", "embed")),
    }
    if cfg.qk_norm and not cross:
        params["q_norm"] = Param(jnp.ones((hd,), jnp.float32), (None,))
        params["k_norm"] = Param(jnp.ones((hd,), jnp.float32), (None,))
    return params


def attention_mask(q_pos, kv_pos, causal: bool, window: int):
    """Boolean mask [..., S_q, S_kv]: True = attend."""
    q = q_pos[..., :, None]
    k = kv_pos[..., None, :]
    mask = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        mask &= k <= q
    if window > 0:
        mask &= (q - k) < window
    mask &= k >= 0  # entries with negative positions = unwritten cache slots
    return mask


def chunked_attention(q, k, v, q_pos, kv_pos, *, causal: bool, window: int,
                      scale: float, softcap: float, policy: Policy,
                      chunk_q: int = 512, unroll: bool = False):
    """Flash-equivalent pure-jnp attention: scan over query chunks, per-chunk
    structural masking, rematerialized backward. Never materializes the full
    [S, T] score matrix or mask — the CPU/dry-run stand-in for the Pallas
    flash kernel with the same memory behaviour.

    q: [B,S,H,hd]; k/v: [B,T,KH,hd]; q_pos: [B,S]; kv_pos: [B,T].
    """
    B, S, H, hd = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    cq = min(chunk_q, S)
    pad = (-S) % cq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    nq = (S + pad) // cq
    qc = jnp.moveaxis(q.reshape(B, nq, cq, H, hd), 1, 0)
    pc = jnp.moveaxis(q_pos.reshape(B, nq, cq), 1, 0)

    def block(qb, qp, kb, vb, kvp):
        # qb: [B,cq,H,hd]; qp: [B,cq]; kb/vb: [B,t,KH,hd]
        qf = qb.reshape(B, cq, KH, G, hd)
        logits = jnp.einsum("bskgh,btkh->bkgst", qf, kb,
                            preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            logits = softcap * jnp.tanh(logits / softcap)
        m = attention_mask(qp, kvp, causal, window)
        m &= qp[..., :, None] >= 0
        logits = jnp.where(m[:, None, None, :, :], logits, _BIG_NEG)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgst,btkh->bskgh",
                         probs.astype(policy.compute_dtype), vb)
        return out.reshape(B, cq, H, hd)

    block = jax.checkpoint(block)  # recompute scores in backward (flash-like)
    if nq == 1:
        o = block(qc[0], pc[0], k, v, kv_pos)[None]
    elif unroll:
        # Static per-chunk KV cropping (the jnp mirror of the flash kernel's
        # causal/window block skipping). Valid because the structural path
        # always runs with positions == arange.
        outs = []
        for i in range(nq):
            lo, hi = 0, T
            if causal:
                hi = min(T, (i + 1) * cq)
            if window > 0:
                lo = max(0, i * cq - window)
            outs.append(block(qc[i], pc[i], k[:, lo:hi], v[:, lo:hi],
                              kv_pos[:, lo:hi]))
        o = jnp.stack(outs)
    else:
        o = jax.lax.map(lambda args: block(*args, k, v, kv_pos), (qc, pc))
    o = jnp.moveaxis(o, 0, 1).reshape(B, nq * cq, H, hd)
    return o[:, :S]


def ref_attention(q, k, v, mask, scale: float, softcap: float, policy: Policy):
    """GQA attention oracle. q: [B,S,H,hd], k/v: [B,T,KH,hd], mask [B,S,T]|[S,T]."""
    B, S, H, hd = q.shape
    KH = k.shape[2]
    G = H // KH
    qf = q.reshape(B, S, KH, G, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qf, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None, :, :], logits, _BIG_NEG)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(policy.compute_dtype), v)
    return out.reshape(B, S, H, hd)


def _attention_inner(q, k, v, cfg: ModelConfig, run: RunConfig, *,
                     positions, kv_pos, causal: bool, window: int,
                     structural: bool):
    """Dispatch to flash kernel / chunked-jnp / materialized reference."""
    scale = cfg.head_dim ** -0.5
    softcap = cfg.attn_logit_softcap
    if structural and run.attn_impl == "flash":
        from repro.kernels import ops as kops  # lazy: avoid cycles
        return kops.flash_attention(q, k, v, causal=causal, window=window,
                                    scale=scale, softcap=softcap)
    if structural and run.attn_impl == "chunked":
        return chunked_attention(q, k, v, positions, kv_pos, causal=causal,
                                 window=window, scale=scale, softcap=softcap,
                                 policy=run.policy, chunk_q=run.chunk_q,
                                 unroll=cfg.unroll)
    mask = attention_mask(positions, kv_pos, causal=causal, window=window)
    return ref_attention(q, k, v, mask, scale, softcap, run.policy)


def _project_qkv(params, cfg: ModelConfig, run: RunConfig, x, positions,
                 kv=None, kv_positions=None, rope: bool = True):
    """Shared q/k/v projection + qk-norm + rope. Returns (q, k, v, kv_pos)."""
    B, S, _ = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pol = run.policy
    cd = pol.compute_dtype

    q = (x @ params["wq"].astype(cd)).reshape(B, S, h, hd)
    kv_src = kv if kv is not None else x
    kv_pos = kv_positions if kv_positions is not None else positions
    k = (kv_src @ params["wk"].astype(cd)).reshape(B, -1, kh, hd)
    v = (kv_src @ params["wv"].astype(cd)).reshape(B, -1, kh, hd)
    q = run.constrain(q, ("batch", None, "q_heads", None))
    k = run.constrain(k, ("batch", None, "kv_heads", None))
    v = run.constrain(v, ("batch", None, "kv_heads", None))

    if "q_norm" in params:
        q = rms_norm_headwise(params["q_norm"], q, pol)
        k = rms_norm_headwise(params["k_norm"], k, pol)
    if rope and cfg.rope_theta > 0 and kv is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_pos, cfg.rope_theta)
    return q, k, v, kv_pos


def _apply_attention_paged(params, cfg: ModelConfig, run: RunConfig, x,
                           positions, *, causal: bool, window: int, cache,
                           cache_index, rope: bool, page_table):
    """Paged-cache attention (DESIGN.md §9): scatter this step's K/V through
    the page table into the shared pool, then attend over the slot's pages.

    cache: k/v [P, ps, KH, hd] + pos [P, ps] — the POOL, no batch dim.
    Vector ``cache_index`` = per-slot decode (S == 1); scalar = chunked
    prefill at batch 1 writing lines [offset, offset + S). Key positions
    are computed structurally from the table (never read back from the
    pool), so stale lines of recycled pages sit beyond the new owner's
    causal frontier and are unreachable (§9.2).
    """
    B, S, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    cd = run.policy.compute_dtype
    q, k, v, _ = _project_qkv(params, cfg, run, x, positions, rope=rope)

    P, ps = cache["k"].shape[0], cache["k"].shape[1]
    MP = page_table.shape[1]
    ptype = cache["pos"].dtype
    if jnp.ndim(cache_index) == 1:
        # Per-slot decode: row b writes line cache_index[b] of its own page
        # run. Dead slots (index < 0) and unallocated table slots map to
        # the out-of-bounds sentinel P and are dropped.
        p = cache_index
        pslot = jnp.minimum(jnp.maximum(p, 0) // ps, MP - 1)
        page = jnp.take_along_axis(page_table, pslot[:, None], axis=1,
                                   mode="clip")[:, 0]
        page = jnp.where((p >= 0) & (page >= 0), page, P)
        line = jnp.where(p >= 0, p % ps, 0)
        ck = cache["k"].at[page, line].set(k[:, 0], mode="drop")
        cv = cache["v"].at[page, line].set(v[:, 0], mode="drop")
        cpos = cache["pos"].at[page, line].set(
            positions[:, 0].astype(ptype), mode="drop")
    else:
        # Chunked prefill at batch 1: per-position scatter through the
        # single request's table (pages need not be physically contiguous).
        lines = cache_index + jnp.arange(S, dtype=jnp.int32)
        pslot = jnp.minimum(lines // ps, MP - 1)
        page = jnp.take(page_table[0], pslot, mode="clip")
        page = jnp.where(page >= 0, page, P)
        ck = cache["k"].at[page, lines % ps].set(k[0], mode="drop")
        cv = cache["v"].at[page, lines % ps].set(v[0], mode="drop")
        cpos = cache["pos"].at[page, lines % ps].set(
            positions[0].astype(ptype), mode="drop")
    new_cache = {"k": ck, "v": cv, "pos": cpos}

    from repro.kernels import ops as kops  # lazy: avoid cycles
    scale = hd ** -0.5
    softcap = cfg.attn_logit_softcap
    if S == 1 and causal and (run.use_gmm_kernel
                              or jax.default_backend() == "tpu"):
        # Block-gathered flash decode over the pool (XLA gather fallback
        # is the use_kernel=False branch inside ops).
        out = kops.paged_decode_attention(
            q[:, 0], ck, cv, page_table, positions[:, 0], scale=scale,
            softcap=softcap, window=window,
            use_kernel=True if run.use_gmm_kernel else None)[:, None]
    else:
        kg, vg, kv_pos = kops.paged_gather_kv(ck, cv, page_table)
        out = _attention_inner(q, kg, vg, cfg, run, positions=positions,
                               kv_pos=kv_pos, causal=causal, window=window,
                               structural=False)
    out = run.constrain(out, ("batch", None, "q_heads", None))
    y = out.reshape(B, S, h * hd) @ params["wo"].astype(cd)
    y = run.constrain(y, ("batch", None, None))
    return y, new_cache


def apply_attention(params, cfg: ModelConfig, run: RunConfig, x, positions,
                    *, causal: bool, window: int = 0, kv=None, kv_positions=None,
                    cache=None, cache_index=None, rope: bool = True,
                    attend_to_cache: bool = False, page_table=None):
    """Full/local/cross attention with optional KV cache (decode).

    x: [B, S, d]; positions: [B, S].
    kv: cross-attention memory [B, T, d] (rope disabled for cross).
    cache: dict(k=[B, C, KH, hd], v=..., pos=[B, C]) -> returns updated cache.
    cache_index: scalar (lockstep decode / prefill offset) or per-slot [B]
        vector (continuous batching, DESIGN.md §7.2): row b writes its own
        cache line at cache_index[b]; rows with negative positions write
        nothing, so dead slots never touch their cache.
    attend_to_cache: with S > 1, attend over the full (just-updated) cache
        instead of assuming it empty — chunked prefill, where earlier
        chunks' keys live in the cache. Unwritten lines (pos == -1) are
        masked out.
    page_table: [B, max_pages] int32 — paged-cache mode (DESIGN.md §9):
        ``cache`` holds the SHARED physical pool (k/v [P, ps, KH, hd],
        pos [P, ps]) and row b's cache line p lives at line p % ps of pool
        page page_table[b, p // ps]. Writes scatter through the table
        (negative positions / unallocated slots drop); attention gathers
        the slot's pages with structurally computed key positions, so
        recycled pages' stale lines stay unreachable. Sliding-window
        layers use the same linear paged layout with the window enforced
        by masking (no ring arithmetic).
    """
    if page_table is not None and cache is not None:
        return _apply_attention_paged(
            params, cfg, run, x, positions, causal=causal, window=window,
            cache=cache, cache_index=cache_index, rope=rope,
            page_table=page_table)
    B, S, _ = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = run.policy.compute_dtype
    q, k, v, kv_pos = _project_qkv(params, cfg, run, x, positions, kv,
                                   kv_positions, rope)

    new_cache = None
    structural = cache is None
    if cache is not None:
        # Ring-buffer cache (window>0) or linear cache. Keys stored post-rope.
        C = cache["k"].shape[1]
        if jnp.ndim(cache_index) == 1:
            # Per-slot positions [B]: each row scatters its single new K/V
            # into its own cache line. Inactive slots carry position -1,
            # which maps to the out-of-bounds sentinel C and is dropped —
            # the write never happens, so freed slots stay inert until the
            # next insert overwrites them wholesale.
            assert S == 1, "per-slot cache_index implies single-token decode"
            slot = (cache_index % C) if window > 0 else cache_index
            slot = jnp.where(cache_index >= 0, slot, C)
            b_ix = jnp.arange(B)
            ck = cache["k"].at[b_ix, slot].set(k[:, 0], mode="drop")
            cv = cache["v"].at[b_ix, slot].set(v[:, 0], mode="drop")
            cpos = cache["pos"].at[b_ix, slot].set(
                positions[:, 0].astype(cache["pos"].dtype), mode="drop")
        elif window > 0 and S >= C:
            # prefill block larger than the ring: only the last C keys
            # survive; place key of position p at ring slot p % C.
            shift = (cache_index + S - C) % C
            ck = jnp.roll(k[:, -C:], shift, axis=1)
            cv = jnp.roll(v[:, -C:], shift, axis=1)
            cpos = jnp.roll(positions[:, -C:].astype(cache["pos"].dtype),
                            shift, axis=1)
        elif window > 0 and S > 1:
            # Chunked prefill into a ring (S < C): per-position modular
            # scatter — a dynamic_update_slice would CLAMP (not wrap) a
            # chunk that crosses the ring edge and corrupt the cache.
            idx = (cache_index + jnp.arange(S)) % C
            ck = cache["k"].at[:, idx].set(k)
            cv = cache["v"].at[:, idx].set(v)
            cpos = cache["pos"].at[:, idx].set(
                positions.astype(cache["pos"].dtype))
        else:
            slot = (cache_index % C) if window > 0 else cache_index
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
            cpos = jax.lax.dynamic_update_slice(
                cache["pos"], positions.astype(cache["pos"].dtype), (0, slot))
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        if S == 1 or attend_to_cache:
            if window > 0 and S > 1:
                # Ring-cache chunked prefill attends BEFORE the write
                # lands: the chunk's own tail evicts ring lines that
                # earlier queries of the same chunk still need (query j
                # sees evicted position p iff j < p's ring successor —
                # the pre-fix approximation dropped those keys). Attention
                # reads the PRE-write ring plus the fresh chunk keys; the
                # window mask trims the union to exactly the right lines,
                # and the write (above) still lands for later chunks.
                k = jnp.concatenate([cache["k"], k], axis=1)
                v = jnp.concatenate([cache["v"], v], axis=1)
                kv_pos = jnp.concatenate(
                    [cache["pos"], positions.astype(cache["pos"].dtype)],
                    axis=1)
            else:
                # decode / linear-cache chunked prefill: attend over the
                # cache contents (earlier chunks included; pos == -1 lines
                # are masked out). Exact: nothing is ever evicted (S == 1
                # writes only the query's own line; a linear cache never
                # wraps).
                k, v, kv_pos = ck, cv, cpos
        else:
            # whole-sequence prefill: the cache is assumed empty at entry,
            # so attention runs structurally over the fresh K/V (never
            # materializing the [S, S] score matrix); the cache write is a
            # side effect.
            structural = True

    out = _attention_inner(
        q, k, v, cfg, run, positions=positions, kv_pos=kv_pos,
        causal=causal and kv is None, window=window, structural=structural)
    out = run.constrain(out, ("batch", None, "q_heads", None))
    y = out.reshape(B, S, h * hd) @ params["wo"].astype(cd)
    y = run.constrain(y, ("batch", None, None))
    return y, new_cache


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int,
                         window: int, dtype):
    C = min(window, max_len) if window > 0 else max_len
    return {
        "k": jnp.zeros((batch, C, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, C, cfg.n_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((batch, C), -1, jnp.int32),
    }


def init_paged_attention_cache(cfg: ModelConfig, n_pages: int,
                               page_size: int, dtype):
    """Shared physical KV pool for ONE attention layer (DESIGN.md §9): no
    batch dim — slots own disjoint page subsets through their page tables.
    Sliding-window layers share the layout (window enforced by masking)."""
    return {
        "k": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, cfg.head_dim),
                       dtype),
        "v": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, cfg.head_dim),
                       dtype),
        "pos": jnp.full((n_pages, page_size), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_act == "swiglu":
        return {
            "wi_gate": Param(fan_in_init(k1, (d, f), jnp.float32, fan_in=d),
                             ("embed", "mlp")),
            "wi_up": Param(fan_in_init(k2, (d, f), jnp.float32, fan_in=d),
                           ("embed", "mlp")),
            "wo": Param(fan_in_init(k3, (f, d), jnp.float32, fan_in=f),
                        ("mlp", "embed")),
        }
    return {  # gelu (whisper)
        "wi": Param(fan_in_init(k1, (d, f), jnp.float32, fan_in=d),
                    ("embed", "mlp")),
        "bi": Param(jnp.zeros((f,), jnp.float32), ("mlp",)),
        "wo": Param(fan_in_init(k2, (f, d), jnp.float32, fan_in=f),
                    ("mlp", "embed")),
        "bo": Param(jnp.zeros((d,), jnp.float32), ("embed",)),
    }


def apply_mlp(params, cfg: ModelConfig, run: RunConfig, x):
    cd = run.policy.compute_dtype
    if "wi_gate" in params:
        g = jax.nn.silu(x @ params["wi_gate"].astype(cd))
        u = x @ params["wi_up"].astype(cd)
        h = run.constrain(g * u, ("batch", None, "mlp"))
        return run.constrain(h @ params["wo"].astype(cd),
                             ("batch", None, None))
    h = jax.nn.gelu(x @ params["wi"].astype(cd) + params["bi"].astype(cd))
    h = run.constrain(h, ("batch", None, "mlp"))
    return run.constrain(h @ params["wo"].astype(cd) + params["bo"].astype(cd),
                         ("batch", None, None))


# ---------------------------------------------------------------------------
# MoE FFN
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": Param(fan_in_init(k0, (d, e), jnp.float32, fan_in=d),
                        ("embed", None)),
        "wi_gate": Param(
            jax.vmap(lambda k: fan_in_init(k, (d, f), jnp.float32, fan_in=d))(
                jax.random.split(k1, e)), ("expert", "embed", "mlp")),
        "wi_up": Param(
            jax.vmap(lambda k: fan_in_init(k, (d, f), jnp.float32, fan_in=d))(
                jax.random.split(k2, e)), ("expert", "embed", "mlp")),
        "wo": Param(
            jax.vmap(lambda k: fan_in_init(k, (f, d), jnp.float32, fan_in=f))(
                jax.random.split(k3, e)), ("expert", "mlp", "embed")),
    }


def moe_route(router_w, cfg: ModelConfig, policy: Policy, x2d):
    """Router in f32: returns (weights [T,k], idx [T,k] int32, aux dict)."""
    logits = jnp.einsum("td,de->te", x2d.astype(policy.accum_dtype),
                        router_w.astype(policy.accum_dtype))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load-balance loss + router z-loss. The assignment
    # fraction f is a histogram of the (non-differentiable) top-k indices:
    # an O(T·k) bincount, not an O(T·E) one_hot materialization.
    T = x2d.shape[0]
    counts = jnp.bincount(idx.reshape(-1), length=cfg.n_experts)
    f = counts.astype(policy.accum_dtype) / (T * cfg.top_k)
    p = jnp.mean(probs, axis=0)
    aux = {
        "moe_aux_loss": cfg.n_experts * jnp.sum(f * p) * cfg.router_aux_coef,
        "moe_z_loss": jnp.mean(
            jnp.square(jax.nn.logsumexp(logits, axis=-1))) * cfg.router_z_coef,
    }
    return weights, idx.astype(jnp.int32), aux


def expert_ffn(wi_gate, wi_up, wo, xs, group_sizes, run: RunConfig,
               row_scales=None):
    """Grouped expert FFN over expert-sorted tokens xs [Tk, d].

    wi_*: [E, d, f]; wo: [E, f, d]; group_sizes: [E] int32.
    row_scales: optional [Tk] per-row combine weights, fused into the
    unpack gather (each output row touched once).

    Single-pack fused pipeline (kernels/ops.moe_ffn): one scatter into the
    tile-aligned packed domain, all three GEMMs there (gate+up fused), one
    gather out, one custom_vjp with activation recompute. use_gmm_kernel
    forces the Pallas grouped kernels; otherwise ops picks the backend
    default (Mosaic on TPU, the XLA tile-gather fallback elsewhere) for
    the same packed-domain pipeline. Decode shapes (M ≲ E·block_m) route
    to the group-dense fallback automatically (DESIGN.md §5.5).
    """
    cd = run.policy.compute_dtype
    from repro.kernels import ops as kops
    return kops.moe_ffn(xs, wi_gate.astype(cd), wi_up.astype(cd),
                        wo.astype(cd), group_sizes, row_scales=row_scales,
                        use_kernel=True if run.use_gmm_kernel else None)


def apply_moe(params, cfg: ModelConfig, run: RunConfig, x):
    """Unsharded MoE block. x: [B, S, d] -> (y, aux)."""
    B, S, d = x.shape
    cd = run.policy.compute_dtype
    x2d = x.reshape(-1, d)
    weights, idx, aux = moe_route(params["router"], cfg, run.policy, x2d)
    T, k = idx.shape

    if run.moe_impl == "dense":
        # Every expert on every token; exact but O(E) compute. TEST
        # REFERENCE ONLY — serve/train paths ride the fused pipeline below
        # (the RunConfig default), which is numerically equivalent
        # (dropless) at O(top_k) compute.
        g = jnp.einsum("td,edf->tef", x2d, params["wi_gate"].astype(cd))
        u = jnp.einsum("td,edf->tef", x2d, params["wi_up"].astype(cd))
        h = jax.nn.silu(g) * u
        y_all = jnp.einsum("tef,efd->ted", h, params["wo"].astype(cd))
        gates = jnp.zeros((T, cfg.n_experts), cd)
        gates = gates.at[jnp.arange(T)[:, None], idx].add(weights.astype(cd))
        y = jnp.einsum("ted,te->td", y_all, gates)
        return y.reshape(B, S, d), aux

    # Dropless gather mode: sort token-copies by expert, grouped matmul.
    # The router combine weight rides into the FFN as a fused row scale,
    # so the unpack gather emits already-weighted rows and the combine is
    # a bare segment-sum (one touch per output row).
    flat_idx = idx.reshape(-1)  # [T*k]
    sort = jnp.argsort(flat_idx)
    tok = sort // k
    xs = jnp.take(x2d, tok, axis=0)
    group_sizes = jnp.bincount(flat_idx, length=cfg.n_experts).astype(jnp.int32)
    w_sorted = jnp.take(weights.reshape(-1), sort, axis=0).astype(cd)
    ys = expert_ffn(params["wi_gate"], params["wi_up"], params["wo"], xs,
                    group_sizes, run, row_scales=w_sorted)
    y = jax.ops.segment_sum(ys, tok, num_segments=T)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (recurrentgemma / Griffin)
# ---------------------------------------------------------------------------

def init_rglru(key, cfg: ModelConfig):
    d, w, cw = cfg.d_model, cfg.lru_width, cfg.conv_width
    ks = jax.random.split(key, 6)
    # Lambda init so that a = sigmoid(Lambda)^8 is in (0.9, 0.999) (Griffin).
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1 / 8) / (1 - u ** (1 / 8)))
    return {
        "proj_gate": Param(fan_in_init(ks[0], (d, w), jnp.float32, fan_in=d),
                           ("embed", "mlp")),
        "proj_rec": Param(fan_in_init(ks[1], (d, w), jnp.float32, fan_in=d),
                          ("embed", "mlp")),
        "conv_w": Param(fan_in_init(ks[2], (cw, w), jnp.float32, fan_in=cw),
                        (None, "mlp")),
        "conv_b": Param(jnp.zeros((w,), jnp.float32), ("mlp",)),
        "w_i": Param(fan_in_init(ks[3], (w, w), jnp.float32, fan_in=w),
                     ("mlp", "mlp_out")),
        "b_i": Param(jnp.zeros((w,), jnp.float32), ("mlp",)),
        "w_a": Param(fan_in_init(ks[4], (w, w), jnp.float32, fan_in=w),
                     ("mlp", "mlp_out")),
        "b_a": Param(jnp.zeros((w,), jnp.float32), ("mlp",)),
        "lam": Param(lam, ("mlp",)),
        "out": Param(fan_in_init(jax.random.fold_in(key, 9), (w, d),
                                 jnp.float32, fan_in=w), ("mlp", "embed")),
    }


def causal_conv1d(x, conv_w, conv_b, state=None):
    """Depthwise causal conv. x: [B, S, C]; conv_w: [W, C]; state: [B, W-1, C]."""
    W = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * conv_w[i].astype(x.dtype)
              for i in range(W))
    out = out + conv_b.astype(x.dtype)
    new_state = xp[:, -(W - 1):, :] if W > 1 else pad
    return out, new_state


def _lru_scan(a, gx, h0=None):
    """Linear recurrence h_t = a_t * h_{t-1} + gx_t along axis 1 (f32)."""
    if h0 is not None:
        gx = gx.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return h


def apply_rglru(params, cfg: ModelConfig, run: RunConfig, x, state=None):
    """Griffin recurrent block. x: [B,S,d] -> (y, new_state)."""
    pol = run.policy
    cd = pol.compute_dtype
    gate = jax.nn.gelu(x @ params["proj_gate"].astype(cd))
    gate = run.constrain(gate, ("batch", None, "mlp"))
    h = run.constrain(x @ params["proj_rec"].astype(cd),
                      ("batch", None, "mlp"))
    conv_state = state["conv"] if state is not None else None
    h, new_conv = causal_conv1d(h, params["conv_w"], params["conv_b"],
                                conv_state)
    hf = h.astype(jnp.float32)
    i_gate = jax.nn.sigmoid(hf @ params["w_i"].astype(jnp.float32)
                            + params["b_i"])
    r_gate = jax.nn.sigmoid(hf @ params["w_a"].astype(jnp.float32)
                            + params["b_a"])
    log_a = -8.0 * r_gate * jax.nn.softplus(params["lam"])  # [B,S,w]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-6)) * (i_gate * hf)
    h0 = state["lru"].astype(jnp.float32) if state is not None else None
    hs = _lru_scan(a, gated, h0)
    y = (hs.astype(cd) * gate) @ params["out"].astype(cd)
    y = run.constrain(y, ("batch", None, None))
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv.astype(state["conv"].dtype),
                     "lru": hs[:, -1].astype(state["lru"].dtype)}
    return y, new_state


def init_rglru_state(cfg: ModelConfig, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dtype),
        "lru": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }


# ---------------------------------------------------------------------------
# SSD block (mamba2)
# ---------------------------------------------------------------------------

def init_ssd(key, cfg: ModelConfig):
    d = cfg.d_model
    din = cfg.ssm_expand * d
    nh, s, cw = cfg.ssm_heads, cfg.ssm_state, cfg.conv_width
    proj_out = 2 * din + 2 * s + nh  # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    a_init = jnp.log(jnp.linspace(1.0, 16.0, nh))  # A in [-16, -1]
    return {
        "in_proj": Param(fan_in_init(ks[0], (d, proj_out), jnp.float32,
                                     fan_in=d), ("embed", "mlp")),
        "conv_w": Param(fan_in_init(ks[1], (cw, din + 2 * s), jnp.float32,
                                    fan_in=cw), (None, "mlp")),
        "conv_b": Param(jnp.zeros((din + 2 * s,), jnp.float32), ("mlp",)),
        "dt_bias": Param(jnp.zeros((nh,), jnp.float32), (None,)),
        "A_log": Param(a_init, (None,)),
        "D": Param(jnp.ones((nh,), jnp.float32), (None,)),
        "norm": Param(jnp.ones((din,), jnp.float32), ("mlp",)),
        "out_proj": Param(fan_in_init(ks[2], (din, d), jnp.float32,
                                      fan_in=din), ("mlp", "embed")),
    }


def apply_ssd(params, cfg: ModelConfig, run: RunConfig, x, state=None):
    """mamba2 SSD mixer. x: [B,S,d] -> (y, new_state)."""
    pol = run.policy
    cd = pol.compute_dtype
    B, S, d = x.shape
    din = cfg.ssm_expand * d
    nh, ns = cfg.ssm_heads, cfg.ssm_state
    hd = din // nh

    zxbcdt = x @ params["in_proj"].astype(cd)
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:din + din + 2 * ns]
    dt_raw = zxbcdt[..., -nh:]

    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = causal_conv1d(xbc, params["conv_w"], params["conv_b"],
                                  conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :din].reshape(B, S, nh, hd)
    xs = run.constrain(xs, ("batch", None, "q_heads", None))
    Bm = xbc[..., din:din + ns]
    Cm = xbc[..., din + ns:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])  # [nh]

    if state is None:
        from repro.kernels import ops as kops  # lazy
        y, last_state = kops.ssd(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk,
                                 use_kernel=run.use_gmm_kernel)
    else:
        from repro.kernels import ref as kref
        y, last_state = kref.ssd_decode_step(
            xs, dt, A, Bm, Cm, state["ssm"].astype(jnp.float32))

    y = y + params["D"].astype(cd)[None, None, :, None] * xs
    y = y.reshape(B, S, din)
    # Gated RMSNorm (mamba2): norm(y * silu(z))
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(ms + 1e-6) * params["norm"]
    out = yf.astype(cd) @ params["out_proj"].astype(cd)
    out = run.constrain(out, ("batch", None, None))

    new_state = None
    if state is not None:
        new_state = {"conv": new_conv.astype(state["conv"].dtype),
                     "ssm": last_state.astype(state["ssm"].dtype)}
    return out, new_state


def init_ssd_state(cfg: ModelConfig, batch: int, dtype):
    din = cfg.ssm_expand * cfg.d_model
    nh, ns = cfg.ssm_heads, cfg.ssm_state
    hd = din // nh
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, din + 2 * ns), dtype),
        "ssm": jnp.zeros((batch, nh, hd, ns), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Transformer layer = mixer + (optional cross-attn) + ffn
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, spec: LayerSpec):
    ks = jax.random.split(key, 4)
    params = {"norm1": init_norm(cfg)}
    if spec.mixer in ("attn", "local_attn"):
        params["mixer"] = init_attention(ks[0], cfg)
    elif spec.mixer == "rglru":
        params["mixer"] = init_rglru(ks[0], cfg)
    elif spec.mixer == "ssd":
        params["mixer"] = init_ssd(ks[0], cfg)
    if spec.cross_attn:
        params["xnorm"] = init_norm(cfg)
        params["xattn"] = init_attention(ks[1], cfg, cross=True)
        # gating scalar for cross-attn residual (llama-3.2-vision style)
        params["xgate"] = Param(jnp.zeros((), jnp.float32), ())
    if spec.ffn != "none":
        params["norm2"] = init_norm(cfg)
        params["ffn"] = (init_moe(ks[2], cfg) if spec.ffn == "moe"
                         else init_mlp(ks[2], cfg))
    return params


def apply_mixer_part(params, cfg: ModelConfig, run: RunConfig, spec: LayerSpec,
                     x, positions, state=None, encoder_out=None,
                     encoder_positions=None, cache_index=None,
                     attend_to_cache: bool = False, page_table=None):
    """Pre-norm mixer + residual (+ cross-attn). Returns (h, new_state)."""
    new_state = dict(state) if state is not None else None
    h = x
    if spec.mixer != "none":
        u = apply_norm(params["norm1"], x, run.policy)
        if spec.mixer in ("attn", "local_attn"):
            window = cfg.window if spec.mixer == "local_attn" else 0
            causal = cfg.causal if spec.causal is None else spec.causal
            cache = state.get("kv") if state is not None else None
            att, new_kv = apply_attention(
                params["mixer"], cfg, run, u, positions, causal=causal,
                window=window, cache=cache, cache_index=cache_index,
                attend_to_cache=attend_to_cache, page_table=page_table)
            if new_state is not None:
                new_state["kv"] = new_kv
            mixed = att
        elif spec.mixer == "rglru":
            mixed, ns = apply_rglru(params["mixer"], cfg, run, u,
                                    state.get("rglru") if state else None)
            if new_state is not None:
                new_state["rglru"] = ns
        elif spec.mixer == "ssd":
            mixed, ns = apply_ssd(params["mixer"], cfg, run, u,
                                  state.get("ssd") if state else None)
            if new_state is not None:
                new_state["ssd"] = ns
        else:
            raise ValueError(spec.mixer)
        h = x + mixed
    if spec.cross_attn:
        u = apply_norm(params["xnorm"], h, run.policy)
        xa, _ = apply_attention(params["xattn"], cfg, run, u, positions,
                                causal=False, kv=encoder_out,
                                kv_positions=encoder_positions)
        gate = jnp.tanh(params["xgate"]).astype(h.dtype)
        h = h + gate * xa
    return h, new_state


def apply_ffn_part(params, cfg: ModelConfig, run: RunConfig, spec: LayerSpec,
                   h, moe_override: Optional[Callable] = None):
    """Pre-norm FFN + residual. Returns (y, aux)."""
    aux = {}
    if spec.ffn == "none":
        return h, aux
    u = apply_norm(params["norm2"], h, run.policy)
    if spec.ffn == "moe":
        if moe_override is not None:
            f, aux = moe_override(params["ffn"], u)
        else:
            f, aux = apply_moe(params["ffn"], cfg, run, u)
    else:
        f = apply_mlp(params["ffn"], cfg, run, u)
    return h + f, aux


def apply_layer(params, cfg: ModelConfig, run: RunConfig, spec: LayerSpec,
                x, positions, state=None, encoder_out=None,
                encoder_positions=None, cache_index=None,
                moe_override: Optional[Callable] = None,
                attend_to_cache: bool = False, page_table=None):
    h, new_state = apply_mixer_part(
        params, cfg, run, spec, x, positions, state=state,
        encoder_out=encoder_out, encoder_positions=encoder_positions,
        cache_index=cache_index, attend_to_cache=attend_to_cache,
        page_table=page_table)
    y, aux = apply_ffn_part(params, cfg, run, spec, h,
                            moe_override=moe_override)
    return y, new_state, aux


def init_layer_state(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, dtype):
    """Decode-state pytree for one layer (None entries for stateless parts)."""
    state = {}
    if spec.mixer in ("attn", "local_attn"):
        window = cfg.window if spec.mixer == "local_attn" else 0
        state["kv"] = init_attention_cache(cfg, batch, max_len, window, dtype)
    elif spec.mixer == "rglru":
        state["rglru"] = init_rglru_state(cfg, batch, dtype)
    elif spec.mixer == "ssd":
        state["ssd"] = init_ssd_state(cfg, batch, dtype)
    return state


def init_paged_layer_state(cfg: ModelConfig, spec: LayerSpec, batch: int,
                           n_pages: int, page_size: int, dtype):
    """Paged decode-state pytree for one layer (DESIGN.md §9): attention KV
    becomes the SHARED pool (no batch dim); recurrent states stay per-slot
    (they are O(d) per slot — paging buys nothing there)."""
    state = {}
    if spec.mixer in ("attn", "local_attn"):
        state["kv"] = init_paged_attention_cache(cfg, n_pages, page_size,
                                                 dtype)
    elif spec.mixer == "rglru":
        state["rglru"] = init_rglru_state(cfg, batch, dtype)
    elif spec.mixer == "ssd":
        state["ssd"] = init_ssd_state(cfg, batch, dtype)
    return state
