"""Continuous-batching serving driver: Poisson arrivals, chunked prefill,
per-slot sampled decode, streaming per-request output (DESIGN.md §7).

The CLI is a thin shell around ONE config object and ONE factory
(DESIGN.md §14.5): flags parse into a :class:`repro.serve.ServeConfig`,
``serve_cfg.validate()`` rejects every invalid combination in a single
clear non-zero-exit error (conflicting ``--fleet``+``--disagg``,
``--ep-size`` on a dense arch, ``--prefix-cache`` without a paged
deployment, malformed chaos/kill specs, ...), and
:func:`repro.serve.build_deployment` constructs whichever engine the
config describes.

    # MoE + dense smoke archs through a mixed-length Poisson trace:
    PYTHONPATH=src python -m repro.launch.serve --smoke --mesh 1x1

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
        --smoke --slots 4 --requests 8 --prompt-len 64 --gen 32 --mesh 1x2

    # paged smoke with an overcommitted pool (preemption exercised):
    PYTHONPATH=src python -m repro.launch.serve --smoke --paged \
        --page-size 16 --pool-pages 12

    # prefix-cached COW paged KV over a shared-prefix multi-tenant trace
    # (DESIGN.md §14); --fair switches admission to per-tenant deficit
    # round-robin:
    PYTHONPATH=src python -m repro.launch.serve --smoke --paged \
        --prefix-cache --tenants 2 --fair --requests 8

    # disaggregated prefill/decode smoke (role-split workers, page-id
    # KV handoff, DESIGN.md §10); tight decode pool exercises the
    # preempt -> re-prefill path:
    PYTHONPATH=src python -m repro.launch.serve --smoke --disagg \
        --page-size 16 --pool-pages 12

``--ep-size N`` shards MoE expert weights across N devices of the mesh
``model`` axis for the decode-time expert hop (DESIGN.md §11); on a
dense arch it is REJECTED (pass an explicit MoE ``--arch``).
``--ep-placement planned`` turns on online heterogeneity-aware
re-placement from the observed routing EMA:

    PYTHONPATH=src python -m repro.launch.serve --smoke \
        --arch qwen3-moe-30b-a3b --mesh 1x2 --ep-size 2 \
        --ep-placement planned

``--fleet`` scales disagg to an elastic multi-group fleet (DESIGN.md
§12): N prefill + M decode groups of mixed device classes behind a
router, with heartbeat failure recovery and (``--fleet-elastic``)
role flips. ``--kill-group GID@TICK`` injects a crash mid-trace (the
shorthand is sugar for a ``crash_start@TICK:gGID`` entry of the ONE
``ft.chaos`` fault grammar, which is also accepted verbatim); the
killed group's in-flight requests re-enter the router and re-prefill
token-exactly:

    PYTHONPATH=src python -m repro.launch.serve --smoke --fleet \
        --prefill-groups a40 --decode-groups v100,v100 \
        --page-size 8 --kill-group 2@8

``--chaos SPEC --chaos-seed N`` (fleet mode only) arms the seeded fault
injector (DESIGN.md §13) with a ``ft.chaos`` schedule — transfer chunk
drop/corrupt/stall, heartbeat loss (zombie + rejoin), mid-tick group
crashes — and ``--slo-ttft S`` turns on SLO-aware shedding. The summary
gains a ``chaos`` section with the replayable event log + signature:

    PYTHONPATH=src python -m repro.launch.serve --smoke --fleet \
        --prefill-groups a40,a40 --decode-groups v100,v100 \
        --page-size 8 --chaos 'drop%0.6*4' --chaos-seed 101

Exit status: non-zero when any request is rejected, dropped, or left
unfinished — the CI serve-smoke, disagg-smoke, ep-smoke, fleet-smoke,
chaos-smoke and prefix-smoke steps gate on it — and when the ServeConfig
is invalid (one aggregated error message, before any device work).
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.obs import format_report, write_chrome_trace
from repro.obs import trace as obs_trace
from repro.models.modules import Policy, RunConfig
from repro.serve import (Request, SamplingParams, ServeConfig,
                         ServeConfigError, ServeMetrics, build_deployment)
# Re-exported here for back-compat (tests and older tooling import the
# parsers from the driver); the implementations live in serve.config.
from repro.serve.config import parse_group_spec, parse_kills  # noqa: F401

SMOKE_ARCHS = ("qwen3-moe-30b-a3b", "llama3.2-3b")  # MoE + dense


def build_trace(seed: int, n: int, rate: float, prompt_len: int, gen: int,
                vocab: int, sampling: SamplingParams,
                eos_token=None) -> list:
    """Mixed-length Poisson trace: exponential inter-arrivals (in engine
    ticks), prompt lengths in [prompt_len/4, prompt_len], generation
    budgets in [gen/2, gen]."""
    rng = np.random.RandomState(seed)
    t, reqs = 0.0, []
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.randint(max(1, prompt_len // 4), prompt_len + 1))
        gmax = int(rng.randint(max(1, gen // 2), gen + 1))
        prompt = rng.randint(0, vocab, size=(plen,)).astype(int).tolist()
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=gmax,
                            sampling=sampling, eos_token=eos_token,
                            arrival=t))
    return reqs


def build_tenant_trace(args, vocab: int, sampling: SamplingParams) -> list:
    """Shared-prefix multi-tenant trace (--tenants N, DESIGN.md §14):
    same-tenant requests share a seeded system prefix, which is what the
    prefix cache and the fairness admission are exercised against."""
    from repro.core.simulator import multi_tenant_trace
    recs = multi_tenant_trace(
        args.seed, args.requests, n_tenants=args.tenants, rate=args.rate,
        prompt_len=args.prompt_len, gen=args.gen, vocab=vocab,
        shared_len=args.shared_prefix_len)
    return [Request(rid=i, prompt=list(r.prompt), max_new_tokens=r.gen,
                    sampling=sampling, arrival=r.arrival, tenant=r.tenant)
            for i, r in enumerate(recs)]


def serve_arch_lockstep(cfg, mesh, run, serve_cfg, prompt_len: int,
                        gen: int) -> dict:
    """Whole-batch lockstep fallback for enc-dec / vision archs (they need
    per-request front embeddings the continuous engine does not carry)."""
    server = build_deployment(cfg, mesh, run, serve_cfg)
    slots = serve_cfg.slots
    key = jax.random.PRNGKey(0)
    prompts = jax.random.randint(key, (slots, prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    fronts = {}
    if cfg.is_encdec:
        fronts["encoder_embeds"] = jnp.zeros(
            (slots, cfg.encoder_seq, cfg.d_model),
            run.policy.compute_dtype)
    if cfg.vision_seq > 0:
        fronts["vision_embeds"] = jnp.zeros(
            (slots, cfg.vision_seq, cfg.vision_dim or cfg.d_model),
            run.policy.compute_dtype)
    t0 = time.perf_counter()
    server.submit_prefill(prompts, fronts)
    out = [server.tokens]
    for _ in range(gen - 1):
        out.append(server.step(fronts))
    toks = jnp.concatenate(out, axis=1)
    dt = time.perf_counter() - t0
    tps = round(slots * gen / dt, 2)
    print(f"[serve] arch={cfg.name} lockstep fallback generated "
          f"{toks.shape} in {dt:.2f}s ({tps} tok/s)")
    return {"tokens_per_s": tps, "lockstep": True,
            "ok": toks.shape == (slots, gen)}


def _prefix_summary(index, alloc, n_prefix_hits: int,
                    tokens_skipped: int) -> dict:
    """The summary's ``prefix`` section: index + allocator accounting."""
    return {
        "lookups_hit": index.hits,
        "lookups_miss": index.misses,
        "tokens_served": index.tokens_served,
        "admissions_hit": n_prefix_hits,
        "tokens_skipped": tokens_skipped,
        "pages_pinned": index.n_pages,
        "pages_evicted": index.n_evicted,
        "pages_allocated": alloc.n_fresh_allocs,
        "pages_shared": alloc.n_shared_allocs,
        "n_cow_forks": alloc.n_cow_forks,
    }


def serve_arch(arch: str, args, serve_cfg: ServeConfig = None) -> dict:
    cfg = registry.get_config(arch)
    if args.smoke:
        cfg = registry.smoke_config(cfg)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    run = RunConfig(policy=Policy(), attn_impl="ref", moe_impl="gather")
    if serve_cfg is None:
        serve_cfg = ServeConfig.from_args(args)
    try:
        # Arch/mesh-dependent validation (EP divisibility, recurrent-arch
        # prefix rejection) — the ONE error path for invalid configs.
        serve_cfg.validate(model_cfg=cfg, mesh=mesh)
    except ServeConfigError as e:
        print(f"[serve] FAIL arch={cfg.name}: invalid serve config: {e}",
              file=sys.stderr)
        return {"ok": False, "n_requests": 0, "config_error": str(e)}
    if cfg.is_encdec or cfg.vision_seq > 0:
        return serve_arch_lockstep(cfg, mesh, run, serve_cfg,
                                   args.prompt_len, args.gen)
    sampling = serve_cfg.sampling
    if args.tenants:
        trace = build_tenant_trace(args, cfg.vocab_size, sampling)
    else:
        trace = build_trace(args.seed, args.requests, args.rate,
                            args.prompt_len, args.gen, cfg.vocab_size,
                            sampling)
    metrics = ServeMetrics()
    stream = None
    if args.stream:
        def stream(rid, tok, fin):
            print(f"[{cfg.name}] rid={rid} tok={tok}"
                  + (" <done>" if fin else ""))

    shed: set = set()
    leaked: list = []
    trace_out = getattr(args, "trace_out", None)
    tracer = None
    if trace_out:
        # Tick-clock tracing (DESIGN.md §15): installed process-wide so
        # every instrumented hot path emits; off by default (NullTracer).
        tracer = obs_trace.Tracer(
            wall=bool(getattr(args, "trace_wall", False)))
        obs_trace.install(tracer)
    try:
        engine = build_deployment(cfg, mesh, run, serve_cfg,
                                  metrics=metrics, on_token=stream)
    except ValueError as e:
        # Anything validate() could not see statically (construction-time
        # topology problems) still fails the run, never half-serves.
        print(f"[serve] FAIL arch={cfg.name}: bad deployment: {e}",
              file=sys.stderr)
        obs_trace.install(None)
        return {"ok": False, "n_requests": 0, "config_error": str(e)}
    if tracer is not None:
        # Unified counters registry: the exporter snapshots these into the
        # trace artifact's reproCounters section.
        tracer.registry.register("serve", metrics.summary)
        tracer.registry.register("robust", metrics.robust.as_dict)
        ema = getattr(engine, "ema", None)
        if ema is None:
            ema = getattr(getattr(engine, "decode", None),
                          "routing_ema", None)
        if ema is not None:
            tracer.registry.register("routing_ema", lambda e=ema: {
                "n_updates": e.n_updates,
                "merged": [round(float(v), 6) for v in e.merged()]})

    t0 = time.perf_counter()
    if serve_cfg.fleet.enabled:
        try:
            results = engine.run(trace,
                                 kills=list(serve_cfg.fleet.kills))
        except RuntimeError as e:
            # Wedged fleet (e.g. the only decode group was killed without
            # --fleet-elastic): requests would be dropped — fail the run.
            print(f"[serve] FAIL arch={cfg.name}: fleet stalled: {e}",
                  file=sys.stderr)
            obs_trace.install(None)
            return {"ok": False, "n_requests": 0, "fleet_error": str(e)}
        shed = set(engine.shed)
    else:
        results = engine.run(trace)
    dt = time.perf_counter() - t0

    for req in trace:
        if req.rid in shed:  # explicit SLO-shed outcome (chaos/slo mode)
            print(f"[{cfg.name}] rid={req.rid} prompt={len(req.prompt)} "
                  f"SHED")
            continue
        tr = metrics.requests.get(req.rid)
        if tr is None:  # rejected at submit — never entered the engine
            print(f"[{cfg.name}] rid={req.rid} prompt={len(req.prompt)} "
                  f"REJECTED")
            continue
        toks = results[req.rid]
        tenant = f" tenant={req.tenant}" if args.tenants else ""
        print(f"[{cfg.name}] rid={req.rid}{tenant} "
              f"prompt={len(req.prompt)} "
              f"gen={len(toks)}/{req.max_new_tokens} "
              f"first_tick={tr.first_token_tick} "
              f"finish_tick={tr.finish_tick} out={toks[:8]}...")
    s = metrics.summary()
    print(f"[serve] arch={cfg.name} {s['n_requests']} requests, "
          f"{s['n_generated_tokens']} tokens in {dt:.2f}s "
          f"({s['tokens_per_s']} tok/s, ttft p50 {s['ttft_s']['p50']:.3f}s, "
          f"itl p50 {s['itl_s']['p50']:.4f}s, "
          f"queue depth max {s['queue_depth']['max']}, "
          f"max concurrent {s['max_concurrent_active']})")
    if serve_cfg.fleet.enabled:
        # Surviving pools must hold the exactly-once page invariant even
        # after kills, recoveries, and role flips.
        for g in engine.groups:
            g.worker.allocator.check()
        chaos = engine.chaos
        if chaos is not None:
            # Chaos acceptance: a drained fleet must hold ZERO pages on
            # every surviving pool — a leftover page is a leak the fault
            # path failed to roll back.
            leaked = [g.gid for g in engine.groups
                      if g.worker.allocator.pages_in_use != 0]
        st = engine.transfer.stats
        s["fleet"] = {
            "elastic": serve_cfg.fleet.elastic,
            "ticks": engine.tick_count,
            "groups": [{"gid": g.gid, "cls": g.cls, "role": g.role,
                        "flips": g.flips} for g in engine.groups],
            "events": [{"tick": e.tick, "kind": e.kind, "gid": e.gid,
                        "detail": e.detail} for e in engine.events],
            "n_flips": engine.n_flips,
            "n_killed": len([e for e in engine.events
                             if e.kind == "dead"]),
            "kv_transfers": st.n_transfers,
            "kv_pages_shipped": st.n_pages,
        }
        if chaos is not None:
            s["chaos"] = {
                "spec": serve_cfg.chaos.spec,
                "seed": serve_cfg.chaos.seed,
                "events": chaos.log(),
                "signature": chaos.log_signature(),
                "counters": metrics.robust.as_dict(),
                "n_shed": len(shed),
                "leaked_groups": leaked,
            }
            print(f"[serve] arch={cfg.name} chaos: "
                  f"spec={serve_cfg.chaos.spec!r} "
                  f"seed={serve_cfg.chaos.seed} faults={len(chaos.log())} "
                  f"sig={chaos.log_signature()} shed={len(shed)} "
                  f"retries={st.n_retries} aborts={st.n_aborts} "
                  f"fenced={metrics.robust.fenced_stale_completions}")
        roles = ",".join(f"g{g.gid}={g.cls}:{g.role}"
                         for g in engine.groups)
        print(f"[serve] arch={cfg.name} fleet: {roles} "
              f"flips={engine.n_flips} "
              f"events={len(engine.events)} transfers={st.n_transfers} "
              f"ttft_p99={s['ttft_s']['p99']:.3f}s "
              f"itl_p99={s['itl_s']['p99']:.4f}s")
    elif serve_cfg.disagg.enabled:
        st = engine.transfer.stats
        s["disagg"] = {
            "page_size": serve_cfg.paged.page_size,
            "decode_pages": engine.decode.allocator.n_pages,
            "prefill_pages": engine.prefill.allocator.n_pages,
            "decode_page_peak": engine.decode.page_peak,
            "n_preempted": engine.decode.sched.n_preempted,
            "kv_transfers": st.n_transfers,
            "kv_pages_shipped": st.n_pages,
            "kv_bytes_shipped": st.bytes,
            "prefix_full_hits": engine.n_full_hits,
        }
        print(f"[serve] arch={cfg.name} disagg: "
              f"page_size={serve_cfg.paged.page_size} "
              f"transfers={st.n_transfers} pages={st.n_pages} "
              f"preempted={engine.decode.sched.n_preempted} "
              f"full_hits={engine.n_full_hits}")
        index = engine.decode.sched.prefix_index
        if index is not None:
            s["prefix"] = _prefix_summary(
                index, engine.decode.allocator,
                engine.prefill.sched.n_prefix_hits,
                engine.prefill.sched.n_tokens_skipped)
            s["prefix"]["full_hits"] = engine.n_full_hits
            index.check()
        engine.prefill.allocator.check()
        engine.decode.allocator.check()
    elif serve_cfg.paged.enabled:
        s["paged"] = eng_occ = engine.page_occupancy()
        print(f"[serve] arch={cfg.name} paged: "
              f"page_size={serve_cfg.paged.page_size} "
              f"pool={engine.p.n_pages} peak={eng_occ['page_peak']} "
              f"preempted={eng_occ['n_preempted']}")
        index = engine.sched.prefix_index
        if index is not None:
            s["prefix"] = _prefix_summary(
                index, engine.sched.allocator,
                engine.sched.prefill.n_prefix_hits,
                engine.sched.prefill.n_tokens_skipped)
            print(f"[serve] arch={cfg.name} prefix: "
                  f"hits={index.hits} tokens_served={index.tokens_served} "
                  f"skipped={engine.sched.prefill.n_tokens_skipped} "
                  f"cow_forks={engine.sched.allocator.n_cow_forks} "
                  f"pinned={index.n_pages}")
            index.check()
        engine.sched.allocator.check()
    if serve_cfg.ep.ep_size and not serve_cfg.disagg.enabled \
            and not serve_cfg.fleet.enabled:
        s["ep"] = {
            "ep_size": serve_cfg.ep.ep_size,
            "placement_mode": serve_cfg.ep.placement,
            "n_rebalances": engine.n_rebalances,
            "ema_updates": engine.ema.n_updates,
        }
        print(f"[serve] arch={cfg.name} ep: "
              f"ep_size={serve_cfg.ep.ep_size} "
              f"placement={serve_cfg.ep.placement} "
              f"rebalances={engine.n_rebalances} "
              f"ema_updates={engine.ema.n_updates}")
    # Gate: every traced request must finish with its full token budget
    # spent (traces carry no EOS) and nothing may be rejected or dropped.
    # Rejected rids never reach metrics (submit raises before on_submit);
    # they count as unfinished here AND appear in engine.rejected. Shed
    # requests (SLO admission, chaos mode) are an EXPLICIT outcome: they
    # are excluded from the finish requirement, and in chaos mode the run
    # additionally fails when any surviving pool leaked pages.
    unfinished = [r.rid for r in trace
                  if r.rid not in shed
                  and (metrics.requests.get(r.rid) is None
                       or metrics.requests[r.rid].finish_tick is None
                       or len(results.get(r.rid, [])) != r.max_new_tokens)]
    if tracer is not None:
        obj = write_chrome_trace(tracer, trace_out,
                                 ticks=getattr(engine, "tick_count", None))
        obs_trace.install(None)
        print(f"[serve] arch={cfg.name} trace: "
              f"{len(obj['traceEvents'])} events -> {trace_out}")
        for line in format_report(obj["reproIdle"]).splitlines():
            print(f"[serve] idle: {line}")
        s["trace"] = {"path": trace_out,
                      "n_events": len(obj["traceEvents"])}
    s["ok"] = not engine.rejected and not unfinished and not leaked \
        and s["n_requests"] == len(trace) - len(shed)
    if not s["ok"]:
        print(f"[serve] FAIL arch={cfg.name}: rejected={engine.rejected} "
              f"unfinished={unfinished} leaked={leaked} "
              f"finished={s['n_requests']}"
              f"/{len(trace) - len(shed)}", file=sys.stderr)
    return s


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="default: llama3.2-3b; with --smoke and no --arch, "
                         "runs the MoE + dense smoke pair")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent KV slots (decode batch)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--rate", type=float, default=0.4,
                    help="Poisson arrival rate (requests per engine tick)")
    ap.add_argument("--prompt-len", type=int, default=48,
                    help="max prompt length (trace mixes lengths below it)")
    ap.add_argument("--gen", type=int, default=24,
                    help="max new tokens (trace mixes budgets below it)")
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="prefill tokens per tick (default: one chunk)")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are generated")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache (block allocator + page-table "
                         "decode, DESIGN.md §9)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="cache lines per page (paged mode)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="physical pool size in pages (default: full "
                         "reservation capacity; smaller values overcommit "
                         "and exercise preemption)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="prefix-cached copy-on-write paged KV (DESIGN.md "
                         "§14): cached prompt prefixes mount as shared "
                         "pages and skip prefill; needs --paged or "
                         "--disagg")
    ap.add_argument("--prefix-capacity", type=int, default=None,
                    metavar="PAGES",
                    help="LRU bound on pages the prefix index may pin "
                         "(default: unbounded — allocator pressure is "
                         "the only bound)")
    ap.add_argument("--fair", action="store_true",
                    help="per-tenant deficit round-robin admission "
                         "(DESIGN.md §14): a flooding tenant cannot "
                         "starve the rest")
    ap.add_argument("--tenants", type=int, default=0,
                    help="build a shared-prefix multi-tenant trace with "
                         "this many tenants (0: classic mixed-length "
                         "Poisson trace)")
    ap.add_argument("--shared-prefix-len", type=int, default=None,
                    help="tenant shared-prefix length in tokens "
                         "(default: half of --prompt-len)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated prefill/decode deployment "
                         "(DESIGN.md §10): role-split workers over "
                         "separate paged pools, KV handed off as pages; "
                         "--pool-pages sizes the decode pool")
    ap.add_argument("--prefill-pool-pages", type=int, default=None,
                    help="prefill-side pool size in pages (disagg mode; "
                         "default: two max-length sequences)")
    ap.add_argument("--fleet", action="store_true",
                    help="elastic multi-group fleet (DESIGN.md §12): "
                         "N prefill + M decode groups of mixed device "
                         "classes behind a router, heartbeat failure "
                         "recovery; see --prefill-groups/--decode-groups")
    ap.add_argument("--prefill-groups", default="a40",
                    help="fleet prefill groups: an integer count or a "
                         "comma-separated device-class list, e.g. "
                         "'a40,a40' or '2' (default one a40 group)")
    ap.add_argument("--decode-groups", default="v100",
                    help="fleet decode groups: an integer count or a "
                         "comma-separated device-class list, e.g. "
                         "'v100,v100' (default one v100 group)")
    ap.add_argument("--fleet-elastic", action="store_true",
                    help="enable elastic role reassignment: idle groups "
                         "flip prefill<->decode when the bottleneck "
                         "role shifts or a role dies out")
    ap.add_argument("--kill-group", action="append", metavar="GID@TICK",
                    help="fault injection (repeatable): crash fleet group "
                         "GID at the start of tick TICK — sugar for a "
                         "crash_start@TICK:gGID entry of the ft.chaos "
                         "grammar (the full entry form is also accepted)")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="seeded fault schedule (fleet mode, DESIGN.md "
                         "§13): ';'-joined ft.chaos entries "
                         "SITE[@TICK][:TARGET][%%PROB][*COUNT][~DURATION] "
                         "— e.g. 'drop%%0.6*4;hb_loss@6:g3~8'; malformed "
                         "specs exit non-zero")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the chaos injector: the same "
                         "(seed, spec) replays the identical fault log")
    ap.add_argument("--slo-ttft", type=float, default=None,
                    help="SLO-aware admission (fleet mode): shed arrivals "
                         "whose best prefill ETA exceeds this many "
                         "seconds of estimated work")
    ap.add_argument("--ep-size", type=int, default=0,
                    help="shard MoE expert weights across this many "
                         "devices of the mesh 'model' axis for decode "
                         "(DESIGN.md §11); must divide the expert count "
                         "and needs a MoE --arch — rejected otherwise, "
                         "never truncated; 0 = off")
    ap.add_argument("--ep-placement", choices=("uniform", "planned"),
                    default="uniform",
                    help="uniform: static round-robin expert placement; "
                         "planned: online heterogeneity-aware re-placement "
                         "from the observed routing EMA")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto/Chrome trace-event JSON of the "
                         "run (tick-clock spans, request flows, counters, "
                         "idle-time attribution — DESIGN.md §15); tracing "
                         "is fully off without this flag")
    ap.add_argument("--trace-wall", action="store_true",
                    help="annotate trace spans with wall-clock readings "
                         "(opt-in; excluded from the deterministic trace "
                         "signature)")
    args = ap.parse_args(argv)

    try:
        # Parse + arch-independent validation: EVERY violation in one
        # message, one non-zero exit, before any device work.
        serve_cfg = ServeConfig.from_args(args)
        serve_cfg.validate()
    except ServeConfigError as e:
        print(f"[serve] invalid configuration: {e}", file=sys.stderr)
        return 1
    archs = [args.arch] if args.arch else \
        (list(SMOKE_ARCHS) if args.smoke else ["llama3.2-3b"])
    failed = []
    trace_out = args.trace_out
    for arch in archs:
        if trace_out and len(archs) > 1:
            # One artifact per arch (the smoke pair would overwrite).
            stem, dot, ext = trace_out.rpartition(".")
            args.trace_out = f"{stem}.{arch}.{ext}" if dot \
                else f"{trace_out}.{arch}"
        s = serve_arch(arch, args, serve_cfg)
        if not s.get("ok", True):
            failed.append(arch)
    if failed:
        print(f"[serve] FAILED archs: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
