"""Batched serving driver: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
        --smoke --batch 4 --prompt-len 64 --gen 32 --mesh 1x2
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.models.config import ShapeConfig
from repro.models.modules import Policy, RunConfig
from repro.pytree import split_params
from repro.serve.engine import BatchedServer, make_serve_program


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args(argv)

    cfg = registry.get_config(args.arch)
    if args.smoke:
        cfg = registry.smoke_config(cfg)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    run = RunConfig(policy=Policy(), attn_impl="ref", moe_impl="gather")
    max_len = args.prompt_len + args.gen
    shape = ShapeConfig("cli", "decode", max_len, args.batch)
    program = make_serve_program(cfg, mesh, run, shape, max_len=max_len)

    key = jax.random.PRNGKey(0)
    from repro.models import stack
    with mesh:
        params = jax.jit(
            lambda: split_params(stack.init_model(key, cfg))[0],
            out_shardings=program.param_shardings)()
    server = BatchedServer(program, params, args.batch, max_len)

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    fronts = {}
    if cfg.is_encdec:
        fronts["encoder_embeds"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model),
            run.policy.compute_dtype)
    if cfg.vision_seq > 0:
        fronts["vision_embeds"] = jnp.zeros(
            (args.batch, cfg.vision_seq, cfg.vision_dim or cfg.d_model),
            run.policy.compute_dtype)

    t0 = time.time()
    server.submit_prefill(prompts, fronts)
    out = [server.tokens]
    for _ in range(args.gen - 1):
        out.append(server.step(fronts))
    toks = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"[serve] arch={cfg.name} generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(toks[:, :16])
    return 0


if __name__ == "__main__":
    sys.exit(main())
